//! Ablation B: segment→thread scheduling (paper §III-C's shuffle).
//!
//! The shuffle's real property is **layout independence**: per-segment
//! costs are skewed and *where* the expensive segments sit in storage
//! order is arbitrary (embedding first? MLP blocks grouped?). A naive
//! contiguous split (Fig. 3's strawman) is great on lucky layouts and
//! terrible on unlucky ones; shuffling gives the same bounded imbalance
//! regardless. We sample 12 random clustered layouts and compare the
//! worst case of each arm:
//!
//! * **chunked** — contiguous parameter-space split per thread;
//! * **interleaved** — round-robin in storage order;
//! * **shuffled (paper)** — shuffle + deal;
//! * **LPT bin-packing** — size-aware greedy lower bound.

use entrollm::bench::{fmt_secs, quick_mode, quick_or};
use entrollm::decode::{ParallelDecoder, Strategy};
use entrollm::metrics::Table;
use entrollm::quant::BitWidth;
use entrollm::rng::Rng;
use entrollm::store::{compress, ElmModel};
use entrollm::tensor::TensorF32;

const N_SEGMENTS: usize = 160;

/// Random layouts sampled per arm (3 in quick/smoke mode — enough to
/// exercise every strategy and assertion, not enough for statistics).
fn n_layouts() -> u64 {
    quick_or(3, 12)
}

/// Segment sizes with 20% expensive segments placed in random clusters.
fn clustered_sizes(seed: u64) -> Vec<usize> {
    let mut rng = Rng::new(seed);
    let mut sizes = vec![0usize; N_SEGMENTS];
    for s in sizes.iter_mut() {
        *s = 300 + rng.below(700);
    }
    // 4 clusters of 8 big segments at random starts.
    for _ in 0..4 {
        let start = rng.below(N_SEGMENTS - 8);
        for s in sizes.iter_mut().skip(start).take(8) {
            *s = 20_000 + rng.below(10_000);
        }
    }
    sizes
}

/// One real decodable model matching a clustered layout (for wallclock).
fn clustered_model(seed: u64) -> ElmModel {
    let mut rng = Rng::new(seed ^ 0xE1);
    let layers: Vec<(String, TensorF32)> = clustered_sizes(seed)
        .into_iter()
        .enumerate()
        .map(|(i, n)| {
            (
                format!("l{i}"),
                TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.05)).unwrap(),
            )
        })
        .collect();
    compress(&layers, BitWidth::U8).unwrap().0
}

fn main() {
    let mut table = Table::new(
        "Ablation B: scheduling imbalance over random clustered layouts",
        &["strategy", "threads", "mean imbalance", "worst imbalance", "wall (one layout)"],
    );

    let thread_counts: &[usize] = if quick_mode() { &[2] } else { &[2, 4, 8] };
    for &threads in thread_counts {
        let arms: [(&str, Strategy); 4] = [
            ("chunked (naive)", Strategy::Chunked),
            ("interleaved", Strategy::Contiguous),
            ("shuffled (paper)", Strategy::Shuffled { seed: 0x5EED }),
            ("LPT bin-packing", Strategy::LargestFirst),
        ];
        let mut worst = [0.0f64; 4];
        let mut mean = [0.0f64; 4];
        for layout in 0..n_layouts() {
            let sizes = clustered_sizes(0xAB + layout);
            for (i, (_, strat)) in arms.iter().enumerate() {
                // For the shuffle, vary the seed per layout too (the
                // engine draws a fresh shuffle per model load).
                let strat = if let Strategy::Shuffled { .. } = strat {
                    Strategy::Shuffled { seed: 0x5EED + layout }
                } else {
                    *strat
                };
                let imb = strat.imbalance_for_sizes(&sizes, threads);
                worst[i] = worst[i].max(imb);
                mean[i] += imb / n_layouts() as f64;
            }
        }
        // Real decode wallclock on one layout per arm.
        let model = clustered_model(0xAB);
        for (i, (name, strat)) in arms.iter().enumerate() {
            let (_, stats) = ParallelDecoder::new(threads)
                .with_strategy(*strat)
                .decode_model(&model)
                .unwrap();
            table.row(&[
                name.to_string(),
                threads.to_string(),
                format!("{:.3}", mean[i]),
                format!("{:.3}", worst[i]),
                fmt_secs(stats.wall.as_secs_f64()),
            ]);
        }

        // §III-C, statistically: shuffling's WORST layout beats the
        // naive chunked split's worst layout, and LPT lower-bounds all.
        let (chunk_worst, shuf_worst, lpt_worst) = (worst[0], worst[2], worst[3]);
        assert!(
            shuf_worst < chunk_worst,
            "T={threads}: shuffled worst {shuf_worst:.3} must beat chunked worst {chunk_worst:.3}"
        );
        assert!(lpt_worst <= shuf_worst + 1e-9, "LPT is the lower bound");
    }
    table.emit("ablation_decode");
    println!("ablation B OK: shuffling bounds imbalance independent of segment layout");
}
