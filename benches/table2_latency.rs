//! Table II: latency breakdown on the Jetson P3450 cost model with
//! measured decoder inputs, plus the §IV-D theoretical-vs-achieved
//! speedup accounting and a real thread-scaling sweep of the parallel
//! decoder.

use entrollm::bench::{fmt_secs, quick_or};
use entrollm::decode::{ParallelDecoder, Strategy};
use entrollm::device::{table2_workloads, LatencyModel, JETSON_P3450};
use entrollm::metrics::Table;
use entrollm::pipeline::build_elm;
use entrollm::quant::BitWidth;

/// phi3-mini-shaped segment byte sizes at a given effective bit width:
/// 32 decoder layers (fused qkv, o, gate_up, down) + embedding. Used to
/// evaluate the §III-C scheduler over the *real* tensor structure of
/// the paper's subject model without materializing 3.8 B weights.
fn phi3_segment_bytes(eff_bits: f64) -> Vec<usize> {
    let d = 3072usize;
    let mut sizes = vec![32_064 * d]; // embedding
    for _ in 0..32 {
        sizes.push(d * 9216); // fused qkv
        sizes.push(d * d); // o_proj
        sizes.push(d * 16_384); // gate_up
        sizes.push(8192 * d); // down
    }
    sizes
        .into_iter()
        .map(|n| (n as f64 * eff_bits / 8.0) as usize)
        .collect()
}

const PHI3_PARAMS: usize = 3_800_000_000;
const PREFILL_TOKENS: usize = 512;

fn main() {
    let have = std::path::Path::new("artifacts/weights.bin").exists();
    let model = LatencyModel::new(JETSON_P3450);

    let mut table = Table::new(
        "Table II: phi3-scale latency on Jetson P3450 (modeled from measured inputs)",
        &["task", "encoding", "w/o huffman", "w/ huffman", "delta"],
    );
    for bits in [BitWidth::U8, BitWidth::U4] {
        // Workload characterization: phi3's effective bits are the
        // paper's measurement of its weight distribution (our trained
        // tiny-LM's distribution is wider — its own bits appear in
        // table1_storage). Scheduling imbalance is OUR shuffled deal
        // evaluated over phi3's real tensor-segment structure.
        let eff = if bits == BitWidth::U8 { 5.58 } else { 1.39 };
        let imb = Strategy::Shuffled { seed: 0x5EED }
            .imbalance_for_sizes(&phi3_segment_bytes(eff), 4);
        let (wo, wi) =
            table2_workloads(PHI3_PARAMS, bits.bits(), eff, PREFILL_TOKENS, 4, imb);
        let bw = model.breakdown(&wo);
        let bh = model.breakdown(&wi);
        let enc = bits.to_string();
        table.row(&[
            "pre-fill".into(),
            enc.clone(),
            fmt_secs(bw.prefill.total),
            fmt_secs(bh.prefill.total),
            format!("{:+.1}%", 100.0 * (1.0 - bh.prefill.total / bw.prefill.total)),
        ]);
        table.row(&[
            "token generation".into(),
            enc.clone(),
            fmt_secs(bw.token_gen.total),
            fmt_secs(bh.token_gen.total),
            format!("{:.2}x", bw.token_gen.total / bh.token_gen.total),
        ]);
        table.row(&[
            "parallel decoding".into(),
            enc.clone(),
            "-".into(),
            fmt_secs(bh.parallel_decode),
            "once/seq".into(),
        ]);
        table.row(&[
            "first token latency".into(),
            enc.clone(),
            fmt_secs(bw.first_token),
            fmt_secs(bh.first_token),
            format!("{:+.1}%", 100.0 * (bh.first_token / bw.first_token - 1.0)),
        ]);

        // Shape assertions against the paper.
        let speedup = bw.token_gen.total / bh.token_gen.total;
        let theory = bits.bits() as f64 / eff;
        assert!(speedup > 1.0 && speedup < theory, "achieved must trail theory");
        if bits == BitWidth::U8 {
            assert!(speedup > 1.15 && speedup < 1.45, "uint8 speedup {speedup}");
        } else {
            assert!(speedup > 1.8, "uint4 speedup {speedup}");
        }
        assert!(
            bh.first_token > bw.first_token,
            "first token slightly worse with upfront decode (paper: 27.18→29.89s)"
        );
    }
    table.emit("table2_latency");

    // Real decoder thread-scaling (work accounting; single-core hosts
    // show the work split even when wallclock can't parallelize).
    if have {
        let mut scale = Table::new(
            "Parallel decode scaling (real decoder, trained uint8 model)",
            &["threads", "wall", "Msym/s", "symbol imbalance", "max thread share"],
        );
        let (m, _) = build_elm("artifacts", BitWidth::U8).unwrap();
        for threads in quick_or(vec![1usize, 2], vec![1, 2, 4, 8]) {
            let (_, stats) = ParallelDecoder::new(threads)
                .with_strategy(Strategy::Shuffled { seed: 0x5EED })
                .decode_model(&m)
                .unwrap();
            let max_share = stats
                .threads
                .iter()
                .map(|t| t.symbols)
                .max()
                .unwrap_or(0) as f64
                / stats.total_symbols() as f64;
            scale.row(&[
                threads.to_string(),
                fmt_secs(stats.wall.as_secs_f64()),
                format!("{:.1}", stats.symbols_per_sec() / 1e6),
                format!("{:.3}", stats.symbol_imbalance()),
                format!("{:.2}", max_share),
            ]);
        }
        scale.emit("table2_decode_scaling");
    }
    println!("paper reference: uint8 token-gen 1.32x, uint4 2.47x; decode 6.66s / 1.66s");
}
