//! Figure 4: quantized-weight distributions for 8-bit (smooth Gaussian)
//! and 4-bit (spiky, central-bucket-dominated) models.
//!
//! Emits per-level CSV (`bench_results/fig4_*.csv`) and ASCII plots, on
//! the trained tiny-LM when artifacts exist plus a synthetic family, and
//! asserts the paper's "bucketing effect": the 4-bit histogram has
//! higher mode mass and lower entropy than the 8-bit one.

use entrollm::bench::quick_or;
use entrollm::entropy::{distribution_stats, Histogram};
use entrollm::huffman::FreqTable;
use entrollm::pipeline::build_elm;
use entrollm::quant::{quantize_mixed, BitWidth};
use entrollm::rng::Rng;
use entrollm::store::decode_layer;
use entrollm::tensor::TensorF32;

fn pooled_freq_from_artifacts(bits: BitWidth) -> Option<FreqTable> {
    if !std::path::Path::new("artifacts/weights.bin").exists() {
        return None;
    }
    let (model, _) = build_elm("artifacts", bits).unwrap();
    let mut freq = FreqTable::new();
    for i in 0..model.layers.len() {
        freq.add_symbols(decode_layer(&model, i).unwrap().symbols.data());
    }
    Some(freq)
}

fn synthetic_freq(bits: BitWidth) -> FreqTable {
    let n = quick_or(50_000, 400_000);
    let mut rng = Rng::new(0xF164);
    let w = TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.04)).unwrap();
    FreqTable::from_symbols(quantize_mixed(&w, bits).symbols.data())
}

fn emit(name: &str, bits: BitWidth, freq: &FreqTable) -> entrollm::entropy::DistributionStats {
    let levels = bits.levels();
    let hist = Histogram::from_freq(freq, levels);
    let stats = distribution_stats(freq).unwrap();
    println!(
        "=== Fig4 {name} ({bits}): entropy {:.3}b, eff {:.3}b, mode mass {:.3}, support {} ===",
        stats.entropy, stats.effective_bits, stats.mode_mass, stats.support
    );
    println!("{}", hist.to_ascii(56, 16));
    let slug = format!("fig4_{name}_{bits}");
    let dir = std::path::Path::new("bench_results");
    std::fs::create_dir_all(dir).ok();
    std::fs::write(dir.join(format!("{slug}.csv")), hist.to_csv()).ok();
    stats
}

fn main() {
    for (name, source) in [("synthetic", false), ("trained", true)] {
        let s8;
        let s4;
        if source {
            let Some(f8) = pooled_freq_from_artifacts(BitWidth::U8) else {
                eprintln!("(artifacts missing — trained panel skipped)");
                continue;
            };
            let f4 = pooled_freq_from_artifacts(BitWidth::U4).unwrap();
            s8 = emit(name, BitWidth::U8, &f8);
            s4 = emit(name, BitWidth::U4, &f4);
        } else {
            s8 = emit(name, BitWidth::U8, &synthetic_freq(BitWidth::U8));
            s4 = emit(name, BitWidth::U4, &synthetic_freq(BitWidth::U4));
        }
        // Paper §IV-A: moving 8→4 bits buckets mass centrally.
        assert!(s4.mode_mass > s8.mode_mass, "{name}: bucketing effect");
        assert!(s4.entropy < s8.entropy, "{name}: entropy must drop");
        assert!(s8.support > s4.support, "{name}: support shrinks");
    }
    println!("fig4 OK: 4-bit histograms are spikier & lower-entropy than 8-bit (paper Fig. 4)");
}
