//! Table I (storage rows): effective bits for uint8/uint4 after mixed
//! quantization + model-global Huffman coding.
//!
//! Three synthetic "model families" stand in for smolLM/phi3/mistral
//! (scaled-down layer counts, same Gaussian weight statistics — see
//! DESIGN.md §Substitutions #1), plus the *real trained* tiny-LM when
//! artifacts exist. Paper reference bands: uint8 → 5.58–5.92 effective
//! bits; uint4 → 1.39–1.62.

use entrollm::baselines::{fixed_pack, gzip_bytes};
use entrollm::bench::{fmt_bytes, quick_mode};
use entrollm::metrics::Table;
use entrollm::pipeline::build_elm;
use entrollm::quant::{quantize_mixed, BitWidth};
use entrollm::rng::Rng;
use entrollm::store::{compress, compress_with_options, CodecChoice};
use entrollm::tensor::TensorF32;

/// A scaled-down stand-in for one of the paper's model families.
///
/// The decisive statistic for effective bits is the **outlier-to-σ
/// ratio**: per-tensor max-abs quantization maps `[−max, max]` onto the
/// grid, so a Gaussian bulk with `max ≈ k·σ` occupies `≈ levels/(2k)`
/// grid steps and pools to entropy `≈ log2(levels·σ/(2·max)·√(2πe))`.
/// Trained LLM weights have heavy tails with `k ≈ 8–15` (the very
/// phenomenon AWQ/SpQR target), which is what puts the paper's models
/// at 5.58–5.92 effective bits (uint8) and 1.39–1.62 (uint4).
struct Family {
    name: &'static str,
    dim: usize,
    layers: usize,
    /// Weight std in float units.
    std: f32,
    /// Outlier magnitude in σ units (`k` above).
    outlier_sigma: f32,
}

const FAMILIES: &[Family] = &[
    Family { name: "smolLM-like (1.7B @ 1/2048)", dim: 96, layers: 6, std: 0.050, outlier_sigma: 9.0 },
    Family { name: "phi3-like (3.8B @ 1/2048)", dim: 128, layers: 8, std: 0.035, outlier_sigma: 12.0 },
    Family { name: "mistral-like (7B @ 1/2048)", dim: 160, layers: 10, std: 0.045, outlier_sigma: 10.0 },
];

fn synth_layers(f: &Family, seed: u64) -> Vec<(String, TensorF32)> {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    for i in 0..f.layers {
        for (kind, rows, cols) in [
            ("wq", f.dim, f.dim),
            ("wk", f.dim, f.dim),
            ("wv", f.dim, f.dim),
            ("wo", f.dim, f.dim),
            ("w_in", f.dim, 4 * f.dim),
            ("w_out", 4 * f.dim, f.dim),
        ] {
            let n = rows * cols;
            // Per-layer mean jitter keeps some layers single-signed.
            let mean = if (i + kind.len()) % 5 == 0 { 2.5 * f.std } else { 0.0 };
            let mut data = rng.gaussian_vec(n, mean, f.std);
            // Heavy tail: ~0.1% of entries are ±k·σ outliers (see the
            // Family docs — this is what sets the paper's bit bands).
            let n_outliers = (n / 1000).max(2);
            for _ in 0..n_outliers {
                let idx = rng.below(n);
                let sign = if rng.below(2) == 0 { 1.0 } else { -1.0 };
                data[idx] = mean + sign * f.outlier_sigma * f.std;
            }
            layers.push((
                format!("blocks.{i}.{kind}"),
                TensorF32::new(vec![rows, cols], data).unwrap(),
            ));
        }
    }
    layers
}

fn main() {
    let mut table = Table::new(
        "Table I (storage): effective bits per weight",
        &[
            "model", "params", "fp16", "uint8 fixed", "uint8 eff.bits", "uint4 fixed",
            "uint4 eff.bits", "u8 saving", "u4 saving",
        ],
    );

    let mut add_row = |name: &str, layers: &[(String, TensorF32)]| {
        let (_, r8) = compress(layers, BitWidth::U8).unwrap();
        let (_, r4) = compress(layers, BitWidth::U4).unwrap();
        table.row(&[
            name.to_string(),
            format!("{}", r8.n_params),
            fmt_bytes(r8.fp16_bytes),
            fmt_bytes(r8.fixed_bytes),
            format!("{:.2}", r8.effective_bits),
            fmt_bytes(r4.fixed_bytes),
            format!("{:.2}", r4.effective_bits),
            format!("{:.0}%", 100.0 * (1.0 - r8.effective_bits / 8.0)),
            format!("{:.0}%", 100.0 * (1.0 - r4.effective_bits / 4.0)),
        ]);
        // Paper-shape assertions: entropy coding must save, and save
        // relatively more at 4-bit.
        assert!(r8.effective_bits < 8.0 && r4.effective_bits < 4.0);
        assert!(
            (1.0 - r4.effective_bits / 4.0) > (1.0 - r8.effective_bits / 8.0),
            "uint4 must save relatively more (paper: 65% vs 30%)"
        );
    };

    // Quick/smoke mode runs one family — the assertions are per-row,
    // so one family still exercises the whole path.
    let families = if quick_mode() { &FAMILIES[..1] } else { FAMILIES };
    for f in families {
        let layers = synth_layers(f, 0x7AB1E1);
        add_row(f.name, &layers);
    }

    // The real trained model, when artifacts exist.
    if std::path::Path::new("artifacts/weights.bin").exists() {
        let (_, r8) = build_elm("artifacts", BitWidth::U8).unwrap();
        let (_, r4) = build_elm("artifacts", BitWidth::U4).unwrap();
        table.row(&[
            "tiny-LM (trained, 0.8M)".into(),
            format!("{}", r8.n_params),
            fmt_bytes(r8.fp16_bytes),
            fmt_bytes(r8.fixed_bytes),
            format!("{:.2}", r8.effective_bits),
            fmt_bytes(r4.fixed_bytes),
            format!("{:.2}", r4.effective_bits),
            format!("{:.0}%", 100.0 * (1.0 - r8.effective_bits / 8.0)),
            format!("{:.0}%", 100.0 * (1.0 - r4.effective_bits / 4.0)),
        ]);
    } else {
        eprintln!("(artifacts missing — trained-model row skipped; run `make artifacts`)");
    }

    table.emit("table1_storage");
    println!("paper reference: uint8 effective bits 5.58-5.92 | uint4 1.39-1.62");

    // Three-way codec comparison on the same fig4-skewed families:
    // Huffman vs the tANS arm vs a generic order-0 entropy coder
    // (gzip stand-in — the offline build has no DEFLATE). tANS charges
    // fractional bits per symbol, so on these skewed post-quantization
    // distributions its payload must be no larger than Huffman's — the
    // premise of the v3 codec-negotiated container (docs/FORMAT.md §v3).
    let mut codecs = Table::new(
        "Table I (codecs): Huffman vs tANS vs generic order-0 entropy",
        &[
            "model", "bits", "huffman", "tans", "generic (sub-gzip)", "tans/huffman",
        ],
    );
    for f in families {
        let layers = synth_layers(f, 0x7AB1E1);
        for bits in [BitWidth::U8, BitWidth::U4] {
            let (_, rh) =
                compress_with_options(&layers, bits, None, CodecChoice::Huffman).unwrap();
            let (_, ra) = compress_with_options(&layers, bits, None, CodecChoice::Ans).unwrap();
            let mut syms = Vec::new();
            for (_, t) in &layers {
                syms.extend_from_slice(quantize_mixed(t, bits).symbols.data());
            }
            let gz = gzip_bytes(&fixed_pack(&syms, bits).unwrap()).unwrap();
            codecs.row(&[
                f.name.to_string(),
                bits.to_string(),
                fmt_bytes(rh.encoded_bytes),
                fmt_bytes(ra.encoded_bytes),
                fmt_bytes(gz.len()),
                format!("{:.4}", ra.encoded_bytes as f64 / rh.encoded_bytes as f64),
            ]);
            assert!(
                ra.encoded_bytes <= rh.encoded_bytes,
                "{} {bits}: tANS payload {} must not exceed Huffman's {}",
                f.name,
                ra.encoded_bytes,
                rh.encoded_bytes
            );
        }
    }
    codecs.emit("table1_codecs");
    println!("codec arm OK: tANS payload <= Huffman on every skewed family");
}
