//! Table I (quality rows): perplexity + cloze accuracy for fp32 / uint8
//! / uint4, measured through the **rust PJRT runtime** on the trained
//! tiny-LM (requires `make artifacts`).
//!
//! Substitutions (DESIGN.md): WikiText2 → held-out synthetic-corpus
//! char perplexity; HellaSwag → a 4-way cloze task (pick the true
//! continuation of a context by total NLL). The paper's claim is
//! *relative*: uint8 ≈ fp16, uint4 degrades modestly; that ordering is
//! asserted here.

use entrollm::bench::quick_or;
use entrollm::metrics::Table;
use entrollm::pipeline::{eval_ppl, load_backend, Flavor};

const CHOICES: usize = 4;

/// Held-out eval windows (fewer in quick/smoke mode).
fn windows() -> usize {
    quick_or(4, 16)
}

/// Cloze cases (fewer in quick/smoke mode).
fn cloze_cases() -> usize {
    quick_or(6, 24)
}

/// 4-way cloze accuracy through the score executable: context = first
/// S-16 chars of a window, candidates = true 16-char continuation + 3
/// continuations stolen from other windows.
fn cloze_accuracy(dir: &str, flavor: Flavor) -> f64 {
    let (backend, _) = load_backend(dir, flavor, 2).unwrap();
    let rt = backend.runtime();
    let s = rt.config().prefill_len;
    let vocab = rt.config().vocab;
    let tail = 16usize;
    let text = std::fs::read_to_string(format!("{dir}/eval.txt")).unwrap();
    let toks: Vec<u32> = text
        .bytes()
        .map(|b| if b < 128 { b as u32 } else { b'?' as u32 })
        .collect();
    let n_windows = (toks.len() / s).min(cloze_cases() + CHOICES);
    assert!(n_windows > CHOICES, "eval text too short");
    let window = |i: usize| &toks[i * s..(i + 1) * s];

    let mut correct = 0usize;
    let cases = n_windows.min(cloze_cases());
    for i in 0..cases {
        let ctx = &window(i)[..s - tail];
        let mut best = (f64::INFINITY, usize::MAX);
        for c in 0..CHOICES {
            // Candidate 0 is the true continuation; others come from
            // different windows (deterministic offsets).
            let src = if c == 0 { i } else { (i + c * 3 + 1) % n_windows };
            let cand = &window(src)[s - tail..];
            let mut seq = ctx.to_vec();
            seq.extend_from_slice(cand);
            let logits = rt.score(&seq).unwrap();
            // NLL of the candidate span only.
            let mut nll = 0.0f64;
            for p in (s - tail - 1)..(s - 1) {
                let row = &logits[p * vocab..(p + 1) * vocab];
                let t = seq[p + 1] as usize;
                let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let lse: f32 = row.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
                nll += (lse - row[t]) as f64;
            }
            if nll < best.0 {
                best = (nll, c);
            }
        }
        if best.1 == 0 {
            correct += 1;
        }
    }
    correct as f64 / cases as f64
}

fn main() {
    let dir = "artifacts";
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("table1_quality requires `make artifacts` — skipping");
        return;
    }
    let mut table = Table::new(
        "Table I (quality): perplexity & cloze accuracy (rust PJRT runtime)",
        &["variant", "eval nll (nats/char)", "char ppl", "cloze acc (4-way)"],
    );
    let mut ppls = Vec::new();
    for (flavor, name) in [
        (Flavor::F32, "fp32"),
        (Flavor::U8, "uint8"),
        (Flavor::U4, "uint4"),
    ] {
        let (nll, ppl) = eval_ppl(dir, flavor, 4, windows()).unwrap();
        let acc = cloze_accuracy(dir, flavor);
        table.row(&[
            name.into(),
            format!("{nll:.4}"),
            format!("{ppl:.3}"),
            format!("{:.1}%", acc * 100.0),
        ]);
        ppls.push((name, ppl, acc));
    }
    table.emit("table1_quality");

    // Paper-shape assertions.
    let (p32, p8, p4) = (ppls[0].1, ppls[1].1, ppls[2].1);
    assert!(p8 <= p32 * 1.02, "uint8 ppl must track fp32 (got {p8} vs {p32})");
    assert!(p4 > p8, "uint4 must degrade vs uint8");
    let chance = 1.0 / CHOICES as f64;
    assert!(ppls[0].2 > chance, "fp32 cloze must beat chance");
    println!(
        "paper shape: ppl(fp)≈ppl(u8)<ppl(u4) ✓  (phi3: 9.03 / 9.44 / 10.10; here {p32:.2} / {p8:.2} / {p4:.2})"
    );
}
