//! Multi-model serving: **2-model interleaved load behind one
//! coordinator** (shared byte ledger + shared decode pool) vs **two
//! isolated single-model servers** at the same total byte budget.
//!
//! Both arms serve the same request mix from file-backed containers
//! (payload on disk, decoded residency bounded), and both must emit
//! bit-identical token streams — the coordinator changes *where bytes
//! are resident*, never *what the models generate*. The second section
//! skews the load (one hot model, one cold) to show the ledger's
//! hot-steals-from-cold behavior, which a static half/half partition
//! cannot express.

use entrollm::bench::{fmt_bytes, quick_or};
use entrollm::coordinator::{
    Engine, EngineConfig, ModelSpec, MultiModelConfig, MultiModelServer, Request,
};
use entrollm::metrics::Table;
use entrollm::pipeline::synthetic_layers;
use entrollm::quant::BitWidth;
use entrollm::residency::{
    Policy, PrefetchConfig, PrefetchingDigestBackend, PrefetchingWeightSet,
};
use entrollm::store::{compress, SegmentSource};
use std::sync::Arc;
use std::time::Instant;

fn max_tokens() -> usize {
    quick_or(4, 12)
}

fn reqs_per_model() -> u64 {
    quick_or(2, 6)
}

fn requests(offset: u64) -> Vec<Request> {
    (0..reqs_per_model())
        .map(|i| {
            Request::greedy(
                offset + i,
                vec![1 + (offset + i) as u32 % 40, 7, 3 + i as u32],
                max_tokens(),
            )
        })
        .collect()
}

fn main() {
    let dir = std::env::temp_dir().join(format!("multi_model_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut paths = Vec::new();
    let mut per_floor = Vec::new();
    let mut total_decoded = 0usize;
    let sizes = quick_or(
        [("alpha", 10usize, 0xA11Au64), ("beta", 8, 0xBE7A)],
        [("alpha", 24, 0xA11A), ("beta", 16, 0xBE7A)],
    );
    for (name, n_layers, seed) in sizes {
        let (elm, _) = compress(&synthetic_layers(n_layers, seed), BitWidth::U8).unwrap();
        let largest = elm.layers.iter().map(|m| m.n_symbols).max().unwrap();
        per_floor.push(4 * largest); // decode-ahead 3 + active layer
        total_decoded += elm.n_params();
        let path = dir.join(format!("{name}.elm"));
        elm.save(&path).unwrap();
        paths.push((name.to_string(), path));
    }
    // Total budget: about half of both models decoded, never below the
    // summed decode-ahead floors; each isolated arm gets exactly half.
    let total_budget = (total_decoded / 2).max(2 * per_floor.iter().sum::<usize>());
    let per_budget = total_budget / 2;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let pool_workers = cores.saturating_sub(1).clamp(1, 4);
    let decode_ahead = 3usize;
    println!(
        "2 models | decoded {} total | shared budget {} ({} per isolated server) | \
         decode-ahead {decode_ahead} | {pool_workers} pool workers\n",
        fmt_bytes(total_decoded),
        fmt_bytes(total_budget),
        fmt_bytes(per_budget),
    );

    // ---- Arm 1: two isolated single-model engines, half the budget
    // each, private worker pools. Driven by the SAME single-threaded
    // interleaved step loop as the coordinator arm below, so the
    // wall-clock delta isolates the shared-ledger/shared-pool design —
    // not a difference in driver threading.
    let isolated_cfg = PrefetchConfig {
        decode_ahead,
        workers: (pool_workers / 2).max(1),
        policy: Policy::SegmentedLru,
    };
    let mut iso_engines: Vec<_> = paths
        .iter()
        .map(|(_, path)| {
            let source = Arc::new(SegmentSource::open(path).unwrap());
            let ws =
                PrefetchingWeightSet::new(source, per_budget, Vec::new(), isolated_cfg).unwrap();
            Engine::new(
                PrefetchingDigestBackend::new(ws, 2, 64, 256),
                EngineConfig::default(),
            )
        })
        .collect();
    let t0 = Instant::now();
    for (mi, engine) in iso_engines.iter_mut().enumerate() {
        for r in requests(100 * mi as u64) {
            engine.submit(r).unwrap();
        }
    }
    let mut iso_results = vec![Vec::new(), Vec::new()];
    let mut steps = 0usize;
    while iso_engines.iter().any(|e| e.has_work()) && steps < 1_000_000 {
        for (mi, engine) in iso_engines.iter_mut().enumerate() {
            let responses = engine.step().unwrap();
            iso_results[mi].extend(responses.into_iter().map(|r| (r.id, r.tokens)));
        }
        steps += 1;
    }
    let iso_wall = t0.elapsed().as_secs_f64();
    for m in &mut iso_results {
        m.sort();
    }
    let iso_tokens: usize = iso_results
        .iter()
        .flat_map(|m| m.iter().map(|(_, t)| t.len()))
        .sum();

    // ---- Arm 2: one coordinator, same total budget, shared ledger +
    // shared pool, interleaved submissions.
    let mut multi = MultiModelServer::new(
        paths
            .iter()
            .map(|(name, path)| {
                ModelSpec::new(name.clone(), Arc::new(SegmentSource::open(path).unwrap()))
            })
            .collect(),
        MultiModelConfig {
            budget_bytes: total_budget,
            decode_ahead,
            workers: pool_workers,
            ..MultiModelConfig::default()
        },
    )
    .unwrap();
    let t1 = Instant::now();
    for (ra, rb) in requests(0).into_iter().zip(requests(100)) {
        multi.engine_mut(0).submit(ra).unwrap();
        multi.engine_mut(1).submit(rb).unwrap();
    }
    let mut multi_results = vec![Vec::new(), Vec::new()];
    let mut steps = 0usize;
    while multi.has_work() && steps < 1_000_000 {
        for mi in 0..multi.n_models() {
            let responses = multi.engine_mut(mi).step().unwrap();
            multi_results[mi].extend(responses.into_iter().map(|r| (r.id, r.tokens)));
        }
        steps += 1;
    }
    let multi_wall = t1.elapsed().as_secs_f64();
    for m in &mut multi_results {
        m.sort();
    }
    let multi_tokens: usize = multi_results
        .iter()
        .flat_map(|m| m.iter().map(|(_, t)| t.len()))
        .sum();

    // Bit-identical acceptance: the coordinator must not change tokens.
    assert_eq!(
        iso_results, multi_results,
        "multi-model serving changed a token stream"
    );
    assert_eq!(iso_tokens, multi_tokens);
    let lc = multi.ledger().counters();
    assert!(lc.peak_used_bytes <= lc.budget_bytes, "budget violated: {lc:?}");

    let mut table = Table::new(
        "Interleaved 2-model load at the same total budget",
        &["arm", "wall s", "tok/s", "tokens"],
    );
    table.row(&[
        "2 isolated servers".into(),
        format!("{iso_wall:.3}"),
        format!("{:.1}", iso_tokens as f64 / iso_wall.max(1e-12)),
        iso_tokens.to_string(),
    ]);
    table.row(&[
        "multi-model coordinator".into(),
        format!("{multi_wall:.3}"),
        format!("{:.1}", multi_tokens as f64 / multi_wall.max(1e-12)),
        multi_tokens.to_string(),
    ]);
    table.emit("multi_model");

    // ---- Skewed load: alpha hot, beta cold. A static half/half split
    // would cap alpha at per_budget; the shared ledger lets it steal
    // beta's residency instead.
    let mut skewed = MultiModelServer::new(
        paths
            .iter()
            .map(|(name, path)| {
                ModelSpec::new(name.clone(), Arc::new(SegmentSource::open(path).unwrap()))
            })
            .collect(),
        MultiModelConfig {
            budget_bytes: total_budget,
            decode_ahead,
            workers: pool_workers,
            ..MultiModelConfig::default()
        },
    )
    .unwrap();
    // One request warms beta, then alpha hammers.
    skewed
        .engine_mut(1)
        .submit(Request::greedy(999, vec![9, 9], 4))
        .unwrap();
    let mut steps = 0usize;
    while skewed.engine(1).has_work() && steps < 100_000 {
        skewed.engine_mut(1).step().unwrap();
        steps += 1;
    }
    for r in requests(0) {
        skewed.engine_mut(0).submit(r).unwrap();
    }
    let mut steps = 0usize;
    while skewed.engine(0).has_work() && steps < 1_000_000 {
        skewed.engine_mut(0).step().unwrap();
        steps += 1;
    }
    let ledger = skewed.ledger();
    let (hot, cold) = (ledger.used_by(0), ledger.used_by(1));
    println!(
        "\nskewed load: hot model holds {} of the shared pool, cold model {} \
         (static 50/50 would cap the hot model at {})",
        fmt_bytes(hot),
        fmt_bytes(cold),
        fmt_bytes(per_budget),
    );
    assert!(
        hot >= cold,
        "hot model must hold at least as much residency as the cold one"
    );
    assert!(ledger.counters().used_bytes <= total_budget);

    std::fs::remove_dir_all(&dir).ok();
    println!("\nmulti_model bench OK");
}
