//! Hot-path microbenchmarks — the §Perf working set.
//!
//! Covers every stage a request touches: Huffman LUT decode (the edge
//! bring-up cost), encode, quantization, bit I/O, parallel decode
//! scaling, single-hot-layer tile scaling (the ELM v2 intra-layer
//! parallelism claim), and — when artifacts exist — the PJRT
//! prefill/decode steps
//! and a full engine round trip. Numbers land in bench_results/ and
//! EXPERIMENTS.md §Perf tracks before/after for each optimization.

use entrollm::bench::{fmt_secs, quick_mode, quick_or, Bench};
use entrollm::bitio::{BitReader, BitWriter};
use entrollm::coordinator::{Backend, Engine, EngineConfig, Request};
use entrollm::corpus::ByteTokenizer;
use entrollm::decode::ParallelDecoder;
use entrollm::huffman::{encode_with_own_code, Decoder, FreqTable};
use entrollm::metrics::Table;
use entrollm::pipeline::{build_elm, load_backend, Flavor};
use entrollm::quant::{quantize_mixed, BitWidth};
use entrollm::rng::Rng;
use entrollm::tensor::TensorF32;

fn main() {
    let bench = Bench::auto(Bench::new());
    let mut table = Table::new("Hot-path microbenchmarks", &["op", "rate", "unit"]);
    let n = quick_or(100_000usize, 1_000_000);
    let mut rng = Rng::new(0x407);
    let w = TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.04)).unwrap();

    // Quantization throughput.
    let stats = bench.run("quantize_mixed u8", || {
        std::hint::black_box(quantize_mixed(&w, BitWidth::U8));
    });
    table.row(&[
        "quantize_mixed u8".into(),
        format!("{:.1}", n as f64 / stats.median.as_secs_f64() / 1e6),
        "Mparam/s".into(),
    ]);

    let syms = quantize_mixed(&w, BitWidth::U8).symbols.into_data();
    let freq = FreqTable::from_symbols(&syms);
    let (spec, enc) = encode_with_own_code(&syms).unwrap();
    let _ = freq;

    // Huffman encode.
    let encoder = entrollm::huffman::Encoder::new(&spec);
    let stats = bench.run("huffman encode", || {
        std::hint::black_box(encoder.encode_to_vec(&syms).unwrap());
    });
    table.row(&[
        "huffman encode".into(),
        format!("{:.1}", n as f64 / stats.median.as_secs_f64() / 1e6),
        "Msym/s".into(),
    ]);

    // Huffman LUT decode — THE edge hot path.
    let dec = Decoder::new(&spec).unwrap();
    let mut out = vec![0u8; syms.len()];
    let stats = bench.run("huffman LUT decode", || {
        dec.decode_into(&enc, &mut out).unwrap();
    });
    let serial_rate = n as f64 / stats.median.as_secs_f64() / 1e6;
    table.row(&[
        "huffman LUT decode".into(),
        format!("{serial_rate:.1}"),
        "Msym/s".into(),
    ]);

    // Bit-serial oracle for comparison (how much the LUT buys).
    let slow = Bench::auto(Bench {
        measure_for: std::time::Duration::from_millis(400),
        ..Bench::new()
    });
    let stats = slow.run("huffman bit-serial decode", || {
        std::hint::black_box(dec.decode_bit_serial(&enc, syms.len()).unwrap());
    });
    table.row(&[
        "huffman bit-serial decode".into(),
        format!("{:.1}", n as f64 / stats.median.as_secs_f64() / 1e6),
        "Msym/s".into(),
    ]);

    // tANS codec arm on the same stream (measured, no hard floor:
    // throughput targets stay pinned to the Huffman LUT path).
    let (ans_table, ans_enc) = entrollm::ans::encode_with_own_table(&syms).unwrap();
    let ans_encoder = entrollm::ans::Encoder::new(&ans_table);
    let stats = bench.run("tans encode", || {
        std::hint::black_box(ans_encoder.encode_to_vec(&syms).unwrap());
    });
    table.row(&[
        "tans encode".into(),
        format!("{:.1}", n as f64 / stats.median.as_secs_f64() / 1e6),
        "Msym/s".into(),
    ]);
    let ans_dec = entrollm::ans::Decoder::new(&ans_table).unwrap();
    let stats = bench.run("tans table decode", || {
        ans_dec.decode_into(&ans_enc, &mut out).unwrap();
    });
    table.row(&[
        "tans table decode".into(),
        format!("{:.1}", n as f64 / stats.median.as_secs_f64() / 1e6),
        "Msym/s".into(),
    ]);

    // Raw BitReader consumption rate.
    let mut writer = BitWriter::new();
    for i in 0..n {
        writer.write_bits((i % 64) as u64, 6);
    }
    let bits = writer.into_bytes();
    let stats = bench.run("bitreader 6-bit fields", || {
        let mut r = BitReader::new(&bits);
        let mut acc = 0u32;
        for _ in 0..n {
            acc = acc.wrapping_add(r.read_bits(6).unwrap());
        }
        std::hint::black_box(acc);
    });
    table.row(&[
        "bitreader read_bits(6)".into(),
        format!("{:.1}", n as f64 / stats.median.as_secs_f64() / 1e6),
        "Mfield/s".into(),
    ]);

    // ELM v2 tile-granular decode: ONE hot layer split into
    // independently decodable tiles, attacked by a growing worker pool.
    // Under v1 (one segment per layer) a single hot layer pinned its
    // whole decode onto one thread no matter how many workers existed;
    // tiles are the unit of work now, so wall time must drop as the
    // pool grows.
    {
        let hot = quick_or(200_000usize, 1_000_000);
        let mut hrng = Rng::new(0x71E5);
        let hot_layer = vec![(
            "hot.w".to_string(),
            TensorF32::new(vec![hot], hrng.gaussian_vec(hot, 0.0, 0.04)).unwrap(),
        )];
        let (model, _) = entrollm::store::compress_with_tile_size(
            &hot_layer,
            BitWidth::U8,
            Some(hot.div_ceil(16)),
        )
        .unwrap();
        let n_tiles = model.layers[0].tiles.len();
        let mut walls: Vec<(usize, f64)> = Vec::new();
        for threads in [1usize, 2, 4] {
            let pd = ParallelDecoder::new(threads);
            // Best-of-3 to keep a one-shot wall measurement honest.
            let mut best = f64::INFINITY;
            let mut rate = 0.0;
            for _ in 0..3 {
                let (out, st) = pd.decode_model(&model).unwrap();
                std::hint::black_box(&out);
                let wall = st.wall.as_secs_f64();
                if wall < best {
                    best = wall;
                    rate = st.symbols_per_sec() / 1e6;
                }
            }
            walls.push((threads, best));
            table.row(&[
                format!("single hot layer decode (T={threads}, {n_tiles} tiles)"),
                format!("{rate:.1}"),
                "Msym/s".into(),
            ]);
        }
        let t1 = walls[0].1;
        let t4 = walls[2].1;
        println!(
            "single hot layer ({n_tiles} tiles): T=1 {} -> T=4 {} ({:.2}x)",
            fmt_secs(t1),
            fmt_secs(t4),
            t1 / t4.max(1e-12)
        );
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        if !quick_mode() && cores >= 4 {
            assert!(
                t4 < t1,
                "tile-granular decode must let extra workers share one hot layer \
                 (T=1 {t1:.4}s vs T=4 {t4:.4}s)"
            );
        }

        // Same hot layer through the tANS arm: tiles stay the parallel
        // unit of work regardless of which codec coded them (measured
        // only — the scaling assert stays pinned to the Huffman arm).
        let (ans_model, _) = entrollm::store::compress_with_options(
            &hot_layer,
            BitWidth::U8,
            Some(hot.div_ceil(16)),
            entrollm::store::CodecChoice::Ans,
        )
        .unwrap();
        for threads in [1usize, 4] {
            let pd = ParallelDecoder::new(threads);
            let mut rate = 0.0f64;
            for _ in 0..3 {
                let (out, st) = pd.decode_model(&ans_model).unwrap();
                std::hint::black_box(&out);
                rate = rate.max(st.symbols_per_sec() / 1e6);
            }
            table.row(&[
                format!("single hot layer tans decode (T={threads}, {n_tiles} tiles)"),
                format!("{rate:.1}"),
                "Msym/s".into(),
            ]);
        }
    }

    // Parallel decode on the trained model (whole-model wall time).
    if std::path::Path::new("artifacts/weights.bin").exists() {
        let (model, _) = build_elm("artifacts", BitWidth::U8).unwrap();
        for threads in [1usize, 4] {
            let pd = ParallelDecoder::new(threads);
            let (_, st) = pd.decode_model(&model).unwrap();
            table.row(&[
                format!("parallel decode trained model (T={threads})"),
                format!("{:.1}", st.symbols_per_sec() / 1e6),
                "Msym/s".into(),
            ]);
        }

        // PJRT phases on the real engine.
        let (backend, _) = load_backend("artifacts", Flavor::U8, 4).unwrap();
        let rt_prompt = ByteTokenizer.encode("the model runs on the edge");
        let (_, d) = bench.once("pjrt prefill (cold)", || {
            backend.runtime().prefill(&rt_prompt).unwrap()
        });
        table.row(&["pjrt prefill cold".into(), fmt_secs(d.as_secs_f64()), "per prompt".into()]);
        let slow = Bench::auto(Bench {
            measure_for: std::time::Duration::from_secs(2),
            warmup_for: std::time::Duration::from_millis(300),
            batches: 7,
        });
        let stats = slow.run("pjrt prefill (warm)", || {
            std::hint::black_box(backend.runtime().prefill(&rt_prompt).unwrap());
        });
        table.row(&[
            "pjrt prefill warm".into(),
            fmt_secs(stats.median.as_secs_f64()),
            "per prompt".into(),
        ]);

        // Engine: tokens/sec at full occupancy.
        let b = backend.cfg().batch;
        let mut engine = Engine::new(backend, EngineConfig::default());
        for i in 0..b as u64 {
            engine
                .submit(Request::greedy(i, ByteTokenizer.encode("the edge model"), 48))
                .unwrap();
        }
        let t0 = std::time::Instant::now();
        let rs = engine.run_to_completion(10_000).unwrap();
        let wall = t0.elapsed();
        let toks: usize = rs.iter().map(|r| r.tokens.len()).sum();
        table.row(&[
            format!("engine tokens/s (B={b} full occupancy)"),
            format!("{:.1}", toks as f64 / wall.as_secs_f64()),
            "tok/s".into(),
        ]);
        table.row(&[
            "engine decode step".into(),
            fmt_secs(
                engine.stats().decode_lat.mean().as_secs_f64(),
            ),
            "per step".into(),
        ]);
    } else {
        eprintln!("(artifacts missing — PJRT/engine rows skipped)");
    }

    table.emit("hotpath");
    assert!(serial_rate > 20.0, "LUT decoder below 20 Msym/s — regression");
}
