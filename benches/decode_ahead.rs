//! Tokens/sec: **decode-ahead prefetch** vs the PR 2 fault-on-demand
//! residency path, at the same byte budget.
//!
//! A synthetic model is compressed, written to disk, and opened lazily
//! ([`entrollm::store::SegmentSource::open`]), so both paths measure
//! the real deploy shape: payload on disk, decoded layers under the
//! budget. The fault-on-demand arm re-decodes cold layers *inline* in
//! the token step (pure LRU, which a cyclic dense pass defeats
//! entirely); the decode-ahead arm schedules layer `i+1`'s decode onto
//! a worker pool while layer `i` is consumed, under the scan-resistant
//! segmented-LRU policy, so the fault bill hides behind compute —
//! `max(compute, decode)` per token instead of their sum. A third arm
//! repeats decode-ahead over a *fine-tiled* ELM v2 container, where
//! prefetch jobs are claimed per tile so the whole pool can share one
//! upcoming layer. The modeled Jetson-scale counterpart of the same
//! comparison is
//! [`entrollm::device::LatencyModel::overlapped_tokens_per_sec`].

use entrollm::bench::{fmt_bytes, quick_mode, quick_or};
use entrollm::coordinator::{Backend, Engine, EngineConfig, Request};
use entrollm::device::{table2_workloads, LatencyModel, JETSON_P3450};
use entrollm::metrics::Table;
use entrollm::pipeline::synthetic_layers;
use entrollm::quant::BitWidth;
use entrollm::residency::{
    PrefetchConfig, PrefetchingDigestBackend, PrefetchingWeightSet, Policy,
    ResidentDigestBackend, ResidentWeightSet,
};
use entrollm::store::{compress, compress_with_tile_size, SegmentSource};
use std::sync::Arc;
use std::time::Instant;

/// One timed serving run: 8 requests × 16 tokens (3 × 6 in quick
/// mode) through a fresh engine. Returns (tokens/sec, tokens served,
/// the drained engine — its counters describe the run).
fn serve_batch<B: Backend>(backend: B) -> (f64, usize, Engine<B>) {
    let mut engine = Engine::new(backend, EngineConfig::default());
    for id in 0..quick_or(3u64, 8) {
        engine
            .submit(Request::greedy(id, vec![1 + id as u32, 2, 3], quick_or(6, 16)))
            .unwrap();
    }
    let t0 = Instant::now();
    let responses = engine.run_to_completion(10_000).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    (tokens as f64 / wall.max(1e-12), tokens, engine)
}

fn main() {
    let n_layers = quick_or(12usize, 24);
    let decode_ahead = 3usize;
    let layers = synthetic_layers(n_layers, 0xFA17);
    let (elm, report) = compress(&layers, BitWidth::U8).unwrap();
    let total: usize = elm.layers.iter().map(|m| m.n_symbols).sum();
    let largest: usize = elm.layers.iter().map(|m| m.n_symbols).max().unwrap();
    // Same byte budget for both arms: about half the model, but never
    // below the decode-ahead floor (window + active layer).
    let budget = (total / 2).max((decode_ahead + 1) * largest);

    let dir = std::env::temp_dir().join(format!("decode_ahead_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.elm");
    elm.save(&path).unwrap();

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let workers = cores.saturating_sub(1).clamp(1, 4);
    println!(
        "synthetic model: {n_layers} layers | decoded {} | budget {} | {:.3} effective bits \
         | {cores} cores -> {workers} prefetch workers\n",
        fmt_bytes(total),
        fmt_bytes(budget),
        report.effective_bits
    );

    let mut table = Table::new(
        "Tokens/sec at the same byte budget (measured, file-backed faults)",
        &["path", "tok/s", "cache hits", "cache misses", "prefetch hits", "sync faults"],
    );

    // Arm 1: PR 2 fault-on-demand (pure LRU, inline re-decode).
    let source = Arc::new(SegmentSource::open(&path).unwrap());
    let ws = ResidentWeightSet::new(source, budget, Vec::new()).unwrap();
    let (fault_tps, fault_tokens, fault_engine) =
        serve_batch(ResidentDigestBackend::new(ws, 2, 64, 256));
    let fc = fault_engine.residency().unwrap();
    assert!(fc.peak_resident_bytes <= budget);
    table.row(&[
        "fault-on-demand (LRU)".into(),
        format!("{fault_tps:.1}"),
        fc.hits.to_string(),
        fc.misses.to_string(),
        "-".into(),
        "-".into(),
    ]);

    // Arm 2: decode-ahead prefetch (segmented LRU + pin-next + pool).
    let source = Arc::new(SegmentSource::open(&path).unwrap());
    let ws = PrefetchingWeightSet::new(
        source,
        budget,
        Vec::new(),
        PrefetchConfig {
            decode_ahead,
            workers,
            policy: Policy::SegmentedLru,
        },
    )
    .unwrap();
    let (ahead_tps, ahead_tokens, ahead_engine) =
        serve_batch(PrefetchingDigestBackend::new(ws, 2, 64, 256));
    let ac = ahead_engine.residency().unwrap();
    let ap = ahead_engine.prefetch().unwrap();
    assert!(
        ac.peak_resident_bytes <= budget,
        "budget violated: {} > {budget}",
        ac.peak_resident_bytes
    );
    assert_eq!(
        fault_tokens, ahead_tokens,
        "both arms must serve the same batch"
    );
    table.row(&[
        format!("decode-ahead ({decode_ahead} ahead, {workers} workers)"),
        format!("{ahead_tps:.1}"),
        ac.hits.to_string(),
        ac.misses.to_string(),
        ap.hits.to_string(),
        ap.sync_faults.to_string(),
    ]);

    // Arm 3: decode-ahead over a *fine-tiled* ELM v2 container
    // (512-symbol tiles, the `--tile-kb` shape). Prefetch jobs are
    // per-tile, so every worker can attack the same upcoming layer
    // instead of one worker owning it end to end.
    let tiled_path = dir.join("model_tiled.elm");
    let (tiled_elm, _) = compress_with_tile_size(&layers, BitWidth::U8, Some(512)).unwrap();
    let n_tiles: usize = tiled_elm.layers.iter().map(|m| m.tiles.len()).sum();
    tiled_elm.save(&tiled_path).unwrap();
    let source = Arc::new(SegmentSource::open(&tiled_path).unwrap());
    let ws = PrefetchingWeightSet::new(
        source,
        budget,
        Vec::new(),
        PrefetchConfig {
            decode_ahead,
            workers,
            policy: Policy::SegmentedLru,
        },
    )
    .unwrap();
    let (tiled_tps, tiled_tokens, tiled_engine) =
        serve_batch(PrefetchingDigestBackend::new(ws, 2, 64, 256));
    let tc = tiled_engine.residency().unwrap();
    let tp = tiled_engine.prefetch().unwrap();
    assert!(tc.peak_resident_bytes <= budget);
    assert_eq!(
        fault_tokens, tiled_tokens,
        "tiled arm must serve the same batch"
    );
    table.row(&[
        format!("decode-ahead, fine tiles ({n_tiles} tiles / {n_layers} layers)"),
        format!("{tiled_tps:.1}"),
        tc.hits.to_string(),
        tc.misses.to_string(),
        tp.hits.to_string(),
        tp.sync_faults.to_string(),
    ]);
    table.emit("decode_ahead");

    let speedup = ahead_tps / fault_tps.max(1e-12);
    println!("\ndecode-ahead speedup over fault-on-demand: {speedup:.2}x (same {budget} B budget)");
    if quick_mode() {
        println!("note: quick mode — workload too small for the 1.2x gate; skipping");
    } else if cores >= 2 {
        assert!(
            speedup >= 1.2,
            "acceptance: decode-ahead must be >= 1.2x fault-on-demand, got {speedup:.2}x"
        );
    } else {
        println!("note: single-core host — overlap cannot help; skipping the 1.2x gate");
    }

    // The same comparison at edge scale, modeled: phi3-class on Jetson.
    let m = LatencyModel::new(JETSON_P3450);
    let (_, with) = table2_workloads(3_800_000_000, 8, 5.58, 512, 4, 1.0);
    let mut modeled = Table::new(
        "Modeled Jetson tokens/sec (phi3-class, uint8, 0 pinned)",
        &["path", "tok/s"],
    );
    modeled.row(&[
        "fault-on-demand (serial)".into(),
        format!("{:.3}", m.faulted_tokens_per_sec(&with, 32, 0)),
    ]);
    modeled.row(&[
        "decode-ahead (overlapped)".into(),
        format!("{:.3}", m.overlapped_tokens_per_sec(&with, 32, 0)),
    ]);
    modeled.emit("decode_ahead_modeled");
    println!(
        "modeled overlap speedup at 0 pinned: {:.2}x (capped at 2.0 when sides balance)",
        m.overlap_speedup(&with, 32, 0)
    );

    // Concurrent segment-read scaling: every worker used to serialize
    // on one shared `Mutex<File>` cursor; positioned reads give each
    // read its own offset, so aggregate CRC-verified read throughput
    // should grow with threads instead of flatlining.
    let source = Arc::new(SegmentSource::open(&path).unwrap());
    let encoded: usize = source.layers().iter().map(|m| m.encoded_len).sum();
    println!("\nconcurrent verified segment reads (encoded payload {}):", fmt_bytes(encoded));
    for threads in [1usize, 4] {
        let rounds = quick_or(2usize, 8);
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for t in 0..threads {
                let source = Arc::clone(&source);
                s.spawn(move || {
                    let n = source.n_layers();
                    for r in 0..rounds {
                        for i in 0..n {
                            source.verified_segment((i + t + r) % n).unwrap();
                        }
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let bytes = threads * rounds * encoded;
        println!(
            "  {threads} thread(s): {:.1} MB/s aggregate ({:.3}s)",
            bytes as f64 / wall.max(1e-12) / 1e6,
            wall
        );
    }

    std::fs::remove_dir_all(&dir).ok();
}
