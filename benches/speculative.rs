//! Speculative decoding across co-resident models: **draft proposes,
//! target verifies** (`--speculate draft=...,target=...,k=K`).
//!
//! Two sections:
//!
//! 1. **Coordinator arm** — a small draft and a large target behind one
//!    [`MultiModelServer`] with speculation on, versus the same server
//!    with speculation off. The target's token streams must be
//!    **bit-identical**: acceptance is greedy-equivalent, so
//!    speculation changes only how many target weight passes each token
//!    costs, never the tokens. (Two unrelated synthetic models agree on
//!    argmax about 1/vocab of the time, so this arm's acceptance is
//!    near zero — the honest worst case, still bit-exact.)
//!
//! 2. **Aligned-draft arm** — a bench-local draft that mirrors the
//!    target's greedy chain but mispredicts a deterministic fraction of
//!    proposal rows, giving a tunable acceptance rate ≥ 0.5 like a real
//!    distilled draft. Measures accepted tokens per verify step and
//!    maps the step shape (one batched target pass + `k` cheap draft
//!    passes) onto the Table II device model for a tokens/sec speedup
//!    vs target-only decode on the same edge profile.

use entrollm::bench::quick_or;
use entrollm::coordinator::{
    Backend, BackendCfg, DigestBackend, Engine, EngineConfig, ModelSpec, MultiModelConfig,
    MultiModelServer, Request, SpecConfig, SpecStats,
};
use entrollm::device::{table2_workloads, LatencyModel, JETSON_P3450};
use entrollm::metrics::Table;
use entrollm::pipeline::synthetic_layers;
use entrollm::quant::BitWidth;
use entrollm::store::{compress, SegmentSource};
use entrollm::Result;
use std::sync::Arc;

const K: usize = 4;
const VOCAB: usize = 256;

/// Draft that follows the target's own greedy chain but corrupts every
/// `every`-th proposal row — a deterministic stand-in for a distilled
/// draft model with acceptance ≈ mean survival of a length-`K` chain.
struct NoisyDraft {
    inner: DigestBackend,
    row: u64,
    every: u64,
}

impl Backend for NoisyDraft {
    fn cfg(&self) -> BackendCfg {
        self.inner.cfg()
    }
    fn prefill(&mut self, prompt: &[u32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.inner.prefill(prompt)
    }
    fn set_slot(&mut self, slot: usize, k1: &[f32], v1: &[f32]) -> Result<()> {
        self.inner.set_slot(slot, k1, v1)
    }
    fn decode(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Vec<f32>> {
        self.inner.decode(tokens, pos)
    }
    fn argmax_rows(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Option<Vec<u32>>> {
        let Some(mut rows) = self.inner.argmax_rows(tokens, pos)? else {
            return Ok(None);
        };
        for r in rows.iter_mut() {
            self.row += 1;
            if self.row % self.every == 0 {
                *r = (*r + 1) % VOCAB as u32;
            }
        }
        Ok(Some(rows))
    }
}

fn spec_model(name: &str, n_layers: usize, seed: u64) -> ModelSpec {
    let (elm, _) = compress(&synthetic_layers(n_layers, seed), BitWidth::U8).unwrap();
    ModelSpec::new(name, Arc::new(SegmentSource::from_model(Arc::new(elm))))
}

fn requests(offset: u64, n: u64, max_tokens: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            Request::greedy(
                offset + i,
                vec![1 + (offset + i) as u32 % 40, 9, 2 + i as u32],
                max_tokens,
            )
        })
        .collect()
}

/// Run the 2-model coordinator over the same load, speculation on/off;
/// returns (per-model sorted streams, spec stats snapshot if on).
fn coordinator_arm(
    spec_on: bool,
    n_reqs: u64,
    max_tokens: usize,
) -> (Vec<Vec<(u64, Vec<u32>)>>, Option<(f64, f64)>) {
    let draft = spec_model("small", quick_or(4, 8), 0xD4AF7);
    let target = spec_model("big", quick_or(8, 16), 0x7A46E7);
    let budget: usize = [&draft, &target]
        .iter()
        .map(|s| {
            let largest = s.source.layers().iter().map(|m| m.n_symbols).max().unwrap();
            s.source.n_params().max(3 * largest)
        })
        .sum();
    let mut multi = MultiModelServer::new(
        vec![draft, target],
        MultiModelConfig {
            budget_bytes: budget,
            ..MultiModelConfig::default()
        },
    )
    .unwrap();
    if spec_on {
        multi
            .enable_speculation(&SpecConfig::parse(&format!("draft=small,target=big,k={K}")).unwrap())
            .unwrap();
    }
    for (rd, rt) in requests(500, n_reqs, max_tokens)
        .into_iter()
        .zip(requests(0, n_reqs, max_tokens))
    {
        multi.engine_mut(0).submit(rd).unwrap();
        multi.engine_mut(1).submit(rt).unwrap();
    }
    let mut out = vec![Vec::new(), Vec::new()];
    let mut steps = 0usize;
    while multi.has_work() && steps < 1_000_000 {
        for mi in 0..2 {
            for resp in multi.step_model(mi).unwrap() {
                out[mi].push((resp.id, resp.tokens));
            }
        }
        steps += 1;
    }
    for m in &mut out {
        m.sort();
    }
    let stats = multi
        .speculation()
        .map(|(_, _, _, st)| (st.acceptance_rate(), st.emitted_per_step()));
    (out, stats)
}

fn main() {
    let n_reqs = quick_or(2u64, 6);
    let max_tokens = quick_or(6, 16);

    // ---- 1. Coordinator arm: bit-identity under speculation.
    let (plain, _) = coordinator_arm(false, n_reqs, max_tokens);
    let (spec, stats) = coordinator_arm(true, n_reqs, max_tokens);
    assert_eq!(
        spec, plain,
        "speculation changed a token stream — acceptance is not greedy-equivalent"
    );
    let (coord_acceptance, coord_emitted) = stats.expect("speculation was enabled");

    // ---- 2. Aligned-draft arm: acceptance ≥ 0.5 like a real draft.
    // Single-slot engine so emitted/step is per-stream, directly
    // comparable to the device model's per-token costs.
    let digest = 0x5EC0DE;
    let gen_len = quick_or(24usize, 96);
    let baseline = {
        let mut e = Engine::new(
            DigestBackend::with_digest(digest, 1, 4 * gen_len, VOCAB),
            EngineConfig::default(),
        );
        e.submit(Request::greedy(1, vec![11, 7], gen_len)).unwrap();
        let out = e.run_to_completion(1_000_000).unwrap();
        (out[0].tokens.clone(), e.stats().decode_steps)
    };
    let mut engine = Engine::new(
        DigestBackend::with_digest(digest, 1, 4 * gen_len, VOCAB),
        EngineConfig::default(),
    );
    // Corrupt every 13th proposal row: chain survival gives acceptance
    // ≈ mean((1-c)^1..(1-c)^K) ≈ 0.85 — comfortably above the 0.5 gate.
    let mut draft = NoisyDraft {
        inner: DigestBackend::with_digest(digest, 1, 4 * gen_len, VOCAB),
        row: 0,
        every: 13,
    };
    let mut st = SpecStats::default();
    engine.submit(Request::greedy(1, vec![11, 7], gen_len)).unwrap();
    let mut out = Vec::new();
    let mut steps = 0usize;
    while engine.has_work() && steps < 1_000_000 {
        out.extend(engine.step_speculative(&mut draft, K, &mut st).unwrap());
        steps += 1;
    }
    assert_eq!(
        out[0].tokens, baseline.0,
        "aligned-draft speculation diverged from target-only greedy decode"
    );
    assert!(
        st.acceptance_rate() >= 0.5,
        "acceptance {:.3} below the 0.5 gate — retune the noise rate",
        st.acceptance_rate()
    );
    assert!(
        st.steps < baseline.1,
        "speculation must finish in fewer verify steps than target-only \
         decode steps ({} vs {})",
        st.steps,
        baseline.1
    );

    // ---- Device-model speedup: one verify step emits E tokens and
    // costs one target pass plus K draft passes; the draft is an
    // 8x-smaller model, so its bandwidth-bound token cost scales with
    // its parameter count on the same edge profile.
    let model = LatencyModel::new(JETSON_P3450);
    let params = 3_800_000_000usize;
    let (_, target_wl) = table2_workloads(params, 8, 5.58, 512, 4, 1.0);
    let (_, draft_wl) = table2_workloads(params / 8, 8, 5.58, 512, 4, 1.0);
    let t_target = model.token_gen(&target_wl).total;
    let t_draft = model.token_gen(&draft_wl).total;
    let emitted = st.emitted_per_step();
    let spec_tok_s = emitted / (t_target + K as f64 * t_draft);
    let plain_tok_s = 1.0 / t_target;
    let speedup = spec_tok_s / plain_tok_s;
    assert!(
        speedup > 1.0,
        "device model shows no speedup at acceptance {:.3} (emitted/step {:.2})",
        st.acceptance_rate(),
        emitted
    );

    let mut table = Table::new(
        &format!("Speculative decoding, draft proposes k={K}, target verifies"),
        &["arm", "acceptance", "emitted/step", "device tok/s", "speedup"],
    );
    table.row(&[
        "coordinator, unrelated models".into(),
        format!("{coord_acceptance:.3}"),
        format!("{coord_emitted:.2}"),
        "-".into(),
        "bit-identical".into(),
    ]);
    table.row(&[
        "target-only decode (device model)".into(),
        "-".into(),
        "1.00".into(),
        format!("{plain_tok_s:.2}"),
        "1.00x".into(),
    ]);
    table.row(&[
        "aligned draft (1/8th-size, noisy)".into(),
        format!("{:.3}", st.acceptance_rate()),
        format!("{emitted:.2}"),
        format!("{spec_tok_s:.2}"),
        format!("{speedup:.2}x"),
    ]);
    table.emit("speculative");

    println!(
        "\nverify steps: {} speculative vs {} target-only; fallbacks {}",
        st.steps, baseline.1, st.fallback_steps
    );
    println!("\nspeculative bench OK");
}
