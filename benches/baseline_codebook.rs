//! Baseline C (paper §II-C): Huffman vs a QMoE-style fixed-dictionary
//! codebook coder vs a generic order-0 entropy coder (gzip stand-in;
//! the offline build has no DEFLATE) vs raw bit-packing, on the same
//! quantized symbol streams.
//!
//! The paper's argument: codebook coding is not Shannon-rate-optimal;
//! Huffman is (within 1 bit). Both bits/weight and decode throughput
//! are reported, since the edge story needs fast decode too.

use entrollm::ans;
use entrollm::baselines::{fixed_pack, gzip_bytes, gunzip_bytes, CodebookCoder};
use entrollm::bench::{quick_or, Bench};
use entrollm::entropy::shannon_entropy;
use entrollm::huffman::{encode_with_own_code, Decoder, FreqTable};
use entrollm::metrics::Table;
use entrollm::quant::{quantize_mixed, BitWidth};
use entrollm::rng::Rng;
use entrollm::tensor::TensorF32;

fn symbols(bits: BitWidth, n: usize) -> Vec<u8> {
    let mut rng = Rng::new(0xC0DE);
    let w = TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.04)).unwrap();
    quantize_mixed(&w, bits).symbols.into_data()
}

fn main() {
    let n = quick_or(100_000, 1_000_000);
    let bench = Bench::auto(Bench::new());
    let mut table = Table::new(
        "Baseline C: entropy-coding methods on quantized Gaussian weights",
        &["bits", "method", "bits/weight", "vs entropy", "decode Msym/s"],
    );

    for bits in [BitWidth::U8, BitWidth::U4] {
        let syms = symbols(bits, n);
        let freq = FreqTable::from_symbols(&syms);
        let h = shannon_entropy(freq.counts());

        // Raw fixed-width packing.
        let packed = fixed_pack(&syms, bits).unwrap();
        table.row(&[
            bits.to_string(),
            "fixed-width".into(),
            format!("{:.3}", 8.0 * packed.len() as f64 / n as f64),
            format!("{:+.2}", 8.0 * packed.len() as f64 / n as f64 - h),
            "-".into(),
        ]);

        // Huffman (ours).
        let (spec, enc) = encode_with_own_code(&syms).unwrap();
        let dec = Decoder::new(&spec).unwrap();
        let hf_bits = 8.0 * enc.len() as f64 / n as f64;
        let mut out = vec![0u8; syms.len()];
        let stats = bench.run(&format!("huffman decode {bits}"), || {
            dec.decode_into(&enc, &mut out).unwrap();
        });
        let hf_rate = n as f64 / stats.median.as_secs_f64() / 1e6;
        table.row(&[
            bits.to_string(),
            "huffman (ours)".into(),
            format!("{hf_bits:.3}"),
            format!("{:+.2}", hf_bits - h),
            format!("{hf_rate:.1}"),
        ]);

        // tANS (our second codec arm): fractional bits per symbol.
        let (ans_table, ans_enc) = ans::encode_with_own_table(&syms).unwrap();
        let ans_dec = ans::Decoder::new(&ans_table).unwrap();
        let ans_bits = 8.0 * ans_enc.len() as f64 / n as f64;
        let stats = bench.run(&format!("tans decode {bits}"), || {
            ans_dec.decode_into(&ans_enc, &mut out).unwrap();
        });
        let ans_rate = n as f64 / stats.median.as_secs_f64() / 1e6;
        table.row(&[
            bits.to_string(),
            "tANS (ours)".into(),
            format!("{ans_bits:.3}"),
            format!("{:+.2}", ans_bits - h),
            format!("{ans_rate:.1}"),
        ]);

        // Codebook (QMoE-style fixed dictionary).
        let cb = CodebookCoder::train(&syms);
        let cb_enc = cb.encode(&syms);
        let cb_bits = 8.0 * cb_enc.len() as f64 / n as f64;
        let stats = bench.run(&format!("codebook decode {bits}"), || {
            cb.decode(&cb_enc, syms.len()).unwrap();
        });
        let cb_rate = n as f64 / stats.median.as_secs_f64() / 1e6;
        table.row(&[
            bits.to_string(),
            "codebook (QMoE-like)".into(),
            format!("{cb_bits:.3}"),
            format!("{:+.2}", cb_bits - h),
            format!("{cb_rate:.1}"),
        ]);

        // Generic entropy coder on the packed stream (order-0 Huffman
        // stand-in; real gzip/DEFLATE would compress harder — see
        // baselines module docs).
        let gz = gzip_bytes(&packed).unwrap();
        let gz_bits = 8.0 * gz.len() as f64 / n as f64;
        let stats = bench.run(&format!("generic entropy decode {bits}"), || {
            gunzip_bytes(&gz).unwrap();
        });
        let gz_rate = n as f64 / stats.median.as_secs_f64() / 1e6;
        table.row(&[
            bits.to_string(),
            "generic entropy (order-0, sub-gzip)".into(),
            format!("{gz_bits:.3}"),
            format!("{:+.2}", gz_bits - h),
            format!("{gz_rate:.1}"),
        ]);

        // Paper-shape assertions: Huffman within 1 bit of entropy and
        // strictly better than the codebook; tANS closes the gap
        // further on these skewed streams, so it must be at least as
        // tight as Huffman and still Shannon-near-optimal.
        assert!(hf_bits < h + 1.0, "huffman must be Shannon-near-optimal");
        assert!(hf_bits < cb_bits, "huffman {hf_bits} must beat codebook {cb_bits}");
        assert!(hf_bits < 8.0 * packed.len() as f64 / n as f64, "must beat fixed width");
        assert!(ans_bits < h + 1.0, "tANS must be Shannon-near-optimal");
        assert!(
            ans_bits <= hf_bits,
            "tANS {ans_bits} must not lose to huffman {hf_bits} on a skewed stream"
        );
    }
    table.emit("baseline_codebook");
    println!("baseline C OK: tANS ≤ huffman ≤ entropy+1, both beat the fixed-dictionary coder");
}
