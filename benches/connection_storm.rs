//! Connection storm against the sharded front door: **thousands of
//! concurrent line-protocol clients against a fixed number of I/O
//! threads**.
//!
//! Three phases:
//!
//! 1. **Thread ceiling** — open ~1100 concurrent idle connections
//!    (quick: 128) from the main thread and assert the server's thread
//!    count stays O(io-shards), not O(connections). The old
//!    thread-per-connection front door spawned reader+writer threads
//!    per socket (2200+ threads here); the event loops hold the whole
//!    storm on `io_shards + 1`.
//! 2. **Latency + fairness** — 1000 concurrent clients (quick: 64)
//!    driven by a small worker pool, several round trips each; reports
//!    p50/p99/max round-trip latency and a fairness ratio (p90/p10 of
//!    per-connection mean latency).
//! 3. **Never-reading client** — a client floods requests and never
//!    reads a byte back against a server with a small per-connection
//!    output cap; the server must shed it (`shed_output_overflow`)
//!    with bounded memory while a healthy neighbor keeps serving.

use entrollm::bench::quick_or;
use entrollm::coordinator::{Engine, EngineConfig, MockBackend};
use entrollm::metrics::Table;
use entrollm::server::{process_thread_count, serve_with, Client, ServeConfig};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Raise the fd soft limit toward `want` (unix): the storm holds both
/// ends of every connection in this one process, so the default soft
/// limit of 1024 fds would cap the storm at ~500 clients.
#[cfg(unix)]
fn raise_fd_limit(want: u64) {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    #[cfg(any(target_os = "linux", target_os = "android"))]
    const RLIMIT_NOFILE: i32 = 7;
    #[cfg(not(any(target_os = "linux", target_os = "android")))]
    const RLIMIT_NOFILE: i32 = 8;
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    unsafe {
        let mut lim = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 || lim.cur >= want {
            return;
        }
        let new = RLimit {
            cur: want.min(lim.max),
            max: lim.max,
        };
        let _ = setrlimit(RLIMIT_NOFILE, &new);
    }
}

#[cfg(not(unix))]
fn raise_fd_limit(_want: u64) {}

fn spawn_server(
    cfg: ServeConfig,
    batch: usize,
) -> (String, Arc<AtomicBool>, std::thread::JoinHandle<u64>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let handle = std::thread::spawn(move || {
        let mut engine = Engine::new(MockBackend::new(batch, 32, 128), EngineConfig::default());
        serve_with(&mut engine, listener, stop2, &cfg).unwrap()
    });
    (addr, stop, handle)
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn main() {
    let io_shards = 4usize;
    let held_conns = quick_or(128usize, 1100);
    let storm_conns = quick_or(64usize, 1000);
    let workers = quick_or(8usize, 32);
    let roundtrips = quick_or(2usize, 3);
    raise_fd_limit(4 * (held_conns.max(storm_conns) as u64) + 256);

    let mut table = Table::new(
        "Connection storm: sharded event-loop front door",
        &["metric", "value"],
    );

    // ---- Phase 1: thread ceiling under held-open connections -------
    let (addr, stop, server) = spawn_server(
        ServeConfig {
            io_shards,
            ..ServeConfig::default()
        },
        8,
    );
    let mut admin = Client::connect(&addr).unwrap();
    admin.request("warm", 1, 0.0).unwrap();
    let t_before = process_thread_count();

    let mut held = Vec::with_capacity(held_conns);
    for i in 0..held_conns {
        held.push(TcpStream::connect(&addr).unwrap());
        // Pace the burst below the listen backlog so no connect stalls
        // on a kernel SYN retransmit while the acceptor catches up.
        if i % 64 == 63 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    std::thread::sleep(Duration::from_millis(100));
    let t_during = process_thread_count();

    // Liveness: a sample of fresh clients does full round trips while
    // the storm of idle connections is held open.
    for i in 0..8 {
        let mut c = Client::connect(&addr).unwrap();
        let r = c.request(&format!("live {i}"), 1, 0.0).unwrap();
        assert_eq!(r.get("tokens").unwrap().as_usize().unwrap(), 1);
    }
    let stats = admin.stats().unwrap();
    let io_threads = stats.get("io_threads").unwrap().as_usize().unwrap();
    assert_eq!(
        io_threads,
        io_shards + 1,
        "front door must run exactly shards + acceptor threads"
    );
    let accepted = stats.get("conns_accepted").unwrap().as_usize().unwrap();
    assert!(accepted >= held_conns, "accepted {accepted} < {held_conns}");
    let thread_delta = match (t_before, t_during) {
        (Some(b), Some(d)) => {
            let delta = d.saturating_sub(b);
            // The whole storm must not grow the process by more than a
            // handful of threads (the old design grew by 2 per conn).
            assert!(
                delta <= io_shards + 3,
                "thread count grew O(connections): before {b}, during {d}"
            );
            format!("{delta}")
        }
        _ => "n/a".into(),
    };
    drop(held);
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
    table.row(&["held connections (phase 1)".into(), held_conns.to_string()]);
    table.row(&["io_threads (stats)".into(), io_threads.to_string()]);
    table.row(&["thread delta under storm".into(), thread_delta]);

    // ---- Phase 2: latency + fairness under concurrent round trips --
    let (addr, stop, server) = spawn_server(
        ServeConfig {
            io_shards,
            ..ServeConfig::default()
        },
        8,
    );
    let mut clients = Vec::with_capacity(storm_conns);
    for i in 0..storm_conns {
        clients.push(Client::connect(&addr).unwrap());
        if i % 64 == 63 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    // Distribute the connected clients across a small worker pool;
    // each worker owns its share and round-robins it, so every
    // connection stays concurrently open and repeatedly active.
    let mut buckets: Vec<Vec<(usize, Client)>> = (0..workers).map(|_| Vec::new()).collect();
    for (ci, c) in clients.into_iter().enumerate() {
        buckets[ci % workers].push((ci, c));
    }
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for mut mine in buckets {
        joins.push(std::thread::spawn(move || {
            let mut lat: Vec<(usize, f64)> = Vec::new();
            for _ in 0..roundtrips {
                for (ci, c) in mine.iter_mut() {
                    let t = Instant::now();
                    let r = c.request("storm", 1, 0.0).unwrap();
                    assert_eq!(r.get("tokens").unwrap().as_usize().unwrap(), 1);
                    lat.push((*ci, t.elapsed().as_secs_f64() * 1e3));
                }
            }
            lat
        }));
    }
    let mut all: Vec<(usize, f64)> = Vec::new();
    for j in joins {
        all.extend(j.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    let total_reqs = all.len();
    assert_eq!(total_reqs, storm_conns * roundtrips);

    let mut lats: Vec<f64> = all.iter().map(|(_, ms)| *ms).collect();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p99, pmax) = (
        percentile(&lats, 0.50),
        percentile(&lats, 0.99),
        percentile(&lats, 1.0),
    );
    // Fairness: p90/p10 ratio of per-connection mean latency. 1.0 is
    // perfectly fair; the assert is a loose sanity bound against one
    // connection being starved by orders of magnitude.
    let mut per_conn = vec![(0.0f64, 0usize); storm_conns];
    for (ci, ms) in &all {
        per_conn[*ci].0 += ms;
        per_conn[*ci].1 += 1;
    }
    let mut means: Vec<f64> = per_conn.iter().map(|(s, n)| s / (*n as f64)).collect();
    means.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let fairness = percentile(&means, 0.90) / percentile(&means, 0.10).max(1e-9);
    assert!(
        fairness < 100.0,
        "per-connection latency wildly unfair: p90/p10 = {fairness:.1}"
    );

    let mut admin = Client::connect(&addr).unwrap();
    let stats = admin.stats().unwrap();
    assert_eq!(
        stats.get("io_threads").unwrap().as_usize().unwrap(),
        io_shards + 1
    );
    assert_eq!(
        stats.get("completed").unwrap().as_usize().unwrap(),
        total_reqs
    );
    stop.store(true, Ordering::Relaxed);
    let served = server.join().unwrap();
    assert_eq!(served as usize, total_reqs);
    table.row(&["concurrent clients (phase 2)".into(), storm_conns.to_string()]);
    table.row(&["round trips".into(), total_reqs.to_string()]);
    table.row(&["req/s".into(), format!("{:.0}", total_reqs as f64 / wall.max(1e-9))]);
    table.row(&["p50 ms".into(), format!("{p50:.2}")]);
    table.row(&["p99 ms".into(), format!("{p99:.2}")]);
    table.row(&["max ms".into(), format!("{pmax:.2}")]);
    table.row(&["fairness p90/p10".into(), format!("{fairness:.2}")]);

    // ---- Phase 3: never-reading client vs small output cap ---------
    let (addr, stop, server) = spawn_server(
        ServeConfig {
            io_shards: 2,
            max_conn_buffered_bytes: 8 * 1024,
            ..ServeConfig::default()
        },
        8,
    );
    let addr2 = addr.clone();
    let flood = std::thread::spawn(move || {
        let mut s = TcpStream::connect(&addr2).unwrap();
        let line = b"{\"stats\":true}\n";
        // Tens of thousands of stats lines, never reading a byte back:
        // replies overrun the kernel socket buffers, then the 8 KiB
        // queue cap, and the server sheds the connection.
        'outer: for _ in 0..200 {
            for _ in 0..200 {
                if s.write_all(line).is_err() {
                    break 'outer;
                }
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    });
    let mut healthy = Client::connect(&addr).unwrap();
    let t0 = Instant::now();
    let mut shed = 0usize;
    while t0.elapsed() < Duration::from_secs(quick_or(5, 20)) {
        let stats = healthy.stats().unwrap();
        shed = stats
            .get("shed_output_overflow")
            .unwrap()
            .as_usize()
            .unwrap();
        if shed >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    flood.join().unwrap();
    assert!(
        shed >= 1,
        "never-reading client was not shed at its output cap"
    );
    let ok = healthy.request("after", 1, 0.0).unwrap();
    assert_eq!(ok.get("tokens").unwrap().as_usize().unwrap(), 1);
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
    table.row(&["shed_output_overflow (phase 3)".into(), shed.to_string()]);

    table.emit("connection_storm");
    println!("\nconnection_storm bench OK");
}
