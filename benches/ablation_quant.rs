//! Ablation A: the mixed quantization rule vs forcing one scheme
//! everywhere (paper §III-A's design choice).
//!
//! Mixed must (a) never lose accuracy vs the forced schemes — every
//! layer still meets the half-step bound — and (b) match or beat
//! all-asymmetric compressibility while avoiding all-symmetric's
//! accuracy blowup on zero-straddling layers.

use entrollm::bench::quick_or;
use entrollm::entropy::shannon_entropy;
use entrollm::huffman::{CodeSpec, FreqTable};
use entrollm::metrics::Table;
use entrollm::quant::{dequantize, quantize_forced, quantize_mixed, BitWidth, Scheme};
use entrollm::rng::Rng;
use entrollm::runtime::load_weights_bin;
use entrollm::tensor::TensorF32;

fn synth_layers(seed: u64) -> Vec<(String, TensorF32)> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    // Smoke runs shrink both the layer count and the layer size; the
    // scheme comparison and its assertions hold at any scale.
    let n_layers = quick_or(8, 24);
    let base = quick_or(1024, 4096);
    for i in 0..n_layers {
        let n = base + rng.below(2 * base);
        // A third of layers single-signed (gates/biases in real nets).
        let data: Vec<f32> = if i % 3 == 0 {
            (0..n).map(|_| rng.range_f32(0.0, 0.12)).collect()
        } else {
            rng.gaussian_vec(n, 0.0, 0.04)
        };
        out.push((format!("l{i}"), TensorF32::new(vec![n], data).unwrap()));
    }
    out
}

fn evaluate(
    layers: &[(String, TensorF32)],
    bits: BitWidth,
    scheme: Option<Scheme>,
) -> (f64, f64, f64) {
    let mut freq = FreqTable::new();
    let mut worst_rel_err = 0.0f64;
    for (_, w) in layers {
        let q = match scheme {
            None => quantize_mixed(w, bits),
            Some(s) => quantize_forced(w, bits, s),
        };
        freq.add_symbols(q.symbols.data());
        let dq = dequantize(&q);
        let (mn, mx) = w.min_max().unwrap();
        let range = (mx - mn).max(1e-9);
        for (a, b) in w.data().iter().zip(dq.data()) {
            worst_rel_err = worst_rel_err.max(((a - b).abs() / range) as f64);
        }
    }
    let spec = CodeSpec::build(&freq).unwrap();
    (
        shannon_entropy(freq.counts()),
        spec.expected_bits(&freq),
        worst_rel_err,
    )
}

fn main() {
    let mut table = Table::new(
        "Ablation A: mixed vs forced quantization schemes",
        &["weights", "bits", "scheme", "entropy", "eff. bits", "worst err (% of range)"],
    );

    let mut run_set = |set_name: &str, layers: &[(String, TensorF32)]| {
        for bits in [BitWidth::U8, BitWidth::U4] {
            let mut results = Vec::new();
            for (scheme, name) in [
                (None, "mixed (paper)"),
                (Some(Scheme::SymmetricUnsigned), "all-symmetric"),
                (Some(Scheme::Asymmetric), "all-asymmetric"),
            ] {
                let (h, eff, err) = evaluate(layers, bits, scheme);
                table.row(&[
                    set_name.into(),
                    bits.to_string(),
                    name.into(),
                    format!("{h:.3}"),
                    format!("{eff:.3}"),
                    format!("{:.2}%", err * 100.0),
                ]);
                results.push((name, eff, err));
            }
            let (_, _, mixed_err) = (results[0].0, results[0].1, results[0].2);
            let sym_err = results[1].2;
            let asym_eff = results[2].1;
            let mixed_eff = results[0].1;
            // Mixed accuracy must match asymmetric-level accuracy...
            assert!(
                mixed_err <= results[2].2 * 1.5 + 1e-3,
                "mixed err {mixed_err} vs asym {}",
                results[2].2
            );
            // ...and all-symmetric on zero-straddling layers wastes half
            // the grid (err >= mixed).
            assert!(sym_err >= mixed_err - 1e-9, "symmetric can't beat mixed accuracy");
            // Compressibility: mixed within a small margin of the best.
            assert!(
                mixed_eff <= asym_eff + 0.25,
                "mixed eff {mixed_eff} vs asym {asym_eff}"
            );
        }
    };

    run_set("synthetic", &synth_layers(0xAB1A));
    if let Ok(ws) = load_weights_bin("artifacts/weights.bin") {
        let big: Vec<_> = ws.into_iter().filter(|(_, t)| t.numel() > 1000).collect();
        run_set("trained tiny-LM", &big);
    } else {
        eprintln!("(artifacts missing — trained row skipped)");
    }

    table.emit("ablation_quant");
    println!("ablation A OK: mixed keeps asymmetric accuracy at (near-)best compressibility");
}
