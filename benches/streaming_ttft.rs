//! Eager vs **streaming** time-to-first-token (TTFT) for the ELM decode
//! path — the number the `decode::stream` subsystem exists to shrink.
//!
//! Eager ([`entrollm::decode::ParallelDecoder`]) is a barrier: the first
//! weight is usable only after the *whole* container decodes. Streaming
//! ([`entrollm::decode::StreamingDecoder`]) hands the first layer over
//! after roughly `prefetch/L` of the decode, and hides the rest behind
//! per-layer staging/compute. This bench measures both on a synthetic
//! model, then prints the modeled Jetson/Table-II numbers where the gap
//! is at edge scale.

use entrollm::bench::{fmt_secs, quick_or, Bench};
use entrollm::coordinator::{fnv1a64, FNV1A64_INIT};
use entrollm::decode::{ParallelDecoder, StreamingDecoder};
use entrollm::device::{table2_workloads, LatencyModel, JETSON_P3450};
use entrollm::metrics::Table;
use entrollm::pipeline::synthetic_layers;
use entrollm::quant::BitWidth;
use entrollm::store::compress;
use std::sync::Arc;

/// Cheap per-layer "staging" stand-in (what the runtime does with each
/// tensor as it arrives): a full pass over the symbol bytes.
fn stage(symbols: &[u8]) -> u64 {
    fnv1a64(FNV1A64_INIT, symbols)
}

fn main() {
    let n_layers = quick_or(12usize, 32);
    let threads = 4usize;
    let layers = synthetic_layers(n_layers, 0x7751);
    let (model, report) = compress(&layers, BitWidth::U8).unwrap();
    let model = Arc::new(model);
    println!(
        "synthetic model: {n_layers} layers, {} params, {:.3} effective bits\n",
        report.n_params, report.effective_bits
    );

    let bench = Bench::auto(Bench::new());
    let mut table = Table::new(
        "Streaming vs eager TTFT (measured on this host + modeled Jetson)",
        &["config", "first weight / TTFT", "note"],
    );

    // Eager: time until ANY weight is usable = the whole decode.
    let eager_stats = bench.run("eager: full parallel decode", || {
        std::hint::black_box(ParallelDecoder::new(threads).decode_model(&model).unwrap());
    });
    let eager_first = eager_stats.median.as_secs_f64();
    table.row(&[
        "measured eager (first weight)".into(),
        fmt_secs(eager_first),
        "barrier: first weight after full decode".into(),
    ]);

    // Streaming: time until the FIRST layer is delivered.
    let mut streaming_first = f64::MAX;
    for prefetch in quick_or(vec![2usize], vec![1, 4, 8]) {
        let stats = bench.run(&format!("streaming: first layer (prefetch {prefetch})"), || {
            let mut stream = StreamingDecoder::new(threads, prefetch)
                .stream(Arc::clone(&model))
                .unwrap();
            std::hint::black_box(stream.next_layer().unwrap().unwrap());
            // Dropping the stream cancels the remaining decode.
        });
        let t = stats.median.as_secs_f64();
        streaming_first = streaming_first.min(t);
        table.row(&[
            format!("measured streaming prefetch={prefetch} (first weight)"),
            fmt_secs(t),
            format!("{:.2}x earlier than eager", eager_first / t.max(1e-12)),
        ]);
    }

    // End-to-end: decode + per-layer staging, serial barrier vs overlap.
    let (sum_eager, eager_e2e) = bench.once("eager decode + stage all", || {
        let (tensors, _) = ParallelDecoder::new(threads).decode_model(&model).unwrap();
        tensors
            .iter()
            .map(|t| stage(t.symbols.data()))
            .fold(0u64, u64::wrapping_add)
    });
    let (sum_stream, stream_e2e) = bench.once("streaming decode + stage overlapped", || {
        let mut stream = StreamingDecoder::new(threads, 4)
            .stream(Arc::clone(&model))
            .unwrap();
        let mut acc = 0u64;
        while let Some(layer) = stream.next_layer() {
            acc = acc.wrapping_add(stage(layer.unwrap().tensor.symbols.data()));
        }
        acc
    });
    assert_eq!(sum_eager, sum_stream, "staged identical weights");
    table.row(&[
        "measured e2e eager (decode then stage)".into(),
        fmt_secs(eager_e2e.as_secs_f64()),
        "staging starts after the barrier".into(),
    ]);
    table.row(&[
        "measured e2e streaming (stage overlaps)".into(),
        fmt_secs(stream_e2e.as_secs_f64()),
        format!(
            "{:.2}x vs eager e2e",
            eager_e2e.as_secs_f64() / stream_e2e.as_secs_f64().max(1e-12)
        ),
    ]);

    // Modeled at edge scale: phi3-class model on the Jetson profile.
    let m = LatencyModel::new(JETSON_P3450);
    let (_, with) = table2_workloads(3_800_000_000, 8, 5.58, 512, threads, 1.0);
    let eager_ttft = m.breakdown(&with).first_token;
    table.row(&[
        "modeled Jetson eager TTFT".into(),
        fmt_secs(eager_ttft),
        "decode barrier + prefill + 1 token".into(),
    ]);
    let mut streaming_wins = true;
    for prefetch in [1usize, 2, 4, 8, 16, n_layers] {
        let t = m.streaming_first_token(&with, n_layers, prefetch);
        let wins = t < eager_ttft - 1e-12;
        if prefetch < n_layers && !wins {
            streaming_wins = false;
        }
        table.row(&[
            format!("modeled Jetson streaming prefetch={prefetch}/{n_layers}"),
            fmt_secs(t),
            if prefetch < n_layers {
                format!("{} ({:.2}x)", if wins { "WIN" } else { "LOSS" }, eager_ttft / t)
            } else {
                "degenerates to eager (full window)".into()
            },
        ]);
    }

    table.emit("streaming_ttft");
    assert!(
        streaming_wins,
        "streaming TTFT must beat eager whenever prefetch < total layers"
    );
    assert!(
        streaming_first < eager_first,
        "first streamed weight ({streaming_first}s) must arrive before eager \
         finishes its full decode ({eager_first}s)"
    );
}
