//! Per-model QoS under the shared residency ledger: a **reserved
//! latency-critical model** keeps serving from residency while a batch
//! peer hammers the shared pool, vs the PR 4 **unreserved baseline**
//! at the same total byte budget.
//!
//! Both arms run the identical request schedule (alternating batch
//! bursts with single latency-model requests) through a
//! [`MultiModelServer`]; the only difference is the latency model's
//! `reserve`/`weight`. The bench asserts the QoS contract, not just
//! measures it:
//!
//! * the reserved model never holds fewer than its reserved bytes
//!   once warmed, no matter how hot the batch peer runs;
//! * its measured fault rate is **strictly lower** than the
//!   unreserved baseline's;
//! * both arms emit **bit-identical token streams** — reservations
//!   move *where bytes are resident*, never *what models generate*;
//! * a config whose reservations exceed the global budget is rejected
//!   at startup.
//!
//! A second, **request-level** arm (PR 9) runs mixed traffic through a
//! single engine: a low-class batch flood holds every batch slot while
//! high-class interactive requests with deadlines land mid-stream. The
//! same deterministic schedule runs with preemption off (FIFO slot
//! tenure) and on; the bench asserts the deadline class's p99 latency
//! (in decode steps — no wall clocks, so CI can't flake) is *strictly
//! lower* with preemption, batch throughput stays within 10%, and every
//! token stream — including the preempted-and-resumed ones — is
//! bit-identical across arms.

use entrollm::bench::{fmt_bytes, quick_or};
use entrollm::coordinator::{
    DigestBackend, Engine, EngineConfig, ModelSpec, MultiModelConfig, MultiModelServer, Request,
};
use entrollm::metrics::Table;
use entrollm::quant::BitWidth;
use entrollm::rng::Rng;
use entrollm::store::{compress, ElmModel, SegmentSource};
use entrollm::tensor::TensorF32;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// `n` equal-size layers (512 decoded bytes each), so "budget = k
/// layers" is exact and the reserve can cover the latency model to
/// the byte.
fn equal_model(n: usize, seed: u64) -> ElmModel {
    let layers: Vec<(String, TensorF32)> = (0..n)
        .map(|i| {
            let mut rng = Rng::new(seed + i as u64);
            (
                format!("l{i}"),
                TensorF32::new(vec![512], rng.gaussian_vec(512, 0.0, 0.05)).unwrap(),
            )
        })
        .collect();
    compress(&layers, BitWidth::U8).unwrap().0
}

struct ArmResult {
    latency_tokens: Vec<(u64, Vec<u32>)>,
    batch_tokens: Vec<(u64, Vec<u32>)>,
    fault_rate: f64,
    latency_tok_per_sec: f64,
    min_latency_resident: usize,
    shed_by_peers: u64,
}

fn drain(multi: &mut MultiModelServer, mi: usize, sink: &mut Vec<(u64, Vec<u32>)>) {
    let mut steps = 0usize;
    while multi.engine(mi).has_work() && steps < 1_000_000 {
        for r in multi.engine_mut(mi).step().unwrap() {
            sink.push((r.id, r.tokens));
        }
        steps += 1;
    }
}

#[allow(clippy::too_many_arguments)]
fn run_arm(
    lat_path: &std::path::Path,
    bat_path: &std::path::Path,
    budget: usize,
    reserve: usize,
    weight: f64,
    rounds: usize,
    batch_reqs: u64,
    max_tokens: usize,
) -> ArmResult {
    let latency_spec = ModelSpec::new(
        "latency",
        Arc::new(SegmentSource::open(lat_path).unwrap()),
    )
    .with_qos(reserve, weight);
    let batch_spec = ModelSpec::new("batch", Arc::new(SegmentSource::open(bat_path).unwrap()));
    let mut multi = MultiModelServer::new(
        vec![latency_spec, batch_spec],
        MultiModelConfig {
            budget_bytes: budget,
            decode_ahead: 1,
            workers: 2,
            ..MultiModelConfig::default()
        },
    )
    .unwrap();

    let mut latency_tokens = Vec::new();
    let mut batch_tokens = Vec::new();

    // Warm the latency model once (fills its reserve, when it has
    // one); warmup faults are excluded from the measured rate.
    multi
        .engine_mut(0)
        .submit(Request::greedy(1, vec![3, 14, 15], max_tokens))
        .unwrap();
    drain(&mut multi, 0, &mut latency_tokens);
    let warm = multi.engine(0).residency().unwrap();
    let warm_token_count: usize = latency_tokens.iter().map(|(_, t)| t.len()).sum();

    let mut latency_wall = Duration::ZERO;
    let mut min_latency_resident = usize::MAX;
    for round in 0..rounds {
        // Batch burst: the peer runs hot while the latency model idles
        // — exactly when an unreserved latency model gets robbed.
        for k in 0..batch_reqs {
            let id = 100 + round as u64 * batch_reqs + k;
            multi
                .engine_mut(1)
                .submit(Request::greedy(id, vec![7 + (id % 30) as u32, 2], max_tokens))
                .unwrap();
        }
        drain(&mut multi, 1, &mut batch_tokens);
        min_latency_resident = min_latency_resident.min(multi.ledger().used_by(0));

        // One latency-critical request lands mid-pressure.
        let t0 = Instant::now();
        multi
            .engine_mut(0)
            .submit(Request::greedy(
                1000 + round as u64,
                vec![5, 9 + round as u32 % 20],
                max_tokens,
            ))
            .unwrap();
        drain(&mut multi, 0, &mut latency_tokens);
        latency_wall += t0.elapsed();
        min_latency_resident = min_latency_resident.min(multi.ledger().used_by(0));
    }

    let after = multi.engine(0).residency().unwrap();
    let faults = after.misses - warm.misses;
    let accesses = faults + (after.hits - warm.hits);
    let lc = multi.ledger().counters();
    assert!(
        lc.peak_used_bytes <= lc.budget_bytes,
        "global budget violated: {lc:?}"
    );
    latency_tokens.sort();
    batch_tokens.sort();
    // tok/s covers only the measured rounds: the warmup request's
    // tokens are excluded from the numerator just as its wall time is
    // excluded from the denominator.
    let measured_tokens: usize =
        latency_tokens.iter().map(|(_, t)| t.len()).sum::<usize>() - warm_token_count;
    ArmResult {
        latency_tokens,
        batch_tokens,
        fault_rate: if accesses == 0 {
            0.0
        } else {
            faults as f64 / accesses as f64
        },
        latency_tok_per_sec: measured_tokens as f64 / latency_wall.as_secs_f64().max(1e-12),
        min_latency_resident,
        shed_by_peers: multi.model_counters(0).shed_by_peers,
    }
}

struct RequestArm {
    /// Every completed (id, tokens), sorted — interactive and batch.
    tokens: Vec<(u64, Vec<u32>)>,
    /// p99 of interactive submit→completion latency, in decode steps.
    interactive_p99_steps: usize,
    /// Batch-class tokens per engine step over the whole run.
    batch_tok_per_step: f64,
    preemptions: u64,
    expired: u64,
}

/// One engine, mixed traffic, step-deterministic: `batch_reqs`
/// class −4 generations of `batch_len` tokens flood a 2-slot batch;
/// class +4 interactive requests (4 tokens, generous deadline) are
/// submitted at the fixed step indices in `submit_steps`. Latency is
/// counted in engine steps, so both arms replay the exact same
/// schedule and differ only in the `preemption` knob.
fn run_request_arm(
    preemption: bool,
    batch_reqs: u64,
    batch_len: usize,
    submit_steps: &[usize],
) -> RequestArm {
    let mut engine = Engine::new(
        DigestBackend::with_digest(0x9051_4EA7, 2, 4096, 512),
        EngineConfig {
            preemption,
            // Aging reorders only within the queue and the interactive
            // class already outranks everything here; disable it so the
            // arms are wall-clock-independent.
            aging: None,
            ..EngineConfig::default()
        },
    );
    for k in 0..batch_reqs {
        engine
            .submit(Request::greedy(k, vec![11 + k as u32, 3], batch_len).with_priority(-4))
            .unwrap();
    }

    let mut submitted = 0usize;
    let mut submit_step = std::collections::HashMap::new();
    let mut latencies = Vec::new();
    let mut tokens = Vec::new();
    let mut batch_token_count = 0usize;
    let mut step = 0usize;
    while engine.has_work() || submitted < submit_steps.len() {
        while submitted < submit_steps.len() && submit_steps[submitted] <= step {
            let id = 1_000 + submitted as u64;
            engine
                .submit(
                    Request::greedy(id, vec![5, submitted as u32], 4)
                        .with_priority(4)
                        .with_deadline(Duration::from_secs(120)),
                )
                .unwrap();
            submit_step.insert(id, step);
            submitted += 1;
        }
        for resp in engine.step().unwrap() {
            if let Some(&s0) = submit_step.get(&resp.id) {
                latencies.push(step + 1 - s0);
            } else {
                batch_token_count += resp.tokens.len();
            }
            tokens.push((resp.id, resp.tokens));
        }
        step += 1;
        assert!(step < 1_000_000, "request-level arm did not converge");
    }

    tokens.sort();
    latencies.sort_unstable();
    assert!(!latencies.is_empty(), "no interactive request completed");
    let p99_idx = ((latencies.len() - 1) as f64 * 0.99).ceil() as usize;
    RequestArm {
        tokens,
        interactive_p99_steps: latencies[p99_idx],
        batch_tok_per_step: batch_token_count as f64 / step.max(1) as f64,
        preemptions: engine.stats().preemptions,
        expired: engine.stats().expired,
    }
}

fn main() {
    let rounds = quick_or(2usize, 6);
    let batch_reqs = quick_or(2u64, 4);
    let max_tokens = quick_or(4usize, 10);

    let dir = std::env::temp_dir().join(format!("qos_isolation_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let lat_elm = equal_model(6, 0x1A7E);
    let bat_elm = equal_model(20, 0xBA7C);
    let lat_total = lat_elm.n_params(); // 6 × 512 B decoded
    let lat_path = dir.join("latency.elm");
    let bat_path = dir.join("batch.elm");
    lat_elm.save(&lat_path).unwrap();
    bat_elm.save(&bat_path).unwrap();

    // Pool holds the latency model plus 4 spare layers: the 20-layer
    // batch model must churn, and without QoS it churns *through* the
    // latency model's residency.
    let budget = lat_total + 4 * 512;
    let reserve = lat_total;
    println!(
        "latency model {} decoded | batch model {} decoded | shared budget {} | \
         QoS arm reserves {} for the latency model\n",
        fmt_bytes(lat_total),
        fmt_bytes(bat_elm.n_params()),
        fmt_bytes(budget),
        fmt_bytes(reserve),
    );

    // Startup acceptance: reserves summing past the budget are
    // rejected before any engine is built.
    let over = MultiModelServer::new(
        vec![
            ModelSpec::new(
                "latency",
                Arc::new(SegmentSource::open(&lat_path).unwrap()),
            )
            .with_qos(budget, 1.0),
            ModelSpec::new("batch", Arc::new(SegmentSource::open(&bat_path).unwrap()))
                .with_qos(1, 1.0),
        ],
        MultiModelConfig {
            budget_bytes: budget,
            ..MultiModelConfig::default()
        },
    );
    let err = over.err().expect("over-reserved config must be rejected");
    assert!(err.to_string().contains("reservations"), "{err}");

    let baseline = run_arm(
        &lat_path, &bat_path, budget, 0, 1.0, rounds, batch_reqs, max_tokens,
    );
    let qos = run_arm(
        &lat_path, &bat_path, budget, reserve, 4.0, rounds, batch_reqs, max_tokens,
    );

    // --- The QoS contract ---
    // Reservations never change a token stream.
    assert_eq!(
        baseline.latency_tokens, qos.latency_tokens,
        "reservation changed the latency model's tokens"
    );
    assert_eq!(
        baseline.batch_tokens, qos.batch_tokens,
        "reservation changed the batch model's tokens"
    );
    // The reserved model keeps >= its reserved bytes resident under
    // sustained pressure; the unreserved baseline gets robbed.
    assert!(
        qos.min_latency_resident >= reserve,
        "reserved model dipped to {} B (< reserve {} B)",
        qos.min_latency_resident,
        reserve
    );
    assert!(
        baseline.min_latency_resident < lat_total,
        "baseline latency model was never robbed ({} B resident) — the bench \
         applied no pressure",
        baseline.min_latency_resident
    );
    assert_eq!(qos.shed_by_peers, 0, "peers shed a reserved-only model");
    // And the reserved model's measured fault rate is strictly lower.
    assert!(
        qos.fault_rate < baseline.fault_rate,
        "QoS fault rate {:.3} must beat the unreserved baseline's {:.3}",
        qos.fault_rate,
        baseline.fault_rate
    );

    let mut table = Table::new(
        "Reserved latency model under batch pressure (same total budget)",
        &[
            "arm",
            "latency fault rate",
            "latency tok/s",
            "min latency resident",
            "shed by peers",
        ],
    );
    table.row(&[
        "unreserved (PR 4 baseline)".into(),
        format!("{:.3}", baseline.fault_rate),
        format!("{:.1}", baseline.latency_tok_per_sec),
        fmt_bytes(baseline.min_latency_resident),
        baseline.shed_by_peers.to_string(),
    ]);
    table.row(&[
        format!("reserve {} weight 4", fmt_bytes(reserve)),
        format!("{:.3}", qos.fault_rate),
        format!("{:.1}", qos.latency_tok_per_sec),
        fmt_bytes(qos.min_latency_resident),
        qos.shed_by_peers.to_string(),
    ]);
    table.emit("qos_isolation");

    // --- Request-level arm: priority/deadline scheduling inside ONE
    // engine, preemption off vs on over the identical schedule. ---
    let batch_len = quick_or(64usize, 96);
    let submit_steps = [6usize, 12, 18, 24];
    let off = run_request_arm(false, 6, batch_len, &submit_steps);
    let on = run_request_arm(true, 6, batch_len, &submit_steps);

    // Preemption changes *when* tokens appear, never *what* they are —
    // preempted-and-resumed generations must match the FIFO arm bit for
    // bit, batch and interactive alike.
    assert_eq!(off.tokens, on.tokens, "preemption changed a token stream");
    assert!(
        on.preemptions > 0,
        "the preemption arm never preempted — the flood applied no slot pressure"
    );
    assert_eq!(off.preemptions, 0, "preemption fired while disabled");
    assert_eq!(on.expired, 0, "interactive deadline missed with preemption on");
    assert_eq!(off.expired, 0, "generous deadline expired in the FIFO arm");
    // The acceptance bar: deadline-class p99 strictly lower with
    // preemption on, batch throughput within 10% of the FIFO arm.
    assert!(
        on.interactive_p99_steps < off.interactive_p99_steps,
        "interactive p99 with preemption ({} steps) must be strictly lower than \
         without ({} steps)",
        on.interactive_p99_steps,
        off.interactive_p99_steps
    );
    let thr_ratio = on.batch_tok_per_step / off.batch_tok_per_step.max(1e-12);
    assert!(
        (thr_ratio - 1.0).abs() <= 0.10,
        "batch throughput drifted {:.1}% under preemption ({:.2} vs {:.2} tok/step)",
        (thr_ratio - 1.0).abs() * 100.0,
        on.batch_tok_per_step,
        off.batch_tok_per_step
    );

    let mut rtable = Table::new(
        "Interactive deadline class vs batch flood in one engine",
        &[
            "arm",
            "interactive p99 steps",
            "batch tok/step",
            "preemptions",
            "expired",
        ],
    );
    rtable.row(&[
        "preemption off (FIFO slot tenure)".into(),
        off.interactive_p99_steps.to_string(),
        format!("{:.2}", off.batch_tok_per_step),
        off.preemptions.to_string(),
        off.expired.to_string(),
    ]);
    rtable.row(&[
        "preemption on".into(),
        on.interactive_p99_steps.to_string(),
        format!("{:.2}", on.batch_tok_per_step),
        on.preemptions.to_string(),
        on.expired.to_string(),
    ]);
    rtable.emit("qos_request_classes");

    std::fs::remove_dir_all(&dir).ok();
    println!("\nqos_isolation bench OK");
}
