//! Tokens/sec vs **weight budget** through the LRU residency cache
//! (`entrollm::residency`) — the cost curve of serving a model whose
//! decoded weights do not fit in RAM.
//!
//! A synthetic model is compressed, written to disk, and opened
//! **lazily** ([`entrollm::store::SegmentSource::open`]), so the
//! measured path is the real deploy shape: payload on disk, decoded
//! layers under the budget, cold layers re-decoded on fault. Each
//! budget rung serves the same request batch through a digest-driven
//! engine whose every weight pass walks the cache; the table reports
//! measured tokens/sec plus the hit/miss/evict counters, then the
//! modeled Jetson-scale fault-in cost for the same residency fractions.

use entrollm::bench::{fmt_bytes, fmt_secs, quick_or};
use entrollm::coordinator::{Engine, EngineConfig, Request};
use entrollm::device::{table2_workloads, LatencyModel, JETSON_P3450};
use entrollm::metrics::Table;
use entrollm::pipeline::synthetic_layers;
use entrollm::quant::BitWidth;
use entrollm::residency::{ResidentDigestBackend, ResidentWeightSet};
use entrollm::store::{compress, SegmentSource};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let n_layers = quick_or(10usize, 24);
    let layers = synthetic_layers(n_layers, 0xFA17);
    let (elm, report) = compress(&layers, BitWidth::U8).unwrap();
    let total_decoded: usize = elm.layers.iter().map(|m| m.n_symbols).sum();
    let largest: usize = elm.layers.iter().map(|m| m.n_symbols).max().unwrap();

    let dir = std::env::temp_dir().join(format!("residency_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.elm");
    elm.save(&path).unwrap();
    println!(
        "synthetic model: {n_layers} layers | decoded {} | encoded {} | {:.3} effective bits\n",
        fmt_bytes(total_decoded),
        fmt_bytes(report.encoded_bytes),
        report.effective_bits
    );

    let mut table = Table::new(
        "Tokens/sec vs weight budget (measured, file-backed faults)",
        &["budget", "tok/s", "hits", "misses", "evictions", "peak resident", "fault time"],
    );

    // Budget rungs: whole model down to a single layer.
    let rungs: Vec<(String, usize)> = vec![
        ("model (100%)".into(), total_decoded),
        ("1/2 model".into(), largest.max(total_decoded / 2)),
        ("1/4 model".into(), largest.max(total_decoded / 4)),
        ("one layer".into(), largest),
    ];

    let mut full_budget_tps = 0.0f64;
    for (label, budget) in rungs {
        let source = Arc::new(SegmentSource::open(&path).unwrap());
        let ws = ResidentWeightSet::new(source, budget, Vec::new()).unwrap();
        let mut engine = Engine::new(
            ResidentDigestBackend::new(ws, 2, 64, 256),
            EngineConfig::default(),
        );
        for id in 0..quick_or(3u64, 8) {
            engine
                .submit(Request::greedy(id, vec![1 + id as u32, 2, 3], quick_or(6, 16)))
                .unwrap();
        }
        let t0 = Instant::now();
        let responses = engine.run_to_completion(10_000).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
        let tps = tokens as f64 / wall.max(1e-12);
        if full_budget_tps == 0.0 {
            full_budget_tps = tps;
        }
        let c = engine.residency().unwrap();
        assert!(
            c.peak_resident_bytes <= budget,
            "budget violated: {} > {budget}",
            c.peak_resident_bytes
        );
        table.row(&[
            format!("{label} ({})", fmt_bytes(budget)),
            format!("{tps:.1}"),
            c.hits.to_string(),
            c.misses.to_string(),
            c.evictions.to_string(),
            fmt_bytes(c.peak_resident_bytes),
            fmt_secs(
                engine
                    .backend()
                    .weights()
                    .cache()
                    .fault_time()
                    .as_secs_f64(),
            ),
        ]);
    }
    table.emit("residency_fault");

    // Modeled at edge scale: phi3-class model on the Jetson profile.
    let m = LatencyModel::new(JETSON_P3450);
    let (_, with) = table2_workloads(3_800_000_000, 8, 5.58, 512, 4, 1.0);
    let mut modeled = Table::new(
        "Modeled Jetson tokens/sec vs pinned residency (phi3-class, uint8)",
        &["pinned layers", "tok/s", "fault s/token"],
    );
    for pinned in [32usize, 16, 8, 1, 0] {
        modeled.row(&[
            format!("{pinned}/32"),
            format!("{:.3}", m.faulted_tokens_per_sec(&with, 32, pinned)),
            fmt_secs(m.fault_in_per_token(&with, 32, pinned)),
        ]);
    }
    modeled.emit("residency_fault_modeled");
    println!(
        "note: 'pinned' is the policy-optimal residency for a cyclic dense pass; the \
         shipped pure-LRU cache corresponds to the 0-pinned row whenever the budget \
         is below the model (see the residency module docs on scan behavior)."
    );

    println!(
        "note: full-budget serving ran at {full_budget_tps:.1} tok/s on this host; \
         budgets below the model trade tokens/sec for bounded RSS."
    );
    std::fs::remove_dir_all(&dir).ok();
}
