"""Mixed-quantization mirror: the python side must agree with the rust
source of truth (scheme rule, grid math, reconstruction bound)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.quantize import LEVELS, choose_scheme, dequantize, quantize, quantize_tree


def test_scheme_rule_matches_paper():
    assert choose_scheme(np.array([0.1, 0.9])) == "symmetric_unsigned"
    assert choose_scheme(np.array([-0.1, -0.9])) == "symmetric_unsigned"
    assert choose_scheme(np.array([-0.1, 0.9])) == "asymmetric"
    assert choose_scheme(np.array([0.0, 0.5])) == "symmetric_unsigned"


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize(
    "mean,std", [(0.0, 0.05), (0.2, 0.02), (-0.3, 0.08)]
)
def test_reconstruction_error_half_step(bits, mean, std):
    rng = np.random.default_rng(1)
    w = rng.normal(mean, std, size=4096).astype(np.float32)
    sym, qp = quantize(w, bits)
    assert sym.max() < LEVELS[bits]
    back = dequantize(sym, qp)
    bound = abs(qp.scale) / 2 + 1e-6
    assert np.max(np.abs(back - w)) <= bound


def test_all_negative_layer_negative_scale():
    w = -np.abs(np.random.default_rng(2).normal(0.2, 0.1, 256)).astype(np.float32)
    sym, qp = quantize(w, 8)
    assert qp.scheme == "symmetric_unsigned"
    assert qp.scale < 0
    back = dequantize(sym, qp)
    assert np.max(np.abs(back - w)) <= abs(qp.scale) / 2 + 1e-6


def test_asymmetric_endpoints_exact():
    w = np.array([-1.0, 0.25, 2.0], np.float32)
    sym, qp = quantize(w, 8)
    assert qp.scheme == "asymmetric"
    back = dequantize(sym, qp)
    assert abs(back[0] - -1.0) < 1e-5
    assert abs(back[2] - 2.0) < 1e-5


def test_constant_and_zero_layers():
    z, qz = quantize(np.zeros(16, np.float32), 4)
    assert (dequantize(z, qz) == 0).all()
    c, qc = quantize(np.full(16, 0.37, np.float32), 8)
    assert np.allclose(dequantize(c, qc), 0.37, atol=1e-6)


@settings(max_examples=60, deadline=None)
@given(
    bits=st.sampled_from([4, 8]),
    n=st.integers(1, 2000),
    mode=st.integers(0, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_error_bound(bits, n, mode, seed):
    rng = np.random.default_rng(seed)
    if mode == 0:
        w = rng.normal(0, 0.1, n)
    elif mode == 1:
        w = rng.uniform(0, 1, n)
    elif mode == 2:
        w = rng.uniform(-3, -0.5, n)
    else:
        w = rng.normal(0.4, 1.5, n)
    w = w.astype(np.float32)
    sym, qp = quantize(w, bits)
    assert sym.dtype == np.uint8 and sym.max() < LEVELS[bits]
    back = dequantize(sym, qp)
    assert np.max(np.abs(back - w)) <= abs(qp.scale) / 2 + 1e-5


def test_quantize_tree_splits_quant_and_f32():
    params = {
        "w": np.random.default_rng(3).normal(0, 0.1, (8, 8)).astype(np.float32),
        "ln": np.ones(8, np.float32),
    }
    out, meta = quantize_tree(params, 8, {"w"})
    assert set(meta) == {"w"}
    assert isinstance(out["w"], dict) and out["w"]["sym"].shape == (8, 8)
    assert isinstance(out["ln"], np.ndarray)
