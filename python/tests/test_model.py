"""L2 model invariants: decode-step chain == full forward, prefill
consistency, quantized-path sanity, flat-arg spec roundtrip."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    Config,
    decode_step,
    flat_from_params,
    flat_weight_spec,
    init_params,
    loss_fn,
    param_shapes,
    params_from_flat,
    prefill,
    quantized_names,
    train_forward,
)
from compile.quantize import quantize_tree

# A miniature config so tests run fast under interpret-mode Pallas.
SMALL = Config(
    vocab=32, dim=32, n_layers=2, n_heads=2, ffn=64, max_seq=24,
    prefill_len=8, decode_batch=2,
)


@pytest.fixture(scope="module")
def params():
    return init_params(SMALL, seed=3)


@pytest.fixture(scope="module")
def qparams(params):
    qp, _ = quantize_tree(
        {k: np.asarray(v) for k, v in params.items()}, 8, set(quantized_names(SMALL))
    )
    return {
        k: ({"sym": jnp.asarray(v["sym"]), "scale": v["scale"], "zp": v["zp"]}
            if isinstance(v, dict) else jnp.asarray(v))
        for k, v in qp.items()
    }


def test_param_count_formula(params):
    total = sum(int(np.prod(np.shape(v))) for v in params.values())
    assert total == SMALL.n_params()


def test_prefill_matches_full_forward(params):
    toks = np.zeros((1, SMALL.prefill_len), np.int32)
    prompt = np.array([3, 7, 11], np.int32)
    toks[0, :3] = prompt
    logits, k, v = prefill(SMALL, params, jnp.asarray(toks), jnp.int32(3))
    full = train_forward(SMALL, params, jnp.asarray(toks[:, :3]))
    np.testing.assert_allclose(
        np.asarray(logits)[0], np.asarray(full)[0, 2], rtol=1e-4, atol=1e-4
    )
    assert k.shape == (SMALL.n_layers, 1, SMALL.max_seq, SMALL.n_heads, SMALL.head_dim)


def test_decode_chain_equals_full_forward(params):
    """Greedy-decode 4 steps via the KV cache; logits at each step must
    match a from-scratch full forward over the growing sequence."""
    prompt = [5, 9, 2]
    toks = np.zeros((1, SMALL.prefill_len), np.int32)
    toks[0, : len(prompt)] = prompt
    logits, k, v = prefill(SMALL, params, jnp.asarray(toks), jnp.int32(len(prompt)))
    b = SMALL.decode_batch
    k = jnp.tile(k, (1, b, 1, 1, 1))
    v = jnp.tile(v, (1, b, 1, 1, 1))
    seq = list(prompt)
    cur = int(np.argmax(np.asarray(logits)[0]))
    pos = len(prompt)
    for _ in range(4):
        seq.append(cur)
        dl, k, v = decode_step(
            SMALL,
            params,
            jnp.full((b,), cur, jnp.int32),
            jnp.full((b,), pos, jnp.int32),
            k,
            v,
        )
        full = train_forward(SMALL, params, jnp.asarray([seq], jnp.int32))
        np.testing.assert_allclose(
            np.asarray(dl)[0], np.asarray(full)[0, -1], rtol=1e-3, atol=1e-3
        )
        cur = int(np.argmax(np.asarray(dl)[0]))
        pos += 1


def test_quant_path_close_to_f32(params, qparams):
    toks = np.zeros((1, SMALL.prefill_len), np.int32)
    toks[0, :4] = [1, 2, 3, 4]
    lf, _, _ = prefill(SMALL, params, jnp.asarray(toks), jnp.int32(4))
    lq, _, _ = prefill(SMALL, qparams, jnp.asarray(toks), jnp.int32(4))
    # uint8 quantization noise is small; rankings should broadly agree.
    cos = float(
        np.dot(np.asarray(lf)[0], np.asarray(lq)[0])
        / (np.linalg.norm(lf) * np.linalg.norm(lq))
    )
    assert cos > 0.98, f"cosine {cos}"


def test_decode_slots_are_independent(params):
    """Different tokens per slot must give different logits per slot and
    not leak across batch lanes."""
    b = SMALL.decode_batch
    k = jnp.zeros((SMALL.n_layers, b, SMALL.max_seq, SMALL.n_heads, SMALL.head_dim))
    v = jnp.zeros_like(k)
    toks = jnp.asarray(np.arange(b, dtype=np.int32))
    pos = jnp.zeros((b,), jnp.int32)
    logits, k2, _ = decode_step(SMALL, params, toks, pos, k, v)
    l = np.asarray(logits)
    assert not np.allclose(l[0], l[1])
    # Writing at pos 0 changed each slot's own cache row only.
    k2 = np.asarray(k2)
    assert not np.allclose(k2[:, 0, 0], k2[:, 1, 0])


def test_flat_spec_roundtrip(params):
    for quant in (False, True):
        if quant:
            qp, _ = quantize_tree(
                {k: np.asarray(v) for k, v in params.items()},
                8,
                set(quantized_names(SMALL)),
            )
            src = {
                k: ({"sym": jnp.asarray(v["sym"]), "scale": v["scale"], "zp": v["zp"]}
                    if isinstance(v, dict) else jnp.asarray(v))
                for k, v in qp.items()
            }
        else:
            src = params
        flat = flat_from_params(SMALL, quant, src)
        spec = flat_weight_spec(SMALL, quant)
        assert len(flat) == len(spec)
        back = params_from_flat(SMALL, quant, flat)
        assert set(back) == set(param_shapes(SMALL))


def test_loss_decreases_with_teacher_signal(params):
    """Sanity: loss on structured (repeating) data < loss on an adversarial
    constant-shift sequence for a trained... here just check finiteness
    and shape plumbing of loss_fn."""
    toks = np.tile(np.arange(8, dtype=np.int32), (2, 2))[:, : SMALL.prefill_len]
    loss = float(loss_fn(SMALL, params, jnp.asarray(toks)))
    assert np.isfinite(loss) and loss > 0
