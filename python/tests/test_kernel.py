"""L1 kernel correctness: Pallas dequant-matmul vs the pure-jnp oracle.

This is the core correctness signal for the compiled hot path —
hypothesis sweeps shapes, value ranges, and quantization schemes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dequant_matmul, int_matmul
from compile.kernels.ref import dequant_matmul_ref, int_matmul_ref


def rand(shape, rng, lo=-1.0, hi=1.0):
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


def rand_sym(shape, rng, levels):
    return jnp.asarray(rng.integers(0, levels, size=shape).astype(np.uint8))


@pytest.mark.parametrize("m,k,n", [(1, 8, 8), (4, 128, 128), (7, 33, 65), (128, 128, 512)])
@pytest.mark.parametrize("levels", [16, 256])
def test_int_matmul_matches_ref(m, k, n, levels):
    rng = np.random.default_rng(m * 1000 + n + levels)
    x = rand((m, k), rng)
    w = rand_sym((k, n), rng, levels)
    got = int_matmul(x, w)
    want = int_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize(
    "scale,zp",
    [
        (0.01, 0.0),  # symmetric-unsigned, positive scale
        (-0.02, 0.0),  # symmetric-unsigned, all-negative layer
        (0.004, -0.5),  # asymmetric
    ],
)
def test_dequant_matmul_both_schemes(scale, zp):
    rng = np.random.default_rng(42)
    x = rand((5, 64), rng)
    w = rand_sym((64, 32), rng, 256)
    got = dequant_matmul(x, w, jnp.float32(scale), jnp.float32(zp))
    want = dequant_matmul_ref(x, w, jnp.float32(scale), jnp.float32(zp))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    levels=st.sampled_from([2, 16, 256]),
    scale=st.floats(-0.125, 0.125, allow_nan=False, allow_infinity=False, width=32),
    zp=st.floats(-1.0, 1.0, allow_nan=False, allow_infinity=False, width=32),
    seed=st.integers(0, 2**31 - 1),
)
def test_dequant_matmul_hypothesis_sweep(m, k, n, levels, scale, zp, seed):
    """Property: kernel == oracle for arbitrary shapes/grids/params."""
    rng = np.random.default_rng(seed)
    x = rand((m, k), rng, -2.0, 2.0)
    w = rand_sym((k, n), rng, levels)
    got = dequant_matmul(x, w, jnp.float32(scale), jnp.float32(zp))
    want = dequant_matmul_ref(x, w, jnp.float32(scale), jnp.float32(zp))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_block_tiling_covers_ragged_edges():
    """Shapes that don't divide the default blocks still agree."""
    rng = np.random.default_rng(7)
    x = rand((130, 100), rng)
    w = rand_sym((100, 130), rng, 256)
    got = int_matmul(x, w, block_m=64, block_n=64)
    want = int_matmul_ref(x, w)
    # atol covers fp32 cancellation noise on near-zero sums (|y| ≲ 1e4
    # accumulated over K=100 terms; tiling changes summation order).
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=5e-3)


def test_zero_scale_collapses_output():
    rng = np.random.default_rng(8)
    x = rand((3, 16), rng)
    w = rand_sym((16, 8), rng, 256)
    got = dequant_matmul(x, w, jnp.float32(0.0), jnp.float32(0.0))
    np.testing.assert_allclose(np.asarray(got), np.zeros((3, 8)), atol=1e-6)
