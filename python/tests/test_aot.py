"""AOT contract tests: io specs, manifest consistency, and (when the
artifacts exist) golden-file sanity. Lowering itself is exercised by
`make artifacts`; these tests pin the *contract* the rust side reads."""

import json
import os

import pytest

from compile.aot import abstract_args, io_spec
from compile.model import TINY, flat_weight_spec, quantized_names

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_io_spec_prefill_order():
    spec = io_spec(TINY, "prefill", quant=True)
    assert spec[0]["name"] == "tokens"
    assert spec[0]["shape"] == [1, TINY.prefill_len]
    assert spec[1]["name"] == "length"
    # Weight args follow in canonical order; first is the embed triple.
    assert spec[2]["name"] == "embed.sym"
    assert spec[2]["dtype"] == "u8"
    assert spec[3]["name"] == "embed.scale"
    assert spec[4]["name"] == "embed.zp"


def test_io_spec_decode_has_kv():
    spec = io_spec(TINY, "decode", quant=False)
    names = [a["name"] for a in spec[:4]]
    assert names == ["tokens", "pos", "k_cache", "v_cache"]
    assert spec[2]["shape"] == [
        TINY.n_layers, TINY.decode_batch, TINY.max_seq, TINY.n_heads, TINY.head_dim,
    ]


def test_weight_spec_counts():
    q = flat_weight_spec(TINY, quant=True)
    f = flat_weight_spec(TINY, quant=False)
    nq = len(quantized_names(TINY))
    # Each quantized tensor contributes 3 args; fp32 tensors 1.
    assert len(q) == len(f) + 2 * nq
    assert sum(1 for a in q if a[2] == "u8") == nq


def test_abstract_args_shapes():
    spec = io_spec(TINY, "score", quant=True)
    aas = abstract_args(spec)
    assert aas[0].shape == (1, TINY.prefill_len)
    assert str(aas[0].dtype) == "int32"


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_matches_current_spec():
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    assert m["format"] == 1
    assert m["config"]["n_params"] == TINY.n_params()
    assert m["quantized_names"] == quantized_names(TINY)
    for which in ("prefill", "decode", "score"):
        for tag, quant in (("f32", False), ("quant", True)):
            got = m["executables"][f"{which}_{tag}"]["args"]
            want = io_spec(TINY, which, quant)
            assert got == want, f"{which}_{tag} arg spec drifted"
            assert os.path.exists(
                os.path.join(ART, m["executables"][f"{which}_{tag}"]["file"])
            )


@needs_artifacts
def test_golden_quality_ordering():
    """The Table I shape: ppl(f32) <= ppl(u8) << ppl(u4)-ish ordering."""
    with open(os.path.join(ART, "golden.json")) as f:
        g = json.load(f)
    p_f32 = g["variants"]["f32"]["eval_char_ppl"]
    p_u8 = g["variants"]["u8"]["eval_char_ppl"]
    p_u4 = g["variants"]["u4"]["eval_char_ppl"]
    assert p_f32 <= p_u8 * 1.01, "u8 must track f32 closely"
    assert p_u8 < p_u4, "u4 must degrade more than u8"
    assert p_f32 < 10, "trained model must beat random (ppl 128)"


@needs_artifacts
def test_golden_has_reference_logits():
    with open(os.path.join(ART, "golden.json")) as f:
        g = json.load(f)
    for tag in ("f32", "u8", "u4"):
        v = g["variants"][tag]
        assert len(v["prefill_logits_head"]) == 8
        assert len(v["decode_logits_head"]) == 8
        assert 0 <= v["prefill_argmax"] < 128
