"""Build-time compile package: L2 model, L1 kernels, AOT lowering.

Never imported at runtime — the rust binary consumes artifacts/ only.
"""
