"""L2: decoder-only transformer whose weight matmuls consume *quantized*
integer weights through the L1 Pallas kernel.

The same block code serves three roles:

* ``train_forward`` — fp32 training/eval forward over a full batch
  (used by train.py; also the fp32 ppl baseline);
* ``prefill`` — single-request prompt pass that fills a KV cache and
  returns next-token logits (AOT-lowered, B=1, fixed prompt buffer);
* ``decode_step`` — one incremental token for a fixed batch of slots
  with device-resident KV caches (AOT-lowered; the serving hot path).

Weights enter as a dict; each "linear" entry is either an fp32 array
(training / fp32 baseline artifacts) or a ``{"sym": u8, "scale": f32,
"zp": f32}`` triple (quantized artifacts), in which case the matmul runs
through ``kernels.dequant_matmul`` — the fused integer-weight kernel —
so fp32 weights are never materialized for the big matmuls.

Canonical AOT argument ordering is defined by ``flat_weight_spec`` and
recorded in artifacts/manifest.json; the rust runtime assembles its
PJRT inputs from that manifest (python never runs at serve time).
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import dequant_matmul


@dataclass(frozen=True)
class Config:
    """Model hyper-parameters (must match artifacts/manifest.json)."""

    vocab: int = 128
    dim: int = 128
    n_layers: int = 4
    n_heads: int = 4
    ffn: int = 512
    max_seq: int = 160
    prefill_len: int = 64
    decode_batch: int = 4

    @property
    def head_dim(self) -> int:
        return self.dim // self.n_heads

    def n_params(self) -> int:
        d, f = self.dim, self.ffn
        per_block = 4 * d * d + 2 * d * f + 2 * d
        return self.vocab * self.dim + self.n_layers * per_block + d


TINY = Config()


def quantized_names(cfg: Config) -> list[str]:
    """Weight tensors that get quantized: all the large 2-D matrices.

    Norms stay fp32 — they are <0.1% of parameters (the paper quantizes
    weight matrices; norm/bias storage is negligible).
    """
    names = ["embed"]
    for i in range(cfg.n_layers):
        for kind in ("wq", "wk", "wv", "wo", "w_in", "w_out"):
            names.append(f"blocks.{i}.{kind}")
    return names


def param_shapes(cfg: Config) -> dict[str, tuple[int, ...]]:
    """Canonical name → shape map (storage order)."""
    shapes: dict[str, tuple[int, ...]] = {"embed": (cfg.vocab, cfg.dim)}
    for i in range(cfg.n_layers):
        shapes[f"blocks.{i}.wq"] = (cfg.dim, cfg.dim)
        shapes[f"blocks.{i}.wk"] = (cfg.dim, cfg.dim)
        shapes[f"blocks.{i}.wv"] = (cfg.dim, cfg.dim)
        shapes[f"blocks.{i}.wo"] = (cfg.dim, cfg.dim)
        shapes[f"blocks.{i}.w_in"] = (cfg.dim, cfg.ffn)
        shapes[f"blocks.{i}.w_out"] = (cfg.ffn, cfg.dim)
        shapes[f"blocks.{i}.ln1"] = (cfg.dim,)
        shapes[f"blocks.{i}.ln2"] = (cfg.dim,)
    shapes["ln_f"] = (cfg.dim,)
    return shapes


def init_params(cfg: Config, seed: int = 0) -> dict:
    """fp32 init (scaled normal) for training."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_shapes(cfg).items():
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else cfg.dim
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) * (fan_in ** -0.5) * 0.7
            )
    return params


# ------------------------------------------------------------------ layers


def _linear(x, w):
    """Matmul against an fp32 array or a quantized triple.

    ``x``: f32[..., K]. Quantized triples route through the L1 Pallas
    kernel (fused integer matmul + affine correction).
    """
    if isinstance(w, dict):
        lead = x.shape[:-1]
        k = x.shape[-1]
        x2 = x.reshape((-1, k))
        y2 = dequant_matmul(x2, w["sym"], w["scale"], w["zp"])
        return y2.reshape(lead + (y2.shape[-1],))
    return jnp.dot(x, w)


def _table(w):
    """Materialize an embedding-style table as fp32 (cheap: V×D)."""
    if isinstance(w, dict):
        return w["sym"].astype(jnp.float32) * w["scale"] + w["zp"]
    return w


def _rmsnorm(x, g):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _pos_encoding(cfg: Config) -> jnp.ndarray:
    """Fixed sinusoidal table [max_seq, dim] (constant-folded into HLO)."""
    pos = jnp.arange(cfg.max_seq, dtype=jnp.float32)[:, None]
    i = jnp.arange(cfg.dim // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2.0 * i / cfg.dim)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def _split_heads(cfg: Config, x):
    # [..., S, D] -> [..., S, H, HD]
    return x.reshape(x.shape[:-1] + (cfg.n_heads, cfg.head_dim))


def _attn_full(cfg: Config, q, k, v):
    """Full causal attention for train/prefill. q,k,v: [B,S,H,HD]."""
    s = q.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(cfg.head_dim))
    causal = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(causal[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.reshape(out.shape[:2] + (cfg.dim,))


def _block_full(cfg: Config, params, i: int, x):
    """One transformer block over a full sequence. x: [B,S,D].

    Returns the block output plus this block's K/V (for prefill caching).
    """
    p = lambda kind: params[f"blocks.{i}.{kind}"]
    h = _rmsnorm(x, params[f"blocks.{i}.ln1"])
    q = _split_heads(cfg, _linear(h, p("wq")))
    k = _split_heads(cfg, _linear(h, p("wk")))
    v = _split_heads(cfg, _linear(h, p("wv")))
    x = x + _linear(_attn_full(cfg, q, k, v), p("wo"))
    h = _rmsnorm(x, params[f"blocks.{i}.ln2"])
    x = x + _linear(jax.nn.gelu(_linear(h, p("w_in"))), p("w_out"))
    return x, k, v


def _logits(cfg: Config, params, x):
    """Tied-embedding LM head. x: [..., D] -> [..., V]."""
    emb = _table(params["embed"])  # [V, D]
    h = _rmsnorm(x, params["ln_f"])
    return jnp.dot(h, emb.T)


# ------------------------------------------------------------- train / eval


def train_forward(cfg: Config, params, tokens):
    """Full-sequence logits for training/eval. tokens: i32[B,S] → [B,S,V]."""
    x = _table(params["embed"])[tokens] + _pos_encoding(cfg)[None, : tokens.shape[1]]
    for i in range(cfg.n_layers):
        x, _, _ = _block_full(cfg, params, i, x)
    return _logits(cfg, params, x)


def loss_fn(cfg: Config, params, tokens):
    """Next-token cross-entropy (nats). tokens: i32[B,S]."""
    logits = train_forward(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


# --------------------------------------------------------------- AOT fwds


def prefill(cfg: Config, params, tokens, length):
    """Prompt pass for one request.

    ``tokens``: i32[1, prefill_len] (prompt padded on the right),
    ``length``: i32 scalar — number of valid prompt tokens.

    Returns ``(logits f32[1, vocab], k f32[L,1,MS,H,HD], v ...)`` where
    the KV caches hold positions [0, prefill_len) (entries ≥ ``length``
    are pad garbage; the decode loop writes each generated token at
    index ``pos`` starting from ``length`` and masks reads to
    ``[0, pos]``, so garbage is overwritten before it is ever visible —
    see rust coordinator::kv).
    """
    s = tokens.shape[1]
    x = _table(params["embed"])[tokens] + _pos_encoding(cfg)[None, :s]
    ks, vs = [], []
    for i in range(cfg.n_layers):
        x, k, v = _block_full(cfg, params, i, x)
        pad = cfg.max_seq - s
        ks.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))))
        vs.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))
    # Next-token logits at the last *valid* prompt position.
    last = jax.lax.dynamic_index_in_dim(x, length - 1, axis=1, keepdims=False)
    logits = _logits(cfg, params, last)
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode_step(cfg: Config, params, tokens, pos, k_cache, v_cache):
    """One generation step for a batch of slots.

    ``tokens``: i32[B] (token just sampled per slot), ``pos``: i32[B]
    (cache index to write; the token attends to [0, pos]), caches:
    f32[L, B, MS, H, HD]. Returns ``(logits f32[B, V], k, v)``.
    """
    b = tokens.shape[0]
    pe = _pos_encoding(cfg)[pos]  # [B, D]
    x = _table(params["embed"])[tokens] + pe  # [B, D]
    x = x[:, None, :]  # [B, 1, D]
    new_k, new_v = [], []
    span = jnp.arange(cfg.max_seq)  # [MS]
    for i in range(cfg.n_layers):
        p = lambda kind: params[f"blocks.{i}.{kind}"]
        h = _rmsnorm(x, params[f"blocks.{i}.ln1"])
        q = _split_heads(cfg, _linear(h, p("wq")))  # [B,1,H,HD]
        k1 = _split_heads(cfg, _linear(h, p("wk")))[:, 0]  # [B,H,HD]
        v1 = _split_heads(cfg, _linear(h, p("wv")))[:, 0]
        # Scatter this step's K/V into the caches at per-slot positions.
        onehot = (span[None, :] == pos[:, None]).astype(jnp.float32)  # [B,MS]
        k = k_cache[i] * (1.0 - onehot)[..., None, None] + onehot[..., None, None] * k1[:, None]
        v = v_cache[i] * (1.0 - onehot)[..., None, None] + onehot[..., None, None] * v1[:, None]
        # Attend over [0, pos].
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(cfg.head_dim))
        valid = (span[None, :] <= pos[:, None])[:, None, None, :]  # [B,1,1,MS]
        scores = jnp.where(valid, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, 1, cfg.dim)
        x = x + _linear(att, p("wo"))
        h = _rmsnorm(x, params[f"blocks.{i}.ln2"])
        x = x + _linear(jax.nn.gelu(_linear(h, p("w_in"))), p("w_out"))
        new_k.append(k)
        new_v.append(v)
    logits = _logits(cfg, params, x[:, 0])
    return logits, jnp.stack(new_k), jnp.stack(new_v)


# ------------------------------------------------- flat AOT argument spec


def flat_weight_spec(cfg: Config, quant: bool) -> list[tuple[str, tuple[int, ...], str]]:
    """Canonical flat weight-argument list: (name, shape, dtype).

    This ordering IS the PJRT calling convention; it is serialized into
    artifacts/manifest.json and consumed by rust runtime::artifacts.
    """
    qnames = set(quantized_names(cfg)) if quant else set()
    spec = []
    for name, shape in param_shapes(cfg).items():
        if name in qnames:
            spec.append((f"{name}.sym", shape, "u8"))
            spec.append((f"{name}.scale", (), "f32"))
            spec.append((f"{name}.zp", (), "f32"))
        else:
            spec.append((name, shape, "f32"))
    return spec


def params_from_flat(cfg: Config, quant: bool, flat: list) -> dict:
    """Rebuild the params dict from flat AOT arguments."""
    qnames = set(quantized_names(cfg)) if quant else set()
    params = {}
    it = iter(flat)
    for name in param_shapes(cfg):
        if name in qnames:
            params[name] = {"sym": next(it), "scale": next(it), "zp": next(it)}
        else:
            params[name] = next(it)
    rest = list(it)
    assert not rest, f"{len(rest)} unconsumed flat args"
    return params


def flat_from_params(cfg: Config, quant: bool, params: dict) -> list:
    """Flatten a params dict into the canonical AOT argument order."""
    qnames = set(quantized_names(cfg)) if quant else set()
    flat = []
    for name in param_shapes(cfg):
        if name in qnames:
            w = params[name]
            flat += [w["sym"], jnp.float32(w["scale"]), jnp.float32(w["zp"])]
        else:
            flat.append(params[name])
    return flat
