"""L1 Pallas kernel: fused integer-weight matmul (the EntroLLM hot spot).

The paper's decode phase streams quantized weights from memory and
dequantizes on the fly before the matmul (on the Jetson this was CUDA
pack/unpack kernels; §IV-D). On TPU-shaped hardware the analogous design
is a Pallas kernel whose *only* HBM traffic for weights is the uint8
symbol tile: the tile is cast and multiplied inside VMEM, so fp32 weights
never exist in HBM (DESIGN.md §Hardware-Adaptation).

Decomposition used here::

    x @ (W_sym * s + z)  ==  s * (x @ W_sym) + z * rowsum(x)

so the kernel proper is the integer-weight matmul ``x @ W_sym`` — the
bandwidth-critical part — and the affine correction is two cheap jnp ops
applied outside (they fuse into the surrounding HLO).

All ``pallas_call``s use ``interpret=True``: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute, and correctness /
AOT artifacts in this repo are CPU-hosted (see DESIGN.md).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM tile sizes. On a real TPU these target the MXU's 128×128
# systolic array; under interpret=True they only shape the emitted loop
# nest. K is kept whole per tile (weights stream K-major, one pass).
BLOCK_M = 128
BLOCK_N = 128


def _int_matmul_kernel(x_ref, w_ref, o_ref):
    """One (BLOCK_M, BLOCK_N) output tile: cast the u8 weight tile in
    VMEM and hit the MXU with an f32 matmul."""
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


def int_matmul(x, w_sym, *, block_m: int = BLOCK_M, block_n: int = BLOCK_N):
    """``x @ w_sym`` with ``x: f32[M, K]``, ``w_sym: u8[K, N]`` → f32[M, N].

    The weight tile is the only non-f32 input: this is the kernel the
    effective-bits saving acts on (fewer bytes per weight ⇒ fewer HBM
    bytes per output tile).
    """
    m, k = x.shape
    k2, n = w_sym.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = min(block_m, m)
    bn = min(block_n, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        _int_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w_sym)


@partial(jax.named_call, name="dequant_matmul")
def dequant_matmul(x, w_sym, scale, zero_point):
    """``x @ dequant(w_sym)`` where ``dequant(w) = w * scale + zero_point``.

    * ``x``: f32[M, K] activations.
    * ``w_sym``: u8[K, N] quantization symbols (uint8 levels, or uint4
      levels stored one-per-byte).
    * ``scale``/``zero_point``: scalars (f32) — the layer's (s, z) from
      the mixed quantization scheme (paper eq. 1/2; z = 0 for the
      symmetric-unsigned branch).

    Uses the affine decomposition so the Pallas kernel touches only the
    integer tile; the correction terms fuse into neighboring HLO ops.
    """
    mm = int_matmul(x, w_sym)
    rowsum = jnp.sum(x, axis=-1, keepdims=True)
    return scale * mm + zero_point * rowsum
