"""Pure-jnp oracles for the Pallas kernels.

pytest checks every kernel against these references over swept shapes
(see python/tests/test_kernel.py); this is the core L1 correctness
signal called out in DESIGN.md §Testing.
"""

import jax.numpy as jnp


def int_matmul_ref(x, w_sym):
    """Reference for kernels.dequant_matmul.int_matmul."""
    return jnp.dot(x, w_sym.astype(jnp.float32))


def dequant_ref(w_sym, scale, zero_point):
    """Reference dequantization: ``w * s + z`` (both schemes — z = 0 for
    symmetric-unsigned; matches rust quant::QuantParams::dequant_one)."""
    return w_sym.astype(jnp.float32) * scale + zero_point


def dequant_matmul_ref(x, w_sym, scale, zero_point):
    """Reference for kernels.dequant_matmul.dequant_matmul: materialize
    the fp32 weights, then matmul."""
    return jnp.dot(x, dequant_ref(w_sym, scale, zero_point))
