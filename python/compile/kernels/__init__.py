"""L1 Pallas kernels for the EntroLLM compute hot-spot.

``dequant_matmul`` is the production kernel (fused integer-weight matmul
with affine dequantization); ``ref`` holds the pure-jnp oracles used by
pytest.
"""

from .dequant_matmul import dequant_matmul, int_matmul  # noqa: F401
