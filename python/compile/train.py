"""Build-time training of the tiny char-LM (DESIGN.md §Substitutions #1).

The paper evaluates pre-trained 1.7–7 B checkpoints that are unavailable
offline; instead we *train* a small decoder-only LM on a seeded
synthetic corpus so the Table I quality rows (fp32 vs uint8 vs uint4
perplexity) are measured on a model that has actually learned its data
distribution — quantization-robustness claims are meaningless on random
weights.

Outputs (under ``--out``, default ``../artifacts``):

* ``weights.bin``  — trained fp32 weights, ETW1 format (rust loads this)
* ``eval.txt``     — held-out corpus slice for perplexity evaluation
* ``train_log.json`` — loss curve + final train/val loss (EXPERIMENTS.md)

Runs once from ``make artifacts``; never at serve time.
"""

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np

from .model import TINY, Config, init_params, loss_fn, param_shapes

# ----------------------------------------------------------------- corpus

WORDS = [
    "the", "model", "edge", "device", "weight", "memory", "bandwidth", "token",
    "layer", "quantized", "entropy", "huffman", "decode", "encode", "parallel",
    "thread", "cache", "inference", "latency", "storage", "compression",
    "symbol", "stream", "segment", "tensor", "matrix", "vector", "scale",
    "zero", "point", "bits", "fast", "small", "large", "runs", "loads",
    "stores", "maps", "reduces", "achieves", "requires", "and", "of", "on",
    "with", "for", "to", "a", "in", "is",
]


def make_corpus(n_chars: int, seed: int) -> str:
    """Order-1 Markov word chain — same flavor as rust corpus::MarkovCorpus
    (Zipf-ish skew, fully seeded). Exact cross-language equality is not
    required; both sides just need a learnable, stable distribution."""
    rng = np.random.default_rng(seed)
    n = len(WORDS)
    trans = rng.random((n, n)).astype(np.float64) * 0.05
    for i in range(n):
        for _ in range(4):
            trans[i, rng.integers(n)] += rng.random() * 2.0
    trans /= trans.sum(axis=1, keepdims=True)
    out, state, i = [], 0, 0
    total = 0
    while total < n_chars:
        w = WORDS[state]
        out.append(w)
        total += len(w) + 1
        state = int(rng.choice(n, p=trans[state]))
        i += 1
        if i % 12 == 0:
            out[-1] += "."
    return " ".join(out)[:n_chars]


def tokenize(text: str) -> np.ndarray:
    """Byte-level tokenizer (mirror of rust corpus::ByteTokenizer)."""
    b = np.frombuffer(text.encode(), dtype=np.uint8).copy()
    b[b >= 128] = ord("?")
    return b.astype(np.int32)


# ------------------------------------------------------------------ adam


def adam_init(params):
    zeros = lambda: {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros(), "v": zeros(), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    new_m, new_v, new_p = {}, {}, {}
    for k in params:
        m = b1 * state["m"][k] + (1 - b1) * grads[k]
        v = b2 * state["v"][k] + (1 - b2) * grads[k] ** 2
        mhat = m / (1 - b1**t)
        vhat = v / (1 - b2**t)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        new_m[k], new_v[k] = m, v
    return new_p, {"m": new_m, "v": new_v, "t": t}


# ------------------------------------------------------------ ETW1 format


def save_weights_bin(path: str, params: dict, order: list[str]) -> None:
    """ETW1: magic | u32 count | per tensor: u16 name_len, name, u8 rank,
    u64 dims..., f32 row-major data. Loaded by rust runtime::weights."""
    with open(path, "wb") as f:
        f.write(b"ETW1")
        f.write(struct.pack("<I", len(order)))
        for name in order:
            w = np.asarray(params[name], dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", w.ndim))
            for d in w.shape:
                f.write(struct.pack("<Q", d))
            f.write(w.tobytes())


# ------------------------------------------------------------------ main


def batches(tokens: np.ndarray, batch: int, seq: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    hi = len(tokens) - seq - 1
    for _ in range(steps):
        idx = rng.integers(0, hi, size=batch)
        yield np.stack([tokens[i : i + seq + 1] for i in idx])


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=int(os.environ.get("ENTROLLM_TRAIN_STEPS", 400)))
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--force", action="store_true", help="retrain even if weights exist")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    done = all(
        os.path.exists(os.path.join(args.out, f))
        for f in ("weights.bin", "eval.txt", "train_log.json")
    )
    if done and not args.force:
        print("weights.bin/eval.txt already present — skipping training (use --force)")
        return

    cfg: Config = TINY
    seq = cfg.prefill_len
    text = make_corpus(220_000, seed=args.seed + 1)
    toks = tokenize(text)
    split = int(len(toks) * 0.9)
    train_toks, val_toks = toks[:split], toks[split:]

    params = init_params(cfg, seed=args.seed)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt_m, opt_v, opt_t, batch, lr):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
        new_p, new_s = adam_update(params, grads, {"m": opt_m, "v": opt_v, "t": opt_t}, lr)
        return loss, new_p, new_s["m"], new_s["v"], new_s["t"]

    @jax.jit
    def val_loss_fn(params, batch):
        return loss_fn(cfg, params, batch)

    print(f"training tiny LM: {cfg.n_params():,} params, {args.steps} steps")
    t0 = time.time()
    log = []
    m, v, t = opt["m"], opt["v"], opt["t"]
    for i, b in enumerate(batches(train_toks, args.batch, seq, args.steps, args.seed + 2)):
        # Cosine decay with a short warmup.
        warm = min(1.0, (i + 1) / 40)
        lr = args.lr * warm * 0.5 * (1 + np.cos(np.pi * i / max(1, args.steps)))
        loss, params, m, v, t = step(params, m, v, t, jnp.asarray(b), lr)
        if i % 50 == 0 or i == args.steps - 1:
            log.append({"step": i, "loss": float(loss), "lr": float(lr)})
            print(f"  step {i:4d} loss {float(loss):.4f} lr {lr:.2e}")

    # Validation loss on fixed windows.
    vrng = np.random.default_rng(args.seed + 3)
    vidx = vrng.integers(0, len(val_toks) - seq - 1, size=32)
    vbatch = np.stack([val_toks[j : j + seq + 1] for j in vidx])
    vloss = float(val_loss_fn(params, jnp.asarray(vbatch)))
    ppl_char = float(np.exp(vloss))
    print(f"val loss {vloss:.4f} (char-ppl {ppl_char:.2f}) in {time.time()-t0:.1f}s")

    order = list(param_shapes(cfg).keys())
    save_weights_bin(os.path.join(args.out, "weights.bin"), params, order)
    with open(os.path.join(args.out, "eval.txt"), "w") as f:
        f.write(text[split:])
    with open(os.path.join(args.out, "train_log.json"), "w") as f:
        json.dump(
            {
                "steps": args.steps,
                "final_train_loss": log[-1]["loss"] if log else None,
                "val_loss_nats": vloss,
                "val_char_ppl": ppl_char,
                "curve": log,
                "n_params": cfg.n_params(),
            },
            f,
            indent=2,
        )
    print(f"wrote weights.bin / eval.txt / train_log.json to {args.out}")


if __name__ == "__main__":
    main()
