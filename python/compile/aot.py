"""AOT lowering: JAX → HLO **text** artifacts for the rust runtime.

Emits, per model variant:

* ``prefill_f32.hlo.txt`` / ``decode_f32.hlo.txt``   — fp32 baseline
* ``prefill_quant.hlo.txt`` / ``decode_quant.hlo.txt`` — quantized path
  (u8 symbol buffers + per-layer scale/zero-point; the SAME executables
  serve uint8 and uint4 ELM models — uint4 symbols are u8 values < 16)

plus ``manifest.json`` (the PJRT calling convention: exact argument
name/shape/dtype order per executable) and ``golden.json`` (reference
outputs the rust integration tests assert against).

HLO *text* — not ``HloModuleProto.serialize()`` — is the interchange
format: jax ≥ 0.5 emits protos with 64-bit instruction ids that
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import (
    TINY,
    Config,
    decode_step,
    flat_from_params,
    flat_weight_spec,
    param_shapes,
    params_from_flat,
    prefill,
    quantized_names,
    train_forward,
)
from .quantize import quantize_tree

# ------------------------------------------------------------- hlo lowering


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    rust side unwraps a single tuple output)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def load_weights_bin(path: str) -> dict:
    """Read the ETW1 container written by train.py."""
    import struct

    with open(path, "rb") as f:
        assert f.read(4) == b"ETW1", "bad weights.bin magic"
        (count,) = struct.unpack("<I", f.read(4))
        params = {}
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            (rank,) = struct.unpack("<B", f.read(1))
            dims = [struct.unpack("<Q", f.read(8))[0] for _ in range(rank)]
            n = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(4 * n), dtype=np.float32).reshape(dims)
            params[name] = jnp.asarray(data)
    return params


# ----------------------------------------------------------- spec plumbing


def io_spec(cfg: Config, which: str, quant: bool) -> list[dict]:
    """Argument list (name/shape/dtype, in order) for one executable."""
    l, b, ms = cfg.n_layers, cfg.decode_batch, cfg.max_seq
    h, hd = cfg.n_heads, cfg.head_dim
    if which == "prefill":
        args = [
            {"name": "tokens", "shape": [1, cfg.prefill_len], "dtype": "i32"},
            {"name": "length", "shape": [], "dtype": "i32"},
        ]
    elif which == "score":
        # Teacher-forced scoring for perplexity eval: full logits over a
        # fixed window (rust pipeline::eval_ppl, Table I quality rows).
        args = [
            {"name": "tokens", "shape": [1, cfg.prefill_len], "dtype": "i32"},
        ]
    elif which == "decode":
        args = [
            {"name": "tokens", "shape": [b], "dtype": "i32"},
            {"name": "pos", "shape": [b], "dtype": "i32"},
            {"name": "k_cache", "shape": [l, b, ms, h, hd], "dtype": "f32"},
            {"name": "v_cache", "shape": [l, b, ms, h, hd], "dtype": "f32"},
        ]
    else:
        raise ValueError(which)
    for name, shape, dtype in flat_weight_spec(cfg, quant):
        args.append({"name": name, "shape": list(shape), "dtype": dtype})
    return args


def abstract_args(spec: list[dict]):
    dt = {"i32": jnp.int32, "f32": jnp.float32, "u8": jnp.uint8}
    return [jax.ShapeDtypeStruct(tuple(a["shape"]), dt[a["dtype"]]) for a in spec]


def lower_variant(cfg: Config, which: str, quant: bool) -> str:
    """Lower one executable to HLO text."""
    n_fixed = {"prefill": 2, "score": 1, "decode": 4}[which]

    def fn(*args):
        fixed, flat = args[:n_fixed], list(args[n_fixed:])
        params = params_from_flat(cfg, quant, flat)
        if which == "prefill":
            tokens, length = fixed
            out = prefill(cfg, params, tokens, length)
        elif which == "score":
            (tokens,) = fixed
            out = (train_forward(cfg, params, tokens),)
        else:
            tokens, pos, k, v = fixed
            out = decode_step(cfg, params, tokens, pos, k, v)
        return tuple(out)

    spec = io_spec(cfg, which, quant)
    lowered = jax.jit(fn).lower(*abstract_args(spec))
    return to_hlo_text(lowered)


# -------------------------------------------------------------- golden data


def golden_outputs(cfg: Config, params_f32: dict, out_dir: str) -> dict:
    """Reference outputs for the rust integration tests + the python side
    of Table I quality rows."""
    qnames = quantized_names(cfg)
    variants = {"f32": params_f32}
    qmeta = {}
    for bits, tag in ((8, "u8"), (4, "u4")):
        qp, meta = quantize_tree(
            {k: np.asarray(v) for k, v in params_f32.items()}, bits, set(qnames)
        )
        variants[tag] = {
            k: ({"sym": jnp.asarray(v["sym"]), "scale": v["scale"], "zp": v["zp"]}
                if isinstance(v, dict) else jnp.asarray(v))
            for k, v in qp.items()
        }
        qmeta[tag] = {
            name: {"scheme": m.scheme, "scale": m.scale, "zero_point": m.zero_point}
            for name, m in meta.items()
        }

    # Fixed prompt: "the model runs on the edge" byte tokens, padded.
    prompt = "the model runs on the edge "
    ptoks = np.frombuffer(prompt.encode(), np.uint8).astype(np.int32)
    length = len(ptoks)
    tokens = np.zeros((1, cfg.prefill_len), np.int32)
    tokens[0, :length] = ptoks

    # Held-out eval windows for perplexity (same data rust eval-ppl uses).
    with open(os.path.join(out_dir, "eval.txt")) as f:
        eval_text = f.read()
    ev = np.frombuffer(eval_text.encode(), np.uint8).copy()
    ev[ev >= 128] = ord("?")
    ev = ev.astype(np.int32)
    n_win, seq = 16, cfg.prefill_len
    windows = np.stack(
        [ev[i * seq : i * seq + seq + 1] for i in range(n_win)]
    )

    golden = {
        "prompt": prompt,
        "prompt_tokens": ptoks.tolist(),
        "prefill_length": length,
        "eval_windows": n_win,
        "variants": {},
        "quant_meta": qmeta,
    }
    for tag, params in variants.items():
        quant = tag != "f32"
        logits, k, v = prefill(cfg, params, jnp.asarray(tokens), jnp.int32(length))
        logits = np.asarray(logits)[0]
        # One decode step from the prefill state (slot 0 of a padded batch).
        b = cfg.decode_batch
        kb = jnp.tile(k, (1, b, 1, 1, 1))
        vb = jnp.tile(v, (1, b, 1, 1, 1))
        ntok = int(np.argmax(logits))
        dtoks = jnp.full((b,), ntok, jnp.int32)
        dpos = jnp.full((b,), length, jnp.int32)
        dlogits, _, _ = decode_step(cfg, params, dtoks, dpos, kb, vb)
        dlogits = np.asarray(dlogits)[0]

        # Perplexity over eval windows (full forward, teacher-forced).
        logp = jax.nn.log_softmax(
            train_forward(cfg, params, jnp.asarray(windows[:, :-1])), axis=-1
        )
        ll = jnp.take_along_axis(logp, jnp.asarray(windows[:, 1:])[..., None], -1)
        nll = float(-jnp.mean(ll))
        golden["variants"][tag] = {
            "prefill_logits_head": [float(x) for x in logits[:8]],
            "prefill_argmax": int(np.argmax(logits)),
            "decode_logits_head": [float(x) for x in dlogits[:8]],
            "decode_argmax": int(np.argmax(dlogits)),
            "eval_nll_nats": nll,
            "eval_char_ppl": float(np.exp(nll)),
        }
        print(
            f"  golden[{tag}]: prefill argmax {golden['variants'][tag]['prefill_argmax']}"
            f" eval ppl {golden['variants'][tag]['eval_char_ppl']:.3f}"
        )
    return golden


# -------------------------------------------------------------------- main


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    cfg: Config = TINY

    params = load_weights_bin(os.path.join(args.out, "weights.bin"))
    assert set(params) == set(param_shapes(cfg)), "weights.bin/model mismatch"

    executables = {}
    for which in ("prefill", "decode", "score"):
        for quant, tag in ((False, "f32"), (True, "quant")):
            name = f"{which}_{tag}"
            print(f"lowering {name} ...")
            hlo = lower_variant(cfg, which, quant)
            fname = f"{name}.hlo.txt"
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(hlo)
            executables[name] = {
                "file": fname,
                "args": io_spec(cfg, which, quant),
                "outputs": (
                    ["logits"] if which == "score" else ["logits", "k_cache", "v_cache"]
                ),
            }
            print(f"  wrote {fname} ({len(hlo)//1024} KiB)")

    print("computing golden outputs ...")
    golden = golden_outputs(cfg, params, args.out)
    with open(os.path.join(args.out, "golden.json"), "w") as f:
        json.dump(golden, f, indent=2)

    manifest = {
        "format": 1,
        "config": {
            "vocab": cfg.vocab,
            "dim": cfg.dim,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim,
            "ffn": cfg.ffn,
            "max_seq": cfg.max_seq,
            "prefill_len": cfg.prefill_len,
            "decode_batch": cfg.decode_batch,
            "n_params": cfg.n_params(),
        },
        "quantized_names": quantized_names(cfg),
        "weights": "weights.bin",
        "eval_text": "eval.txt",
        "golden": "golden.json",
        "executables": executables,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json to {args.out}")


if __name__ == "__main__":
    main()
