"""Python mirror of the rust mixed-quantization scheme (paper §III-A).

The rust implementation (``rust/src/quant.rs``) is the source of truth
for serving; this mirror exists so the AOT path can produce quantized
weight buffers for golden-output generation and so pytest can check the
two implementations agree bit-for-bit (test_quantize.py fixtures are
regenerated against rust via the integration test in rust/tests/).

Scheme selection (Algorithm 1 line 5): a layer whose weights are
single-signed (``max * min >= 0``) takes symmetric-unsigned quantization
(eq. 1); a layer straddling zero takes asymmetric (eq. 2). Dequantization
is uniformly ``w = sym * scale + zero_point`` (zero_point = 0 for the
symmetric branch; scale may be negative for all-negative layers).
"""

from dataclasses import dataclass

import numpy as np

LEVELS = {4: 16, 8: 256}


@dataclass(frozen=True)
class QuantParams:
    """Per-layer grid parameters (mirror of rust quant::QuantParams)."""

    scheme: str  # "symmetric_unsigned" | "asymmetric"
    bits: int  # 4 | 8
    scale: float
    zero_point: float


def choose_scheme(w: np.ndarray) -> str:
    """Paper's rule: single-signed layers go symmetric-unsigned."""
    if w.size == 0 or float(w.max()) * float(w.min()) >= 0.0:
        return "symmetric_unsigned"
    return "asymmetric"


def quantize(w: np.ndarray, bits: int, scheme: str | None = None):
    """Quantize one layer. Returns (symbols u8 ndarray, QuantParams)."""
    levels = LEVELS[bits]
    w = np.asarray(w, dtype=np.float32)
    if scheme is None:
        scheme = choose_scheme(w)
    if w.size == 0:
        mn = mx = 0.0
    else:
        mn = float(w.min())
        mx = float(w.max())
    if scheme == "symmetric_unsigned":
        extreme = mx if abs(mx) >= abs(mn) else mn
        scale = 1.0 if extreme == 0.0 else extreme / (levels - 1)
        zero_point = 0.0
        q = np.rint(w / np.float32(scale))
    elif scheme == "asymmetric":
        zero_point = mn
        rng = mx - mn
        scale = 1.0 if rng == 0.0 else rng / (levels - 1)
        q = np.rint((w - np.float32(zero_point)) / np.float32(scale))
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    sym = np.clip(q, 0, levels - 1).astype(np.uint8)
    return sym, QuantParams(scheme, bits, float(np.float32(scale)), float(np.float32(zero_point)))


def dequantize(sym: np.ndarray, params: QuantParams) -> np.ndarray:
    """Uniform inverse: ``sym * scale + zero_point`` as f32."""
    return (
        sym.astype(np.float32) * np.float32(params.scale)
        + np.float32(params.zero_point)
    )


def quantize_tree(params: dict, bits: int, quantize_names) -> tuple[dict, dict]:
    """Quantize the fp32 weight dict of the L2 model.

    Returns ``(qparams, meta)`` where ``qparams`` replaces each array
    named in ``quantize_names`` by a dict ``{"sym", "scale", "zp"}`` and
    leaves the rest (norms etc.) fp32; ``meta`` maps name → QuantParams.
    """
    out, meta = {}, {}
    for name, w in params.items():
        if name in quantize_names:
            sym, qp = quantize(np.asarray(w), bits)
            out[name] = {"sym": sym, "scale": qp.scale, "zp": qp.zero_point}
            meta[name] = qp
        else:
            out[name] = np.asarray(w, dtype=np.float32)
    return out, meta
