//! Compress the *trained* tiny-LM artifacts into ELM containers and
//! print the Table I storage rows (requires `make artifacts`).
//!
//! This is the paper's "cloud processing" path on real learned weights:
//! effective bits land well below the fixed quantized width because
//! trained weight distributions are near-Gaussian (paper Fig. 4 / [27]).

use entrollm::bench::fmt_bytes;
use entrollm::entropy::{distribution_stats, Histogram};
use entrollm::huffman::FreqTable;
use entrollm::pipeline::build_elm;
use entrollm::quant::BitWidth;
use entrollm::store::decode_layer;

fn main() -> entrollm::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("compressing trained weights from {artifacts}/weights.bin\n");

    for bits in [BitWidth::U8, BitWidth::U4] {
        let (model, report) = build_elm(&artifacts, bits)?;
        let out = format!("model_{bits}.elm");
        model.save(&out)?;

        println!("=== {bits} → {out} ===");
        println!("  parameters      : {}", report.n_params);
        println!("  fp16 baseline   : {}", fmt_bytes(report.fp16_bytes));
        println!(
            "  fixed {}     : {} ({}x vs fp16)",
            bits,
            fmt_bytes(report.fixed_bytes),
            report.fp16_bytes / report.fixed_bytes.max(1)
        );
        println!("  huffman payload : {}", fmt_bytes(report.encoded_bytes));
        println!("  entropy         : {:.3} bits/param", report.entropy_bits);
        println!("  effective bits  : {:.3} bits/param", report.effective_bits);
        println!(
            "  storage saving  : {:.1}% vs fixed {}",
            100.0 * (1.0 - report.effective_bits / bits.bits() as f64),
            bits
        );
        let sym = report
            .schemes
            .iter()
            .filter(|(_, s)| *s == entrollm::quant::Scheme::SymmetricUnsigned)
            .count();
        println!(
            "  layer schemes   : {sym} symmetric-unsigned, {} asymmetric",
            report.schemes.len() - sym
        );

        // Fig. 4 companion: pooled symbol histogram + moments.
        let mut freq = FreqTable::new();
        for i in 0..model.layers.len() {
            freq.add_symbols(decode_layer(&model, i)?.symbols.data());
        }
        let s = distribution_stats(&freq)?;
        println!(
            "  distribution    : mean {:.1} std {:.1} skew {:+.2} kurtosis {:+.2}",
            s.mean, s.std, s.skewness, s.kurtosis
        );
        println!("{}", Histogram::from_freq(&freq, bits.levels()).to_ascii(48, 16));
    }
    Ok(())
}
