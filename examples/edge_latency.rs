//! Table II driver: latency breakdown for a phi3-scale model on the
//! Jetson P3450 cost model, with the Huffman decode throughput and
//! load-balance factor *measured* from the real rust decoder.
//!
//! The paper's testbed (a physical Jetson) is unavailable; DESIGN.md
//! §Substitutions explains the split between measured quantities
//! (decoder throughput, effective bits, imbalance) and modeled ones
//! (DRAM streaming at 25.6 GB/s). The *shape* to reproduce: token-gen
//! speedups ≈1.3× (uint8) and ≈2.5× (uint4), decode amortized to
//! negligible, first-token slightly worse with Huffman.

use entrollm::bench::fmt_secs;
use entrollm::decode::{ParallelDecoder, Strategy};
use entrollm::device::{table2_workloads, LatencyModel, JETSON_P3450};
use entrollm::pipeline::build_elm;
use entrollm::quant::BitWidth;

/// phi3-mini-shaped segment byte sizes at a given effective bit width:
/// 32 decoder layers (fused qkv, o, gate_up, down) + embedding. Used to
/// evaluate the §III-C scheduler over the *real* tensor structure of
/// the paper's subject model without materializing 3.8 B weights.
fn phi3_segment_bytes(eff_bits: f64) -> Vec<usize> {
    let d = 3072usize;
    let mut sizes = vec![32_064 * d]; // embedding
    for _ in 0..32 {
        sizes.push(d * 9216); // fused qkv
        sizes.push(d * d); // o_proj
        sizes.push(d * 16_384); // gate_up
        sizes.push(8192 * d); // down
    }
    sizes
        .into_iter()
        .map(|n| (n as f64 * eff_bits / 8.0) as usize)
        .collect()
}

const PHI3_PARAMS: usize = 3_800_000_000;
const PREFILL_TOKENS: usize = 512;
const THREADS: usize = 4;

fn main() -> entrollm::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let have_artifacts = std::path::Path::new(&artifacts).join("weights.bin").exists();

    println!("=== Table II: latency breakdown (Jetson P3450 cost model) ===\n");
    for bits in [BitWidth::U8, BitWidth::U4] {
        // The workload is phi3-scale, so the effective bit width is the
        // paper's measured property of phi3's weight distribution; the
        // *scheduler* inputs (imbalance over phi3's segment structure)
        // and the decoder-throughput sanity check come from our code.
        let eff_bits = if bits == BitWidth::U8 { 5.58 } else { 1.39 };
        let imbalance = Strategy::Shuffled { seed: 0x5EED }
            .imbalance_for_sizes(&phi3_segment_bytes(eff_bits), THREADS);
        let measured_rate = if have_artifacts {
            let (model, report) = build_elm(&artifacts, bits)?;
            let (_, stats) = ParallelDecoder::new(THREADS)
                .with_strategy(Strategy::Shuffled { seed: 0x5EED })
                .decode_model(&model)?;
            println!(
                "(tiny-LM measured: effective bits {:.2}, decode {:.1} Msym/s on this host)",
                report.effective_bits,
                stats.symbols_per_sec() / 1e6
            );
            Some(stats.symbols_per_sec())
        } else {
            None
        };

        let model = LatencyModel::new(JETSON_P3450);
        let (without, with) = table2_workloads(
            PHI3_PARAMS,
            bits.bits(),
            eff_bits,
            PREFILL_TOKENS,
            THREADS,
            imbalance,
        );
        let bw = model.breakdown(&without);
        let bh = model.breakdown(&with);

        println!("--- {bits} (phi3 effective bits {eff_bits}, scheduler imbalance {imbalance:.3}) ---");
        let _ = measured_rate;
        println!("  {:<22}{:>14}{:>14}", "phase", "w/o huffman", "w/ huffman");
        println!(
            "  {:<22}{:>14}{:>14}   ({:+.1}%)",
            "pre-fill",
            fmt_secs(bw.prefill.total),
            fmt_secs(bh.prefill.total),
            100.0 * (1.0 - bh.prefill.total / bw.prefill.total)
        );
        println!(
            "  {:<22}{:>14}{:>14}   ({:.2}x)",
            "token generation",
            fmt_secs(bw.token_gen.total),
            fmt_secs(bh.token_gen.total),
            bw.token_gen.total / bh.token_gen.total
        );
        println!(
            "  {:<22}{:>14}{:>14}",
            "parallel decoding",
            "-",
            fmt_secs(bh.parallel_decode)
        );
        println!(
            "  {:<22}{:>14}{:>14}",
            "first token latency",
            fmt_secs(bw.first_token),
            fmt_secs(bh.first_token)
        );
        // §IV-D accounting: theoretical vs achieved speedup.
        let theory = bits.bits() as f64 / eff_bits;
        let achieved = bw.token_gen.total / bh.token_gen.total;
        println!(
            "  theoretical speedup {:.2}x vs achieved {:.2}x (gap = unpack overhead)\n",
            theory, achieved
        );
    }
    println!("paper reference (phi3-mini, Jetson P3450):");
    println!("  uint8: prefill 27.10→23.17s, token 0.083→0.063s (1.32x), decode 6.66s");
    println!("  uint4: prefill  9.69→ 8.34s, token 0.062→0.025s (2.47x), decode 1.66s");
    Ok(())
}
