//! Quickstart: the EntroLLM pipeline on synthetic weights, no artifacts
//! needed — run with `cargo run --release --example quickstart`.
//!
//! Walks Algorithm 1 end to end:
//! 1. make some "trained" layers (Gaussian weights, like Fig. 4 assumes),
//! 2. mixed-quantize + Huffman-encode into an ELM container (cloud side),
//! 3. parallel-decode it back on T threads (edge side),
//! 4. verify losslessness and print the storage accounting.

use entrollm::bench::fmt_bytes;
use entrollm::decode::ParallelDecoder;
use entrollm::quant::{dequantize, quantize_mixed, BitWidth};
use entrollm::rng::Rng;
use entrollm::store::{compress, ElmModel};
use entrollm::tensor::TensorF32;

fn main() -> entrollm::Result<()> {
    // 1. Synthetic model: a few transformer-shaped layers. Real flows
    //    load trained weights (see examples/compress_model.rs).
    let mut rng = Rng::new(42);
    let mut layers = Vec::new();
    for i in 0..6 {
        let (rows, cols) = if i % 3 == 2 { (256, 1024) } else { (256, 256) };
        let n = rows * cols;
        // Mix single-signed and zero-straddling layers so both branches
        // of the mixed scheme (§III-A) get exercised.
        let data = if i % 4 == 3 {
            (0..n).map(|_| rng.range_f32(0.0, 0.1)).collect()
        } else {
            rng.gaussian_vec(n, 0.0, 0.04)
        };
        layers.push((
            format!("blocks.{i}.w"),
            TensorF32::new(vec![rows, cols], data)?,
        ));
    }
    let n_params: usize = layers.iter().map(|(_, t)| t.numel()).sum();
    println!("synthetic model: {} layers, {n_params} params", layers.len());

    for bits in [BitWidth::U8, BitWidth::U4] {
        // 2. Cloud side: mixed quantization + model-global Huffman code.
        let (model, report) = compress(&layers, bits)?;
        println!("\n=== {bits} ===");
        println!("  fp16 baseline   : {}", fmt_bytes(report.fp16_bytes));
        println!("  fixed-width     : {}", fmt_bytes(report.fixed_bytes));
        println!("  huffman payload : {}", fmt_bytes(report.encoded_bytes));
        println!(
            "  effective bits  : {:.3} (entropy {:.3})",
            report.effective_bits, report.entropy_bits
        );

        // Round-trip through disk like a real deployment.
        let path = std::env::temp_dir().join(format!("quickstart_{bits}.elm"));
        model.save(&path)?;
        let loaded = ElmModel::load(&path)?;

        // 3. Edge side: parallel Huffman decode (§III-C).
        let (decoded, stats) = ParallelDecoder::new(4).decode_model(&loaded)?;
        println!(
            "  parallel decode : {:.2} ms on {} threads ({:.1} Msym/s)",
            stats.wall.as_secs_f64() * 1e3,
            stats.threads.len(),
            stats.symbols_per_sec() / 1e6
        );

        // 4. Lossless check: decoded symbols == direct quantization, and
        //    dequantized weights within half a quantization step.
        for ((name, w), q) in layers.iter().zip(&decoded) {
            let direct = quantize_mixed(w, bits);
            assert_eq!(q.symbols.data(), direct.symbols.data(), "{name}");
            let dq = dequantize(q);
            let bound = entrollm::quant::max_error_bound(&q.params);
            for (a, b) in w.data().iter().zip(dq.data()) {
                assert!((a - b).abs() <= bound);
            }
        }
        println!("  losslessness    : verified on all layers");
        std::fs::remove_file(&path).ok();
    }
    println!("\nquickstart OK");
    Ok(())
}
