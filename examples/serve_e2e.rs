//! End-to-end serving driver (the repo's headline validation run):
//! load the trained tiny-LM, compress to uint8 ELM, **parallel-decode**
//! it, bring up the TCP server on the real PJRT engine, fire a batch of
//! concurrent clients, and report latency/throughput. Results are
//! recorded in EXPERIMENTS.md §E2E.
//!
//! The PJRT client is not `Send`, so the engine runs on the main thread
//! and the load-generating clients run on spawned threads.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example serve_e2e [artifacts_dir] [n_requests]`

use entrollm::bench::fmt_secs;
use entrollm::coordinator::{Engine, EngineConfig};
use entrollm::corpus::MarkovCorpus;
use entrollm::pipeline::{load_backend, Flavor};
use entrollm::server::{serve, Client};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> entrollm::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let n_requests: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let max_tokens = 24;

    // --- edge bring-up: ELM decode + PJRT load ---
    let t0 = Instant::now();
    let (backend, decode_stats) = load_backend(&artifacts, Flavor::U8, 4)?;
    let bringup = t0.elapsed();
    if let Some(s) = &decode_stats {
        println!(
            "parallel huffman decode: {} symbols in {} ({:.1} Msym/s, imbalance {:.2})",
            s.total_symbols(),
            fmt_secs(s.wall.as_secs_f64()),
            s.symbols_per_sec() / 1e6,
            s.symbol_imbalance()
        );
    }
    println!(
        "engine bring-up (decode + compile + upload): {}",
        fmt_secs(bringup.as_secs_f64())
    );

    // --- clients on spawned threads; engine serves on this thread ---
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let stop = Arc::new(AtomicBool::new(false));

    let mut corpus = MarkovCorpus::new(0xE2E);
    let prompts = corpus.prompts(n_requests, 6);
    let t1 = Instant::now();
    let client_threads: Vec<_> = prompts
        .into_iter()
        .enumerate()
        .map(|(i, prompt)| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                let t = Instant::now();
                let reply = c.request(&prompt, max_tokens, 0.0).expect("request");
                let wall = t.elapsed();
                let text = reply.get("text").unwrap().as_str().unwrap().to_string();
                let tokens = reply.get("tokens").unwrap().as_usize().unwrap();
                (i, wall, tokens, text)
            })
        })
        .collect();

    // Watcher joins the clients, then stops the server loop.
    let stop_w = stop.clone();
    let watcher = std::thread::spawn(move || {
        let mut total_tokens = 0usize;
        let mut latencies = Vec::new();
        let mut samples = Vec::new();
        for t in client_threads {
            let (i, wall, tokens, text) = t.join().expect("client");
            total_tokens += tokens;
            latencies.push(wall);
            if i < 3 {
                samples.push((i, wall, tokens, text));
            }
        }
        // Give the engine a beat to settle, then stop it.
        std::thread::sleep(Duration::from_millis(20));
        stop_w.store(true, Ordering::Relaxed);
        (total_tokens, latencies, samples)
    });

    let mut engine = Engine::new(backend, EngineConfig::default());
    let served = serve(&mut engine, listener, stop.clone())?;
    let (total_tokens, mut latencies, samples) = watcher.join().expect("watcher");
    let wall = t1.elapsed();

    for (i, lat, tokens, text) in &samples {
        println!(
            "  [{i}] {tokens} tok in {}: {:?}",
            fmt_secs(lat.as_secs_f64()),
            text
        );
    }
    let stats = engine.stats();
    latencies.sort_unstable();
    println!("\n=== serve_e2e summary (uint8, {n_requests} concurrent requests) ===");
    println!("  served           : {served} requests, {total_tokens} tokens");
    println!("  wallclock        : {}", fmt_secs(wall.as_secs_f64()));
    println!(
        "  throughput       : {:.1} tok/s, {:.2} req/s",
        total_tokens as f64 / wall.as_secs_f64(),
        served as f64 / wall.as_secs_f64()
    );
    println!(
        "  client latency   : p50 {} p95 {} max {}",
        fmt_secs(latencies[latencies.len() / 2].as_secs_f64()),
        fmt_secs(latencies[latencies.len() * 95 / 100].as_secs_f64()),
        fmt_secs(latencies.last().unwrap().as_secs_f64()),
    );
    println!(
        "  engine           : {} decode steps, occupancy {:.2} slots",
        stats.decode_steps,
        stats.mean_occupancy(),
    );
    println!("  engine prefill   : {}", stats.prefill_lat.summary());
    println!("  engine decode    : {}", stats.decode_lat.summary());
    let q = engine.queue_stats();
    println!("  queue            : admitted {} rejected {}", q.admitted, q.rejected);
    assert_eq!(served as usize, n_requests, "all requests must complete");
    println!("\nserve_e2e OK");
    Ok(())
}
