//! `entrollm` — the L3 coordinator CLI.
//!
//! ```text
//! entrollm compress   --artifacts DIR --bits u8|u4 --out model.elm
//!                     [--synthetic N --seed S]   (no artifacts needed)
//!                     [--tile-kb K]   (ELM v2 tile granularity, 0 = auto)
//!                     [--codec huffman|ans|auto]   (per-layer entropy codec)
//! entrollm inspect    --model model.elm [--histogram]
//! entrollm decompress --model model.elm --out weights.eqw [--threads N]
//!                     [--stream --prefetch-layers K]
//! entrollm decode-bench --model model.elm --threads N [--repeat R]
//! entrollm eval-ppl   --artifacts DIR --flavor f32|u8|u4 [--windows N]
//! entrollm generate   --artifacts DIR --flavor u8 --prompt "..." [--max-tokens N]
//!                     [--stream --prefetch-layers K [--elm model.elm]]
//!                     [--weight-budget-mb M [--elm model.elm | --synthetic N]
//!                      [--decode-ahead N [--prefetch-workers W]]]
//! entrollm serve      --artifacts DIR --flavor u8 --port 7433 [--threads T]
//!                     [--stream --prefetch-layers K [--elm model.elm]]
//!                     [--weight-budget-mb M [--elm model.elm | --synthetic N]
//!                      [--decode-ahead N [--prefetch-workers W]]]
//! entrollm serve      --elm a.elm --elm b.elm
//!                     | --model name=path[,reserve-mb=N][,weight=W] [--model ...]
//!                     [--port 7433] [--weight-budget-mb M]
//!                     [--decode-ahead N] [--prefetch-workers W]
//!                     [--speculate draft=NAME,target=NAME,k=K]
//! entrollm latency    [--params 3.8e9] [--prefill-tokens 512]
//!                     [--layers L --prefetch-layers K]
//! ```
//!
//! `--weight-budget-mb` (fractional MiB allowed) serves through the
//! weight-residency cache: decoded layers stay under the budget and
//! cold layers are re-decoded on demand — no PJRT artifacts required
//! (generation is digest-driven). `--decode-ahead N` overlaps those
//! re-decodes with token compute: a worker pool decodes the next `N`
//! layers of the walk while the current one is consumed, under a
//! scan-resistant (segmented LRU) replacement policy. `{"stats":true}`
//! on the serve port reports the cache's hit/miss/evict counters plus
//! the `prefetch_*` counters when decode-ahead is on.
//!
//! Passing several containers (repeated `--elm`, or named `--model
//! name=path` pairs) serves them all from one port: requests route by
//! an optional `"model"` field, every model's cache draws on the
//! **shared** `--weight-budget-mb` (a hot model steals residency from
//! a cold one), one worker pool decodes ahead for all of them, and
//! `{"stats":true}` grows a per-model `models` array plus `ledger_*`
//! fields. Per-model QoS rides on the `--model` value: `--model
//! name=path,reserve-mb=N,weight=W` guarantees the model `N` MiB of
//! residency that peers can never reclaim, and lets a higher `weight`
//! shed hotter lower-weight peers; startup rejects reserves that sum
//! past the budget. `--speculate draft=NAME,target=NAME,k=K` pairs two
//! hosted models for speculative decoding: the draft proposes `k`
//! greedy tokens per step, the target verifies them in one batched
//! pass — the target's streams stay bit-identical to target-only
//! decode. See `docs/SERVING.md`.

use entrollm::bench::{fmt_bytes, fmt_secs};
use entrollm::cli::Args;
use entrollm::coordinator::{Engine, EngineConfig, PjrtBackend, Request};
use entrollm::corpus::ByteTokenizer;
use entrollm::decode::{ParallelDecoder, StreamingDecoder};
use entrollm::device::{table2_workloads, LatencyModel, JETSON_P3450};
use entrollm::entropy::{distribution_stats, Histogram};
use entrollm::huffman::FreqTable;
use entrollm::codec::Codec;
use entrollm::pipeline::{build_elm_with, load_backend, Flavor};
use entrollm::quant::BitWidth;
use entrollm::store::{CodecChoice, ElmModel};
use entrollm::{Error, Result};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "compress" => cmd_compress(args),
        "inspect" => cmd_inspect(args),
        "decompress" => cmd_decompress(args),
        "decode-bench" => cmd_decode_bench(args),
        "eval-ppl" => cmd_eval_ppl(args),
        "generate" => cmd_generate(args),
        "serve" => cmd_serve(args),
        "latency" => cmd_latency(args),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(Error::InvalidArg(format!(
            "unknown command {other:?} (try `entrollm help`)"
        ))),
    }
}

const HELP: &str = r#"entrollm — entropy-encoded weight compression for edge LLM inference

commands:
  compress      quantize (mixed scheme) + entropy-encode -> .elm container
                (--synthetic N builds a seeded synthetic model, no artifacts;
                --tile-kb K writes independently decodable tiles of K KiB
                decoded symbols each — 0/default auto-sizes ~4-8 per layer;
                --codec huffman|ans|auto picks the entropy coder per layer:
                huffman = canonical Huffman (default, v2-compatible),
                ans = tabled asymmetric numeral system (tANS, writes v3),
                auto = measure both per layer and keep the smaller)
  inspect       print an .elm container's manifest and symbol statistics
  decompress    decode an .elm container back to raw quantized weights
                (--stream decodes layer-ahead with a bounded prefetch
                window, reading the payload lazily from disk)
  decode-bench  measure parallel entropy-decode throughput
  eval-ppl      held-out perplexity via the AOT score executable
  generate      one-shot generation through the serving engine
                (--stream loads weights via the streaming decoder;
                --weight-budget-mb serves through the residency cache;
                --decode-ahead N prefetches the next N layers on a
                worker pool while the current one is consumed)
  serve         TCP serving (line-protocol JSON); --stream as above;
                --weight-budget-mb M [--elm F | --synthetic N] serves a
                model larger than the budget via the residency cache,
                no artifacts needed; --decode-ahead N overlaps fault-in
                with token compute; repeated --elm (or --model
                name=path[,reserve-mb=N][,weight=W]) serves several
                models from one port behind one shared budget + decode
                pool, routed by the request's "model" field —
                reserve-mb guarantees a model residency peers can never
                reclaim, weight sets shed aggressiveness; front-door
                tuning: --io-shards N event-loop threads (thread count
                is O(shards), not O(connections)), --max-conn-buffered-kb
                K caps each connection's reply queue (non-reading
                clients are shed at the cap), --drain-timeout-ms T
                bounds the graceful drain at shutdown; request QoS:
                requests may carry "priority" (-8..8, higher first) and
                "deadline_ms" (queued requests past it are answered
                with {"error":...,"expired":true}); --preemption on|off
                lets a higher-class arrival checkpoint the lowest-class
                running generation and resume it bit-identically later
                (default on), --aging-ms N promotes a waiting request
                one class per N ms so low classes never starve (0
                disables, default 1000; a deadline also stops an
                already-running generation at the next engine step,
                answering with the generated prefix); on a multi-model
                host the admin line {"reserve":{model:mb}} re-tunes
                residency reservations live under startup's validation,
                and --speculate draft=NAME,target=NAME,k=K turns on
                speculative decoding between two hosted models (draft
                proposes k greedy tokens/step, target verifies in one
                batched pass; bit-identical to target-only decode;
                spec_* fields join the stats line)
  latency       Table II-style latency model for an edge profile,
                including streaming (layer-ahead) first-token estimates
                and residency fault-in costs (serial and decode-ahead
                overlapped)
"#;

/// Convert the CLI's `--tile-kb` (KiB of decoded symbols per ELM v2
/// tile; fractional allowed so sub-KiB test models can exercise
/// multi-tile layers; 0 = auto-size ~4–8 tiles per layer) into the
/// compressor's per-tile symbol count.
fn tile_symbols_from_kb(kb: f64) -> Result<Option<usize>> {
    if !kb.is_finite() || kb < 0.0 {
        return Err(Error::InvalidArg(format!(
            "--tile-kb must be a non-negative finite number (0 = auto), got {kb}"
        )));
    }
    if kb == 0.0 {
        return Ok(None);
    }
    Ok(Some(((kb * 1024.0) as usize).max(1)))
}

/// Parse the `--codec` flag into the compressor's per-layer choice.
fn codec_choice_from_flag(raw: &str) -> Result<CodecChoice> {
    match raw {
        "huffman" => Ok(CodecChoice::Huffman),
        "ans" | "tans" => Ok(CodecChoice::Ans),
        "auto" => Ok(CodecChoice::Auto),
        other => Err(Error::InvalidArg(format!(
            "--codec must be huffman, ans, or auto, got {other:?}"
        ))),
    }
}

/// Human summary of which entropy coders a container's layers use.
fn codec_summary(layers: &[entrollm::store::LayerMeta]) -> String {
    let n_ans = layers.iter().filter(|m| m.codec == Codec::Ans).count();
    if n_ans == 0 {
        Codec::Huffman.name().to_string()
    } else if n_ans == layers.len() {
        Codec::Ans.name().to_string()
    } else {
        format!("mixed: {} huffman / {n_ans} tans", layers.len() - n_ans)
    }
}

fn cmd_compress(args: &Args) -> Result<()> {
    let bits = BitWidth::parse(args.opt("bits", "u8"))?;
    let default_out = format!("model_{bits}.elm");
    let out = args.opt("out", &default_out);
    let synthetic: usize = args.opt_parse("synthetic", 0usize)?;
    let tile_kb: f64 = args.opt_parse("tile-kb", 0.0f64)?;
    let tile_symbols = tile_symbols_from_kb(tile_kb)?;
    let choice = codec_choice_from_flag(args.opt("codec", "huffman"))?;
    let (model, report) = if synthetic > 0 {
        let seed: u64 = args.opt_parse("seed", 0x5EED_u64)?;
        let layers = entrollm::pipeline::synthetic_layers(synthetic, seed);
        println!("synthetic model: {synthetic} layers (seed {seed:#x})");
        entrollm::store::compress_with_options(&layers, bits, tile_symbols, choice)?
    } else {
        build_elm_with(args.opt("artifacts", "artifacts"), bits, tile_symbols, choice)?
    };
    model.save(out)?;
    println!("wrote {out}");
    let n_tiles: usize = model.layers.iter().map(|m| m.tiles.len()).sum();
    println!(
        "  tiles           : {n_tiles} across {} layers (independently decodable)",
        model.layers.len()
    );
    println!("  parameters      : {}", report.n_params);
    println!("  fp16 baseline   : {}", fmt_bytes(report.fp16_bytes));
    println!("  fixed {}    : {}", bits, fmt_bytes(report.fixed_bytes));
    println!(
        "  encoded payload : {} ({})",
        fmt_bytes(report.encoded_bytes),
        codec_summary(&model.layers)
    );
    println!("  entropy         : {:.3} bits/param", report.entropy_bits);
    println!("  effective bits  : {:.3} bits/param", report.effective_bits);
    let sym = report
        .schemes
        .iter()
        .filter(|(_, s)| *s == entrollm::quant::Scheme::SymmetricUnsigned)
        .count();
    println!(
        "  schemes         : {sym} symmetric-unsigned / {} asymmetric",
        report.schemes.len() - sym
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let model = ElmModel::load(args.req("model")?)?;
    println!("ELM container: {} layers, {}", model.layers.len(), model.bits);
    println!("  payload        : {}", fmt_bytes(model.payload.len()));
    println!(
        "  codecs         : {}{}",
        codec_summary(&model.layers),
        if model.ans.is_some() {
            " (tANS table present)"
        } else {
            ""
        }
    );
    println!("  parameters     : {}", model.n_params());
    println!("  effective bits : {:.3}", model.effective_bits());
    if model.layers.is_empty() {
        // Zero-layer containers are legal (see docs/FORMAT.md); there
        // are no symbols to run statistics over.
        println!("  (empty weight set: no symbols to analyze)");
        return Ok(());
    }
    let mut freq = FreqTable::new();
    for i in 0..model.layers.len() {
        let q = entrollm::store::decode_layer(&model, i)?;
        freq.add_symbols(q.symbols.data());
    }
    let stats = distribution_stats(&freq)?;
    println!(
        "  symbol stats   : H={:.3}b eff={:.3}b mean={:.2} std={:.2} skew={:.3} kurt={:.3}",
        stats.entropy, stats.effective_bits, stats.mean, stats.std, stats.skewness, stats.kurtosis
    );
    if args.has("histogram") {
        let levels = model.bits.levels();
        println!("{}", Histogram::from_freq(&freq, levels).to_ascii(60, 16));
    }
    for m in model.layers.iter().take(8) {
        println!(
            "  layer {:<24} {} {:?} s={:+.5} z={:+.5} {} -> {} ({} tiles, {})",
            m.name,
            m.shape,
            m.params.scheme,
            m.params.scale,
            m.params.zero_point,
            fmt_bytes(m.n_symbols * if model.bits == BitWidth::U8 { 1 } else { 1 } / 1),
            fmt_bytes(m.encoded_len),
            m.tiles.len(),
            m.codec.name(),
        );
    }
    if model.layers.len() > 8 {
        println!("  ... {} more layers", model.layers.len() - 8);
    }
    Ok(())
}

/// Decode a container back to its raw quantized weights and write them
/// as an `EQW1` file: `magic | u8 bitwidth | u32 n_layers | per layer:
/// u16 name_len, name, u8 rank, rank × u64 dims, u8 scheme, f32 scale,
/// f32 zp, u64 n_symbols, symbol bytes`. The output is a deterministic
/// function of the container, so any two decode paths (serial,
/// parallel, streaming) must produce byte-identical files.
fn cmd_decompress(args: &Args) -> Result<()> {
    let path = args.req("model")?;
    let out = args.req("out")?;
    let threads: usize = args.opt_parse("threads", 4)?;

    use std::io::Write as _;
    fn write_layer<W: std::io::Write>(
        w: &mut W,
        meta: &entrollm::store::LayerMeta,
        q: &entrollm::quant::QuantizedTensor,
    ) -> Result<()> {
        w.write_all(&(meta.name.len() as u16).to_le_bytes())?;
        w.write_all(meta.name.as_bytes())?;
        w.write_all(&[meta.shape.rank() as u8])?;
        for &d in meta.shape.dims() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        w.write_all(&[q.params.scheme.tag()])?;
        w.write_all(&q.params.scale.to_le_bytes())?;
        w.write_all(&q.params.zero_point.to_le_bytes())?;
        w.write_all(&(q.symbols.numel() as u64).to_le_bytes())?;
        w.write_all(q.symbols.data())?;
        Ok(())
    }

    // Open/validate the container BEFORE touching the output path, so a
    // bad --model never truncates an existing --out file.
    enum Opened {
        /// Lazy: only header + manifest resident; workers read each
        /// segment from disk when the prefetch window admits it, and
        /// each layer is written the moment it decodes — peak RSS is
        /// O(prefetch window), not O(model).
        Lazy(std::sync::Arc<entrollm::store::SegmentSource>),
        Eager(ElmModel),
    }
    let opened = if args.has("stream") {
        Opened::Lazy(std::sync::Arc::new(entrollm::store::SegmentSource::open(
            path,
        )?))
    } else {
        Opened::Eager(ElmModel::load(path)?)
    };

    let file = std::fs::File::create(out)?;
    let mut w = std::io::BufWriter::new(file);
    w.write_all(b"EQW1")?;
    // Bit width first (after magic): without it a reader cannot tell u4
    // symbols (values 0..16, one per byte) from narrow-range u8 symbols.

    let (n_layers, n_params) = match opened {
        Opened::Lazy(source) => {
            w.write_all(&[source.bits().bits() as u8])?;
            w.write_all(&(source.n_layers() as u32).to_le_bytes())?;
            let prefetch: usize = args.opt_parse("prefetch-layers", 4)?;
            let mut stream = StreamingDecoder::new(threads, prefetch)
                .stream_source(std::sync::Arc::clone(&source))?;
            while let Some(layer) = stream.next_layer() {
                let layer = layer?;
                write_layer(&mut w, source.meta(layer.index), &layer.tensor)?;
            }
            let stats = stream.into_stats();
            println!(
                "streaming decode: first layer after {} | total {} | window <= {} layers \
                 (payload read lazily from disk)",
                fmt_secs(stats.time_to_first_layer.as_secs_f64()),
                fmt_secs(stats.wall.as_secs_f64()),
                stats.max_layers_ahead,
            );
            (source.n_layers(), source.n_params())
        }
        Opened::Eager(model) => {
            w.write_all(&[model.bits.bits() as u8])?;
            w.write_all(&(model.layers.len() as u32).to_le_bytes())?;
            let (tensors, stats) = ParallelDecoder::new(threads).decode_model(&model)?;
            println!(
                "parallel decode: {} in {} ({:.1} Msym/s)",
                stats.total_symbols(),
                fmt_secs(stats.wall.as_secs_f64()),
                stats.symbols_per_sec() / 1e6,
            );
            for (meta, q) in model.layers.iter().zip(&tensors) {
                write_layer(&mut w, meta, q)?;
            }
            (model.layers.len(), model.n_params())
        }
    };
    w.flush()?;
    println!("decoded {n_layers} layers / {n_params} symbols (all segments CRC-clean) -> {out}");
    Ok(())
}

fn cmd_decode_bench(args: &Args) -> Result<()> {
    let model = ElmModel::load(args.req("model")?)?;
    let threads: usize = args.opt_parse("threads", 4)?;
    let repeat: usize = args.opt_parse("repeat", 3)?;
    println!(
        "parallel decode: {} params, {} encoded, {threads} threads",
        model.n_params(),
        fmt_bytes(model.payload.len())
    );
    for r in 0..repeat {
        let (_, stats) = ParallelDecoder::new(threads).decode_model(&model)?;
        println!(
            "  run {r}: wall {} | {:.1} Msym/s | imbalance {:.3} (symbols {:.3})",
            fmt_secs(stats.wall.as_secs_f64()),
            stats.symbols_per_sec() / 1e6,
            stats.imbalance(),
            stats.symbol_imbalance(),
        );
    }
    Ok(())
}

fn cmd_eval_ppl(args: &Args) -> Result<()> {
    let artifacts = args.opt("artifacts", "artifacts");
    let flavor = Flavor::parse(args.opt("flavor", "u8"))?;
    let windows: usize = args.opt_parse("windows", 16)?;
    let threads: usize = args.opt_parse("threads", 4)?;
    let (nll, ppl) = entrollm::pipeline::eval_ppl(artifacts, flavor, threads, windows)?;
    println!(
        "{}: nll {nll:.4} nats/char | char-ppl {ppl:.4} ({windows} windows)",
        flavor.tag()
    );
    Ok(())
}

/// Resolve the serving backend for `generate`/`serve`: eager by
/// default; `--stream` (with optional `--elm PATH` and
/// `--prefetch-layers N`) loads through the streaming decoder.
/// Prints how the weights arrived either way.
fn load_serving_backend(
    args: &Args,
    artifacts: &str,
    flavor: Flavor,
    threads: usize,
) -> Result<PjrtBackend> {
    if args.has("stream") {
        let prefetch: usize = args.opt_parse("prefetch-layers", 4)?;
        let (backend, stats) = match args.flags.get("elm") {
            Some(elm) => {
                entrollm::pipeline::load_backend_streaming(artifacts, elm, threads, prefetch)?
            }
            None => entrollm::pipeline::load_backend_streaming_from_artifacts(
                artifacts, flavor, threads, prefetch,
            )?,
        };
        println!(
            "streaming decode: {} symbols | first layer {} | total {} | prefetch {} \
             (runtime upload follows the full set)",
            stats.total_symbols(),
            fmt_secs(stats.time_to_first_layer.as_secs_f64()),
            fmt_secs(stats.wall.as_secs_f64()),
            stats.prefetch_layers,
        );
        Ok(backend)
    } else {
        let (backend, decode_stats) = load_backend(artifacts, flavor, threads)?;
        if let Some(s) = &decode_stats {
            println!(
                "parallel decode: {} in {} ({:.1} Msym/s)",
                s.total_symbols(),
                fmt_secs(s.wall.as_secs_f64()),
                s.symbols_per_sec() / 1e6
            );
        }
        Ok(backend)
    }
}

/// Does this invocation ask for the weight-residency serving path?
/// Any of these flags implies it: a budget means "cache-serve this
/// model", `--decode-ahead` prefetches through that cache, and
/// `--synthetic` (for generate/serve) has no artifacts to run PJRT on.
fn wants_residency(args: &Args) -> bool {
    args.flags.contains_key("weight-budget-mb")
        || args.flags.contains_key("decode-ahead")
        || args.flags.contains_key("synthetic")
}

/// The two residency-serving backends `generate`/`serve` can run:
/// fault-on-demand (PR 2), or decode-ahead prefetching.
enum ResidentServing {
    Plain(entrollm::residency::ResidentDigestBackend),
    Prefetching(entrollm::residency::PrefetchingDigestBackend),
}

/// Build the residency-cache serving backend from CLI flags: an `.elm`
/// file opened lazily, or a freshly compressed synthetic model —
/// decode-ahead prefetching when `--decode-ahead N` is present.
fn resident_serving(args: &Args) -> Result<ResidentServing> {
    // The residency path is digest-driven and never touches PJRT
    // artifacts; refuse combinations that would silently pretend
    // otherwise instead of serving pseudo-tokens behind the user's back.
    for conflicting in ["artifacts", "flavor"] {
        if args.flags.contains_key(conflicting) {
            return Err(Error::InvalidArg(format!(
                "--{conflicting} cannot be combined with --weight-budget-mb/--synthetic \
                 serving: the weight-residency path uses a digest-driven backend and \
                 ignores PJRT artifacts; drop --{conflicting} or the residency flags"
            )));
        }
    }
    if args.has("stream") {
        return Err(Error::InvalidArg(
            "--stream is the PJRT streaming-load path; the residency path \
             (--weight-budget-mb/--synthetic) already reads segments lazily — drop one"
                .into(),
        ));
    }
    if args.flags.contains_key("elm") && args.flags.contains_key("synthetic") {
        return Err(Error::InvalidArg(
            "--elm and --synthetic both name a model to serve — pass exactly one".into(),
        ));
    }
    let mb: f64 = args.opt_parse("weight-budget-mb", 64.0f64)?;
    let budget = entrollm::pipeline::weight_budget_bytes(mb)?;
    // Digest serving shape: byte-level vocab so prompts/replies are text.
    let (batch, max_seq, vocab) = (2usize, 64usize, 256usize);
    let elm = args.flags.get("elm").map(|s| s.as_str());
    let synthetic: usize = args.opt_parse("synthetic", 12usize)?;
    let seed: u64 = args.opt_parse("seed", 0x5EED_u64)?;
    let bits = BitWidth::parse(args.opt("bits", "u8"))?;
    if elm.is_none() {
        println!("synthetic model: {synthetic} layers (seed {seed:#x})");
    }
    let source = entrollm::pipeline::residency_source(elm, synthetic, seed, bits)?;
    println!(
        "weight-residency cache: budget {} | {} layers / {} decoded bytes total \
         (digest-driven serving; PJRT artifacts not used)",
        fmt_bytes(budget),
        source.n_layers(),
        fmt_bytes(source.n_params()),
    );
    let decode_ahead: usize = args.opt_parse("decode-ahead", 0usize)?;
    if decode_ahead == 0 {
        return Ok(ResidentServing::Plain(
            entrollm::pipeline::resident_digest_backend(source, budget, batch, max_seq, vocab)?,
        ));
    }
    let workers: usize = args.opt_parse("prefetch-workers", 2usize)?;
    let cfg = entrollm::residency::PrefetchConfig {
        decode_ahead,
        // One worker at least; more pool threads than cores never
        // helps, so cap a fat-fingered value instead of spawning it.
        workers: workers.clamp(1, 32),
        policy: entrollm::residency::Policy::SegmentedLru,
    };
    let backend = entrollm::pipeline::prefetching_digest_backend(
        source, budget, cfg, batch, max_seq, vocab,
    )?;
    println!(
        "decode-ahead prefetch: window {} layers | {} workers | scan-resistant \
         (segmented LRU) policy",
        backend.weights().decode_ahead(),
        backend.weights().workers(),
    );
    Ok(ResidentServing::Prefetching(backend))
}

fn generate_with<B: entrollm::coordinator::Backend>(
    backend: B,
    prompt: &str,
    max_tokens: usize,
    temperature: f32,
) -> Result<()> {
    let mut engine = Engine::new(backend, EngineConfig::default());
    let tok = ByteTokenizer;
    let mut req = Request::greedy(1, tok.encode(prompt), max_tokens);
    req.temperature = temperature;
    engine.submit(req)?;
    let responses = engine.run_to_completion(10_000)?;
    for r in &responses {
        println!("--- response {} ({:?}) ---", r.id, r.finish_reason);
        println!("{}{}", prompt, tok.decode(&r.tokens));
        println!(
            "first token {} | {} tokens | decode {}",
            fmt_secs(r.timing.first_token.as_secs_f64()),
            r.tokens.len(),
            fmt_secs(r.timing.decode.as_secs_f64()),
        );
    }
    if let Some(c) = engine.residency() {
        println!(
            "cache: {} hits / {} misses / {} evictions | peak {} of {} budget",
            c.hits,
            c.misses,
            c.evictions,
            fmt_bytes(c.peak_resident_bytes),
            fmt_bytes(c.budget_bytes),
        );
    }
    if let Some(p) = engine.prefetch() {
        println!(
            "prefetch: {} scheduled / {} completed / {} hits / {} waits / {} sync faults",
            p.scheduled, p.completed, p.hits, p.waits, p.sync_faults,
        );
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let prompt = args.req("prompt")?.to_string();
    let max_tokens: usize = args.opt_parse("max-tokens", 48)?;
    let temperature: f32 = args.opt_parse("temperature", 0.0f32)?;
    if wants_residency(args) {
        return match resident_serving(args)? {
            ResidentServing::Plain(b) => generate_with(b, &prompt, max_tokens, temperature),
            ResidentServing::Prefetching(b) => {
                generate_with(b, &prompt, max_tokens, temperature)
            }
        };
    }
    let artifacts = args.opt("artifacts", "artifacts");
    let flavor = Flavor::parse(args.opt("flavor", "u8"))?;
    let threads: usize = args.opt_parse("threads", 4)?;
    let backend = load_serving_backend(args, artifacts, flavor, threads)?;
    generate_with(backend, &prompt, max_tokens, temperature)
}

/// Front-door tuning shared by single- and multi-model serving:
/// `--io-shards N` (event-loop shard threads), `--max-conn-buffered-kb K`
/// (per-connection reply-queue byte cap; a client that stops reading is
/// shed at this bound), `--drain-timeout-ms T` (graceful-drain budget at
/// shutdown).
fn serve_config(args: &Args) -> Result<entrollm::server::ServeConfig> {
    let defaults = entrollm::server::ServeConfig::default();
    let io_shards: usize = args.opt_parse("io-shards", defaults.io_shards)?;
    let buffered_kb: f64 = args.opt_parse(
        "max-conn-buffered-kb",
        defaults.max_conn_buffered_bytes as f64 / 1024.0,
    )?;
    if !buffered_kb.is_finite() || buffered_kb <= 0.0 {
        return Err(Error::InvalidArg(format!(
            "--max-conn-buffered-kb must be a positive finite number, got {buffered_kb}"
        )));
    }
    let drain_ms: u64 =
        args.opt_parse("drain-timeout-ms", defaults.drain_timeout.as_millis() as u64)?;
    Ok(entrollm::server::ServeConfig {
        io_shards,
        max_conn_buffered_bytes: ((buffered_kb * 1024.0) as usize).max(1),
        drain_timeout: std::time::Duration::from_millis(drain_ms),
        ..defaults
    })
}

/// Request-QoS tuning shared by single- and multi-model serving:
/// `--preemption on|off` (a higher-class arrival may checkpoint and
/// requeue the lowest-class in-flight generation; default on) and
/// `--aging-ms N` (a queued request gains one effective priority step
/// per N ms waited, so low classes never starve; 0 disables).
fn engine_config(args: &Args) -> Result<EngineConfig> {
    let defaults = EngineConfig::default();
    let preemption = match args.opt("preemption", "on") {
        "on" => true,
        "off" => false,
        other => {
            return Err(Error::InvalidArg(format!(
                "--preemption must be on or off, got {other:?}"
            )))
        }
    };
    let default_aging_ms = defaults.aging.map(|d| d.as_millis() as u64).unwrap_or(0);
    let aging_ms: u64 = args.opt_parse("aging-ms", default_aging_ms)?;
    Ok(EngineConfig {
        preemption,
        aging: if aging_ms > 0 {
            Some(std::time::Duration::from_millis(aging_ms))
        } else {
            None
        },
        ..defaults
    })
}

fn serve_with<B: entrollm::coordinator::Backend>(
    backend: B,
    port: u16,
    tag: &str,
    cfg: &entrollm::server::ServeConfig,
    engine_cfg: EngineConfig,
) -> Result<()> {
    let mut engine = Engine::new(backend, engine_cfg);
    let listener = std::net::TcpListener::bind(("127.0.0.1", port))?;
    println!(
        "serving {tag} on 127.0.0.1:{port} ({} I/O shards; ctrl-c to stop)",
        cfg.io_shards
    );
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let served = entrollm::server::serve_with(&mut engine, listener, stop, cfg)?;
    println!("served {served} requests");
    Ok(())
}

/// Parse one `--model` value: `name=path[,reserve-mb=N][,weight=W]`.
/// `reserve-mb` is a minimum residency reservation (fractional MiB
/// allowed, like `--weight-budget-mb`); `weight` is the admission
/// weight. Both are optional and order-free after the path. Commas
/// separate options, so a container path containing a comma cannot be
/// expressed here — the errors point such users at repeated `--elm`,
/// which takes the path verbatim.
fn parse_model_flag(raw: &str) -> Result<entrollm::pipeline::ModelFileSpec> {
    let mut parts = raw.split(',');
    let head = parts.next().unwrap_or("");
    let Some((name, path)) = head.split_once('=') else {
        return Err(Error::InvalidArg(format!(
            "--model expects name=path[,reserve-mb=N][,weight=W] \
             (e.g. --model chat=chat.elm,reserve-mb=16,weight=4), got {raw:?}"
        )));
    };
    if name.is_empty() || path.is_empty() {
        return Err(Error::InvalidArg(format!(
            "--model expects a non-empty name and path, got {raw:?}"
        )));
    }
    let mut spec = entrollm::pipeline::ModelFileSpec::new(name, path);
    for part in parts {
        let Some((key, value)) = part.split_once('=') else {
            return Err(Error::InvalidArg(format!(
                "--model option {part:?} must be key=value (reserve-mb=N or \
                 weight=W), in {raw:?}; paths containing commas cannot be \
                 passed via --model — use repeated --elm instead"
            )));
        };
        match key {
            "reserve-mb" => {
                let mb: f64 = value.parse().map_err(|_| {
                    Error::InvalidArg(format!(
                        "--model {name}: cannot parse reserve-mb value {value:?}"
                    ))
                })?;
                if !mb.is_finite() || mb < 0.0 {
                    return Err(Error::InvalidArg(format!(
                        "--model {name}: reserve-mb must be a non-negative finite \
                         number, got {value}"
                    )));
                }
                spec.reserve_bytes = (mb * 1024.0 * 1024.0) as usize;
            }
            "weight" => {
                // Range validation (finite, > 0) happens at coordinator
                // construction, which names the model in its error.
                spec.weight = value.parse().map_err(|_| {
                    Error::InvalidArg(format!(
                        "--model {name}: cannot parse weight value {value:?}"
                    ))
                })?;
            }
            other => {
                return Err(Error::InvalidArg(format!(
                    "--model {name}: unknown option {other:?} (expected reserve-mb \
                     or weight; paths containing commas cannot be passed via \
                     --model — use repeated --elm instead)"
                )));
            }
        }
    }
    Ok(spec)
}

/// `serve` hosts several models when `--model name=path[,qos...]`
/// appears (any count) or `--elm` is repeated; a single `--elm` stays
/// on the single-model residency path. Bare `--elm` entries are named
/// by file stem and carry no reservation.
fn multi_model_specs(args: &Args) -> Result<Option<Vec<entrollm::pipeline::ModelFileSpec>>> {
    let models = args.all("model");
    let elms = args.all("elm");
    if models.is_empty() && elms.len() < 2 {
        return Ok(None);
    }
    let mut specs = Vec::with_capacity(models.len() + elms.len());
    for m in models {
        specs.push(parse_model_flag(m)?);
    }
    for path in elms {
        let name = std::path::Path::new(path)
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or(path.as_str())
            .to_string();
        specs.push(entrollm::pipeline::ModelFileSpec::new(name, path.clone()));
    }
    Ok(Some(specs))
}

/// Multi-model serving: every named container behind one port, one
/// shared byte budget, one decode worker pool — with optional
/// per-model QoS (residency reservations + admission weights).
fn serve_multi_models(
    args: &Args,
    specs: Vec<entrollm::pipeline::ModelFileSpec>,
    port: u16,
) -> Result<()> {
    for conflicting in ["artifacts", "flavor", "synthetic"] {
        if args.flags.contains_key(conflicting) {
            return Err(Error::InvalidArg(format!(
                "--{conflicting} cannot be combined with multi-model serving \
                 (repeated --elm / --model name=path)"
            )));
        }
    }
    if args.has("stream") {
        return Err(Error::InvalidArg(
            "--stream is the PJRT streaming-load path; multi-model serving already \
             reads segments lazily — drop it"
                .into(),
        ));
    }
    let mb: f64 = args.opt_parse("weight-budget-mb", 64.0f64)?;
    let budget = entrollm::pipeline::weight_budget_bytes(mb)?;
    let decode_ahead: usize = args.opt_parse("decode-ahead", 2usize)?;
    let workers: usize = args.opt_parse("prefetch-workers", 2usize)?.clamp(1, 32);
    let mut multi = entrollm::pipeline::open_multi_model_server(
        specs,
        budget,
        decode_ahead,
        workers,
        engine_config(args)?,
    )?;
    println!(
        "multi-model serving: {} models | shared budget {} | decode-ahead {} | \
         {} pool workers",
        multi.n_models(),
        fmt_bytes(budget),
        decode_ahead,
        multi.pool().workers(),
    );
    if let Some(spec) = args.flags.get("speculate") {
        multi.enable_speculation(&entrollm::coordinator::SpecConfig::parse(spec)?)?;
        let (draft, target, k, _) = multi.speculation().expect("just enabled");
        println!(
            "speculative decoding: draft {draft} proposes k={k} tokens/step, \
             target {target} verifies (greedy bit-exact)"
        );
    }
    for i in 0..multi.n_models() {
        let q = multi.model_counters(i);
        let qos = if q.reserved_bytes > 0 || q.weight != 1.0 {
            format!(
                " | reserve {} | weight {}",
                fmt_bytes(q.reserved_bytes),
                q.weight
            )
        } else {
            String::new()
        };
        println!(
            "  model {:<20} {} quantized layers{qos}",
            multi.name(i),
            multi.engine(i).backend().weights().n_layers(),
        );
    }
    let cfg = serve_config(args)?;
    let listener = std::net::TcpListener::bind(("127.0.0.1", port))?;
    println!(
        "serving {} models on 127.0.0.1:{port} ({} I/O shards; route with the \
         request's \"model\" field; ctrl-c to stop)",
        multi.n_models(),
        cfg.io_shards,
    );
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let served = entrollm::server::serve_multi_with(&mut multi, listener, stop, &cfg)?;
    println!("served {served} requests");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let port: u16 = args.opt_parse("port", 7433)?;
    if let Some(specs) = multi_model_specs(args)? {
        return serve_multi_models(args, specs, port);
    }
    if args.flags.contains_key("speculate") {
        return Err(Error::InvalidArg(
            "--speculate pairs two co-resident models — host both with repeated \
             --elm or --model name=path"
                .into(),
        ));
    }
    let cfg = serve_config(args)?;
    let ecfg = engine_config(args)?;
    if wants_residency(args) {
        return match resident_serving(args)? {
            ResidentServing::Plain(b) => {
                serve_with(b, port, "resident (digest backend)", &cfg, ecfg)
            }
            ResidentServing::Prefetching(b) => {
                serve_with(b, port, "resident (decode-ahead digest backend)", &cfg, ecfg)
            }
        };
    }
    let artifacts = args.opt("artifacts", "artifacts");
    let flavor = Flavor::parse(args.opt("flavor", "u8"))?;
    let threads: usize = args.opt_parse("threads", 4)?;
    let backend = load_serving_backend(args, artifacts, flavor, threads)?;
    serve_with(backend, port, flavor.tag(), &cfg, ecfg)
}

fn cmd_latency(args: &Args) -> Result<()> {
    let n_params: f64 = args.opt_parse("params", 3.8e9)?;
    let prefill_tokens: usize = args.opt_parse("prefill-tokens", 512)?;
    let threads: usize = args.opt_parse("threads", 4)?;
    let n_layers: usize = args.opt_parse("layers", 32)?;
    let prefetch: usize = args.opt_parse("prefetch-layers", 4)?;
    let model = LatencyModel::new(JETSON_P3450);
    println!("latency model: {} | {} params", model.profile.name, n_params);
    for (bits, eff) in [(8u32, 5.58f64), (4, 1.39)] {
        let (without, with) = table2_workloads(
            n_params as usize,
            bits,
            eff,
            prefill_tokens,
            threads,
            1.0,
        );
        let bw = model.breakdown(&without);
        let bh = model.breakdown(&with);
        println!("uint{bits} (effective {eff} bits):");
        println!(
            "  prefill       : {} -> {}  ({:+.1}%)",
            fmt_secs(bw.prefill.total),
            fmt_secs(bh.prefill.total),
            100.0 * (bw.prefill.total / bh.prefill.total - 1.0)
        );
        println!(
            "  token gen     : {} -> {}  ({:.2}x)",
            fmt_secs(bw.token_gen.total),
            fmt_secs(bh.token_gen.total),
            bw.token_gen.total / bh.token_gen.total
        );
        println!("  decode (once) : {}", fmt_secs(bh.parallel_decode));
        println!(
            "  first token   : {} -> {}",
            fmt_secs(bw.first_token),
            fmt_secs(bh.first_token)
        );
        println!(
            "  streamed TTFT : {} (prefetch {prefetch}/{n_layers} layers, {:.2}x vs eager decode)",
            fmt_secs(model.streaming_first_token(&with, n_layers, prefetch)),
            model.streaming_speedup(&with, n_layers, prefetch),
        );
        // Residency fault-in: steady-state tokens/sec with part of the
        // decoded model pinned resident. 0 pinned = the shipped LRU
        // cache on a cyclic dense pass (every access misses).
        let full = model.faulted_tokens_per_sec(&with, n_layers, n_layers);
        let half = model.faulted_tokens_per_sec(&with, n_layers, n_layers / 2);
        let none = model.faulted_tokens_per_sec(&with, n_layers, 0);
        println!(
            "  resident tok/s: {full:.3} (all pinned) | {half:.3} (1/2 pinned) | \
             {none:.3} (LRU, cyclic scan)"
        );
        // Decode-ahead overlap: the fault bill hides behind compute, so
        // a token costs max(compute, decode) instead of their sum.
        let hidden = model.overlapped_tokens_per_sec(&with, n_layers, 0);
        println!(
            "  decode-ahead  : {hidden:.3} tok/s with fault-in overlapped \
             ({:.2}x vs fault-on-demand at 0 pinned)",
            model.overlap_speedup(&with, n_layers, 0),
        );
    }
    Ok(())
}
