//! `entrollm` — the L3 coordinator CLI.
//!
//! ```text
//! entrollm compress   --artifacts DIR --bits u8|u4 --out model.elm
//! entrollm inspect    --model model.elm [--histogram]
//! entrollm decode-bench --model model.elm --threads N [--repeat R]
//! entrollm eval-ppl   --artifacts DIR --flavor f32|u8|u4 [--windows N]
//! entrollm generate   --artifacts DIR --flavor u8 --prompt "..." [--max-tokens N]
//! entrollm serve      --artifacts DIR --flavor u8 --port 7433 [--threads T]
//! entrollm latency    [--params 3.8e9] [--prefill-tokens 512]
//! ```

use entrollm::bench::{fmt_bytes, fmt_secs};
use entrollm::cli::Args;
use entrollm::coordinator::{Engine, EngineConfig, Request};
use entrollm::corpus::ByteTokenizer;
use entrollm::decode::ParallelDecoder;
use entrollm::device::{table2_workloads, LatencyModel, JETSON_P3450};
use entrollm::entropy::{distribution_stats, Histogram};
use entrollm::huffman::FreqTable;
use entrollm::pipeline::{build_elm, load_backend, Flavor};
use entrollm::quant::BitWidth;
use entrollm::store::ElmModel;
use entrollm::{Error, Result};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "compress" => cmd_compress(args),
        "inspect" => cmd_inspect(args),
        "decode-bench" => cmd_decode_bench(args),
        "eval-ppl" => cmd_eval_ppl(args),
        "generate" => cmd_generate(args),
        "serve" => cmd_serve(args),
        "latency" => cmd_latency(args),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(Error::InvalidArg(format!(
            "unknown command {other:?} (try `entrollm help`)"
        ))),
    }
}

const HELP: &str = r#"entrollm — entropy-encoded weight compression for edge LLM inference

commands:
  compress      quantize (mixed scheme) + Huffman-encode -> .elm container
  inspect       print an .elm container's manifest and symbol statistics
  decode-bench  measure parallel Huffman decode throughput
  eval-ppl      held-out perplexity via the AOT score executable
  generate      one-shot generation through the serving engine
  serve         TCP serving (line-protocol JSON)
  latency       Table II-style latency model for an edge profile
"#;

fn cmd_compress(args: &Args) -> Result<()> {
    let artifacts = args.opt("artifacts", "artifacts");
    let bits = BitWidth::parse(args.opt("bits", "u8"))?;
    let default_out = format!("model_{bits}.elm");
    let out = args.opt("out", &default_out);
    let (model, report) = build_elm(artifacts, bits)?;
    model.save(out)?;
    println!("wrote {out}");
    println!("  parameters      : {}", report.n_params);
    println!("  fp16 baseline   : {}", fmt_bytes(report.fp16_bytes));
    println!("  fixed {}    : {}", bits, fmt_bytes(report.fixed_bytes));
    println!("  huffman payload : {}", fmt_bytes(report.encoded_bytes));
    println!("  entropy         : {:.3} bits/param", report.entropy_bits);
    println!("  effective bits  : {:.3} bits/param", report.effective_bits);
    let sym = report
        .schemes
        .iter()
        .filter(|(_, s)| *s == entrollm::quant::Scheme::SymmetricUnsigned)
        .count();
    println!(
        "  schemes         : {sym} symmetric-unsigned / {} asymmetric",
        report.schemes.len() - sym
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let model = ElmModel::load(args.req("model")?)?;
    println!("ELM container: {} layers, {}", model.layers.len(), model.bits);
    println!("  payload        : {}", fmt_bytes(model.payload.len()));
    println!("  parameters     : {}", model.n_params());
    println!("  effective bits : {:.3}", model.effective_bits());
    let mut freq = FreqTable::new();
    for i in 0..model.layers.len() {
        let q = entrollm::store::decode_layer(&model, i)?;
        freq.add_symbols(q.symbols.data());
    }
    let stats = distribution_stats(&freq)?;
    println!(
        "  symbol stats   : H={:.3}b eff={:.3}b mean={:.2} std={:.2} skew={:.3} kurt={:.3}",
        stats.entropy, stats.effective_bits, stats.mean, stats.std, stats.skewness, stats.kurtosis
    );
    if args.has("histogram") {
        let levels = model.bits.levels();
        println!("{}", Histogram::from_freq(&freq, levels).to_ascii(60, 16));
    }
    for m in model.layers.iter().take(8) {
        println!(
            "  layer {:<24} {} {:?} s={:+.5} z={:+.5} {} -> {}",
            m.name,
            m.shape,
            m.params.scheme,
            m.params.scale,
            m.params.zero_point,
            fmt_bytes(m.n_symbols * if model.bits == BitWidth::U8 { 1 } else { 1 } / 1),
            fmt_bytes(m.encoded_len),
        );
    }
    if model.layers.len() > 8 {
        println!("  ... {} more layers", model.layers.len() - 8);
    }
    Ok(())
}

fn cmd_decode_bench(args: &Args) -> Result<()> {
    let model = ElmModel::load(args.req("model")?)?;
    let threads: usize = args.opt_parse("threads", 4)?;
    let repeat: usize = args.opt_parse("repeat", 3)?;
    println!(
        "parallel decode: {} params, {} encoded, {threads} threads",
        model.n_params(),
        fmt_bytes(model.payload.len())
    );
    for r in 0..repeat {
        let (_, stats) = ParallelDecoder::new(threads).decode_model(&model)?;
        println!(
            "  run {r}: wall {} | {:.1} Msym/s | imbalance {:.3} (symbols {:.3})",
            fmt_secs(stats.wall.as_secs_f64()),
            stats.symbols_per_sec() / 1e6,
            stats.imbalance(),
            stats.symbol_imbalance(),
        );
    }
    Ok(())
}

fn cmd_eval_ppl(args: &Args) -> Result<()> {
    let artifacts = args.opt("artifacts", "artifacts");
    let flavor = Flavor::parse(args.opt("flavor", "u8"))?;
    let windows: usize = args.opt_parse("windows", 16)?;
    let threads: usize = args.opt_parse("threads", 4)?;
    let (nll, ppl) = entrollm::pipeline::eval_ppl(artifacts, flavor, threads, windows)?;
    println!(
        "{}: nll {nll:.4} nats/char | char-ppl {ppl:.4} ({windows} windows)",
        flavor.tag()
    );
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let artifacts = args.opt("artifacts", "artifacts");
    let flavor = Flavor::parse(args.opt("flavor", "u8"))?;
    let prompt = args.req("prompt")?.to_string();
    let max_tokens: usize = args.opt_parse("max-tokens", 48)?;
    let temperature: f32 = args.opt_parse("temperature", 0.0f32)?;
    let threads: usize = args.opt_parse("threads", 4)?;

    let (backend, decode_stats) = load_backend(artifacts, flavor, threads)?;
    if let Some(s) = &decode_stats {
        println!(
            "huffman parallel decode: {} in {} ({:.1} Msym/s)",
            s.total_symbols(),
            fmt_secs(s.wall.as_secs_f64()),
            s.symbols_per_sec() / 1e6
        );
    }
    let mut engine = Engine::new(backend, EngineConfig::default());
    let tok = ByteTokenizer;
    let mut req = Request::greedy(1, tok.encode(&prompt), max_tokens);
    req.temperature = temperature;
    engine.submit(req)?;
    let responses = engine.run_to_completion(10_000)?;
    for r in &responses {
        println!("--- response {} ({:?}) ---", r.id, r.finish_reason);
        println!("{}{}", prompt, tok.decode(&r.tokens));
        println!(
            "first token {} | {} tokens | decode {}",
            fmt_secs(r.timing.first_token.as_secs_f64()),
            r.tokens.len(),
            fmt_secs(r.timing.decode.as_secs_f64()),
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = args.opt("artifacts", "artifacts");
    let flavor = Flavor::parse(args.opt("flavor", "u8"))?;
    let port: u16 = args.opt_parse("port", 7433)?;
    let threads: usize = args.opt_parse("threads", 4)?;
    let (backend, _) = load_backend(artifacts, flavor, threads)?;
    let mut engine = Engine::new(backend, EngineConfig::default());
    let listener = std::net::TcpListener::bind(("127.0.0.1", port))?;
    println!("serving {} on 127.0.0.1:{port} (ctrl-c to stop)", flavor.tag());
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let served = entrollm::server::serve(&mut engine, listener, stop)?;
    println!("served {served} requests");
    Ok(())
}

fn cmd_latency(args: &Args) -> Result<()> {
    let n_params: f64 = args.opt_parse("params", 3.8e9)?;
    let prefill_tokens: usize = args.opt_parse("prefill-tokens", 512)?;
    let threads: usize = args.opt_parse("threads", 4)?;
    let model = LatencyModel::new(JETSON_P3450);
    println!("latency model: {} | {} params", model.profile.name, n_params);
    for (bits, eff) in [(8u32, 5.58f64), (4, 1.39)] {
        let (without, with) = table2_workloads(
            n_params as usize,
            bits,
            eff,
            prefill_tokens,
            threads,
            1.0,
        );
        let bw = model.breakdown(&without);
        let bh = model.breakdown(&with);
        println!("uint{bits} (effective {eff} bits):");
        println!(
            "  prefill       : {} -> {}  ({:+.1}%)",
            fmt_secs(bw.prefill.total),
            fmt_secs(bh.prefill.total),
            100.0 * (bw.prefill.total / bh.prefill.total - 1.0)
        );
        println!(
            "  token gen     : {} -> {}  ({:.2}x)",
            fmt_secs(bw.token_gen.total),
            fmt_secs(bh.token_gen.total),
            bw.token_gen.total / bh.token_gen.total
        );
        println!("  decode (once) : {}", fmt_secs(bh.parallel_decode));
        println!(
            "  first token   : {} -> {}",
            fmt_secs(bw.first_token),
            fmt_secs(bh.first_token)
        );
    }
    Ok(())
}
