//! `entrollm` — the L3 coordinator CLI.
//!
//! ```text
//! entrollm compress   --artifacts DIR --bits u8|u4 --out model.elm
//!                     [--synthetic N --seed S]   (no artifacts needed)
//! entrollm inspect    --model model.elm [--histogram]
//! entrollm decompress --model model.elm --out weights.eqw [--threads N]
//!                     [--stream --prefetch-layers K]
//! entrollm decode-bench --model model.elm --threads N [--repeat R]
//! entrollm eval-ppl   --artifacts DIR --flavor f32|u8|u4 [--windows N]
//! entrollm generate   --artifacts DIR --flavor u8 --prompt "..." [--max-tokens N]
//!                     [--stream --prefetch-layers K [--elm model.elm]]
//! entrollm serve      --artifacts DIR --flavor u8 --port 7433 [--threads T]
//!                     [--stream --prefetch-layers K [--elm model.elm]]
//! entrollm latency    [--params 3.8e9] [--prefill-tokens 512]
//!                     [--layers L --prefetch-layers K]
//! ```

use entrollm::bench::{fmt_bytes, fmt_secs};
use entrollm::cli::Args;
use entrollm::coordinator::{Engine, EngineConfig, PjrtBackend, Request};
use entrollm::corpus::ByteTokenizer;
use entrollm::decode::{ParallelDecoder, StreamingDecoder};
use entrollm::device::{table2_workloads, LatencyModel, JETSON_P3450};
use entrollm::entropy::{distribution_stats, Histogram};
use entrollm::huffman::FreqTable;
use entrollm::pipeline::{build_elm, load_backend, Flavor};
use entrollm::quant::BitWidth;
use entrollm::store::ElmModel;
use entrollm::{Error, Result};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "compress" => cmd_compress(args),
        "inspect" => cmd_inspect(args),
        "decompress" => cmd_decompress(args),
        "decode-bench" => cmd_decode_bench(args),
        "eval-ppl" => cmd_eval_ppl(args),
        "generate" => cmd_generate(args),
        "serve" => cmd_serve(args),
        "latency" => cmd_latency(args),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(Error::InvalidArg(format!(
            "unknown command {other:?} (try `entrollm help`)"
        ))),
    }
}

const HELP: &str = r#"entrollm — entropy-encoded weight compression for edge LLM inference

commands:
  compress      quantize (mixed scheme) + Huffman-encode -> .elm container
                (--synthetic N builds a seeded synthetic model, no artifacts)
  inspect       print an .elm container's manifest and symbol statistics
  decompress    decode an .elm container back to raw quantized weights
                (--stream decodes layer-ahead with a bounded prefetch window)
  decode-bench  measure parallel Huffman decode throughput
  eval-ppl      held-out perplexity via the AOT score executable
  generate      one-shot generation through the serving engine
                (--stream loads weights via the streaming decoder)
  serve         TCP serving (line-protocol JSON); --stream as above
  latency       Table II-style latency model for an edge profile,
                including streaming (layer-ahead) first-token estimates
"#;

fn cmd_compress(args: &Args) -> Result<()> {
    let bits = BitWidth::parse(args.opt("bits", "u8"))?;
    let default_out = format!("model_{bits}.elm");
    let out = args.opt("out", &default_out);
    let synthetic: usize = args.opt_parse("synthetic", 0usize)?;
    let (model, report) = if synthetic > 0 {
        let seed: u64 = args.opt_parse("seed", 0x5EED_u64)?;
        let layers = entrollm::pipeline::synthetic_layers(synthetic, seed);
        println!("synthetic model: {synthetic} layers (seed {seed:#x})");
        entrollm::store::compress(&layers, bits)?
    } else {
        build_elm(args.opt("artifacts", "artifacts"), bits)?
    };
    model.save(out)?;
    println!("wrote {out}");
    println!("  parameters      : {}", report.n_params);
    println!("  fp16 baseline   : {}", fmt_bytes(report.fp16_bytes));
    println!("  fixed {}    : {}", bits, fmt_bytes(report.fixed_bytes));
    println!("  huffman payload : {}", fmt_bytes(report.encoded_bytes));
    println!("  entropy         : {:.3} bits/param", report.entropy_bits);
    println!("  effective bits  : {:.3} bits/param", report.effective_bits);
    let sym = report
        .schemes
        .iter()
        .filter(|(_, s)| *s == entrollm::quant::Scheme::SymmetricUnsigned)
        .count();
    println!(
        "  schemes         : {sym} symmetric-unsigned / {} asymmetric",
        report.schemes.len() - sym
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let model = ElmModel::load(args.req("model")?)?;
    println!("ELM container: {} layers, {}", model.layers.len(), model.bits);
    println!("  payload        : {}", fmt_bytes(model.payload.len()));
    println!("  parameters     : {}", model.n_params());
    println!("  effective bits : {:.3}", model.effective_bits());
    let mut freq = FreqTable::new();
    for i in 0..model.layers.len() {
        let q = entrollm::store::decode_layer(&model, i)?;
        freq.add_symbols(q.symbols.data());
    }
    let stats = distribution_stats(&freq)?;
    println!(
        "  symbol stats   : H={:.3}b eff={:.3}b mean={:.2} std={:.2} skew={:.3} kurt={:.3}",
        stats.entropy, stats.effective_bits, stats.mean, stats.std, stats.skewness, stats.kurtosis
    );
    if args.has("histogram") {
        let levels = model.bits.levels();
        println!("{}", Histogram::from_freq(&freq, levels).to_ascii(60, 16));
    }
    for m in model.layers.iter().take(8) {
        println!(
            "  layer {:<24} {} {:?} s={:+.5} z={:+.5} {} -> {}",
            m.name,
            m.shape,
            m.params.scheme,
            m.params.scale,
            m.params.zero_point,
            fmt_bytes(m.n_symbols * if model.bits == BitWidth::U8 { 1 } else { 1 } / 1),
            fmt_bytes(m.encoded_len),
        );
    }
    if model.layers.len() > 8 {
        println!("  ... {} more layers", model.layers.len() - 8);
    }
    Ok(())
}

/// Decode a container back to its raw quantized weights and write them
/// as an `EQW1` file: `magic | u8 bitwidth | u32 n_layers | per layer:
/// u16 name_len, name, u8 rank, rank × u64 dims, u8 scheme, f32 scale,
/// f32 zp, u64 n_symbols, symbol bytes`. The output is a deterministic
/// function of the container, so any two decode paths (serial,
/// parallel, streaming) must produce byte-identical files.
fn cmd_decompress(args: &Args) -> Result<()> {
    // Arc so the streaming workers share the payload instead of
    // copying a potentially GB-scale container.
    let model = std::sync::Arc::new(ElmModel::load(args.req("model")?)?);
    let out = args.req("out")?;
    let threads: usize = args.opt_parse("threads", 4)?;

    use std::io::Write as _;
    fn write_layer<W: std::io::Write>(
        w: &mut W,
        meta: &entrollm::store::LayerMeta,
        q: &entrollm::quant::QuantizedTensor,
    ) -> Result<()> {
        w.write_all(&(meta.name.len() as u16).to_le_bytes())?;
        w.write_all(meta.name.as_bytes())?;
        w.write_all(&[meta.shape.rank() as u8])?;
        for &d in meta.shape.dims() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        w.write_all(&[q.params.scheme.tag()])?;
        w.write_all(&q.params.scale.to_le_bytes())?;
        w.write_all(&q.params.zero_point.to_le_bytes())?;
        w.write_all(&(q.symbols.numel() as u64).to_le_bytes())?;
        w.write_all(q.symbols.data())?;
        Ok(())
    }

    let file = std::fs::File::create(out)?;
    let mut w = std::io::BufWriter::new(file);
    w.write_all(b"EQW1")?;
    // Bit width first: without it a reader cannot tell u4 symbols
    // (values 0..16, one per byte) from narrow-range u8 symbols.
    w.write_all(&[model.bits.bits() as u8])?;
    w.write_all(&(model.layers.len() as u32).to_le_bytes())?;

    if args.has("stream") {
        // Each layer is written the moment it decodes, so resident
        // decoded memory stays bounded by the prefetch window.
        let prefetch: usize = args.opt_parse("prefetch-layers", 4)?;
        let mut stream =
            StreamingDecoder::new(threads, prefetch).stream(std::sync::Arc::clone(&model))?;
        while let Some(layer) = stream.next_layer() {
            let layer = layer?;
            write_layer(&mut w, &model.layers[layer.index], &layer.tensor)?;
        }
        let stats = stream.into_stats();
        println!(
            "streaming decode: first layer after {} | total {} | window <= {} layers",
            fmt_secs(stats.time_to_first_layer.as_secs_f64()),
            fmt_secs(stats.wall.as_secs_f64()),
            stats.max_layers_ahead,
        );
    } else {
        let (tensors, stats) = ParallelDecoder::new(threads).decode_model(&model)?;
        println!(
            "parallel decode: {} in {} ({:.1} Msym/s)",
            stats.total_symbols(),
            fmt_secs(stats.wall.as_secs_f64()),
            stats.symbols_per_sec() / 1e6,
        );
        for (meta, q) in model.layers.iter().zip(&tensors) {
            write_layer(&mut w, meta, q)?;
        }
    }
    w.flush()?;
    println!(
        "decoded {} layers / {} symbols (all segments CRC-clean) -> {out}",
        model.layers.len(),
        model.n_params(),
    );
    Ok(())
}

fn cmd_decode_bench(args: &Args) -> Result<()> {
    let model = ElmModel::load(args.req("model")?)?;
    let threads: usize = args.opt_parse("threads", 4)?;
    let repeat: usize = args.opt_parse("repeat", 3)?;
    println!(
        "parallel decode: {} params, {} encoded, {threads} threads",
        model.n_params(),
        fmt_bytes(model.payload.len())
    );
    for r in 0..repeat {
        let (_, stats) = ParallelDecoder::new(threads).decode_model(&model)?;
        println!(
            "  run {r}: wall {} | {:.1} Msym/s | imbalance {:.3} (symbols {:.3})",
            fmt_secs(stats.wall.as_secs_f64()),
            stats.symbols_per_sec() / 1e6,
            stats.imbalance(),
            stats.symbol_imbalance(),
        );
    }
    Ok(())
}

fn cmd_eval_ppl(args: &Args) -> Result<()> {
    let artifacts = args.opt("artifacts", "artifacts");
    let flavor = Flavor::parse(args.opt("flavor", "u8"))?;
    let windows: usize = args.opt_parse("windows", 16)?;
    let threads: usize = args.opt_parse("threads", 4)?;
    let (nll, ppl) = entrollm::pipeline::eval_ppl(artifacts, flavor, threads, windows)?;
    println!(
        "{}: nll {nll:.4} nats/char | char-ppl {ppl:.4} ({windows} windows)",
        flavor.tag()
    );
    Ok(())
}

/// Resolve the serving backend for `generate`/`serve`: eager by
/// default; `--stream` (with optional `--elm PATH` and
/// `--prefetch-layers N`) loads through the streaming decoder.
/// Prints how the weights arrived either way.
fn load_serving_backend(
    args: &Args,
    artifacts: &str,
    flavor: Flavor,
    threads: usize,
) -> Result<PjrtBackend> {
    if args.has("stream") {
        let prefetch: usize = args.opt_parse("prefetch-layers", 4)?;
        let (backend, stats) = match args.flags.get("elm") {
            Some(elm) => {
                entrollm::pipeline::load_backend_streaming(artifacts, elm, threads, prefetch)?
            }
            None => entrollm::pipeline::load_backend_streaming_from_artifacts(
                artifacts, flavor, threads, prefetch,
            )?,
        };
        println!(
            "huffman streaming decode: {} symbols | first layer {} | total {} | prefetch {} \
             (runtime upload follows the full set)",
            stats.total_symbols(),
            fmt_secs(stats.time_to_first_layer.as_secs_f64()),
            fmt_secs(stats.wall.as_secs_f64()),
            stats.prefetch_layers,
        );
        Ok(backend)
    } else {
        let (backend, decode_stats) = load_backend(artifacts, flavor, threads)?;
        if let Some(s) = &decode_stats {
            println!(
                "huffman parallel decode: {} in {} ({:.1} Msym/s)",
                s.total_symbols(),
                fmt_secs(s.wall.as_secs_f64()),
                s.symbols_per_sec() / 1e6
            );
        }
        Ok(backend)
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let artifacts = args.opt("artifacts", "artifacts");
    let flavor = Flavor::parse(args.opt("flavor", "u8"))?;
    let prompt = args.req("prompt")?.to_string();
    let max_tokens: usize = args.opt_parse("max-tokens", 48)?;
    let temperature: f32 = args.opt_parse("temperature", 0.0f32)?;
    let threads: usize = args.opt_parse("threads", 4)?;

    let backend = load_serving_backend(args, artifacts, flavor, threads)?;
    let mut engine = Engine::new(backend, EngineConfig::default());
    let tok = ByteTokenizer;
    let mut req = Request::greedy(1, tok.encode(&prompt), max_tokens);
    req.temperature = temperature;
    engine.submit(req)?;
    let responses = engine.run_to_completion(10_000)?;
    for r in &responses {
        println!("--- response {} ({:?}) ---", r.id, r.finish_reason);
        println!("{}{}", prompt, tok.decode(&r.tokens));
        println!(
            "first token {} | {} tokens | decode {}",
            fmt_secs(r.timing.first_token.as_secs_f64()),
            r.tokens.len(),
            fmt_secs(r.timing.decode.as_secs_f64()),
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = args.opt("artifacts", "artifacts");
    let flavor = Flavor::parse(args.opt("flavor", "u8"))?;
    let port: u16 = args.opt_parse("port", 7433)?;
    let threads: usize = args.opt_parse("threads", 4)?;
    let backend = load_serving_backend(args, artifacts, flavor, threads)?;
    let mut engine = Engine::new(backend, EngineConfig::default());
    let listener = std::net::TcpListener::bind(("127.0.0.1", port))?;
    println!("serving {} on 127.0.0.1:{port} (ctrl-c to stop)", flavor.tag());
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let served = entrollm::server::serve(&mut engine, listener, stop)?;
    println!("served {served} requests");
    Ok(())
}

fn cmd_latency(args: &Args) -> Result<()> {
    let n_params: f64 = args.opt_parse("params", 3.8e9)?;
    let prefill_tokens: usize = args.opt_parse("prefill-tokens", 512)?;
    let threads: usize = args.opt_parse("threads", 4)?;
    let n_layers: usize = args.opt_parse("layers", 32)?;
    let prefetch: usize = args.opt_parse("prefetch-layers", 4)?;
    let model = LatencyModel::new(JETSON_P3450);
    println!("latency model: {} | {} params", model.profile.name, n_params);
    for (bits, eff) in [(8u32, 5.58f64), (4, 1.39)] {
        let (without, with) = table2_workloads(
            n_params as usize,
            bits,
            eff,
            prefill_tokens,
            threads,
            1.0,
        );
        let bw = model.breakdown(&without);
        let bh = model.breakdown(&with);
        println!("uint{bits} (effective {eff} bits):");
        println!(
            "  prefill       : {} -> {}  ({:+.1}%)",
            fmt_secs(bw.prefill.total),
            fmt_secs(bh.prefill.total),
            100.0 * (bw.prefill.total / bh.prefill.total - 1.0)
        );
        println!(
            "  token gen     : {} -> {}  ({:.2}x)",
            fmt_secs(bw.token_gen.total),
            fmt_secs(bh.token_gen.total),
            bw.token_gen.total / bh.token_gen.total
        );
        println!("  decode (once) : {}", fmt_secs(bh.parallel_decode));
        println!(
            "  first token   : {} -> {}",
            fmt_secs(bw.first_token),
            fmt_secs(bh.first_token)
        );
        println!(
            "  streamed TTFT : {} (prefetch {prefetch}/{n_layers} layers, {:.2}x vs eager decode)",
            fmt_secs(model.streaming_first_token(&with, n_layers, prefetch)),
            model.streaming_speedup(&with, n_layers, prefetch),
        );
    }
    Ok(())
}
