//! Serving metrics: latency histograms, throughput counters, and the
//! markdown/CSV table reporters every bench uses to emit its paper
//! table/figure.

use std::time::{Duration, Instant};

/// Log-bucketed latency histogram (microsecond resolution, buckets grow
/// ×2 from 1 µs to ~17 min).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: u128,
    max_us: u64,
    min_us: u64,
}

const N_BUCKETS: usize = 30;

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: vec![0; N_BUCKETS],
            count: 0,
            sum_us: 0,
            max_us: 0,
            min_us: u64::MAX,
        }
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket_of(us: u64) -> usize {
        (64 - us.max(1).leading_zeros() as usize - 1).min(N_BUCKETS - 1)
    }

    /// Record one sample.
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[Self::bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us += us as u128;
        self.max_us = self.max_us.max(us);
        self.min_us = self.min_us.min(us);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean latency.
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros((self.sum_us / self.count as u128) as u64)
    }

    /// Approximate quantile (bucket upper bound, clamped into the
    /// observed `[min, max]` range), q in [0,1].
    ///
    /// The target rank is clamped to at least 1: `q = 0.0` means "the
    /// smallest sample", not "before any sample" — an unclamped
    /// `target = 0` made `seen >= target` true at bucket 0 and
    /// returned ~2 µs no matter what was recorded.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                // Bucket i covers [2^i, 2^(i+1)); report its upper
                // bound, but never outside what was actually seen.
                let upper = 1u64 << (i + 1);
                return Duration::from_micros(upper.clamp(self.min_us, self.max_us));
            }
        }
        Duration::from_micros(self.max_us)
    }

    /// Max sample.
    pub fn max(&self) -> Duration {
        Duration::from_micros(if self.count == 0 { 0 } else { self.max_us })
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:?} p50={:?} p95={:?} max={:?}",
            self.count,
            self.mean(),
            self.quantile(0.5),
            self.quantile(0.95),
            self.max()
        )
    }
}

/// Monotonic throughput counter.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    events: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    /// Counter starting now.
    pub fn new() -> Self {
        Throughput {
            start: Instant::now(),
            events: 0,
        }
    }

    /// Record `n` events.
    pub fn add(&mut self, n: u64) {
        self.events += n;
    }

    /// Total events.
    pub fn total(&self) -> u64 {
        self.events
    }

    /// Events per second since construction.
    pub fn per_sec(&self) -> f64 {
        self.events as f64 / self.start.elapsed().as_secs_f64().max(1e-9)
    }
}

/// A markdown table builder — every bench prints its paper table through
/// this so EXPERIMENTS.md entries are copy-pasteable.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render as github markdown.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("### {}\n\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Render as a JSON object (`{"title","headers","rows"}`) through
    /// the real serializer, so commas/quotes in cells stay lossless —
    /// the machine-readable artifact CI uploads per bench run.
    pub fn to_json(&self) -> String {
        use crate::json;
        json::obj(vec![
            ("title", json::s(&self.title)),
            (
                "headers",
                json::arr(self.headers.iter().map(|h| json::s(h)).collect()),
            ),
            (
                "rows",
                json::arr(
                    self.rows
                        .iter()
                        .map(|r| json::arr(r.iter().map(|c| json::s(c)).collect()))
                        .collect(),
                ),
            ),
        ])
        .to_json()
    }

    /// Print markdown to stdout and also save CSV, markdown, and a
    /// `BENCH_<slug>.json` machine-readable copy next to the bench
    /// results (best-effort; directory created on demand). CI's
    /// bench-smoke job uploads `bench_results/` as a workflow
    /// artifact, so every run's tables survive the runner.
    pub fn emit(&self, slug: &str) {
        println!("{}", self.to_markdown());
        let dir = std::path::Path::new("bench_results");
        if std::fs::create_dir_all(dir).is_ok() {
            let _ = std::fs::write(dir.join(format!("{slug}.csv")), self.to_csv());
            let _ = std::fs::write(dir.join(format!("{slug}.md")), self.to_markdown());
            let _ = std::fs::write(dir.join(format!("BENCH_{slug}.json")), self.to_json());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basic_stats() {
        let mut h = LatencyHistogram::new();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(20));
        assert!(h.max() >= Duration::from_millis(100));
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.summary().contains("n=5"));
    }

    #[test]
    fn empty_histogram_is_zeroes() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.9), Duration::ZERO);
    }

    /// Regression: `q = 0.0` used to make `target = 0`, so the scan
    /// matched bucket 0 immediately and reported ~2 µs regardless of
    /// the data. It must mean "smallest observed sample".
    #[test]
    fn quantile_zero_tracks_the_smallest_sample() {
        let mut h = LatencyHistogram::new();
        for ms in [50u64, 80, 120] {
            h.record(Duration::from_millis(ms));
        }
        let q0 = h.quantile(0.0);
        assert!(
            q0 >= Duration::from_millis(50),
            "q=0 must not undershoot the minimum, got {q0:?}"
        );
        assert!(q0 <= Duration::from_millis(120));
    }

    /// q = 1.0 lands in the last non-empty bucket and is clamped to
    /// the observed maximum — never a bucket bound past it.
    #[test]
    fn quantile_one_is_clamped_to_the_observed_max() {
        let mut h = LatencyHistogram::new();
        for ms in [3u64, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.quantile(1.0), Duration::from_millis(100));
        assert_eq!(h.quantile(1.0), h.max());
    }

    /// A single sample answers every quantile with itself (clamped
    /// into [min, max], which collapses to one point).
    #[test]
    fn quantile_of_single_sample_is_that_sample() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_millis(7));
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(
                h.quantile(q),
                Duration::from_millis(7),
                "q={q} on a single-sample histogram"
            );
        }
    }

    /// Samples past the last bucket's lower bound (the catch-all top
    /// bucket) must report the real max, not the bucket's huge upper
    /// bound.
    #[test]
    fn quantile_in_top_bucket_reports_real_bounds() {
        let mut h = LatencyHistogram::new();
        // 2^29 µs ≈ 537 s; anything >= that lands in bucket 29.
        let big = Duration::from_micros((1u64 << 29) + 123);
        for _ in 0..4 {
            h.record(big);
        }
        for q in [0.0, 0.5, 1.0] {
            assert_eq!(h.quantile(q), big, "q={q} must clamp into [min, max]");
        }
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.add(10);
        t.add(5);
        assert_eq!(t.total(), 15);
        assert!(t.per_sec() > 0.0);
    }

    #[test]
    fn table_renders_markdown_and_csv() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(&["1".into(), "x,y".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.lines().count() >= 4);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    /// The BENCH_*.json artifact is real JSON: hostile cell content
    /// (commas, quotes) survives a parse round-trip losslessly.
    #[test]
    fn table_json_roundtrips_through_the_parser() {
        let mut t = Table::new("T, with \"quotes\"", &["col,a", "b"]);
        t.row(&["1,5".into(), "x\"y".into()]);
        let v = crate::json::Value::parse(&t.to_json()).unwrap();
        assert_eq!(
            v.get("title").unwrap().as_str().unwrap(),
            "T, with \"quotes\""
        );
        let headers = v.get("headers").unwrap().as_array().unwrap().to_vec();
        assert_eq!(headers[0].as_str().unwrap(), "col,a");
        let rows = v.get("rows").unwrap().as_array().unwrap().to_vec();
        assert_eq!(rows.len(), 1);
        let cells = rows[0].as_array().unwrap();
        assert_eq!(cells[0].as_str().unwrap(), "1,5");
        assert_eq!(cells[1].as_str().unwrap(), "x\"y");
    }
}
