//! Canonical, length-limited Huffman codec (paper §III-B).
//!
//! EntroLLM builds **one model-global code** from the pooled frequency
//! table of every quantized weight in the model (Algorithm 1, lines
//! 11–12), then encodes each layer's tensor as an independent,
//! byte-aligned segment so decoding can be parallelized (§III-C).
//!
//! Pipeline:
//!
//! 1. [`FreqTable`] — count symbol occurrences (symbols are the uint4 /
//!    uint8 quantization levels, so the alphabet is ≤ 256).
//! 2. [`CodeSpec`] — derive optimal code *lengths* (heap-based Huffman;
//!    package-merge fallback caps lengths at [`MAX_CODE_LEN`] so the
//!    decoder can use a single-probe lookup table).
//! 3. Canonical code assignment — codes are reconstructable from the
//!    256-byte length array alone, which is all the ELM container stores.
//! 4. [`Encoder`] / [`Decoder`] — bit-serial encode, table-driven decode
//!    (one `peek`/`consume` pair per symbol, no branching on tree nodes).
//!
//! The slow reference decoder ([`Decoder::decode_bit_serial`]) walks the
//! canonical code space bit by bit; tests cross-check it against the LUT
//! path on random inputs.
//!
//! ## Example: lossless encode/decode roundtrip
//!
//! ```
//! use entrollm::huffman::{CodeSpec, Decoder, Encoder, FreqTable};
//!
//! let symbols = vec![3u8, 1, 3, 3, 0, 2, 3, 1, 3, 3];
//! let spec = CodeSpec::build(&FreqTable::from_symbols(&symbols))?;
//! let encoded = Encoder::new(&spec).encode_to_vec(&symbols)?;
//! assert!(encoded.len() < symbols.len(), "skewed input must compress");
//! let decoded = Decoder::new(&spec)?.decode(&encoded, symbols.len())?;
//! assert_eq!(decoded, symbols);
//! # Ok::<(), entrollm::Error>(())
//! ```

mod code;
mod decoder;
mod encoder;

pub use code::{CodeSpec, FreqTable, MAX_CODE_LEN};
pub use decoder::Decoder;
pub use encoder::Encoder;

use crate::Result;

/// Encode `symbols` with a code built from their own frequencies.
/// Convenience for tests/benches; real flows build one global
/// [`CodeSpec`] per model.
pub fn encode_with_own_code(symbols: &[u8]) -> Result<(CodeSpec, Vec<u8>)> {
    let freq = FreqTable::from_symbols(symbols);
    let spec = CodeSpec::build(&freq)?;
    let enc = Encoder::new(&spec);
    let bytes = enc.encode_to_vec(symbols)?;
    Ok((spec, bytes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn gaussian_symbols(n: usize, levels: usize, seed: u64) -> Vec<u8> {
        // Discretized Gaussian — the shape quantized LLM weights take
        // (paper Fig. 4), so these tests exercise the real distribution.
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let g = rng.gaussian_f32(levels as f32 / 2.0, levels as f32 / 8.0);
                (g.round().max(0.0) as usize).min(levels - 1) as u8
            })
            .collect()
    }

    #[test]
    fn roundtrip_gaussian_u8_alphabet() {
        let syms = gaussian_symbols(50_000, 256, 0xAA);
        let (spec, bytes) = encode_with_own_code(&syms).unwrap();
        let dec = Decoder::new(&spec).unwrap();
        let out = dec.decode(&bytes, syms.len()).unwrap();
        assert_eq!(out, syms);
    }

    #[test]
    fn roundtrip_gaussian_u4_alphabet() {
        let syms = gaussian_symbols(50_000, 16, 0xBB);
        let (spec, bytes) = encode_with_own_code(&syms).unwrap();
        let dec = Decoder::new(&spec).unwrap();
        assert_eq!(dec.decode(&bytes, syms.len()).unwrap(), syms);
    }

    #[test]
    fn compresses_skewed_data_below_fixed_width() {
        // A Gaussian occupying ~1/16 of the 256-level grid (σ = 16
        // levels, the shape Fig. 4's 8-bit panels show) has entropy
        // ≈ log2(σ·√(2πe)) ≈ 6.1 bits — well below the fixed 8.
        let mut rng = Rng::new(0xCC);
        let syms: Vec<u8> = (0..100_000)
            .map(|_| {
                let g = rng.gaussian_f32(128.0, 16.0);
                g.round().clamp(0.0, 255.0) as u8
            })
            .collect();
        let (spec, bytes) = encode_with_own_code(&syms).unwrap();
        let fixed = syms.len(); // 1 byte/symbol
        assert!(
            bytes.len() < fixed * 85 / 100,
            "huffman {} vs fixed {fixed}",
            bytes.len()
        );
        // Effective bits matches the paper's definition: encoded bits / n.
        let eff = spec.expected_bits(&FreqTable::from_symbols(&syms));
        assert!(eff < 6.5, "effective bits {eff}");
    }

    #[test]
    fn single_symbol_stream() {
        let syms = vec![7u8; 1000];
        let (spec, bytes) = encode_with_own_code(&syms).unwrap();
        let dec = Decoder::new(&spec).unwrap();
        assert_eq!(dec.decode(&bytes, syms.len()).unwrap(), syms);
        // One symbol ⇒ 1-bit codes ⇒ 1000 bits ⇒ 125 bytes.
        assert_eq!(bytes.len(), 125);
    }

    #[test]
    fn empty_stream() {
        let freq = FreqTable::from_symbols(&[1, 2, 3]);
        let spec = CodeSpec::build(&freq).unwrap();
        let enc = Encoder::new(&spec);
        let bytes = enc.encode_to_vec(&[]).unwrap();
        assert!(bytes.is_empty());
        let dec = Decoder::new(&spec).unwrap();
        assert_eq!(dec.decode(&bytes, 0).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn encoding_unknown_symbol_fails() {
        let freq = FreqTable::from_symbols(&[1, 1, 2]);
        let spec = CodeSpec::build(&freq).unwrap();
        let enc = Encoder::new(&spec);
        assert!(enc.encode_to_vec(&[3]).is_err());
    }

    #[test]
    fn lut_and_bit_serial_decoders_agree() {
        let mut rng = Rng::new(0xD0D0);
        for case in 0..30 {
            let levels = [2, 3, 16, 100, 256][case % 5];
            let n = 200 + rng.below(2000);
            let syms: Vec<u8> = (0..n).map(|_| rng.below(levels) as u8).collect();
            let (spec, bytes) = encode_with_own_code(&syms).unwrap();
            let dec = Decoder::new(&spec).unwrap();
            let fast = dec.decode(&bytes, syms.len()).unwrap();
            let slow = dec.decode_bit_serial(&bytes, syms.len()).unwrap();
            assert_eq!(fast, slow);
            assert_eq!(fast, syms);
        }
    }

    #[test]
    fn property_roundtrip_arbitrary_streams() {
        // Property-test: ANY byte stream roundtrips exactly.
        let mut rng = Rng::new(0x5EED);
        for _ in 0..100 {
            let n = 1 + rng.below(4096);
            // Mix distributions: uniform, heavily skewed, tiny alphabets.
            let mode = rng.below(3);
            let syms: Vec<u8> = (0..n)
                .map(|_| match mode {
                    0 => rng.below(256) as u8,
                    1 => {
                        if rng.f32() < 0.9 {
                            128
                        } else {
                            rng.below(256) as u8
                        }
                    }
                    _ => rng.below(2) as u8,
                })
                .collect();
            let (spec, bytes) = encode_with_own_code(&syms).unwrap();
            let dec = Decoder::new(&spec).unwrap();
            assert_eq!(dec.decode(&bytes, syms.len()).unwrap(), syms);
        }
    }

    #[test]
    fn prop_roundtrip_random_freq_tables_u4_and_u8_widths() {
        // prop-harness: arbitrary frequency tables over 4-bit and 8-bit
        // alphabets; a payload drawn from the table's support must
        // roundtrip exactly through the canonical code.
        crate::prop::forall(
            0xF00D,
            60,
            |rng| {
                let levels = if rng.below(2) == 0 { 16usize } else { 256 };
                let distinct = 1 + rng.below(levels);
                let mut pool: Vec<u8> = (0..levels).map(|x| x as u8).collect();
                rng.shuffle(&mut pool);
                let support: Vec<u8> = pool.into_iter().take(distinct).collect();
                let weights: Vec<f32> =
                    support.iter().map(|_| 1.0 + rng.below(1000) as f32).collect();
                let n = 1 + rng.below(3000);
                let payload: Vec<u8> =
                    (0..n).map(|_| support[rng.categorical(&weights)]).collect();
                (support, payload)
            },
            |(support, payload)| {
                let mut freq = FreqTable::from_symbols(payload);
                // Support symbols absent from the payload still get codes.
                freq.add_symbols(support);
                let spec = CodeSpec::build(&freq).map_err(|e| e.to_string())?;
                let bytes = Encoder::new(&spec)
                    .encode_to_vec(payload)
                    .map_err(|e| e.to_string())?;
                let dec = Decoder::new(&spec).map_err(|e| e.to_string())?;
                let out = dec
                    .decode(&bytes, payload.len())
                    .map_err(|e| e.to_string())?;
                if &out == payload {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }

    #[test]
    fn prop_degenerate_single_symbol_table() {
        // A table with one distinct symbol must produce the 1-bit code
        // and roundtrip any repetition count, including zero.
        crate::prop::forall(
            0x0D0,
            40,
            |rng| (rng.below(256) as u8, rng.below(2000)),
            |&(sym, n)| {
                let freq = FreqTable::from_symbols(&[sym]);
                let spec = CodeSpec::build(&freq).map_err(|e| e.to_string())?;
                if spec.lengths()[sym as usize] != 1 {
                    return Err(format!(
                        "degenerate code length {} != 1",
                        spec.lengths()[sym as usize]
                    ));
                }
                let payload = vec![sym; n];
                let bytes = Encoder::new(&spec)
                    .encode_to_vec(&payload)
                    .map_err(|e| e.to_string())?;
                if bytes.len() != n.div_ceil(8) {
                    return Err(format!("{} bytes for {n} one-bit symbols", bytes.len()));
                }
                let dec = Decoder::new(&spec).map_err(|e| e.to_string())?;
                let fast = dec.decode(&bytes, n).map_err(|e| e.to_string())?;
                let slow = dec.decode_bit_serial(&bytes, n).map_err(|e| e.to_string())?;
                if fast == payload && slow == payload {
                    Ok(())
                } else {
                    Err("degenerate roundtrip mismatch".into())
                }
            },
        );
    }

    #[test]
    fn prop_decode_into_equals_bit_serial_on_random_payloads() {
        // The LUT hot path and the bit-serial oracle must agree on any
        // distribution shape the shared generator produces.
        crate::prop::forall(
            0x5EAD,
            60,
            |rng| crate::prop::gen::symbols(rng, 2000),
            |syms| {
                let (spec, bytes) = encode_with_own_code(syms).map_err(|e| e.to_string())?;
                let dec = Decoder::new(&spec).map_err(|e| e.to_string())?;
                let mut fast = vec![0u8; syms.len()];
                dec.decode_into(&bytes, &mut fast).map_err(|e| e.to_string())?;
                let slow = dec
                    .decode_bit_serial(&bytes, syms.len())
                    .map_err(|e| e.to_string())?;
                if fast != slow {
                    return Err("LUT and bit-serial decoders disagree".into());
                }
                if &fast != syms {
                    return Err("decode does not invert encode".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn spec_survives_length_serialization() {
        // The ELM container persists only the 256-byte length array.
        let syms = gaussian_symbols(10_000, 256, 0xE1);
        let (spec, bytes) = encode_with_own_code(&syms).unwrap();
        let lengths = spec.lengths().to_vec();
        let spec2 = CodeSpec::from_lengths(&lengths).unwrap();
        assert_eq!(spec.codes(), spec2.codes());
        let dec = Decoder::new(&spec2).unwrap();
        assert_eq!(dec.decode(&bytes, syms.len()).unwrap(), syms);
    }
}
