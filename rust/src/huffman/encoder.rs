//! Bit-serial Huffman encoder.
//!
//! Encoding happens once, "in the cloud" (Algorithm 1, `CLOUD
//! PROCESSING`), so it favors clarity over speed; the *decoder* is the
//! edge-side hot path.

use super::code::{CodeSpec, ALPHABET};
use crate::bitio::BitWriter;
use crate::{Error, Result};

/// Symbol-stream encoder for a fixed [`CodeSpec`].
#[derive(Debug, Clone)]
pub struct Encoder {
    codes: [u32; ALPHABET],
    lengths: [u8; ALPHABET],
}

impl Encoder {
    /// Encoder for the given code.
    pub fn new(spec: &CodeSpec) -> Self {
        Encoder {
            codes: *spec.codes(),
            lengths: *spec.lengths(),
        }
    }

    /// Append the encoding of `symbols` to `w`.
    ///
    /// Fails on a symbol that has no codeword (i.e. one that never
    /// appeared in the frequency table the code was built from).
    pub fn encode(&self, symbols: &[u8], w: &mut BitWriter) -> Result<()> {
        for &s in symbols {
            let len = self.lengths[s as usize];
            if len == 0 {
                return Err(Error::InvalidArg(format!(
                    "symbol {s} has no codeword in this CodeSpec"
                )));
            }
            w.write_bits(self.codes[s as usize] as u64, len);
        }
        Ok(())
    }

    /// Encode into a fresh byte vector (zero-padded to a whole byte —
    /// segments in the ELM container are byte-aligned, §III-C).
    pub fn encode_to_vec(&self, symbols: &[u8]) -> Result<Vec<u8>> {
        let mut w = BitWriter::with_capacity(symbols.len() / 2 + 8);
        self.encode(symbols, &mut w)?;
        Ok(w.into_bytes())
    }

    /// Exact encoded bit count for `symbols` (without encoding).
    pub fn bit_len(&self, symbols: &[u8]) -> Result<usize> {
        let mut bits = 0usize;
        for &s in symbols {
            let len = self.lengths[s as usize];
            if len == 0 {
                return Err(Error::InvalidArg(format!(
                    "symbol {s} has no codeword in this CodeSpec"
                )));
            }
            bits += len as usize;
        }
        Ok(bits)
    }
}

#[cfg(test)]
mod tests {
    use super::super::code::FreqTable;
    use super::*;

    #[test]
    fn bit_len_matches_actual_encoding() {
        let syms: Vec<u8> = (0..255u8).chain(0..100).collect();
        let spec = CodeSpec::build(&FreqTable::from_symbols(&syms)).unwrap();
        let enc = Encoder::new(&spec);
        let bits = enc.bit_len(&syms).unwrap();
        let bytes = enc.encode_to_vec(&syms).unwrap();
        assert_eq!(bytes.len(), bits.div_ceil(8));
    }

    #[test]
    fn frequent_symbols_get_short_codes() {
        let mut syms = vec![0u8; 1000];
        syms.extend_from_slice(&[1, 2, 3, 4, 5]);
        let spec = CodeSpec::build(&FreqTable::from_symbols(&syms)).unwrap();
        let l = spec.lengths();
        assert!(l[0] < l[1], "dominant symbol must be shortest");
    }
}
