//! Table-driven Huffman decoder — the edge-side hot path.
//!
//! The decoder materializes a flat lookup table indexed by the next
//! `max_len` bits of the stream: entry `i` holds the symbol whose
//! codeword prefixes `i` and that codeword's length. Decoding one symbol
//! is then a single `peek` + table load + `consume` — no per-bit tree
//! walking. This is the standard construction used by production
//! inflate/zstd decoders and is what makes the paper's "parallel decode
//! in 1.66 s for 3.8 B parameters" plausible on four A57 cores.
//!
//! A bit-serial canonical decoder is kept alongside as a correctness
//! oracle ([`Decoder::decode_bit_serial`]).

use super::code::{CodeSpec, ALPHABET};
use crate::bitio::BitReader;
use crate::{Error, Result};

/// One LUT entry: the decoded symbol and its code length in bits.
/// Packed into 2 bytes so a 16-bit table stays L2-resident (128 KiB).
#[derive(Debug, Clone, Copy, Default)]
struct Entry {
    symbol: u8,
    len: u8, // 0 marks an invalid (unreachable) prefix
}

/// Fast table-driven decoder for one [`CodeSpec`].
pub struct Decoder {
    table: Vec<Entry>,
    probe_bits: u8,
    /// True when the code exactly fills the probe space (Kraft sum
    /// equals 1): every probe value maps to a symbol, so the hot loop
    /// needs no validity branch. Canonical codes built from real
    /// frequency tables are always complete except the degenerate
    /// single-symbol code.
    complete: bool,
    /// Canonical-decode metadata for the bit-serial oracle:
    /// `first_code[l]`, `first_index[l]` per length, plus symbols sorted
    /// by (length, symbol).
    first_code: [u32; 17],
    first_index: [u32; 17],
    sorted_symbols: Vec<u8>,
    max_len: u8,
}

impl Decoder {
    /// Build the LUT (`2^max_len` entries) for `spec`.
    pub fn new(spec: &CodeSpec) -> Result<Self> {
        let max_len = spec.max_len();
        debug_assert!(max_len >= 1 && max_len <= 16);
        let probe_bits = max_len;
        let size = 1usize << probe_bits;
        let mut table = vec![Entry::default(); size];
        let mut filled = 0usize;
        for s in 0..ALPHABET {
            let len = spec.lengths()[s];
            if len == 0 {
                continue;
            }
            let code = spec.codes()[s];
            // Every probe window that starts with this codeword maps to s.
            let shift = probe_bits - len;
            let lo = (code as usize) << shift;
            let hi = lo + (1usize << shift);
            filled += hi - lo;
            for e in &mut table[lo..hi] {
                *e = Entry {
                    symbol: s as u8,
                    len,
                };
            }
        }
        let complete = filled == size;

        // Canonical metadata for the oracle decoder.
        let mut count = [0u32; 17];
        for &l in spec.lengths().iter() {
            if l > 0 {
                count[l as usize] += 1;
            }
        }
        let mut first_code = [0u32; 17];
        let mut first_index = [0u32; 17];
        let mut index = 0u32;
        for l in 1..=16usize {
            first_code[l] = if l == 1 {
                0
            } else {
                (first_code[l - 1] + count[l - 1]) << 1
            };
            first_index[l] = index;
            index += count[l];
        }
        let mut sorted: Vec<(u8, u8)> = (0..ALPHABET)
            .filter(|&s| spec.lengths()[s] > 0)
            .map(|s| (spec.lengths()[s], s as u8))
            .collect();
        sorted.sort_unstable();
        let sorted_symbols = sorted.into_iter().map(|(_, s)| s).collect();

        Ok(Decoder {
            table,
            probe_bits,
            complete,
            first_code,
            first_index,
            sorted_symbols,
            max_len,
        })
    }

    /// Width of the LUT probe in bits.
    pub fn probe_bits(&self) -> u8 {
        self.probe_bits
    }

    /// LUT memory footprint in bytes (reported by the device model —
    /// it must stay L2-resident on the edge target).
    pub fn table_bytes(&self) -> usize {
        self.table.len() * std::mem::size_of::<Entry>()
    }

    /// Decode exactly `n` symbols from `bytes` into a new vector.
    pub fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<u8>> {
        let mut out = vec![0u8; n];
        self.decode_into(bytes, &mut out)?;
        Ok(out)
    }

    /// Decode exactly `out.len()` symbols from `bytes` into `out`.
    ///
    /// This is the per-segment hot loop of §III-C parallel decoding:
    /// each worker thread calls `decode_into` on its own (segment,
    /// output-slice) pairs with zero shared state.
    ///
    /// §Perf: hand-rolled bit feed instead of [`BitReader`] — a 64-bit
    /// accumulator refilled with whole-byte big-endian bulk loads, one
    /// table probe + shift per symbol, no per-symbol `Result` plumbing
    /// (validity is checked once at the end; a corrupt stream can only
    /// mis-decode, run the accumulator dry, or leave bits over — all
    /// detected). ~2× over the BitReader-based loop (EXPERIMENTS §Perf).
    pub fn decode_into(&self, bytes: &[u8], out: &mut [u8]) -> Result<()> {
        let total_bits = bytes.len() * 8;
        let probe_shift = 64 - self.probe_bits as u32;
        let table = &self.table[..];

        // Accumulator: upcoming bits left-aligned; `acc_bits` counts the
        // *loaded* bits (shifted-out low bits read as zero, which is
        // exactly the byte-alignment padding semantics).
        let mut acc: u64 = 0;
        let mut acc_bits: u32 = 0;
        let mut pos: usize = 0; // next byte to load
        let mut consumed: usize = 0; // bits consumed across the stream

        let mut refill = |acc: &mut u64, acc_bits: &mut u32, pos: &mut usize| {
            if *pos + 8 <= bytes.len() {
                // Bulk load: whole bytes only, masked so no partial
                // byte is double-loaded on the next refill.
                let chunk = u64::from_be_bytes(bytes[*pos..*pos + 8].try_into().unwrap());
                let nbytes = ((64 - *acc_bits) >> 3) as usize;
                let keep_bits = (nbytes * 8) as u32;
                let masked = if keep_bits == 64 {
                    chunk
                } else {
                    chunk & (!0u64 << (64 - keep_bits))
                };
                *acc |= masked >> *acc_bits;
                *pos += nbytes;
                *acc_bits += keep_bits;
            } else {
                while *acc_bits <= 56 && *pos < bytes.len() {
                    *acc |= (bytes[*pos] as u64) << (56 - *acc_bits);
                    *pos += 1;
                    *acc_bits += 8;
                }
            }
        };

        if self.complete {
            // Branch-free fast path: every probe is a valid entry, and
            // one refill (≥48 bits) covers 3 probes of ≤16 bits.
            let mut i = 0usize;
            let n = out.len();
            while i + 3 <= n {
                if acc_bits < 48 {
                    refill(&mut acc, &mut acc_bits, &mut pos);
                }
                for _ in 0..3 {
                    let e = table[(acc >> probe_shift) as usize];
                    let len = e.len as u32;
                    unsafe { *out.get_unchecked_mut(i) = e.symbol };
                    acc <<= len;
                    acc_bits = acc_bits.saturating_sub(len);
                    consumed += len as usize;
                    i += 1;
                }
            }
            while i < n {
                if acc_bits < 48 {
                    refill(&mut acc, &mut acc_bits, &mut pos);
                }
                let e = table[(acc >> probe_shift) as usize];
                let len = e.len as u32;
                out[i] = e.symbol;
                acc <<= len;
                acc_bits = acc_bits.saturating_sub(len);
                consumed += len as usize;
                i += 1;
            }
        } else {
            for (i, slot) in out.iter_mut().enumerate() {
                if acc_bits < 48 {
                    refill(&mut acc, &mut acc_bits, &mut pos);
                }
                let e = table[(acc >> probe_shift) as usize];
                let len = e.len as u32;
                if len == 0 {
                    return Err(Error::Format(format!(
                        "corrupt huffman stream at symbol {i}"
                    )));
                }
                *slot = e.symbol;
                acc <<= len;
                acc_bits = acc_bits.saturating_sub(len);
                consumed += len as usize;
            }
        }
        if consumed > total_bits {
            return Err(Error::Format(format!(
                "huffman stream overrun: consumed {consumed} of {total_bits} bits"
            )));
        }
        // Trailing padding must be < 8 zero bits (byte alignment only).
        if total_bits - consumed >= 8 {
            return Err(Error::Format(format!(
                "{} unconsumed bits after decoding {} symbols",
                total_bits - consumed,
                out.len()
            )));
        }
        Ok(())
    }

    /// Bit-serial canonical decoder — the slow correctness oracle.
    pub fn decode_bit_serial(&self, bytes: &[u8], n: usize) -> Result<Vec<u8>> {
        let mut r = BitReader::new(bytes);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let mut code = 0u32;
            let mut len = 0u8;
            loop {
                if r.remaining_bits() == 0 {
                    return Err(Error::Format(format!(
                        "stream exhausted at symbol {i} (bit-serial)"
                    )));
                }
                code = (code << 1) | r.read_bits(1)?;
                len += 1;
                if len > self.max_len {
                    return Err(Error::Format("no codeword matches (bit-serial)".into()));
                }
                // Canonical property: at length l, valid codes are
                // [first_code[l], first_code[l] + count[l]).
                let l = len as usize;
                let idx_base = self.first_index[l];
                let next_base = if l < 16 {
                    self.first_index[l + 1]
                } else {
                    self.sorted_symbols.len() as u32
                };
                let count = next_base - idx_base;
                if count > 0 && code >= self.first_code[l] && code < self.first_code[l] + count {
                    let idx = idx_base + (code - self.first_code[l]);
                    out.push(self.sorted_symbols[idx as usize]);
                    break;
                }
            }
        }
        Ok(out)
    }
}

impl std::fmt::Debug for Decoder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Decoder")
            .field("probe_bits", &self.probe_bits)
            .field("table_entries", &self.table.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::super::code::FreqTable;
    use super::super::encoder::Encoder;
    use super::*;
    use crate::rng::Rng;

    fn spec_for(symbols: &[u8]) -> CodeSpec {
        CodeSpec::build(&FreqTable::from_symbols(symbols)).unwrap()
    }

    #[test]
    fn decode_into_reuses_buffer() {
        let syms: Vec<u8> = (0..200u8).collect();
        let spec = spec_for(&syms);
        let bytes = Encoder::new(&spec).encode_to_vec(&syms).unwrap();
        let dec = Decoder::new(&spec).unwrap();
        let mut out = vec![0u8; syms.len()];
        dec.decode_into(&bytes, &mut out).unwrap();
        assert_eq!(out, syms);
    }

    #[test]
    fn corrupt_stream_is_detected_not_panicking() {
        let syms: Vec<u8> = (0..=50u8).cycle().take(5000).collect();
        let spec = spec_for(&syms);
        let mut bytes = Encoder::new(&spec).encode_to_vec(&syms).unwrap();
        // Flip bits throughout; decoder must either error or produce
        // *some* output, never panic / read OOB.
        let mut rng = Rng::new(77);
        for _ in 0..50 {
            let i = rng.below(bytes.len());
            bytes[i] ^= 1 << rng.below(8);
        }
        let dec = Decoder::new(&spec).unwrap();
        let _ = dec.decode(&bytes, syms.len()); // must not panic
    }

    #[test]
    fn truncated_stream_errors() {
        let syms: Vec<u8> = (0..100u8).cycle().take(10_000).collect();
        let spec = spec_for(&syms);
        let bytes = Encoder::new(&spec).encode_to_vec(&syms).unwrap();
        let dec = Decoder::new(&spec).unwrap();
        let res = dec.decode(&bytes[..bytes.len() / 2], syms.len());
        assert!(res.is_err());
    }

    #[test]
    fn excess_trailing_bytes_error() {
        let syms = vec![1u8, 2, 3, 1, 2, 3];
        let spec = spec_for(&syms);
        let mut bytes = Encoder::new(&spec).encode_to_vec(&syms).unwrap();
        bytes.extend_from_slice(&[0, 0, 0, 0]);
        let dec = Decoder::new(&spec).unwrap();
        assert!(dec.decode(&bytes, syms.len()).is_err());
    }

    /// Seeded differential fuzz: the table-driven hot path
    /// ([`Decoder::decode_into`]) against the bit-serial canonical
    /// oracle ([`Decoder::decode_bit_serial`]) on valid, truncated,
    /// and bit-flipped streams. On every input the two must agree —
    /// identical output or both reject. The oracle does not itself
    /// check the byte-alignment padding invariant `decode_into`
    /// enforces (< 8 leftover bits), so the check re-applies it from
    /// the code lengths before comparing. `ENTROLLM_FUZZ_CASES`
    /// bounds the case count (CI smoke runs a small budget); failures
    /// print a replay seed for [`crate::prop::forall_seeded`].
    #[test]
    fn differential_fuzz_decode_into_vs_bit_serial() {
        let cases: usize = std::env::var("ENTROLLM_FUZZ_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(200);
        crate::prop::forall(
            0xD1FF_CA5E,
            cases,
            |rng| {
                let syms = crate::prop::gen::symbols(rng, 2000);
                let spec = spec_for(&syms);
                let mut bytes = Encoder::new(&spec).encode_to_vec(&syms).unwrap();
                let label = match rng.below(3) {
                    0 => "valid",
                    1 => {
                        bytes.truncate(rng.below(bytes.len() + 1));
                        "truncated"
                    }
                    _ => {
                        for _ in 0..1 + rng.below(8) {
                            let i = rng.below(bytes.len());
                            bytes[i] ^= 1 << rng.below(8);
                        }
                        "bit-flipped"
                    }
                };
                (label, syms, bytes)
            },
            |(label, syms, bytes)| {
                let spec = spec_for(syms);
                let dec = Decoder::new(&spec).unwrap();
                let total_bits = bytes.len() * 8;

                let mut buf = vec![0u8; syms.len()];
                let fast = dec.decode_into(bytes, &mut buf).map(|()| buf);

                // Oracle, with decode_into's padding invariant applied
                // on top (consumed bits = sum of decoded code lengths;
                // the oracle never over-reads, it errors on exhaustion).
                let oracle = dec.decode_bit_serial(bytes, syms.len()).and_then(|out| {
                    let consumed: usize = out
                        .iter()
                        .map(|&s| spec.lengths()[s as usize] as usize)
                        .sum();
                    if total_bits - consumed >= 8 {
                        Err(Error::Format(format!(
                            "{} unconsumed bits (oracle padding check)",
                            total_bits - consumed
                        )))
                    } else {
                        Ok(out)
                    }
                });

                match (fast, oracle) {
                    (Ok(a), Ok(b)) if a != b => {
                        Err(format!("{label}: both decoded but outputs differ"))
                    }
                    (Ok(a), Ok(_)) if *label == "valid" && a != *syms => {
                        Err(format!("{label}: decoded output differs from the encoded symbols"))
                    }
                    (Ok(_), Ok(_)) | (Err(_), Err(_)) => Ok(()),
                    (Ok(_), Err(e)) => {
                        Err(format!("{label}: LUT accepted a stream the oracle rejects ({e})"))
                    }
                    (Err(e), Ok(_)) => {
                        Err(format!("{label}: LUT rejected a stream the oracle accepts ({e})"))
                    }
                }
            },
        );
    }

    #[test]
    fn table_bytes_bounded_by_l2() {
        // The LUT must fit the Jetson's 2 MiB shared L2 with room to spare.
        let mut rng = Rng::new(1);
        let syms: Vec<u8> = (0..100_000).map(|_| rng.below(256) as u8).collect();
        let spec = spec_for(&syms);
        let dec = Decoder::new(&spec).unwrap();
        assert!(dec.table_bytes() <= 128 * 1024);
    }
}
