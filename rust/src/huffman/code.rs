//! Code construction: frequency tables, optimal code lengths, canonical
//! code assignment.
//!
//! Two length-derivation algorithms are implemented and cross-checked:
//!
//! * `huffman_lengths` — the classic two-queue O(n log n) Huffman tree
//!   (unbounded depth), used when the optimal tree already fits in
//!   [`MAX_CODE_LEN`] bits (always true for the Gaussian-ish weight
//!   histograms the paper targets, but not for adversarial inputs);
//! * `package_merge_lengths` — the Larmore–Hirschberg package-merge
//!   algorithm producing *optimal length-limited* codes, used as the
//!   fallback so the LUT decoder's probe width stays bounded.

use crate::{Error, Result};

/// Alphabet size: quantized weights are uint4/uint8 symbols.
pub const ALPHABET: usize = 256;

/// Hard cap on code length. 16 bits keeps the decoder LUT at 2^16
/// entries (128 KiB of u16s) — it fits in an edge CPU's L2, which is the
/// paper's deployment regime (the Jetson A57 has a 2 MiB shared L2).
pub const MAX_CODE_LEN: u8 = 16;

/// Symbol frequency table over the 256-symbol alphabet.
#[derive(Debug, Clone)]
pub struct FreqTable {
    counts: [u64; ALPHABET],
}

impl Default for FreqTable {
    fn default() -> Self {
        FreqTable {
            counts: [0; ALPHABET],
        }
    }
}

impl FreqTable {
    /// Empty table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Count the symbols of one stream.
    pub fn from_symbols(symbols: &[u8]) -> Self {
        let mut t = Self::new();
        t.add_symbols(symbols);
        t
    }

    /// Accumulate more symbols (Algorithm 1 line 11 pools counts across
    /// *all* layers into one table).
    pub fn add_symbols(&mut self, symbols: &[u8]) {
        for &s in symbols {
            self.counts[s as usize] += 1;
        }
    }

    /// Add `count` occurrences of one symbol directly (saturating).
    /// Lets tests and table builders express frequencies too large to
    /// enumerate symbol by symbol (e.g. near-u64 saturation).
    pub fn add_count(&mut self, symbol: u8, count: u64) {
        let slot = &mut self.counts[symbol as usize];
        *slot = slot.saturating_add(count);
    }

    /// Merge another table into this one.
    pub fn merge(&mut self, other: &FreqTable) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
    }

    /// Count for one symbol.
    pub fn count(&self, symbol: u8) -> u64 {
        self.counts[symbol as usize]
    }

    /// Raw counts.
    pub fn counts(&self) -> &[u64; ALPHABET] {
        &self.counts
    }

    /// Total symbols counted.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Number of symbols with non-zero frequency.
    pub fn distinct(&self) -> usize {
        self.counts.iter().filter(|&&c| c > 0).count()
    }

    /// Empirical probabilities (zero for absent symbols).
    pub fn probabilities(&self) -> Vec<f64> {
        let total = self.total() as f64;
        self.counts
            .iter()
            .map(|&c| if total > 0.0 { c as f64 / total } else { 0.0 })
            .collect()
    }
}

/// Classic Huffman code lengths via the sorted two-queue method.
/// Returns per-symbol lengths (0 for absent symbols); depth unbounded.
fn huffman_lengths(freq: &FreqTable) -> [u8; ALPHABET] {
    let mut lengths = [0u8; ALPHABET];
    let present: Vec<usize> = (0..ALPHABET).filter(|&s| freq.counts[s] > 0).collect();
    match present.len() {
        0 => return lengths,
        1 => {
            // Degenerate: a single symbol still needs 1 bit so the
            // bitstream length is well-defined.
            lengths[present[0]] = 1;
            return lengths;
        }
        _ => {}
    }

    // Leaves sorted ascending by count; two-queue merge is O(n).
    let mut leaves: Vec<(u64, usize)> = present.iter().map(|&s| (freq.counts[s], s)).collect();
    leaves.sort_unstable();

    // Node arena: (weight, left, right); leaves have usize::MAX children.
    #[derive(Clone, Copy)]
    struct Node {
        weight: u64,
        kids: Option<(usize, usize)>,
        symbol: usize,
    }
    let mut arena: Vec<Node> = leaves
        .iter()
        .map(|&(w, s)| Node {
            weight: w,
            kids: None,
            symbol: s,
        })
        .collect();

    let mut q1: std::collections::VecDeque<usize> = (0..arena.len()).collect();
    let mut q2: std::collections::VecDeque<usize> = std::collections::VecDeque::new();

    let pop_min = |q1: &mut std::collections::VecDeque<usize>,
                       q2: &mut std::collections::VecDeque<usize>,
                       arena: &Vec<Node>|
     -> usize {
        match (q1.front(), q2.front()) {
            (Some(&a), Some(&b)) => {
                if arena[a].weight <= arena[b].weight {
                    q1.pop_front().unwrap()
                } else {
                    q2.pop_front().unwrap()
                }
            }
            (Some(_), None) => q1.pop_front().unwrap(),
            (None, Some(_)) => q2.pop_front().unwrap(),
            (None, None) => unreachable!("empty queues"),
        }
    };

    while q1.len() + q2.len() > 1 {
        let a = pop_min(&mut q1, &mut q2, &arena);
        let b = pop_min(&mut q1, &mut q2, &arena);
        let merged = Node {
            weight: arena[a].weight + arena[b].weight,
            kids: Some((a, b)),
            symbol: usize::MAX,
        };
        arena.push(merged);
        q2.push_back(arena.len() - 1);
    }
    let root = pop_min(&mut q1, &mut q2, &arena);

    // Depth-first traversal assigns depths = code lengths.
    let mut stack = vec![(root, 0u8)];
    while let Some((idx, depth)) = stack.pop() {
        match arena[idx].kids {
            Some((l, r)) => {
                stack.push((l, depth + 1));
                stack.push((r, depth + 1));
            }
            None => lengths[arena[idx].symbol] = depth.max(1),
        }
    }
    lengths
}

/// Optimal length-limited code lengths via package-merge.
///
/// `limit` must satisfy `2^limit >= distinct symbols`. O(limit · n log n).
fn package_merge_lengths(freq: &FreqTable, limit: u8) -> Result<[u8; ALPHABET]> {
    let present: Vec<usize> = (0..ALPHABET).filter(|&s| freq.counts[s] > 0).collect();
    let n = present.len();
    let mut lengths = [0u8; ALPHABET];
    if n == 0 {
        return Ok(lengths);
    }
    if n == 1 {
        lengths[present[0]] = 1;
        return Ok(lengths);
    }
    if (1usize << limit.min(31)) < n {
        return Err(Error::InvalidArg(format!(
            "cannot code {n} symbols within {limit} bits"
        )));
    }

    // A package is a set of original symbols with a combined weight.
    #[derive(Clone)]
    struct Pkg {
        weight: u64,
        // Count per present-symbol index; packages are small so a Vec of
        // (idx, count) pairs keeps memory proportional to content.
        syms: Vec<(u16, u16)>,
    }
    fn merge_syms(a: &[(u16, u16)], b: &[(u16, u16)]) -> Vec<(u16, u16)> {
        let mut out: Vec<(u16, u16)> = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0, 0);
        while i < a.len() && j < b.len() {
            match a[i].0.cmp(&b[j].0) {
                std::cmp::Ordering::Less => {
                    out.push(a[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    out.push(b[j]);
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    out.push((a[i].0, a[i].1 + b[j].1));
                    i += 1;
                    j += 1;
                }
            }
        }
        out.extend_from_slice(&a[i..]);
        out.extend_from_slice(&b[j..]);
        out
    }

    let mut leaves: Vec<Pkg> = present
        .iter()
        .enumerate()
        .map(|(i, &s)| Pkg {
            weight: freq.counts[s],
            syms: vec![(i as u16, 1)],
        })
        .collect();
    leaves.sort_by_key(|p| p.weight);

    // Level 1 (deepest) starts as the leaves; each subsequent level is
    // leaves ∪ pairwise-packages(previous level), sorted by weight.
    let mut level = leaves.clone();
    for _ in 1..limit {
        let mut packaged: Vec<Pkg> = level
            .chunks(2)
            .filter(|c| c.len() == 2)
            .map(|c| Pkg {
                weight: c[0].weight + c[1].weight,
                syms: merge_syms(&c[0].syms, &c[1].syms),
            })
            .collect();
        packaged.extend(leaves.iter().cloned());
        packaged.sort_by_key(|p| p.weight);
        level = packaged;
    }

    // Take the 2n-2 cheapest packages at the top level; each occurrence
    // of a symbol adds one to its code length.
    let take = 2 * n - 2;
    if level.len() < take {
        return Err(Error::InvalidArg(
            "package-merge: not enough packages (limit too small)".into(),
        ));
    }
    let mut len_per_present = vec![0u32; n];
    for pkg in level.iter().take(take) {
        for &(idx, cnt) in &pkg.syms {
            len_per_present[idx as usize] += cnt as u32;
        }
    }
    for (i, &s) in present.iter().enumerate() {
        debug_assert!(len_per_present[i] >= 1 && len_per_present[i] <= limit as u32);
        lengths[s] = len_per_present[i] as u8;
    }
    Ok(lengths)
}

/// A complete canonical code: per-symbol lengths and codewords.
///
/// Canonical form means codes are fully determined by the length array:
/// symbols are sorted by `(length, symbol)` and assigned consecutive
/// codewords. The ELM container therefore persists only the lengths
/// (256 bytes) — [`CodeSpec::from_lengths`] rebuilds everything.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodeSpec {
    lengths: [u8; ALPHABET],
    codes: [u32; ALPHABET],
    max_len: u8,
}

impl CodeSpec {
    /// Build an optimal (length-limited) canonical code for `freq`.
    pub fn build(freq: &FreqTable) -> Result<Self> {
        if freq.distinct() == 0 {
            return Err(Error::InvalidArg("CodeSpec::build: empty frequency table".into()));
        }
        let lengths = huffman_lengths(freq);
        let max = lengths.iter().copied().max().unwrap();
        let lengths = if max > MAX_CODE_LEN {
            package_merge_lengths(freq, MAX_CODE_LEN)?
        } else {
            lengths
        };
        Self::from_lengths(&lengths)
    }

    /// Reconstruct a canonical code from a length array (e.g. loaded from
    /// an ELM container). Validates the Kraft inequality.
    pub fn from_lengths(lengths: &[u8]) -> Result<Self> {
        if lengths.len() != ALPHABET {
            return Err(Error::Format(format!(
                "code length array has {} entries, want {ALPHABET}",
                lengths.len()
            )));
        }
        let mut arr = [0u8; ALPHABET];
        arr.copy_from_slice(lengths);
        let max_len = arr.iter().copied().max().unwrap_or(0);
        if max_len > MAX_CODE_LEN {
            return Err(Error::Format(format!(
                "code length {max_len} exceeds max {MAX_CODE_LEN}"
            )));
        }
        if max_len == 0 {
            return Err(Error::Format("no symbols in code".into()));
        }
        // Kraft: sum 2^-len <= 1 (we allow < 1 for the degenerate
        // 1-symbol code, which uses half the code space).
        let kraft: u64 = arr
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 1u64 << (MAX_CODE_LEN - l))
            .sum();
        if kraft > 1u64 << MAX_CODE_LEN {
            return Err(Error::Format("code lengths violate Kraft inequality".into()));
        }

        // Canonical assignment: first code of length L is
        // (first_code[L-1] + count[L-1]) << 1.
        let mut count = [0u32; (MAX_CODE_LEN + 1) as usize];
        for &l in arr.iter() {
            if l > 0 {
                count[l as usize] += 1;
            }
        }
        let mut next = [0u32; (MAX_CODE_LEN + 1) as usize];
        let mut code = 0u32;
        for l in 1..=MAX_CODE_LEN as usize {
            code = (code + count[l - 1]) << 1;
            next[l] = code;
        }
        let mut codes = [0u32; ALPHABET];
        for s in 0..ALPHABET {
            let l = arr[s] as usize;
            if l > 0 {
                codes[s] = next[l];
                next[l] += 1;
            }
        }
        Ok(CodeSpec {
            lengths: arr,
            codes,
            max_len,
        })
    }

    /// Per-symbol code lengths (0 = absent).
    pub fn lengths(&self) -> &[u8; ALPHABET] {
        &self.lengths
    }

    /// Per-symbol canonical codewords (valid where length > 0).
    pub fn codes(&self) -> &[u32; ALPHABET] {
        &self.codes
    }

    /// Longest codeword.
    pub fn max_len(&self) -> u8 {
        self.max_len
    }

    /// Expected bits/symbol of this code under `freq` — the paper's
    /// "effective bits" when `freq` is the model's own histogram.
    pub fn expected_bits(&self, freq: &FreqTable) -> f64 {
        let total = freq.total();
        if total == 0 {
            return 0.0;
        }
        let bits: u64 = (0..ALPHABET)
            .map(|s| freq.counts[s] * self.lengths[s] as u64)
            .sum();
        bits as f64 / total as f64
    }

    /// Exact encoded size in bits for a symbol stream described by `freq`.
    pub fn encoded_bits(&self, freq: &FreqTable) -> u64 {
        (0..ALPHABET)
            .map(|s| freq.counts[s] * self.lengths[s] as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::shannon_entropy;
    use crate::rng::Rng;

    fn table(counts: &[(u8, u64)]) -> FreqTable {
        let mut t = FreqTable::new();
        for &(s, c) in counts {
            t.counts[s as usize] = c;
        }
        t
    }

    #[test]
    fn freq_table_counts_and_merges() {
        let mut a = FreqTable::from_symbols(&[1, 1, 2]);
        let b = FreqTable::from_symbols(&[2, 3]);
        a.merge(&b);
        assert_eq!(a.count(1), 2);
        assert_eq!(a.count(2), 2);
        assert_eq!(a.count(3), 1);
        assert_eq!(a.total(), 5);
        assert_eq!(a.distinct(), 3);
    }

    #[test]
    fn textbook_example_lengths() {
        // Freqs 5,9,12,13,16,45 — the classic example; optimal lengths
        // are 4,4,3,3,3,1.
        let t = table(&[(0, 5), (1, 9), (2, 12), (3, 13), (4, 16), (5, 45)]);
        let spec = CodeSpec::build(&t).unwrap();
        let l = spec.lengths();
        assert_eq!(&l[0..6], &[4, 4, 3, 3, 3, 1]);
    }

    #[test]
    fn kraft_equality_for_full_codes() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let n = 2 + rng.below(200);
            let mut t = FreqTable::new();
            for s in 0..n {
                t.counts[s] = 1 + rng.below(10_000) as u64;
            }
            let spec = CodeSpec::build(&t).unwrap();
            let kraft: f64 = spec
                .lengths()
                .iter()
                .filter(|&&l| l > 0)
                .map(|&l| 2f64.powi(-(l as i32)))
                .sum();
            assert!((kraft - 1.0).abs() < 1e-9, "kraft {kraft}");
        }
    }

    #[test]
    fn canonical_codes_are_prefix_free() {
        let mut rng = Rng::new(17);
        let mut t = FreqTable::new();
        for s in 0..256 {
            t.counts[s] = 1 + rng.below(100_000) as u64;
        }
        let spec = CodeSpec::build(&t).unwrap();
        let pairs: Vec<(u32, u8)> = (0..ALPHABET)
            .filter(|&s| spec.lengths()[s] > 0)
            .map(|s| (spec.codes()[s], spec.lengths()[s]))
            .collect();
        for (i, &(ca, la)) in pairs.iter().enumerate() {
            for &(cb, lb) in &pairs[i + 1..] {
                let (short, ls, long, ll) = if la <= lb {
                    (ca, la, cb, lb)
                } else {
                    (cb, lb, ca, la)
                };
                assert_ne!(
                    short,
                    long >> (ll - ls),
                    "code {short:0ls$b} prefixes {long:0ll$b}",
                    ls = ls as usize,
                    ll = ll as usize
                );
            }
        }
    }

    #[test]
    fn average_length_within_entropy_plus_one() {
        // Shannon: H <= avg_len < H + 1 for optimal codes.
        let mut rng = Rng::new(99);
        for _ in 0..10 {
            let mut t = FreqTable::new();
            for s in 0..256 {
                // Zipf-ish skew.
                t.counts[s] = (100_000 / (s as u64 + 1)) + rng.below(10) as u64;
            }
            let spec = CodeSpec::build(&t).unwrap();
            let h = shannon_entropy(t.counts());
            let avg = spec.expected_bits(&t);
            assert!(avg >= h - 1e-9, "avg {avg} < H {h}");
            assert!(avg < h + 1.0, "avg {avg} >= H+1 {}", h + 1.0);
        }
    }

    #[test]
    fn length_limit_is_enforced() {
        // Fibonacci-like weights force deep Huffman trees; the limiter
        // must cap at MAX_CODE_LEN while staying a valid code.
        let mut t = FreqTable::new();
        let (mut a, mut b) = (1u64, 1u64);
        for s in 0..40 {
            t.counts[s] = a;
            let c = a + b;
            a = b;
            b = c;
        }
        let spec = CodeSpec::build(&t).unwrap();
        assert!(spec.max_len() <= MAX_CODE_LEN);
        let kraft: f64 = spec
            .lengths()
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32)))
            .sum();
        assert!(kraft <= 1.0 + 1e-9);
        // Still near-optimal: within 1% of unlimited average length.
        let h = shannon_entropy(t.counts());
        assert!(spec.expected_bits(&t) < h + 1.0);
    }

    #[test]
    fn package_merge_matches_huffman_when_unconstrained() {
        // With a generous limit, package-merge total cost must equal
        // Huffman's (both optimal).
        let mut rng = Rng::new(123);
        for _ in 0..20 {
            let n = 2 + rng.below(50);
            let mut t = FreqTable::new();
            for s in 0..n {
                t.counts[s] = 1 + rng.below(1000) as u64;
            }
            let h_len = huffman_lengths(&t);
            let p_len = package_merge_lengths(&t, MAX_CODE_LEN).unwrap();
            let cost = |lens: &[u8; ALPHABET]| -> u64 {
                (0..ALPHABET).map(|s| t.counts[s] * lens[s] as u64).sum()
            };
            if h_len.iter().copied().max().unwrap() <= MAX_CODE_LEN {
                assert_eq!(cost(&h_len), cost(&p_len));
            }
        }
    }

    #[test]
    fn from_lengths_rejects_bad_input() {
        assert!(CodeSpec::from_lengths(&[1u8; 10]).is_err()); // wrong size
        let zeros = [0u8; ALPHABET];
        assert!(CodeSpec::from_lengths(&zeros).is_err()); // empty
        let mut too_long = [0u8; ALPHABET];
        too_long[0] = MAX_CODE_LEN + 1;
        too_long[1] = 1;
        assert!(CodeSpec::from_lengths(&too_long).is_err());
        // Kraft violation: three 1-bit codes.
        let mut kraft = [0u8; ALPHABET];
        kraft[0] = 1;
        kraft[1] = 1;
        kraft[2] = 1;
        assert!(CodeSpec::from_lengths(&kraft).is_err());
    }

    #[test]
    fn empty_table_is_an_error() {
        assert!(CodeSpec::build(&FreqTable::new()).is_err());
    }
}
