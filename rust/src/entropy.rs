//! Entropy statistics: Shannon entropy, effective bits, histograms.
//!
//! These back the paper's Table I "Effective Bits" rows and the Fig. 4
//! weight-distribution plots. "Effective bits" is the paper's headline
//! storage metric: total encoded bits divided by parameter count.

use crate::huffman::{CodeSpec, FreqTable};

/// Shannon entropy in bits/symbol of a count histogram.
pub fn shannon_entropy(counts: &[u64]) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let total = total as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / total;
            -p * p.log2()
        })
        .sum()
}

/// Effective bits per weight achieved by a Huffman code over its own
/// frequency table — the quantity reported in Table I.
pub fn effective_bits(freq: &FreqTable) -> crate::Result<f64> {
    let spec = CodeSpec::build(freq)?;
    Ok(spec.expected_bits(freq))
}

/// Summary statistics of a symbol distribution (Fig. 4 companion data).
#[derive(Debug, Clone)]
pub struct DistributionStats {
    /// Shannon entropy, bits/symbol.
    pub entropy: f64,
    /// Huffman effective bits/symbol.
    pub effective_bits: f64,
    /// Mean symbol value.
    pub mean: f64,
    /// Standard deviation of symbol values.
    pub std: f64,
    /// Skewness (3rd standardized moment).
    pub skewness: f64,
    /// Excess kurtosis (4th standardized moment − 3).
    pub kurtosis: f64,
    /// Fraction of mass in the single most frequent symbol.
    pub mode_mass: f64,
    /// Number of occupied levels.
    pub support: usize,
}

/// Compute [`DistributionStats`] from a frequency table.
pub fn distribution_stats(freq: &FreqTable) -> crate::Result<DistributionStats> {
    let counts = freq.counts();
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return Err(crate::Error::InvalidArg("empty distribution".into()));
    }
    let totf = total as f64;
    let mean: f64 = counts
        .iter()
        .enumerate()
        .map(|(v, &c)| v as f64 * c as f64)
        .sum::<f64>()
        / totf;
    let central = |p: i32| -> f64 {
        counts
            .iter()
            .enumerate()
            .map(|(v, &c)| (v as f64 - mean).powi(p) * c as f64)
            .sum::<f64>()
            / totf
    };
    let var = central(2);
    let std = var.sqrt();
    let (skewness, kurtosis) = if std > 0.0 {
        (central(3) / std.powi(3), central(4) / var.powi(2) - 3.0)
    } else {
        (0.0, 0.0)
    };
    let mode_mass = *counts.iter().max().unwrap() as f64 / totf;
    Ok(DistributionStats {
        entropy: shannon_entropy(counts),
        effective_bits: effective_bits(freq)?,
        mean,
        std,
        skewness,
        kurtosis,
        mode_mass,
        support: freq.distinct(),
    })
}

/// A printable histogram over quantization levels (Fig. 4 regenerator).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Count per level (level = symbol value).
    pub counts: Vec<u64>,
}

impl Histogram {
    /// Histogram of the first `levels` symbols of a frequency table.
    pub fn from_freq(freq: &FreqTable, levels: usize) -> Self {
        Histogram {
            counts: freq.counts()[..levels].to_vec(),
        }
    }

    /// CSV lines `level,count,probability` (with header).
    pub fn to_csv(&self) -> String {
        let total: u64 = self.counts.iter().sum();
        let mut out = String::from("level,count,probability\n");
        for (lvl, &c) in self.counts.iter().enumerate() {
            let p = if total > 0 { c as f64 / total as f64 } else { 0.0 };
            out.push_str(&format!("{lvl},{c},{p:.6}\n"));
        }
        out
    }

    /// ASCII bar rendering, `width` characters for the tallest bar.
    /// Buckets are grouped down to at most `max_rows` rows.
    pub fn to_ascii(&self, width: usize, max_rows: usize) -> String {
        let n = self.counts.len();
        let group = n.div_ceil(max_rows.max(1));
        let grouped: Vec<(usize, u64)> = self
            .counts
            .chunks(group)
            .enumerate()
            .map(|(i, c)| (i * group, c.iter().sum()))
            .collect();
        let max = grouped.iter().map(|&(_, c)| c).max().unwrap_or(0).max(1);
        let mut out = String::new();
        for (lvl, c) in grouped {
            let bar = (c as usize * width) / max as usize;
            out.push_str(&format!("{lvl:>4} | {}{} {c}\n", "#".repeat(bar), "", ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_mixed, BitWidth};
    use crate::rng::Rng;
    use crate::tensor::TensorF32;

    #[test]
    fn entropy_of_uniform_and_point_masses() {
        assert_eq!(shannon_entropy(&[0, 0, 0]), 0.0);
        assert_eq!(shannon_entropy(&[5]), 0.0);
        let h = shannon_entropy(&[1, 1, 1, 1]);
        assert!((h - 2.0).abs() < 1e-12);
        let h = shannon_entropy(&[1; 256]);
        assert!((h - 8.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_is_scale_invariant() {
        let a = shannon_entropy(&[1, 2, 3, 4]);
        let b = shannon_entropy(&[10, 20, 30, 40]);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn gaussian_u8_effective_bits_in_paper_band() {
        // Table I reports 5.58–5.92 effective bits for uint8 models whose
        // quantized histograms are Gaussian. A Gaussian using ~1/8 of the
        // 256-level range has entropy ≈ log2(sqrt(2πe)·σ) ≈ 7.05-ish for
        // σ=32; the paper's band corresponds to σ≈10–20 levels. Check the
        // monotonic relationship and that we land in a plausible band.
        let mut rng = Rng::new(0xF1);
        let w = TensorF32::new(vec![100_000], rng.gaussian_vec(100_000, 0.0, 0.05)).unwrap();
        let q = quantize_mixed(&w, BitWidth::U8);
        let freq = FreqTable::from_symbols(q.symbols.data());
        let eff = effective_bits(&freq).unwrap();
        assert!(eff < 8.0, "entropy coding must beat fixed 8 bits, got {eff}");
        assert!(eff > 3.0, "Gaussian over 256 levels shouldn't crush below 3 bits");
    }

    #[test]
    fn effective_bits_close_to_entropy() {
        let mut rng = Rng::new(0xF2);
        let w = TensorF32::new(vec![50_000], rng.gaussian_vec(50_000, 0.0, 0.03)).unwrap();
        let q = quantize_mixed(&w, BitWidth::U4);
        let freq = FreqTable::from_symbols(q.symbols.data());
        let h = shannon_entropy(freq.counts());
        let eff = effective_bits(&freq).unwrap();
        assert!(eff >= h - 1e-9 && eff < h + 1.0, "H={h} eff={eff}");
    }

    #[test]
    fn stats_of_symmetric_distribution() {
        let mut freq = FreqTable::new();
        freq.add_symbols(&[4, 5, 5, 6, 6, 6, 7, 7, 8]);
        let s = distribution_stats(&freq).unwrap();
        assert!((s.mean - 6.0).abs() < 1e-9);
        assert!(s.skewness.abs() < 1e-9, "symmetric ⇒ zero skew");
        assert_eq!(s.support, 5);
    }

    #[test]
    fn u4_has_higher_mode_mass_than_u8() {
        // The paper's "bucketing effect": 4-bit quantization concentrates
        // mass in central buckets vs 8-bit.
        let mut rng = Rng::new(0xF3);
        let w = TensorF32::new(vec![100_000], rng.gaussian_vec(100_000, 0.0, 0.05)).unwrap();
        let q8 = quantize_mixed(&w, BitWidth::U8);
        let q4 = quantize_mixed(&w, BitWidth::U4);
        let s8 = distribution_stats(&FreqTable::from_symbols(q8.symbols.data())).unwrap();
        let s4 = distribution_stats(&FreqTable::from_symbols(q4.symbols.data())).unwrap();
        assert!(s4.mode_mass > s8.mode_mass);
        assert!(s4.entropy < s8.entropy);
    }

    #[test]
    fn histogram_csv_and_ascii_render() {
        let freq = FreqTable::from_symbols(&[0, 1, 1, 2, 2, 2, 3]);
        let h = Histogram::from_freq(&freq, 4);
        let csv = h.to_csv();
        assert!(csv.starts_with("level,count,probability\n"));
        assert_eq!(csv.lines().count(), 5);
        assert!(csv.contains("2,3,0.428571"));
        let ascii = h.to_ascii(40, 16);
        assert_eq!(ascii.lines().count(), 4);
    }

    #[test]
    fn empty_distribution_stats_error() {
        assert!(distribution_stats(&FreqTable::new()).is_err());
    }
}
