//! CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`) — the segment
//! checksum of the ELM container.
//!
//! Offline build: no `crc32fast`, so the classic one-byte-at-a-time
//! table algorithm is implemented here. The output is bit-identical to
//! `crc32fast::hash` / zlib's `crc32` (init `!0`, final xor `!0`), so
//! containers written before this module existed verify unchanged.

const POLY: u32 = 0xEDB8_8320;

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// CRC-32 of `bytes` in one shot.
pub fn hash(bytes: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(bytes);
    h.finalize()
}

/// Incremental CRC-32 (same construction as `crc32fast::Hasher`).
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u32,
}

impl Default for Hasher {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher {
    /// Fresh hasher.
    pub fn new() -> Self {
        Hasher { state: !0 }
    }

    /// Feed more bytes.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = TABLE[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
        }
        self.state = crc;
    }

    /// Final checksum.
    pub fn finalize(&self) -> u32 {
        !self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical check value for CRC-32/IEEE.
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"a"), 0xE8B7_BE43);
        assert_eq!(hash(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut h = Hasher::new();
        for chunk in data.chunks(37) {
            h.update(chunk);
        }
        assert_eq!(h.finalize(), hash(&data));
    }

    #[test]
    fn detects_single_bit_flips() {
        let mut data: Vec<u8> = (0..128u8).collect();
        let clean = hash(&data);
        for i in 0..data.len() {
            for bit in 0..8 {
                data[i] ^= 1 << bit;
                assert_ne!(hash(&data), clean, "flip at {i}.{bit} undetected");
                data[i] ^= 1 << bit;
            }
        }
    }
}
