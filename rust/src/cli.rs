//! Hand-rolled CLI argument parsing (offline build: no clap).
//!
//! Grammar: `entrollm <command> [--flag value]... [--switch]... [positional]...`
//! Flags may use `--key value` or `--key=value`.

use crate::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First token (the subcommand).
    pub command: String,
    /// `--key value` pairs (last occurrence wins — the single-value
    /// view; see [`Args::all`] for every occurrence).
    pub flags: BTreeMap<String, String>,
    /// Every occurrence of each flag, in command-line order (repeatable
    /// flags like `--elm a.elm --elm b.elm`).
    pub repeated: BTreeMap<String, Vec<String>>,
    /// Bare `--switch` tokens.
    pub switches: Vec<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument tokens (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut args = Args {
            command,
            ..Default::default()
        };
        fn put(args: &mut Args, k: String, v: String) {
            args.repeated.entry(k.clone()).or_default().push(v.clone());
            args.flags.insert(k, v);
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    put(&mut args, k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    put(&mut args, stripped.to_string(), v);
                } else {
                    args.switches.push(stripped.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// Required string flag.
    pub fn req(&self, key: &str) -> Result<&str> {
        self.flags
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| Error::InvalidArg(format!("missing required --{key}")))
    }

    /// Optional string flag with default.
    pub fn opt<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    /// Optional parsed flag with default.
    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::InvalidArg(format!("--{key}: cannot parse {v:?}"))),
        }
    }

    /// Is a bare switch present?
    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Every value a repeatable flag was given, in command-line order
    /// (empty slice when absent) — e.g. `--elm a.elm --elm b.elm`.
    pub fn all(&self, key: &str) -> &[String] {
        self.repeated.get(key).map(Vec::as_slice).unwrap_or(&[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_command_flags_switches_positionals() {
        // NB: a bare switch directly followed by a positional would be
        // parsed as `--switch value` (documented grammar limitation), so
        // switches go last.
        let a = parse(&[
            "compress", "--bits", "4", "--out=model.elm", "input.npz", "--verbose",
        ]);
        assert_eq!(a.command, "compress");
        assert_eq!(a.req("bits").unwrap(), "4");
        assert_eq!(a.opt("out", ""), "model.elm");
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["input.npz"]);
    }

    #[test]
    fn missing_required_flag_errors() {
        let a = parse(&["serve"]);
        assert!(a.req("port").is_err());
    }

    #[test]
    fn opt_parse_types_and_defaults() {
        let a = parse(&["x", "--threads", "8"]);
        assert_eq!(a.opt_parse("threads", 4usize).unwrap(), 8);
        assert_eq!(a.opt_parse("missing", 4usize).unwrap(), 4);
        let bad = parse(&["x", "--threads", "lots"]);
        assert!(bad.opt_parse("threads", 4usize).is_err());
    }

    #[test]
    fn trailing_switch_without_value() {
        let a = parse(&["x", "--fast"]);
        assert!(a.has("fast"));
        assert!(a.flags.is_empty());
    }

    #[test]
    fn empty_argv() {
        let a = parse(&[]);
        assert_eq!(a.command, "");
    }

    #[test]
    fn repeated_flags_keep_every_value_in_order() {
        let a = parse(&[
            "serve", "--elm", "a.elm", "--elm=b.elm", "--model", "x=1.elm", "--port", "7",
        ]);
        assert_eq!(a.all("elm"), ["a.elm", "b.elm"]);
        assert_eq!(a.all("model"), ["x=1.elm"]);
        assert!(a.all("missing").is_empty());
        // The single-value view still works (last wins).
        assert_eq!(a.opt("elm", ""), "b.elm");
        assert_eq!(a.req("port").unwrap(), "7");
    }
}
