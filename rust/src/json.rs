//! Minimal JSON parser/serializer (offline build: no serde facade).
//!
//! Used for the `artifacts/manifest.json` handshake between the python
//! compile path and the rust runtime, for config files, and for bench
//! report emission. Supports the full JSON grammar except for exotic
//! number forms beyond f64.

use crate::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` so serialization is
/// deterministic (stable diffs in EXPERIMENTS.md).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.pos)));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_json_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Array(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors ----

    /// As object map.
    pub fn as_object(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Ok(m),
            other => Err(Error::Json(format!("expected object, got {other:?}"))),
        }
    }

    /// As array.
    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(a) => Ok(a),
            other => Err(Error::Json(format!("expected array, got {other:?}"))),
        }
    }

    /// As string.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(Error::Json(format!("expected string, got {other:?}"))),
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            other => Err(Error::Json(format!("expected number, got {other:?}"))),
        }
    }

    /// As usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::Json(format!("expected non-negative integer, got {n}")));
        }
        Ok(n as usize)
    }

    /// As u64, **strictly**: the number must be a non-negative integer
    /// *below* 2^53, the range where f64 represents every integer
    /// exactly. Anything else — negative, fractional, NaN/infinite, or
    /// at/beyond 2^53 (where the JSON→f64 parse itself already rounds,
    /// e.g. 2^53+1 parses to 2^53) — is an error, never a silent
    /// truncation or wrap: `n as u64` on such values would quietly
    /// collide distinct inputs (the request-id bug this accessor
    /// exists to prevent).
    pub fn as_u64(&self) -> Result<u64> {
        const EXACT_BOUND: f64 = 9_007_199_254_740_992.0; // 2^53
        let n = self.as_f64()?;
        // NaN fails the fract test (NaN != 0.0), infinities the bound.
        if n < 0.0 || n >= EXACT_BOUND || n.fract() != 0.0 {
            return Err(Error::Json(format!(
                "expected an integer in [0, 2^53), got {n}"
            )));
        }
        Ok(n as u64)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_object()?
            .get(key)
            .ok_or_else(|| Error::Json(format!("missing field {key:?}")))
    }

    /// Optional object field lookup.
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: build `Value::Object` from pairs.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience constructors.
pub fn num(n: f64) -> Value {
    Value::Num(n)
}
/// String value.
pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}
/// Array value.
pub fn arr(items: Vec<Value>) -> Value {
    Value::Array(items)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!(
                "unexpected {other:?} at byte {}",
                self.pos
            ))),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(map));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected ',' or '}}' at byte {}, found {other:?}",
                        self.pos
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected ',' or ']' at byte {}, found {other:?}",
                        self.pos
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| Error::Json("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| Error::Json("bad \\u escape".into()))?,
                                16,
                            )
                            .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            // Surrogate pairs unsupported (not produced by
                            // our python side); map lone surrogates to U+FFFD.
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => {
                            return Err(Error::Json(format!("bad escape {other:?}")));
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| Error::Json("invalid utf-8".into()))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| Error::Json("invalid number".into()))?;
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| Error::Json(format!("invalid number {text:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(
            Value::parse(r#""a\nb""#).unwrap(),
            Value::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested_structures() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"phi3","dims":[32,64],"pi":3.25,"ok":true,"z":null}"#;
        let v = Value::parse(src).unwrap();
        let compact = v.to_json();
        assert_eq!(Value::parse(&compact).unwrap(), v);
        let pretty = v.to_json_pretty();
        assert_eq!(Value::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_decimal_point() {
        let v = obj(vec![("n", num(42.0))]);
        assert_eq!(v.to_json(), r#"{"n":42}"#);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("tru").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Value::Str("quote\" slash\\ nl\n tab\t ctrl\u{1}".into());
        let ser = v.to_json();
        assert_eq!(Value::parse(&ser).unwrap(), v);
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(
            Value::parse(r#""é""#).unwrap(),
            Value::Str("é".into())
        );
    }

    #[test]
    fn typed_accessors_error_on_wrong_type() {
        let v = Value::parse(r#"{"a": 1.5}"#).unwrap();
        assert!(v.get("a").unwrap().as_str().is_err());
        assert!(v.get("a").unwrap().as_usize().is_err());
        assert!(v.get("missing").is_err());
        assert!(v.get_opt("missing").is_none());
    }

    #[test]
    fn as_u64_is_exact_or_error() {
        let ok = |s: &str| Value::parse(s).unwrap().as_u64();
        assert_eq!(ok("0").unwrap(), 0);
        assert_eq!(ok("7").unwrap(), 7);
        assert_eq!(ok("9007199254740991").unwrap(), (1 << 53) - 1);
        // Negative, fractional, and ≥2^53 values would all wrap or
        // collide under `as u64` — they must be errors instead. Note
        // 2^53+1 already parses to 2^53, which is exactly why the
        // bound is strict.
        assert!(ok("-1").is_err());
        assert!(ok("1.5").is_err());
        assert!(ok("1e20").is_err());
        assert!(ok("9007199254740992").is_err());
        assert!(ok("9007199254740993").is_err());
        assert!(ok("\"7\"").is_err(), "strings are not ids");
    }
}
