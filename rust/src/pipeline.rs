//! High-level flows gluing the subsystems together — what the CLI,
//! examples, and benches call.
//!
//! * [`build_elm`] — Algorithm 1 cloud side: trained fp32 weights →
//!   mixed quantization → model-global Huffman → ELM container.
//! * [`load_backend`] — Algorithm 1 edge side: ELM → parallel decode →
//!   PJRT upload → serving backend.
//! * [`eval_ppl`] — teacher-forced perplexity over the held-out corpus
//!   through the AOT `score_*` executables (Table I quality rows).

use crate::coordinator::PjrtBackend;
use crate::quant::BitWidth;
use crate::runtime::{load_weights_bin, Manifest, ModelRuntime, Variant, WeightSet};
use crate::store::{compress, CompressionReport, ElmModel};
use crate::tensor::TensorF32;
use crate::{Error, Result};
use std::path::Path;

/// Which weight flavor to serve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flavor {
    /// fp32 baseline.
    F32,
    /// uint8 mixed-quant + Huffman.
    U8,
    /// uint4 mixed-quant + Huffman.
    U4,
}

impl Flavor {
    /// Parse `"f32" | "u8" | "u4"`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" | "fp32" => Ok(Flavor::F32),
            "u8" | "uint8" => Ok(Flavor::U8),
            "u4" | "uint4" => Ok(Flavor::U4),
            other => Err(Error::InvalidArg(format!("unknown flavor {other:?}"))),
        }
    }

    /// Bit width for quantized flavors.
    pub fn bits(self) -> Option<BitWidth> {
        match self {
            Flavor::F32 => None,
            Flavor::U8 => Some(BitWidth::U8),
            Flavor::U4 => Some(BitWidth::U4),
        }
    }

    /// Tag used in file names / reports.
    pub fn tag(self) -> &'static str {
        match self {
            Flavor::F32 => "f32",
            Flavor::U8 => "u8",
            Flavor::U4 => "u4",
        }
    }
}

/// Split the trained weights into (quantizable, fp32-rest) per manifest.
pub fn split_weights(
    manifest: &Manifest,
    weights: Vec<(String, TensorF32)>,
) -> (Vec<(String, TensorF32)>, Vec<(String, TensorF32)>) {
    let qset: std::collections::HashSet<&str> =
        manifest.quantized_names.iter().map(|s| s.as_str()).collect();
    weights
        .into_iter()
        .partition(|(name, _)| qset.contains(name.as_str()))
}

/// Build an ELM container from the artifacts' trained weights
/// (Algorithm 1 `CLOUD PROCESSING`).
pub fn build_elm(
    artifacts: impl AsRef<Path>,
    bits: BitWidth,
) -> Result<(ElmModel, CompressionReport)> {
    let dir = artifacts.as_ref();
    let manifest = Manifest::load(dir.join("manifest.json"))?;
    let weights = load_weights_bin(dir.join("weights.bin"))?;
    let (quantizable, _) = split_weights(&manifest, weights);
    compress(&quantizable, bits)
}

/// Load a serving backend for a flavor (Algorithm 1 `EDGE DEVICE
/// OPERATIONS` for the quant flavors: ELM → parallel decode → upload).
///
/// Returns the backend plus the decode stats when Huffman decoding
/// happened (None for f32).
pub fn load_backend(
    artifacts: impl AsRef<Path>,
    flavor: Flavor,
    threads: usize,
) -> Result<(PjrtBackend, Option<crate::decode::DecodeStats>)> {
    let dir = artifacts.as_ref();
    let manifest = Manifest::load(dir.join("manifest.json"))?;
    let weights = load_weights_bin(dir.join("weights.bin"))?;
    match flavor.bits() {
        None => {
            let ws = WeightSet::from_f32(weights);
            let rt = ModelRuntime::load(dir, Variant::F32, &ws)?;
            Ok((PjrtBackend::new(rt), None))
        }
        Some(bits) => {
            let (quantizable, rest) = split_weights(&manifest, weights);
            let (elm, _) = compress(&quantizable, bits)?;
            let (tensors, stats) =
                crate::decode::ParallelDecoder::new(threads).decode_model(&elm)?;
            let named: Vec<_> = elm
                .layers
                .iter()
                .map(|m| m.name.clone())
                .zip(tensors)
                .collect();
            let ws = WeightSet::from_quantized(named, rest);
            let rt = ModelRuntime::load(dir, Variant::Quant, &ws)?;
            Ok((PjrtBackend::new(rt), Some(stats)))
        }
    }
}

/// Load a backend straight from an ELM file on disk (the deploy path:
/// the edge device has only the `.elm` + norm weights + artifacts).
pub fn load_backend_from_elm(
    artifacts: impl AsRef<Path>,
    elm_path: impl AsRef<Path>,
    threads: usize,
) -> Result<(PjrtBackend, crate::decode::DecodeStats)> {
    let dir = artifacts.as_ref();
    let manifest = Manifest::load(dir.join("manifest.json"))?;
    let weights = load_weights_bin(dir.join("weights.bin"))?;
    let (_, rest) = split_weights(&manifest, weights);
    let elm = ElmModel::load(elm_path)?;
    let (tensors, stats) = crate::decode::ParallelDecoder::new(threads).decode_model(&elm)?;
    let named: Vec<_> = elm
        .layers
        .iter()
        .map(|m| m.name.clone())
        .zip(tensors)
        .collect();
    let ws = WeightSet::from_quantized(named, rest);
    let rt = ModelRuntime::load(dir, Variant::Quant, &ws)?;
    Ok((PjrtBackend::new(rt), stats))
}

/// Teacher-forced perplexity over `windows` held-out windows using the
/// `score_*` executable. Returns (nll nats/char, char perplexity).
pub fn eval_ppl(
    artifacts: impl AsRef<Path>,
    flavor: Flavor,
    threads: usize,
    windows: usize,
) -> Result<(f64, f64)> {
    let dir = artifacts.as_ref();
    let manifest = Manifest::load(dir.join("manifest.json"))?;
    let weights = load_weights_bin(dir.join("weights.bin"))?;
    let ws = match flavor.bits() {
        None => WeightSet::from_f32(weights),
        Some(bits) => {
            let (quantizable, rest) = split_weights(&manifest, weights);
            let (elm, _) = compress(&quantizable, bits)?;
            WeightSet::from_elm(&elm, threads, rest)?
        }
    };
    let variant = if flavor == Flavor::F32 {
        Variant::F32
    } else {
        Variant::Quant
    };
    let rt = ModelRuntime::load(dir, variant, &ws)?;
    let text = std::fs::read_to_string(dir.join("eval.txt"))?;
    rt.score_ppl(&text, windows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flavor_parsing() {
        assert_eq!(Flavor::parse("u8").unwrap(), Flavor::U8);
        assert_eq!(Flavor::parse("fp32").unwrap(), Flavor::F32);
        assert_eq!(Flavor::parse("uint4").unwrap(), Flavor::U4);
        assert!(Flavor::parse("u2").is_err());
        assert_eq!(Flavor::U4.bits(), Some(BitWidth::U4));
        assert!(Flavor::F32.bits().is_none());
    }
}
