//! High-level flows gluing the subsystems together — what the CLI,
//! examples, and benches call.
//!
//! * [`build_elm`] — Algorithm 1 cloud side: trained fp32 weights →
//!   mixed quantization → model-global Huffman → ELM container.
//! * [`load_backend`] — Algorithm 1 edge side: ELM → parallel decode →
//!   PJRT upload → serving backend.
//! * [`eval_ppl`] — teacher-forced perplexity over the held-out corpus
//!   through the AOT `score_*` executables (Table I quality rows).

use crate::coordinator::PjrtBackend;
use crate::decode::{StreamStats, StreamingDecoder};
use crate::quant::BitWidth;
use crate::residency::{
    PrefetchConfig, PrefetchingDigestBackend, PrefetchingWeightSet, ResidentDigestBackend,
    ResidentWeightSet,
};
use crate::rng::Rng;
use crate::runtime::{load_weights_bin, Manifest, ModelRuntime, Variant, WeightSet};
use crate::store::{
    compress, compress_with_options, CodecChoice, CompressionReport, ElmModel, SegmentSource,
};
use crate::tensor::TensorF32;
use crate::{Error, Result};
use std::path::Path;
use std::sync::Arc;

/// Which weight flavor to serve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flavor {
    /// fp32 baseline.
    F32,
    /// uint8 mixed-quant + Huffman.
    U8,
    /// uint4 mixed-quant + Huffman.
    U4,
}

impl Flavor {
    /// Parse `"f32" | "u8" | "u4"`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" | "fp32" => Ok(Flavor::F32),
            "u8" | "uint8" => Ok(Flavor::U8),
            "u4" | "uint4" => Ok(Flavor::U4),
            other => Err(Error::InvalidArg(format!("unknown flavor {other:?}"))),
        }
    }

    /// Bit width for quantized flavors.
    pub fn bits(self) -> Option<BitWidth> {
        match self {
            Flavor::F32 => None,
            Flavor::U8 => Some(BitWidth::U8),
            Flavor::U4 => Some(BitWidth::U4),
        }
    }

    /// Tag used in file names / reports.
    pub fn tag(self) -> &'static str {
        match self {
            Flavor::F32 => "f32",
            Flavor::U8 => "u8",
            Flavor::U4 => "u4",
        }
    }
}

/// Split the trained weights into (quantizable, fp32-rest) per manifest.
pub fn split_weights(
    manifest: &Manifest,
    weights: Vec<(String, TensorF32)>,
) -> (Vec<(String, TensorF32)>, Vec<(String, TensorF32)>) {
    let qset: std::collections::HashSet<&str> =
        manifest.quantized_names.iter().map(|s| s.as_str()).collect();
    weights
        .into_iter()
        .partition(|(name, _)| qset.contains(name.as_str()))
}

/// Build an ELM container from the artifacts' trained weights
/// (Algorithm 1 `CLOUD PROCESSING`), with the default auto tile
/// sizing (~4–8 independently decodable tiles per typical layer).
pub fn build_elm(
    artifacts: impl AsRef<Path>,
    bits: BitWidth,
) -> Result<(ElmModel, CompressionReport)> {
    build_elm_tiled(artifacts, bits, None)
}

/// [`build_elm`] with explicit tile granularity: `tile_symbols` caps
/// how many decoded symbols each ELM tile covers (`None` = auto).
/// This is the `compress --tile-kb N` path — smaller tiles buy more
/// intra-layer decode parallelism for a few manifest bytes per tile.
pub fn build_elm_tiled(
    artifacts: impl AsRef<Path>,
    bits: BitWidth,
    tile_symbols: Option<usize>,
) -> Result<(ElmModel, CompressionReport)> {
    build_elm_with(artifacts, bits, tile_symbols, CodecChoice::Huffman)
}

/// [`build_elm_tiled`] plus codec negotiation: the `compress --codec`
/// path. Every layer's tiles are written with the chosen codec
/// (`Auto` = per-layer smaller-of-both), recorded in the v3 manifest.
pub fn build_elm_with(
    artifacts: impl AsRef<Path>,
    bits: BitWidth,
    tile_symbols: Option<usize>,
    choice: CodecChoice,
) -> Result<(ElmModel, CompressionReport)> {
    let dir = artifacts.as_ref();
    let manifest = Manifest::load(dir.join("manifest.json"))?;
    let weights = load_weights_bin(dir.join("weights.bin"))?;
    let (quantizable, _) = split_weights(&manifest, weights);
    compress_with_options(&quantizable, bits, tile_symbols, choice)
}

/// Load a serving backend for a flavor (Algorithm 1 `EDGE DEVICE
/// OPERATIONS` for the quant flavors: ELM → parallel decode → upload).
///
/// Returns the backend plus the decode stats when Huffman decoding
/// happened (None for f32).
pub fn load_backend(
    artifacts: impl AsRef<Path>,
    flavor: Flavor,
    threads: usize,
) -> Result<(PjrtBackend, Option<crate::decode::DecodeStats>)> {
    let dir = artifacts.as_ref();
    let manifest = Manifest::load(dir.join("manifest.json"))?;
    let weights = load_weights_bin(dir.join("weights.bin"))?;
    match flavor.bits() {
        None => {
            let ws = WeightSet::from_f32(weights);
            let rt = ModelRuntime::load(dir, Variant::F32, &ws)?;
            Ok((PjrtBackend::new(rt), None))
        }
        Some(bits) => {
            let (quantizable, rest) = split_weights(&manifest, weights);
            let (elm, _) = compress(&quantizable, bits)?;
            let (tensors, stats) =
                crate::decode::ParallelDecoder::new(threads).decode_model(&elm)?;
            let named: Vec<_> = elm
                .layers
                .iter()
                .map(|m| m.name.clone())
                .zip(tensors)
                .collect();
            let ws = WeightSet::from_quantized(named, rest);
            let rt = ModelRuntime::load(dir, Variant::Quant, &ws)?;
            Ok((PjrtBackend::new(rt), Some(stats)))
        }
    }
}

/// Load a backend straight from an ELM file on disk (the deploy path:
/// the edge device has only the `.elm` + norm weights + artifacts).
pub fn load_backend_from_elm(
    artifacts: impl AsRef<Path>,
    elm_path: impl AsRef<Path>,
    threads: usize,
) -> Result<(PjrtBackend, crate::decode::DecodeStats)> {
    let dir = artifacts.as_ref();
    let manifest = Manifest::load(dir.join("manifest.json"))?;
    let weights = load_weights_bin(dir.join("weights.bin"))?;
    let (_, rest) = split_weights(&manifest, weights);
    let elm = ElmModel::load(elm_path)?;
    let (tensors, stats) = crate::decode::ParallelDecoder::new(threads).decode_model(&elm)?;
    let named: Vec<_> = elm
        .layers
        .iter()
        .map(|m| m.name.clone())
        .zip(tensors)
        .collect();
    let ws = WeightSet::from_quantized(named, rest);
    let rt = ModelRuntime::load(dir, Variant::Quant, &ws)?;
    Ok((PjrtBackend::new(rt), stats))
}

/// Streaming deploy path: like [`load_backend_from_elm`], but the ELM
/// container is decoded **layer-ahead with a bounded prefetch window**
/// (`decode::stream`, §III-C pipelined): each [`crate::quant::QuantizedTensor`]
/// is installed into the weight set the moment its segment decodes,
/// instead of after the whole model has been decoded. Lossless: serves
/// exactly the tensors the eager path serves.
///
/// Scope note: decode overlaps weight-set *staging* only. The PJRT
/// upload ([`ModelRuntime::load`]) still consumes the complete set, so
/// today's wall-clock win at this call is bounded by the staging
/// overlap; the runtime-level TTFT win arrives when the upload itself
/// goes incremental (ROADMAP: incremental weight upload / decode-ahead
/// generation). The per-layer delivery, window bound, and
/// time-to-first-layer accounting are real now and are what the
/// benches and the decompress path measure.
pub fn load_backend_streaming(
    artifacts: impl AsRef<Path>,
    elm_path: impl AsRef<Path>,
    threads: usize,
    prefetch_layers: usize,
) -> Result<(PjrtBackend, StreamStats)> {
    let dir = artifacts.as_ref();
    let manifest = Manifest::load(dir.join("manifest.json"))?;
    let weights = load_weights_bin(dir.join("weights.bin"))?;
    let (_, rest) = split_weights(&manifest, weights);
    // Lazy open: the payload stays on disk and each segment is read
    // only when the prefetch window admits it, so peak RSS during the
    // load is O(prefetch window), not O(model).
    let source = Arc::new(SegmentSource::open(elm_path)?);
    load_backend_streaming_source(dir, source, rest, threads, prefetch_layers)
}

/// [`load_backend_streaming`] from an in-memory container plus the fp32
/// rest (norm tensors) — the building block the CLI's in-memory flow
/// and the tests use directly.
pub fn load_backend_streaming_elm(
    artifacts: impl AsRef<Path>,
    elm: ElmModel,
    f32_rest: Vec<(String, TensorF32)>,
    threads: usize,
    prefetch_layers: usize,
) -> Result<(PjrtBackend, StreamStats)> {
    let source = Arc::new(SegmentSource::from_model(Arc::new(elm)));
    load_backend_streaming_source(artifacts, source, f32_rest, threads, prefetch_layers)
}

/// Shared core of the streaming deploy paths: drain a windowed
/// [`StreamingDecoder`] over any [`SegmentSource`] into a weight set,
/// then hand it to the runtime.
pub fn load_backend_streaming_source(
    artifacts: impl AsRef<Path>,
    source: Arc<SegmentSource>,
    f32_rest: Vec<(String, TensorF32)>,
    threads: usize,
    prefetch_layers: usize,
) -> Result<(PjrtBackend, StreamStats)> {
    let mut stream = StreamingDecoder::new(threads, prefetch_layers).stream_source(source)?;
    let ws = WeightSet::from_layer_stream(&mut stream, f32_rest)?;
    let stats = stream.into_stats();
    let rt = ModelRuntime::load(artifacts, Variant::Quant, &ws)?;
    Ok((PjrtBackend::new(rt), stats))
}

/// Streaming counterpart of [`load_backend`] when no `.elm` file has
/// been written yet: compress the artifacts' trained weights in memory,
/// then stream-decode the container into the serving backend.
pub fn load_backend_streaming_from_artifacts(
    artifacts: impl AsRef<Path>,
    flavor: Flavor,
    threads: usize,
    prefetch_layers: usize,
) -> Result<(PjrtBackend, StreamStats)> {
    let bits = flavor
        .bits()
        .ok_or_else(|| Error::InvalidArg("streaming load requires a quantized flavor (u8|u4)".into()))?;
    let dir = artifacts.as_ref();
    let manifest = Manifest::load(dir.join("manifest.json"))?;
    let weights = load_weights_bin(dir.join("weights.bin"))?;
    let (quantizable, rest) = split_weights(&manifest, weights);
    let (elm, _) = compress(&quantizable, bits)?;
    load_backend_streaming_elm(dir, elm, rest, threads, prefetch_layers)
}

/// Convert the CLI's `--weight-budget-mb` (fractional MiB allowed, so
/// sub-MiB test models can exercise eviction) into a byte budget.
pub fn weight_budget_bytes(mb: f64) -> Result<usize> {
    if !mb.is_finite() || mb <= 0.0 {
        return Err(Error::InvalidArg(format!(
            "--weight-budget-mb must be a positive number, got {mb}"
        )));
    }
    Ok((mb * 1024.0 * 1024.0) as usize)
}

/// Open an ELM container **lazily** and build a weight-residency
/// serving set over it: payload stays on disk, decoded layers stay
/// under `budget_bytes` (the `--weight-budget-mb` deploy path for
/// models whose decoded weights exceed device RAM).
pub fn open_resident_weights(
    elm_path: impl AsRef<Path>,
    budget_bytes: usize,
    f32_rest: Vec<(String, TensorF32)>,
) -> Result<ResidentWeightSet> {
    let source = Arc::new(SegmentSource::open(elm_path)?);
    ResidentWeightSet::new(source, budget_bytes, f32_rest)
}

/// Fault-on-demand residency-serving backend over any segment source:
/// no PJRT artifacts needed — generation is digest-driven
/// ([`crate::residency::ResidentDigestBackend`]), faulting layers
/// through the LRU cache on every weight pass. The single construction
/// point the CLI and the convenience wrappers below share, and the
/// fault-on-demand counterpart of [`prefetching_digest_backend`].
pub fn resident_digest_backend(
    source: Arc<SegmentSource>,
    budget_bytes: usize,
    batch: usize,
    max_seq: usize,
    vocab: usize,
) -> Result<ResidentDigestBackend> {
    let ws = ResidentWeightSet::new(source, budget_bytes, Vec::new())?;
    Ok(ResidentDigestBackend::new(ws, batch, max_seq, vocab))
}

/// [`resident_digest_backend`] straight from an `.elm` file on disk
/// (lazy open: the payload stays there).
pub fn load_resident_digest_backend(
    elm_path: impl AsRef<Path>,
    budget_bytes: usize,
    batch: usize,
    max_seq: usize,
    vocab: usize,
) -> Result<ResidentDigestBackend> {
    let source = Arc::new(SegmentSource::open(elm_path)?);
    resident_digest_backend(source, budget_bytes, batch, max_seq, vocab)
}

/// In-memory variant of [`load_resident_digest_backend`] over a
/// freshly compressed synthetic model (`serve --synthetic N`): the
/// encoded payload lives in memory, but decoded residency is still
/// bounded by the budget.
pub fn synthetic_resident_digest_backend(
    n_layers: usize,
    seed: u64,
    bits: BitWidth,
    budget_bytes: usize,
    batch: usize,
    max_seq: usize,
    vocab: usize,
) -> Result<ResidentDigestBackend> {
    let source = residency_source(None, n_layers, seed, bits)?;
    resident_digest_backend(source, budget_bytes, batch, max_seq, vocab)
}

/// Resolve the CLI's residency model source: a lazily opened `.elm`
/// file (payload stays on disk), or a freshly compressed in-memory
/// synthetic model.
pub fn residency_source(
    elm: Option<&str>,
    synthetic: usize,
    seed: u64,
    bits: BitWidth,
) -> Result<Arc<SegmentSource>> {
    match elm {
        Some(path) => Ok(Arc::new(SegmentSource::open(path)?)),
        None => {
            let layers = synthetic_layers(synthetic, seed);
            let (elm, _) = compress(&layers, bits)?;
            Ok(Arc::new(SegmentSource::from_model(Arc::new(elm))))
        }
    }
}

/// One `--model` entry resolved from the CLI: a named container path
/// plus its optional per-model QoS knobs (`--model
/// name=path,reserve-mb=N,weight=W`).
#[derive(Debug, Clone)]
pub struct ModelFileSpec {
    /// Routing name.
    pub name: String,
    /// `.elm` container path (opened lazily).
    pub path: String,
    /// Minimum residency reservation in bytes (0 = none).
    pub reserve_bytes: usize,
    /// Admission weight (1.0 = default).
    pub weight: f64,
}

impl ModelFileSpec {
    /// Spec with no reservation and the default admission weight.
    pub fn new(name: impl Into<String>, path: impl Into<String>) -> Self {
        ModelFileSpec {
            name: name.into(),
            path: path.into(),
            reserve_bytes: 0,
            weight: 1.0,
        }
    }
}

/// Open several ELM containers **lazily** and assemble the multi-model
/// serving coordinator: one engine per [`ModelFileSpec`], all drawing
/// on one shared decoded-byte budget
/// ([`crate::residency::ResidencyLedger`]) and one shared decode
/// worker pool — the `entrollm serve --model name=path[,reserve-mb=N]
/// [,weight=W] --model ...` (or repeated `--elm`) deploy path. QoS
/// validation (reserves must sum within the budget, weights must be
/// positive and finite) happens in
/// [`crate::coordinator::MultiModelServer::new`].
pub fn open_multi_model_server(
    specs: Vec<ModelFileSpec>,
    budget_bytes: usize,
    decode_ahead: usize,
    workers: usize,
    engine: crate::coordinator::EngineConfig,
) -> Result<crate::coordinator::MultiModelServer> {
    let mut model_specs = Vec::with_capacity(specs.len());
    for spec in specs {
        model_specs.push(
            crate::coordinator::ModelSpec::new(
                spec.name,
                Arc::new(SegmentSource::open(&spec.path)?),
            )
            .with_qos(spec.reserve_bytes, spec.weight),
        );
    }
    let cfg = crate::coordinator::MultiModelConfig {
        budget_bytes,
        decode_ahead,
        workers,
        engine,
        ..crate::coordinator::MultiModelConfig::default()
    };
    crate::coordinator::MultiModelServer::new(model_specs, cfg)
}

/// Decode-ahead serving backend over any segment source — what
/// `entrollm generate/serve --decode-ahead N` runs: the residency
/// cache under a scan-resistant policy, with a worker pool decoding
/// layer `i+1` while layer `i` is consumed.
pub fn prefetching_digest_backend(
    source: Arc<SegmentSource>,
    budget_bytes: usize,
    cfg: PrefetchConfig,
    batch: usize,
    max_seq: usize,
    vocab: usize,
) -> Result<PrefetchingDigestBackend> {
    let ws = PrefetchingWeightSet::new(source, budget_bytes, Vec::new(), cfg)?;
    Ok(PrefetchingDigestBackend::new(ws, batch, max_seq, vocab))
}

/// Deterministic synthetic "trained" layers (Gaussian-ish, like Fig. 4
/// assumes) — lets `compress`/`decompress`/benches run end to end with
/// no artifacts directory. Mixes single-signed and zero-straddling
/// layers so both branches of the mixed scheme (§III-A) are exercised,
/// and skews sizes so scheduling matters.
pub fn synthetic_layers(n_layers: usize, seed: u64) -> Vec<(String, TensorF32)> {
    let mut rng = Rng::new(seed);
    (0..n_layers)
        .map(|i| {
            let n = 256 + rng.below(4096) * (1 + i % 3);
            let data = if i % 4 == 3 {
                (0..n).map(|_| rng.range_f32(0.0, 0.1)).collect()
            } else {
                rng.gaussian_vec(n, 0.0, 0.04)
            };
            (
                format!("blocks.{i}.w"),
                TensorF32::new(vec![n], data).expect("length matches shape"),
            )
        })
        .collect()
}

/// Teacher-forced perplexity over `windows` held-out windows using the
/// `score_*` executable. Returns (nll nats/char, char perplexity).
pub fn eval_ppl(
    artifacts: impl AsRef<Path>,
    flavor: Flavor,
    threads: usize,
    windows: usize,
) -> Result<(f64, f64)> {
    let dir = artifacts.as_ref();
    let manifest = Manifest::load(dir.join("manifest.json"))?;
    let weights = load_weights_bin(dir.join("weights.bin"))?;
    let ws = match flavor.bits() {
        None => WeightSet::from_f32(weights),
        Some(bits) => {
            let (quantizable, rest) = split_weights(&manifest, weights);
            let (elm, _) = compress(&quantizable, bits)?;
            WeightSet::from_elm(&elm, threads, rest)?
        }
    };
    let variant = if flavor == Flavor::F32 {
        Variant::F32
    } else {
        Variant::Quant
    };
    let rt = ModelRuntime::load(dir, variant, &ws)?;
    let text = std::fs::read_to_string(dir.join("eval.txt"))?;
    rt.score_ppl(&text, windows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flavor_parsing() {
        assert_eq!(Flavor::parse("u8").unwrap(), Flavor::U8);
        assert_eq!(Flavor::parse("fp32").unwrap(), Flavor::F32);
        assert_eq!(Flavor::parse("uint4").unwrap(), Flavor::U4);
        assert!(Flavor::parse("u2").is_err());
        assert_eq!(Flavor::U4.bits(), Some(BitWidth::U4));
        assert!(Flavor::F32.bits().is_none());
    }

    #[test]
    fn synthetic_layers_are_deterministic_and_mixed() {
        let a = synthetic_layers(8, 42);
        let b = synthetic_layers(8, 42);
        assert_eq!(a.len(), 8);
        for ((na, ta), (nb, tb)) in a.iter().zip(&b) {
            assert_eq!(na, nb);
            assert_eq!(ta.data(), tb.data());
        }
        let c = synthetic_layers(8, 43);
        assert_ne!(a[0].1.data(), c[0].1.data(), "seed must matter");
        // At least one single-signed layer (i % 4 == 3) exercises the
        // symmetric-unsigned branch.
        assert!(a[3].1.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn weight_budget_parses_fractional_mb() {
        assert_eq!(weight_budget_bytes(1.0).unwrap(), 1024 * 1024);
        assert_eq!(weight_budget_bytes(0.5).unwrap(), 512 * 1024);
        assert!(weight_budget_bytes(0.0).is_err());
        assert!(weight_budget_bytes(-3.0).is_err());
        assert!(weight_budget_bytes(f64::NAN).is_err());
    }

    #[test]
    fn open_resident_weights_serves_from_disk_lazily() {
        let layers = synthetic_layers(7, 0xD15C);
        let (elm, _) = compress(&layers, BitWidth::U4).unwrap();
        let dir = std::env::temp_dir().join(format!("pipe_res_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.elm");
        elm.save(&path).unwrap();

        let largest = elm.layers.iter().map(|m| m.n_symbols).max().unwrap();
        let mut ws = open_resident_weights(&path, largest, Vec::new()).unwrap();
        // Lazy open: no payload bytes resident before any access.
        assert_eq!(ws.cache().source().resident_payload_bytes(), 0);
        for i in 0..elm.layers.len() {
            let want = crate::store::decode_layer(&elm, i).unwrap();
            let got = ws.layer(i).unwrap();
            assert_eq!(got.symbols.data(), want.symbols.data());
        }
        let c = ws.counters();
        assert!(c.evictions > 0, "one-layer budget must evict on a walk");
        assert!(c.peak_resident_bytes <= largest);

        // A budget below one layer is rejected up front.
        assert!(open_resident_weights(&path, largest - 1, Vec::new()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multi_model_server_opens_lazily_from_disk() {
        let dir = std::env::temp_dir().join(format!("pipe_multi_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut paths = Vec::new();
        let mut budget = 0usize;
        for (name, n, seed) in [("a", 5usize, 0xA1u64), ("b", 7, 0xB2)] {
            let (elm, _) = compress(&synthetic_layers(n, seed), BitWidth::U8).unwrap();
            let largest = elm.layers.iter().map(|m| m.n_symbols).max().unwrap();
            // Whole model, but never below the decode-ahead floor
            // (window 2 + active layer) the coordinator enforces.
            budget += elm.n_params().max(3 * largest);
            let path = dir.join(format!("{name}.elm"));
            elm.save(&path).unwrap();
            paths.push(ModelFileSpec::new(name, path.to_str().unwrap()));
        }
        // Give the first model a reservation + weight through the file
        // spec: it must land in the ledger.
        paths[0].reserve_bytes = budget / 8;
        paths[0].weight = 2.0;
        let multi = open_multi_model_server(
            paths,
            budget,
            2,
            1,
            crate::coordinator::EngineConfig::default(),
        )
        .unwrap();
        assert_eq!(multi.n_models(), 2);
        assert_eq!(multi.name(0), "a");
        assert_eq!(multi.resolve(Some("b")).unwrap(), 1);
        assert!(multi.resolve(Some("zzz")).is_err());
        assert_eq!(multi.ledger().counters().budget_bytes, budget);
        assert_eq!(multi.model_counters(0).reserved_bytes, budget / 8);
        assert_eq!(multi.model_counters(0).weight, 2.0);
        // A missing container path fails cleanly.
        assert!(open_multi_model_server(
            vec![ModelFileSpec::new(
                "x",
                dir.join("absent.elm").to_str().unwrap()
            )],
            budget,
            2,
            1,
            crate::coordinator::EngineConfig::default()
        )
        .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streaming_weightset_matches_eager_weightset() {
        let layers = synthetic_layers(9, 0xBEEF);
        let (elm, _) = compress(&layers, BitWidth::U4).unwrap();
        let elm = Arc::new(elm);

        let (tensors, _) = crate::decode::ParallelDecoder::new(4)
            .decode_model(&elm)
            .unwrap();
        let named: Vec<_> = elm
            .layers
            .iter()
            .map(|m| m.name.clone())
            .zip(tensors)
            .collect();
        let eager = WeightSet::from_quantized(named, Vec::new());

        let mut stream = StreamingDecoder::new(3, 2)
            .stream(Arc::clone(&elm))
            .unwrap();
        let streamed = WeightSet::from_layer_stream(&mut stream, Vec::new()).unwrap();
        let stats = stream.into_stats();
        assert_eq!(stats.total_symbols(), elm.n_params());

        assert_eq!(eager.quants.len(), streamed.quants.len());
        for (name, q) in &eager.quants {
            let s = streamed.quants.get(name).expect("layer present");
            assert_eq!(q.symbols.data(), s.symbols.data());
            assert_eq!(q.params, s.params);
        }
    }
}
