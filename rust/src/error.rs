//! Crate-wide error type.
//!
//! Every fallible public API in this crate returns [`Result<T>`]. The
//! variants are deliberately coarse — callers match on the category
//! (corrupt container vs. runtime failure vs. bad argument), and the
//! message carries the detail. Offline build: no `thiserror`, so the
//! `Display`/`From` impls are written out by hand.

use crate::xla;

/// Errors produced by the EntroLLM library.
#[derive(Debug)]
pub enum Error {
    /// Malformed or corrupt ELM container / Huffman table / bitstream.
    Format(String),

    /// An argument violated a documented precondition.
    InvalidArg(String),

    /// Underlying I/O failure.
    Io(std::io::Error),

    /// PJRT / XLA runtime failure.
    Xla(String),

    /// JSON parse error (artifact manifests, configs).
    Json(String),

    /// Serving-engine error (queue closed, request rejected, ...).
    Engine(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Format(m) => write!(f, "format error: {m}"),
            Error::InvalidArg(m) => write!(f, "invalid argument: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Engine(m) => write!(f, "engine error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand for `Err(Error::Format(format!(...)))`.
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        return Err($crate::Error::Format(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_includes_category_and_detail() {
        let e = Error::Format("bad magic".into());
        assert_eq!(e.to_string(), "format error: bad magic");
        let e = Error::InvalidArg("n must be > 0".into());
        assert!(e.to_string().contains("n must be > 0"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        use std::error::Error as _;
        assert!(e.source().is_some());
    }

    #[test]
    fn xla_error_converts_to_xla_variant() {
        let e: Error = crate::xla::PjRtClient::cpu().unwrap_err().into();
        assert!(matches!(e, Error::Xla(_)));
        assert!(e.to_string().starts_with("xla error:"));
    }
}
