//! Crate-wide error type.
//!
//! Every fallible public API in this crate returns [`Result<T>`]. The
//! variants are deliberately coarse — callers match on the category
//! (corrupt container vs. runtime failure vs. bad argument), and the
//! message carries the detail.

use thiserror::Error;

/// Errors produced by the EntroLLM library.
#[derive(Debug, Error)]
pub enum Error {
    /// Malformed or corrupt ELM container / Huffman table / bitstream.
    #[error("format error: {0}")]
    Format(String),

    /// An argument violated a documented precondition.
    #[error("invalid argument: {0}")]
    InvalidArg(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// PJRT / XLA runtime failure.
    #[error("xla error: {0}")]
    Xla(String),

    /// JSON parse error (artifact manifests, configs).
    #[error("json error: {0}")]
    Json(String),

    /// Serving-engine error (queue closed, request rejected, ...).
    #[error("engine error: {0}")]
    Engine(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand for `Err(Error::Format(format!(...)))`.
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        return Err($crate::Error::Format(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_includes_category_and_detail() {
        let e = Error::Format("bad magic".into());
        assert_eq!(e.to_string(), "format error: bad magic");
        let e = Error::InvalidArg("n must be > 0".into());
        assert!(e.to_string().contains("n must be > 0"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
