//! Codec negotiation (L2): the per-segment codec id and the
//! [`TileCodec`] seam that makes everything above the container parser
//! codec-agnostic.
//!
//! Since container v3 every layer manifest entry names its codec
//! ([`Codec::Huffman`] or [`Codec::Ans`]); v1/v2 containers predate
//! the field and default to Huffman. Decode consumers — eager parallel
//! decode, the streaming window, the residency prefetcher — never
//! branch on the codec themselves: they build one [`CodecSet`] from
//! the container's tables and fetch `&dyn TileCodec` per layer. A tile
//! is the unit of decode work for both codecs (byte-aligned,
//! independently decodable, CRC-guarded), so tiled parallel decode
//! works identically whichever codec wrote the bytes.

use crate::ans::{self, AnsTable};
use crate::huffman::{self, CodeSpec};
use crate::{Error, Result};

/// Wire-level codec id of a layer's segment (v3 manifest field).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Codec {
    /// Canonical length-limited Huffman (tag 0) — the only codec of
    /// container v1/v2, still the default.
    #[default]
    Huffman,
    /// Table-driven asymmetric numeral system (tag 1), v3+.
    Ans,
}

impl Codec {
    /// Manifest byte for this codec.
    pub fn tag(self) -> u8 {
        match self {
            Codec::Huffman => 0,
            Codec::Ans => 1,
        }
    }

    /// Parse a manifest byte; unknown ids are a format error (a v3
    /// reader must not guess how unknown payload bytes decode).
    pub fn from_tag(tag: u8) -> Result<Self> {
        match tag {
            0 => Ok(Codec::Huffman),
            1 => Ok(Codec::Ans),
            other => Err(Error::Format(format!(
                "unknown codec id {other} (known: 0 = huffman, 1 = tans)"
            ))),
        }
    }

    /// Human-facing name (CLI `inspect`/`compress` output).
    pub fn name(self) -> &'static str {
        match self {
            Codec::Huffman => "huffman",
            Codec::Ans => "tans",
        }
    }
}

impl std::fmt::Display for Codec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One tile's worth of decode work, codec-blind: exactly `out.len()`
/// symbols from one byte-aligned, independently decodable stream.
/// Implementations must validate the stream (truncation, trailing
/// garbage, codec-specific integrity) — callers only add CRC checks.
pub trait TileCodec: Send + Sync {
    /// Decode `bytes` into `out`, filling it exactly.
    fn decode_tile(&self, bytes: &[u8], out: &mut [u8]) -> Result<()>;
}

impl TileCodec for huffman::Decoder {
    fn decode_tile(&self, bytes: &[u8], out: &mut [u8]) -> Result<()> {
        self.decode_into(bytes, out)
    }
}

impl TileCodec for ans::Decoder {
    fn decode_tile(&self, bytes: &[u8], out: &mut [u8]) -> Result<()> {
        self.decode_into(bytes, out)
    }
}

/// The decoders a container's tables support, built once per
/// decode session and shared (read-only) across worker threads.
#[derive(Debug)]
pub struct CodecSet {
    huffman: huffman::Decoder,
    /// Present iff the container carried a tANS table (v3 with a
    /// non-zero table section).
    ans: Option<ans::Decoder>,
}

impl CodecSet {
    /// Build the per-codec decoders from a container's tables.
    pub fn new(code: &CodeSpec, ans_table: Option<&AnsTable>) -> Result<Self> {
        Ok(CodecSet {
            huffman: huffman::Decoder::new(code)?,
            ans: ans_table.map(ans::Decoder::new).transpose()?,
        })
    }

    /// The decoder for one layer's codec. `Codec::Ans` without a tANS
    /// table is unreachable through a validated container
    /// (`read_manifest` rejects that combination at open) but still an
    /// error, not a panic, for hand-built models.
    pub fn get(&self, codec: Codec) -> Result<&dyn TileCodec> {
        match codec {
            Codec::Huffman => Ok(&self.huffman),
            Codec::Ans => self
                .ans
                .as_ref()
                .map(|d| d as &dyn TileCodec)
                .ok_or_else(|| {
                    Error::Format(
                        "layer coded with tANS but the container carries no tANS table".into(),
                    )
                }),
        }
    }

    /// The Huffman decoder (always present; pre-v3 paths and
    /// benchmarks that want it directly).
    pub fn huffman(&self) -> &huffman::Decoder {
        &self.huffman
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::huffman::FreqTable;

    #[test]
    fn codec_tags_roundtrip_and_unknown_rejected() {
        for codec in [Codec::Huffman, Codec::Ans] {
            assert_eq!(Codec::from_tag(codec.tag()).unwrap(), codec);
        }
        for bad in [2u8, 3, 0x7F, 0xFF] {
            assert!(Codec::from_tag(bad).is_err(), "codec id {bad} must be rejected");
        }
        assert_eq!(Codec::default(), Codec::Huffman);
    }

    #[test]
    fn codec_set_dispatches_both_codecs_on_the_same_symbols() {
        let syms: Vec<u8> = (0..800).map(|i| ((i * 7) % 16) as u8).collect();
        let freq = FreqTable::from_symbols(&syms);
        let spec = CodeSpec::build(&freq).unwrap();
        let table = AnsTable::build(&freq).unwrap();

        let h_bytes = huffman::Encoder::new(&spec).encode_to_vec(&syms).unwrap();
        let a_bytes = ans::Encoder::new(&table).encode_to_vec(&syms).unwrap();

        let set = CodecSet::new(&spec, Some(&table)).unwrap();
        let mut h_out = vec![0u8; syms.len()];
        let mut a_out = vec![0u8; syms.len()];
        set.get(Codec::Huffman).unwrap().decode_tile(&h_bytes, &mut h_out).unwrap();
        set.get(Codec::Ans).unwrap().decode_tile(&a_bytes, &mut a_out).unwrap();
        assert_eq!(h_out, syms);
        assert_eq!(a_out, syms, "both codecs must decode to identical symbols");
    }

    #[test]
    fn ans_codec_without_table_errors_cleanly() {
        let syms = [1u8, 2, 3];
        let spec = CodeSpec::build(&FreqTable::from_symbols(&syms)).unwrap();
        let set = CodecSet::new(&spec, None).unwrap();
        assert!(set.get(Codec::Huffman).is_ok());
        assert!(set.get(Codec::Ans).is_err());
    }
}
