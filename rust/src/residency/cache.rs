//! The byte-budgeted layer cache: resident [`QuantizedTensor`]s behind
//! a replacement [`Policy`], with the fault-in path through
//! [`SegmentDecoder`] and **pinning** for the decode-ahead prefetcher
//! ([`crate::residency::prefetch`]).
//!
//! Residency is keyed and charged per **layer** (a layer's u8 symbol
//! buffer is the unit a consumer borrows, so it is also the unit that
//! can be evicted), but since ELM v2 every fault *decodes* at tile
//! granularity: [`SegmentDecoder`] verifies and decodes each tile of
//! the layer behind its own CRC, and the decode-ahead prefetcher
//! claims individual tiles so several workers can fill one layer's
//! buffer concurrently before the assembled layer is inserted here.
//! Byte accounting is exact either way — tiles partition the layer's
//! symbols, so the per-layer charge equals the sum of its tiles.

use super::ledger::ResidencyLedger;
use crate::decode::{SegmentDecoder, ThreadStats};
use crate::quant::QuantizedTensor;
use crate::store::SegmentSource;
use crate::{Error, Result};
use std::sync::Arc;
use std::time::Duration;

/// Replacement policy of a [`WeightCache`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Policy {
    /// Pure least-recently-used eviction (the PR 2 behavior). Optimal
    /// for skewed access, but a strictly cyclic full pass over a model
    /// bigger than the budget misses on **every** access — the residents
    /// always form a most-recent suffix of the scan (see the
    /// [`crate::residency`] module docs).
    #[default]
    Lru,
    /// Scan-resistant segmented LRU. Entries enter a *probationary*
    /// segment and are promoted to a *protected* segment on re-access;
    /// eviction takes the **most recently inserted** probationary entry
    /// first (so a scan's stream of once-touched layers churns a single
    /// slot while established residents survive) and falls back to the
    /// protected LRU only when probation is empty. On a cyclic pass over
    /// `N` equal layers with budget `N-1`, this hits `N-2` layers per
    /// pass where pure LRU hits zero.
    SegmentedLru,
}

/// Observability counters for one [`WeightCache`] — what the server's
/// `{"stats":true}` admin line surfaces as `cache_*` fields.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheCounters {
    /// Accesses served from a resident layer.
    pub hits: u64,
    /// Accesses that had to re-decode the layer's segment.
    pub misses: u64,
    /// Layers dropped to make room.
    pub evictions: u64,
    /// Decoded bytes currently resident.
    pub resident_bytes: usize,
    /// High-water mark of `resident_bytes` — the acceptance bound:
    /// never exceeds `budget_bytes` by construction.
    pub peak_resident_bytes: usize,
    /// Configured byte budget.
    pub budget_bytes: usize,
    /// Layers currently resident.
    pub resident_layers: usize,
    /// Layers currently pinned (never evicted; the decode-ahead
    /// prefetcher pins a published layer until it is consumed).
    pub pinned_layers: usize,
}

impl CacheCounters {
    /// Hit fraction over all accesses so far (0 when untouched).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    tensor: QuantizedTensor,
    /// Decoded size this entry charges against the budget (one byte per
    /// symbol — the u8 symbol buffer dominates a decoded layer).
    bytes: usize,
    /// Logical timestamp of the last access (recency order).
    last_used: u64,
    /// Logical timestamp of insertion (scan-resistant victim order).
    inserted: u64,
    /// Promoted out of probation by a re-access ([`Policy::SegmentedLru`]).
    protected: bool,
    /// Pinned entries are never chosen as eviction victims.
    pinned: bool,
}

/// Byte-budgeted **weight-residency cache** over a [`SegmentSource`].
///
/// Holds decoded layers up to a configurable byte budget; a miss
/// re-decodes the layer's segment via the re-entrant
/// [`SegmentDecoder`] (CRC-checked random re-entry), evicting victims
/// chosen by the configured [`Policy`] until the faulted layer fits.
/// This is what lets a model whose *decoded* weights exceed device RAM
/// keep serving: resident decoded bytes never exceed the budget, and
/// cold layers pay a re-decode instead of permanent residency.
///
/// The decode-ahead prefetcher drives the cache through the split
/// [`WeightCache::lookup`] / [`WeightCache::insert`] halves (decode
/// happens on a worker, outside any lock) and pins published layers so
/// eviction can never outrun the consumer.
///
/// Construction fails up front if the budget cannot hold the largest
/// single layer — such a cache could never hit and every access would
/// thrash, so it is an error, not a degraded mode.
pub struct WeightCache {
    decoder: SegmentDecoder,
    policy: Policy,
    entries: Vec<Option<Entry>>,
    /// Logical clock; bumped on every access.
    clock: u64,
    counters: CacheCounters,
    /// Fault-decode accounting (busy time, segments, symbols).
    stats: ThreadStats,
    /// Shared byte budget this cache draws from, when it is one of
    /// several in a multi-model pool (`None` → private budget).
    ledger: Option<(Arc<ResidencyLedger>, usize)>,
}

impl WeightCache {
    /// Cache over `source` with a decoded-byte `budget_bytes` and the
    /// default pure-LRU policy.
    pub fn new(source: Arc<SegmentSource>, budget_bytes: usize) -> Result<Self> {
        Self::with_policy(source, budget_bytes, Policy::Lru)
    }

    /// Cache with an explicit replacement [`Policy`].
    pub fn with_policy(
        source: Arc<SegmentSource>,
        budget_bytes: usize,
        policy: Policy,
    ) -> Result<Self> {
        Self::build(source, budget_bytes, policy, None)
    }

    /// Cache drawing on a **shared** [`ResidencyLedger`] instead of a
    /// private budget: every charge/release moves the global ledger, so
    /// several models' caches compete for one byte pool (the
    /// multi-model serving shape). The cache registers itself as one
    /// ledger slot; eviction still only removes *this* cache's entries
    /// — cross-model reclaim is driven by
    /// [`super::PrefetchShared`]'s peer-shed path. No reservation, the
    /// default admission weight — see [`WeightCache::with_ledger_qos`].
    pub fn with_ledger(
        source: Arc<SegmentSource>,
        ledger: Arc<ResidencyLedger>,
        policy: Policy,
    ) -> Result<Self> {
        Self::with_ledger_qos(source, ledger, policy, 0, 1.0)
    }

    /// [`WeightCache::with_ledger`] with per-model QoS: a minimum
    /// residency `reserve` (bytes peers can never reclaim from this
    /// cache, and headroom the ledger holds committed for it even when
    /// unfilled) and an admission `weight` (how aggressively this
    /// model may shed peers above everyone's reserve — see
    /// [`ResidencyLedger`]'s module docs). The reservation must fit
    /// the global budget on its own; the coordinator additionally
    /// validates that the *sum* of every model's reserve fits.
    pub fn with_ledger_qos(
        source: Arc<SegmentSource>,
        ledger: Arc<ResidencyLedger>,
        policy: Policy,
        reserve: usize,
        weight: f64,
    ) -> Result<Self> {
        let budget = ledger.budget();
        if reserve > budget {
            return Err(Error::InvalidArg(format!(
                "residency reservation {reserve} B exceeds the global weight \
                 budget {budget} B"
            )));
        }
        let slot = ledger.register_with(reserve, weight);
        Self::build(source, budget, policy, Some((ledger, slot)))
    }

    fn build(
        source: Arc<SegmentSource>,
        budget_bytes: usize,
        policy: Policy,
        ledger: Option<(Arc<ResidencyLedger>, usize)>,
    ) -> Result<Self> {
        let largest = source
            .layers()
            .iter()
            .map(|m| m.n_symbols)
            .max()
            .unwrap_or(0);
        if budget_bytes < largest {
            return Err(Error::InvalidArg(format!(
                "weight budget {budget_bytes} B is smaller than the largest decoded \
                 layer ({largest} B); the cache would thrash on every access — raise \
                 the budget to at least one layer"
            )));
        }
        let n = source.n_layers();
        Ok(WeightCache {
            decoder: SegmentDecoder::new(source)?,
            policy,
            entries: (0..n).map(|_| None).collect(),
            clock: 0,
            counters: CacheCounters {
                budget_bytes,
                ..CacheCounters::default()
            },
            stats: ThreadStats::default(),
            ledger,
        })
    }

    /// The shared ledger and this cache's slot in it, when budgeted
    /// through one.
    pub(crate) fn ledger_handle(&self) -> Option<(Arc<ResidencyLedger>, usize)> {
        self.ledger.as_ref().map(|(l, s)| (Arc::clone(l), *s))
    }

    fn release_bytes(&mut self, bytes: usize) {
        self.counters.resident_bytes -= bytes;
        if let Some((ledger, slot)) = &self.ledger {
            ledger.release(*slot, bytes);
        }
    }

    fn touch_ledger(&self) {
        if let Some((ledger, slot)) = &self.ledger {
            ledger.touch(*slot);
        }
    }

    /// Stamp this cache's model as just-accessed in the shared ledger
    /// (no-op with a private budget). The prefetch consumer calls it
    /// once on entry — the single recency stamp per access, so even a
    /// model's first-ever fault ranks hotter than idle peers (a cold
    /// model could otherwise neither steal nor fit) without doubling
    /// traffic on the one mutex every model shares.
    pub(crate) fn touch_shared(&self) {
        self.touch_ledger();
    }

    /// The source the cache faults from.
    pub fn source(&self) -> &Arc<SegmentSource> {
        self.decoder.source()
    }

    /// The configured replacement policy.
    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Counter snapshot.
    pub fn counters(&self) -> CacheCounters {
        self.counters
    }

    /// Wallclock spent re-decoding faulted segments so far (only the
    /// synchronous [`WeightCache::get`] path; prefetch workers account
    /// their decode time separately).
    pub fn fault_time(&self) -> Duration {
        self.stats.busy
    }

    /// Layers the underlying model has.
    pub fn n_layers(&self) -> usize {
        self.entries.len()
    }

    /// Is layer `index` currently resident?
    pub fn is_resident(&self, index: usize) -> bool {
        matches!(self.entries.get(index), Some(Some(_)))
    }

    /// Is layer `index` resident *and* pinned?
    pub fn is_pinned(&self, index: usize) -> bool {
        matches!(self.entries.get(index), Some(Some(e)) if e.pinned)
    }

    /// Pin a resident layer so it cannot be evicted. Returns `false`
    /// (and does nothing) when the layer is not resident.
    pub fn pin(&mut self, index: usize) -> bool {
        match self.entries.get_mut(index) {
            Some(Some(e)) => {
                if !e.pinned {
                    e.pinned = true;
                    self.counters.pinned_layers += 1;
                }
                true
            }
            _ => false,
        }
    }

    /// Release a pin (no-op if the layer is absent or unpinned).
    pub fn unpin(&mut self, index: usize) {
        if let Some(Some(e)) = self.entries.get_mut(index) {
            if e.pinned {
                e.pinned = false;
                self.counters.pinned_layers -= 1;
            }
        }
    }

    fn check_index(&self, index: usize) -> Result<()> {
        if index >= self.entries.len() {
            return Err(Error::InvalidArg(format!(
                "layer index {index} out of range ({} layers)",
                self.entries.len()
            )));
        }
        Ok(())
    }

    /// Record an access outcome without touching an entry (the prefetch
    /// consumer counts hits/misses itself because an access may resolve
    /// only after a worker publishes the layer).
    pub(crate) fn note_access(&mut self, hit: bool) {
        if hit {
            self.counters.hits += 1;
        } else {
            self.counters.misses += 1;
        }
    }

    /// Touch layer `index` if resident: bump recency, promote out of
    /// probation under [`Policy::SegmentedLru`], and return the tensor.
    /// Does **not** move the hit/miss counters (the prefetch consumer
    /// counts its own access outcomes); [`WeightCache::get`] is the
    /// counting all-in-one path.
    pub fn lookup(&mut self, index: usize) -> Option<&QuantizedTensor> {
        self.clock += 1;
        let clock = self.clock;
        let protect = self.policy == Policy::SegmentedLru;
        match self.entries.get_mut(index) {
            Some(Some(e)) => {
                e.last_used = clock;
                if protect {
                    e.protected = true;
                }
                Some(&e.tensor)
            }
            _ => None,
        }
    }

    /// Touch layer `index` for a serve that already paid its decode
    /// (prefetch consume / post-fault serve): recency bump only — no
    /// probation promotion, mirroring [`WeightCache::get`]'s
    /// first-touch semantics — and no counters.
    pub(crate) fn peek_serve(&mut self, index: usize) -> Option<&QuantizedTensor> {
        self.clock += 1;
        let clock = self.clock;
        match self.entries.get_mut(index) {
            Some(Some(e)) => {
                e.last_used = clock;
                Some(&e.tensor)
            }
            _ => None,
        }
    }

    /// Pick an eviction victim under the policy, skipping pinned
    /// entries. `None` when every resident entry is pinned.
    fn victim(&self) -> Option<usize> {
        self.victim_within(usize::MAX)
    }

    /// [`WeightCache::victim`] restricted to entries of at most `cap`
    /// decoded bytes — the reserve-floor-aware variant the peer-shed
    /// path uses: when the policy's first choice is too large to evict
    /// without breaching the reservation, a smaller entry later in
    /// policy order is still a legal victim (layer sizes vary in real
    /// models, so "first victim too big" must not strand the rest of
    /// the reclaimable bytes).
    fn victim_within(&self, cap: usize) -> Option<usize> {
        let live = |(i, e): (usize, &Option<Entry>)| e.as_ref().map(|e| (i, e));
        match self.policy {
            Policy::Lru => self
                .entries
                .iter()
                .enumerate()
                .filter_map(live)
                .filter(|(_, e)| !e.pinned && e.bytes <= cap)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i),
            Policy::SegmentedLru => {
                let probation = self
                    .entries
                    .iter()
                    .enumerate()
                    .filter_map(live)
                    .filter(|(_, e)| !e.pinned && !e.protected && e.bytes <= cap)
                    .max_by_key(|(_, e)| e.inserted)
                    .map(|(i, _)| i);
                probation.or_else(|| {
                    self.entries
                        .iter()
                        .enumerate()
                        .filter_map(live)
                        .filter(|(_, e)| !e.pinned && e.protected && e.bytes <= cap)
                        .min_by_key(|(_, e)| e.last_used)
                        .map(|(i, _)| i)
                })
            }
        }
    }

    /// Evict unpinned victims until `bytes` more decoded bytes fit
    /// under the budget. Errors when pinned layers block eviction —
    /// the prefetch window validation at construction makes that
    /// unreachable in the shipped configurations.
    /// Secure `bytes` of budget for layer `index`, evicting this
    /// cache's own unpinned victims as needed. Construction guarantees
    /// `bytes <= budget`, so this terminates with the invariant
    /// `resident <= budget` intact unless pins (or, under a shared
    /// ledger, peer models — the peer-shed path reclaims from them
    /// *before* an insert reaches here) hold everything.
    ///
    /// With a shared ledger the check-and-charge is **atomic** (the
    /// ledger's `try_charge`): concurrent inserts from different
    /// models can never both pass a room check and overshoot the
    /// global budget together.
    fn reserve(&mut self, index: usize, bytes: usize) -> Result<()> {
        loop {
            let charged = match &self.ledger {
                Some((ledger, slot)) => ledger.try_charge(*slot, bytes),
                None => self.counters.resident_bytes + bytes <= self.counters.budget_bytes,
            };
            if charged {
                self.counters.resident_bytes += bytes;
                self.counters.peak_resident_bytes = self
                    .counters
                    .peak_resident_bytes
                    .max(self.counters.resident_bytes);
                return Ok(());
            }
            let Some(victim) = self.victim() else {
                return Err(Error::Engine(format!(
                    "cache budget {} B exhausted ({} pinned layers here, peers may \
                     hold the rest); cannot make room for layer {index} ({bytes} B) \
                     — shrink the decode-ahead window or raise the budget",
                    self.counters.budget_bytes, self.counters.pinned_layers
                )));
            };
            if let Some(evicted) = self.entries[victim].take() {
                self.release_bytes(evicted.bytes);
                self.counters.resident_layers -= 1;
                self.counters.evictions += 1;
            }
        }
    }

    /// Evict unpinned entries in policy order until at least `bytes`
    /// decoded bytes have been released, or nothing evictable remains.
    /// Returns the bytes actually freed. This is the **peer-shed**
    /// entry point of shared-ledger serving: a hot model reclaiming
    /// global budget calls it on a colder model's cache — which is why
    /// it honors this model's own **minimum residency reservation**: an
    /// eviction that would drop resident bytes below the reserve is
    /// refused, so peers can pressure this cache down *to* its
    /// guarantee but never through it. (The cache's own insert path
    /// evicts through its internal `reserve` step instead and is free
    /// to dip below its reserve — the guarantee protects a model from
    /// its peers, not from itself.) Pinned entries are skipped as
    /// always.
    pub fn shed(&mut self, bytes: usize) -> usize {
        let floor = self
            .ledger
            .as_ref()
            .map(|(ledger, slot)| ledger.reserve_of(*slot))
            .unwrap_or(0);
        let mut freed = 0usize;
        while freed < bytes {
            // Only entries small enough to leave the reservation
            // intact are admissible victims; with unequal layer sizes
            // the policy's first choice may be too large while a
            // smaller entry is still legally evictable.
            let reclaimable = self.counters.resident_bytes.saturating_sub(floor);
            if reclaimable == 0 {
                break;
            }
            let Some(victim) = self.victim_within(reclaimable) else {
                break;
            };
            match self.entries[victim].take() {
                Some(evicted) => {
                    self.release_bytes(evicted.bytes);
                    self.counters.resident_layers -= 1;
                    self.counters.evictions += 1;
                    freed += evicted.bytes;
                }
                None => break,
            }
        }
        freed
    }

    /// Install an externally decoded layer (the prefetch publish path),
    /// evicting unpinned victims until it fits. `pinned` entries stay
    /// resident until [`WeightCache::unpin`]. Inserting an
    /// already-resident layer keeps the existing tensor and only
    /// strengthens the pin (a prefetch that raced a synchronous fault).
    ///
    /// Does not move the hit/miss counters: an insert is not an access.
    /// The layer was necessarily decoded *before* this call, so on the
    /// concurrent prefetch path the decoded-but-uninserted tensor
    /// transiently lives beside a full cache — that overshoot is what
    /// the `(window + 1) × largest` construction floor budgets for.
    /// The synchronous [`WeightCache::get`] path instead evicts before
    /// it decodes and never exceeds the budget at any instant.
    pub fn insert(&mut self, index: usize, tensor: QuantizedTensor, pinned: bool) -> Result<()> {
        self.check_index(index)?;
        if self.entries[index].is_some() {
            if pinned {
                self.pin(index);
            }
            return Ok(());
        }
        let bytes = self.decoder.source().meta(index).n_symbols;
        self.reserve(index, bytes)?;
        self.install(index, tensor, pinned, bytes);
        Ok(())
    }

    /// Create the entry for a layer whose bytes were already secured by
    /// [`WeightCache::reserve`] (byte accounting happens there, entry
    /// bookkeeping here).
    fn install(&mut self, index: usize, tensor: QuantizedTensor, pinned: bool, bytes: usize) {
        self.clock += 1;
        let clock = self.clock;
        self.counters.resident_layers += 1;
        if pinned {
            self.counters.pinned_layers += 1;
        }
        self.entries[index] = Some(Entry {
            tensor,
            bytes,
            last_used: clock,
            inserted: clock,
            protected: false,
            pinned,
        });
    }

    /// Fetch layer `index`, faulting it in synchronously (and evicting
    /// cold layers) on a miss. The borrow is valid until the next cache
    /// call.
    pub fn get(&mut self, index: usize) -> Result<&QuantizedTensor> {
        self.check_index(index)?;
        self.touch_ledger();
        if self.entries[index].is_some() {
            self.counters.hits += 1;
            self.clock += 1;
            let clock = self.clock;
            let protect = self.policy == Policy::SegmentedLru;
            let e = self.entries[index].as_mut().expect("checked resident");
            e.last_used = clock;
            if protect {
                e.protected = true;
            }
            return Ok(&e.tensor);
        }

        self.counters.misses += 1;
        // Reserve *before* decoding (PR 2 ordering): the decoded buffer
        // is only allocated once room exists, so resident decoded
        // bytes never exceed the budget even transiently on this path —
        // and under a shared ledger the reservation also keeps a
        // concurrent peer from claiming the same headroom mid-decode.
        let bytes = self.decoder.source().meta(index).n_symbols;
        self.reserve(index, bytes)?;
        let tensor = match self.decoder.decode_layer_stats(index, &mut self.stats) {
            Ok(t) => t,
            Err(e) => {
                // Hand the unused reservation back before surfacing.
                self.release_bytes(bytes);
                return Err(e);
            }
        };
        self.install(index, tensor, false, bytes);
        match self.entries[index].as_ref() {
            Some(e) => Ok(&e.tensor),
            None => Err(Error::Engine(format!(
                "layer {index} missing immediately after fault-in"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::synthetic_layers;
    use crate::quant::BitWidth;
    use crate::rng::Rng;
    use crate::store::{compress, decode_layer, ElmModel};

    fn source(n_layers: usize, seed: u64) -> (ElmModel, Arc<SegmentSource>) {
        let layers = synthetic_layers(n_layers, seed);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let src = Arc::new(SegmentSource::from_model(Arc::new(model.clone())));
        (model, src)
    }

    /// `n` equal-size layers (512 decoded bytes each) — the shape the
    /// policy tests need so "budget = k layers" is exact.
    fn equal_source(n: usize, seed: u64) -> Arc<SegmentSource> {
        let layers: Vec<(String, crate::tensor::TensorF32)> = (0..n)
            .map(|i| {
                let mut rng = Rng::new(seed + i as u64);
                (
                    format!("l{i}"),
                    crate::tensor::TensorF32::new(vec![512], rng.gaussian_vec(512, 0.0, 0.05))
                        .unwrap(),
                )
            })
            .collect();
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        Arc::new(SegmentSource::from_model(Arc::new(model)))
    }

    fn layer_bytes(model: &ElmModel) -> Vec<usize> {
        model.layers.iter().map(|m| m.n_symbols).collect()
    }

    #[test]
    fn budget_smaller_than_one_layer_errors_cleanly() {
        let (model, src) = source(6, 0x10);
        let largest = *layer_bytes(&model).iter().max().unwrap();
        let err = WeightCache::new(Arc::clone(&src), largest - 1).unwrap_err();
        assert!(err.to_string().contains("thrash"), "{err}");
        // Exactly one layer is the smallest legal budget.
        assert!(WeightCache::new(src, largest).is_ok());
    }

    #[test]
    fn hits_require_no_decode_and_bump_no_miss() {
        let (model, src) = source(5, 0x11);
        let total: usize = layer_bytes(&model).iter().sum();
        let mut cache = WeightCache::new(src, total).unwrap();
        for i in 0..model.layers.len() {
            cache.get(i).unwrap();
        }
        let after_walk = cache.counters();
        assert_eq!(after_walk.misses, model.layers.len() as u64);
        assert_eq!(after_walk.evictions, 0, "everything fits: no evictions");
        for i in 0..model.layers.len() {
            cache.get(i).unwrap();
        }
        let after_rewalk = cache.counters();
        assert_eq!(after_rewalk.misses, after_walk.misses);
        assert_eq!(after_rewalk.hits, model.layers.len() as u64);
        assert_eq!(after_rewalk.resident_layers, model.layers.len());
    }

    #[test]
    fn eviction_keeps_resident_bytes_within_budget() {
        let (model, src) = source(10, 0x12);
        let bytes = layer_bytes(&model);
        let largest = *bytes.iter().max().unwrap();
        let total: usize = bytes.iter().sum();
        // A budget around half the model forces evictions on a full walk.
        let budget = largest.max(total / 2);
        let mut cache = WeightCache::new(src, budget).unwrap();
        for round in 0..3 {
            for i in 0..model.layers.len() {
                let got = cache.get(i).unwrap();
                let want = decode_layer(&model, i).unwrap();
                assert_eq!(got.symbols.data(), want.symbols.data(), "round {round} layer {i}");
                let c = cache.counters();
                assert!(
                    c.resident_bytes <= budget,
                    "resident {} exceeds budget {budget}",
                    c.resident_bytes
                );
            }
        }
        let c = cache.counters();
        assert!(c.evictions > 0, "budget {budget} < total {total} must evict");
        assert!(c.peak_resident_bytes <= budget);
        assert!(cache.fault_time() > Duration::ZERO);
    }

    #[test]
    fn lru_order_evicts_the_coldest_layer() {
        // Three equal-sized layers, budget for exactly two: touching
        // 0,1 then 2 must evict 0 (the coldest), keep 1 and 2.
        let src = equal_source(3, 0x20);
        let mut cache = WeightCache::new(src, 1024).unwrap();
        cache.get(0).unwrap();
        cache.get(1).unwrap();
        cache.get(0).unwrap(); // 1 is now the coldest
        cache.get(2).unwrap(); // must evict 1
        assert!(cache.is_resident(0));
        assert!(!cache.is_resident(1));
        assert!(cache.is_resident(2));
        assert_eq!(cache.counters().evictions, 1);
    }

    #[test]
    fn out_of_range_index_is_an_error_not_a_panic() {
        let (_, src) = source(4, 0x13);
        let mut cache = WeightCache::new(src, usize::MAX / 2).unwrap();
        assert!(cache.get(4).is_err());
        assert!(cache.lookup(4).is_none());
    }

    /// The scan-resistance satellite, on the policy alone: a cyclic
    /// full pass over `N` equal layers with budget `N-1` must hit at
    /// least `N-2` layers per pass under [`Policy::SegmentedLru`],
    /// while pure LRU hits zero.
    #[test]
    fn segmented_lru_survives_cyclic_scans_where_lru_scores_zero() {
        let n = 8usize;
        let budget = (n - 1) * 512;

        let mut slru =
            WeightCache::with_policy(equal_source(n, 0x30), budget, Policy::SegmentedLru).unwrap();
        let mut lru =
            WeightCache::with_policy(equal_source(n, 0x30), budget, Policy::Lru).unwrap();

        // Warmup pass: everything cold on both policies.
        for i in 0..n {
            slru.get(i).unwrap();
            lru.get(i).unwrap();
        }
        assert_eq!(slru.counters().hits, 0);
        assert_eq!(lru.counters().hits, 0);

        for pass in 0..4 {
            let before = slru.counters().hits;
            for i in 0..n {
                slru.get(i).unwrap();
                lru.get(i).unwrap();
                assert!(slru.counters().resident_bytes <= budget);
            }
            let per_pass = slru.counters().hits - before;
            assert!(
                per_pass as usize >= n - 2,
                "pass {pass}: segmented LRU hit {per_pass} of {n}, want >= {}",
                n - 2
            );
        }
        assert_eq!(lru.counters().hits, 0, "pure LRU thrashes on a cyclic scan");
        assert!(lru.counters().evictions > slru.counters().evictions);
    }

    #[test]
    fn pinned_layers_are_never_eviction_victims() {
        // Budget for three layers; pin one, then stream the rest
        // through — the pinned layer must survive every eviction even
        // though (under LRU, never being re-accessed) it would
        // otherwise be the first victim every time.
        let n = 6usize;
        let src = equal_source(n, 0x31);
        let mut cache = WeightCache::with_policy(src, 3 * 512, Policy::Lru).unwrap();
        cache.get(3).unwrap();
        assert!(cache.pin(3));
        assert!(cache.is_pinned(3));
        assert_eq!(cache.counters().pinned_layers, 1);
        for round in 0..3 {
            for i in [0usize, 1, 2, 4, 5] {
                cache.get(i).unwrap();
                assert!(cache.is_resident(3), "round {round}: pinned layer evicted");
            }
        }
        assert!(cache.counters().evictions > 0, "unpinned layers must churn");
        // Unpinning makes it the coldest entry — the very next eviction
        // takes it.
        cache.unpin(3);
        assert_eq!(cache.counters().pinned_layers, 0);
        let absent = (0..n).find(|&i| !cache.is_resident(i)).unwrap();
        cache.get(absent).unwrap();
        assert!(!cache.is_resident(3), "unpinned layer must fall out first");
        // Pinning a non-resident layer reports failure.
        assert!(!cache.pin(3));
    }

    #[test]
    fn insert_when_everything_pinned_errors_instead_of_breaking_budget() {
        let n = 4usize;
        let src = equal_source(n, 0x32);
        let mut cache = WeightCache::with_policy(src, 2 * 512, Policy::SegmentedLru).unwrap();
        cache.get(0).unwrap();
        cache.get(1).unwrap();
        assert!(cache.pin(0));
        assert!(cache.pin(1));
        let err = cache.get(2).unwrap_err();
        assert!(err.to_string().contains("pinned"), "{err}");
        assert!(cache.counters().resident_bytes <= 2 * 512);
        // Releasing a pin unblocks the fault.
        cache.unpin(0);
        assert!(cache.get(2).is_ok());
    }

    #[test]
    fn lookup_insert_split_matches_get() {
        // The prefetch path (external decode + insert + lookup) must
        // leave the cache bit-identical to the synchronous get path.
        let (model, src) = source(6, 0x33);
        let total: usize = layer_bytes(&model).iter().sum();
        let decoder = SegmentDecoder::new(Arc::clone(&src)).unwrap();
        let mut cache = WeightCache::with_policy(src, total, Policy::SegmentedLru).unwrap();
        assert!(cache.lookup(2).is_none(), "cold lookup is a miss");
        let tensor = decoder.decode_layer(2).unwrap();
        cache.insert(2, tensor, true).unwrap();
        assert!(cache.is_pinned(2));
        // Double insert is a no-op that keeps the pin.
        let again = decoder.decode_layer(2).unwrap();
        cache.insert(2, again, false).unwrap();
        assert!(cache.is_pinned(2));
        assert_eq!(cache.counters().resident_layers, 1);
        let want = decode_layer(&model, 2).unwrap();
        let got = cache.lookup(2).expect("resident after insert");
        assert_eq!(got.symbols.data(), want.symbols.data());
        assert_eq!(got.params, want.params);
        // Inserts and lookups moved no hit/miss counters.
        let c = cache.counters();
        assert_eq!((c.hits, c.misses), (0, 0));
    }

    /// The QoS floor at the cache level: a peer shed can pressure a
    /// reserved cache down **to** its reservation, never through it —
    /// while an unreserved cache sheds to empty as before.
    #[test]
    fn shed_never_drops_a_reserved_cache_below_its_reserve() {
        let ledger = ResidencyLedger::new(6 * 512);
        let mut reserved = WeightCache::with_ledger_qos(
            equal_source(6, 0x70),
            Arc::clone(&ledger),
            Policy::Lru,
            2 * 512,
            1.0,
        )
        .unwrap();
        for i in 0..4 {
            reserved.get(i).unwrap();
        }
        assert_eq!(reserved.counters().resident_bytes, 4 * 512);
        // A peer demanding the world reclaims only down to the reserve.
        let freed = reserved.shed(usize::MAX);
        assert_eq!(freed, 2 * 512);
        assert_eq!(reserved.counters().resident_bytes, 2 * 512);
        assert_eq!(ledger.used_by(0), 2 * 512);
        // At the floor, further sheds free nothing.
        assert_eq!(reserved.shed(1), 0);
        assert_eq!(reserved.counters().resident_bytes, 2 * 512);
        // The cache's own insert path is NOT floor-bound: faulting new
        // layers may still evict its own entries freely.
        reserved.get(4).unwrap();
        assert!(reserved.counters().resident_bytes >= 2 * 512);

        // Unreserved: shed drains to empty, exactly the PR 4 behavior.
        let ledger2 = ResidencyLedger::new(6 * 512);
        let mut plain =
            WeightCache::with_ledger(equal_source(6, 0x71), ledger2, Policy::Lru).unwrap();
        for i in 0..3 {
            plain.get(i).unwrap();
        }
        assert_eq!(plain.shed(usize::MAX), 3 * 512);
        assert_eq!(plain.counters().resident_bytes, 0);
    }

    /// Unequal layer sizes: when the policy's first victim is too
    /// large to evict without breaching the reserve, a smaller entry
    /// later in policy order must be shed instead of stranding the
    /// reclaimable bytes.
    #[test]
    fn shed_skips_oversized_policy_victim_for_a_smaller_admissible_one() {
        let layers: Vec<(String, crate::tensor::TensorF32)> = [600usize, 100]
            .iter()
            .enumerate()
            .map(|(i, &n)| {
                let mut rng = Rng::new(0x80 + i as u64);
                (
                    format!("l{i}"),
                    crate::tensor::TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.05))
                        .unwrap(),
                )
            })
            .collect();
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let src = Arc::new(SegmentSource::from_model(Arc::new(model)));
        let ledger = ResidencyLedger::new(4096);
        let mut cache =
            WeightCache::with_ledger_qos(src, Arc::clone(&ledger), Policy::Lru, 150, 1.0).unwrap();
        cache.get(0).unwrap();
        cache.get(1).unwrap(); // LRU victim is now layer 0 (600 B)
        assert_eq!(cache.counters().resident_bytes, 700);
        // Evicting the 600 B policy victim would leave 100 B, under
        // the 150 B floor — so the 100 B entry is the legal victim.
        let freed = cache.shed(usize::MAX);
        assert_eq!(freed, 100, "the smaller admissible entry must shed");
        assert!(cache.is_resident(0));
        assert!(!cache.is_resident(1));
        assert_eq!(cache.counters().resident_bytes, 600);
        assert_eq!(ledger.used_by(0), 600);
    }

    #[test]
    fn reservation_larger_than_the_global_budget_is_rejected() {
        let ledger = ResidencyLedger::new(1024);
        let err = WeightCache::with_ledger_qos(
            equal_source(2, 0x72),
            ledger,
            Policy::Lru,
            1025,
            1.0,
        )
        .unwrap_err();
        assert!(err.to_string().contains("reservation"), "{err}");
    }

    #[test]
    fn property_any_access_pattern_any_budget_any_policy_is_bitexact() {
        // The eviction-correctness property: whatever the access
        // pattern, budget, and policy, every fetched layer is
        // bit-identical to the eager decode, and residency never
        // exceeds the budget.
        let mut rng = Rng::new(0xCAC4E);
        for case in 0..6 {
            let n_layers = 2 + rng.below(10);
            let (model, src) = source(n_layers, 0x9000 + case);
            let bytes = layer_bytes(&model);
            let largest = *bytes.iter().max().unwrap();
            let total: usize = bytes.iter().sum();
            let budget = largest + rng.below(total.saturating_sub(largest) + 1);
            let policy = if rng.below(2) == 0 {
                Policy::Lru
            } else {
                Policy::SegmentedLru
            };
            let mut cache = WeightCache::with_policy(src, budget, policy).unwrap();
            let eager: Vec<_> = (0..n_layers)
                .map(|i| decode_layer(&model, i).unwrap())
                .collect();
            for _ in 0..60 {
                let i = rng.below(n_layers);
                let got = cache.get(i).unwrap();
                assert_eq!(got.symbols.data(), eager[i].symbols.data());
                assert_eq!(got.params, eager[i].params);
                assert!(cache.counters().resident_bytes <= budget);
            }
            let c = cache.counters();
            assert_eq!(c.hits + c.misses, 60);
            assert!(c.peak_resident_bytes <= budget);
        }
    }
}
