//! The LRU layer cache: a byte budget, resident [`QuantizedTensor`]s,
//! and the fault-in path through [`SegmentDecoder`].

use crate::decode::SegmentDecoder;
use crate::quant::QuantizedTensor;
use crate::store::SegmentSource;
use crate::{Error, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Observability counters for one [`LruWeightCache`] — what the
/// server's `{"stats":true}` admin line surfaces as `cache_*` fields.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheCounters {
    /// Accesses served from a resident layer.
    pub hits: u64,
    /// Accesses that had to re-decode the layer's segment.
    pub misses: u64,
    /// Layers dropped to make room.
    pub evictions: u64,
    /// Decoded bytes currently resident.
    pub resident_bytes: usize,
    /// High-water mark of `resident_bytes` — the acceptance bound:
    /// never exceeds `budget_bytes` by construction.
    pub peak_resident_bytes: usize,
    /// Configured byte budget.
    pub budget_bytes: usize,
    /// Layers currently resident.
    pub resident_layers: usize,
}

impl CacheCounters {
    /// Hit fraction over all accesses so far (0 when untouched).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    tensor: QuantizedTensor,
    /// Decoded size this entry charges against the budget (one byte per
    /// symbol — the u8 symbol buffer dominates a decoded layer).
    bytes: usize,
    /// Logical timestamp of the last access (LRU order).
    last_used: u64,
}

/// LRU **weight-residency cache** over a [`SegmentSource`].
///
/// Holds decoded layers up to a configurable byte budget; a miss
/// re-decodes the layer's segment via the re-entrant
/// [`SegmentDecoder`] (CRC-checked random re-entry), evicting
/// least-recently-used layers first until the faulted layer fits. This
/// is what lets a model whose *decoded* weights exceed device RAM keep
/// serving: resident decoded bytes never exceed the budget, and cold
/// layers pay a re-decode instead of permanent residency.
///
/// Construction fails up front if the budget cannot hold the largest
/// single layer — such a cache could never hit and every access would
/// thrash, so it is an error, not a degraded mode.
pub struct LruWeightCache {
    decoder: SegmentDecoder,
    entries: Vec<Option<Entry>>,
    /// Logical clock; bumped on every access.
    clock: u64,
    counters: CacheCounters,
    /// Wallclock spent re-decoding faulted segments.
    fault_time: Duration,
}

impl LruWeightCache {
    /// Cache over `source` with a decoded-byte `budget_bytes`.
    pub fn new(source: Arc<SegmentSource>, budget_bytes: usize) -> Result<Self> {
        let largest = source
            .layers()
            .iter()
            .map(|m| m.n_symbols)
            .max()
            .unwrap_or(0);
        if budget_bytes < largest {
            return Err(Error::InvalidArg(format!(
                "weight budget {budget_bytes} B is smaller than the largest decoded \
                 layer ({largest} B); the cache would thrash on every access — raise \
                 the budget to at least one layer"
            )));
        }
        let n = source.n_layers();
        Ok(LruWeightCache {
            decoder: SegmentDecoder::new(source)?,
            entries: (0..n).map(|_| None).collect(),
            clock: 0,
            counters: CacheCounters {
                budget_bytes,
                ..CacheCounters::default()
            },
            fault_time: Duration::ZERO,
        })
    }

    /// The source the cache faults from.
    pub fn source(&self) -> &Arc<SegmentSource> {
        self.decoder.source()
    }

    /// Counter snapshot.
    pub fn counters(&self) -> CacheCounters {
        self.counters
    }

    /// Wallclock spent re-decoding faulted segments so far.
    pub fn fault_time(&self) -> Duration {
        self.fault_time
    }

    /// Layers the underlying model has.
    pub fn n_layers(&self) -> usize {
        self.entries.len()
    }

    /// Is layer `index` currently resident?
    pub fn is_resident(&self, index: usize) -> bool {
        matches!(self.entries.get(index), Some(Some(_)))
    }

    /// Fetch layer `index`, faulting it in (and evicting cold layers)
    /// on a miss. The borrow is valid until the next cache call.
    pub fn get(&mut self, index: usize) -> Result<&QuantizedTensor> {
        if index >= self.entries.len() {
            return Err(Error::InvalidArg(format!(
                "layer index {index} out of range ({} layers)",
                self.entries.len()
            )));
        }
        self.clock += 1;
        let clock = self.clock;
        if self.entries[index].is_some() {
            self.counters.hits += 1;
            let e = self.entries[index].as_mut().expect("checked resident");
            e.last_used = clock;
            return Ok(&e.tensor);
        }

        self.counters.misses += 1;
        let bytes = self.decoder.source().meta(index).n_symbols;
        // Evict LRU layers until the faulted one fits; construction
        // guarantees `bytes <= budget`, so this terminates with the
        // invariant `resident_bytes <= budget` intact.
        while self.counters.resident_bytes + bytes > self.counters.budget_bytes {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter_map(|(i, e)| e.as_ref().map(|e| (e.last_used, i)))
                .min()
                .map(|(_, i)| i)
                .expect("over budget implies a resident entry");
            let evicted = self.entries[victim].take().expect("victim is resident");
            self.counters.resident_bytes -= evicted.bytes;
            self.counters.resident_layers -= 1;
            self.counters.evictions += 1;
        }

        let t0 = Instant::now();
        let tensor = self.decoder.decode_layer(index)?;
        self.fault_time += t0.elapsed();

        self.counters.resident_bytes += bytes;
        self.counters.resident_layers += 1;
        self.counters.peak_resident_bytes = self
            .counters
            .peak_resident_bytes
            .max(self.counters.resident_bytes);
        self.entries[index] = Some(Entry {
            tensor,
            bytes,
            last_used: clock,
        });
        Ok(&self.entries[index].as_ref().expect("just inserted").tensor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::synthetic_layers;
    use crate::quant::BitWidth;
    use crate::rng::Rng;
    use crate::store::{compress, decode_layer, ElmModel};

    fn source(n_layers: usize, seed: u64) -> (ElmModel, Arc<SegmentSource>) {
        let layers = synthetic_layers(n_layers, seed);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let src = Arc::new(SegmentSource::from_model(Arc::new(model.clone())));
        (model, src)
    }

    fn layer_bytes(model: &ElmModel) -> Vec<usize> {
        model.layers.iter().map(|m| m.n_symbols).collect()
    }

    #[test]
    fn budget_smaller_than_one_layer_errors_cleanly() {
        let (model, src) = source(6, 0x10);
        let largest = *layer_bytes(&model).iter().max().unwrap();
        let err = LruWeightCache::new(Arc::clone(&src), largest - 1).unwrap_err();
        assert!(err.to_string().contains("thrash"), "{err}");
        // Exactly one layer is the smallest legal budget.
        assert!(LruWeightCache::new(src, largest).is_ok());
    }

    #[test]
    fn hits_require_no_decode_and_bump_no_miss() {
        let (model, src) = source(5, 0x11);
        let total: usize = layer_bytes(&model).iter().sum();
        let mut cache = LruWeightCache::new(src, total).unwrap();
        for i in 0..model.layers.len() {
            cache.get(i).unwrap();
        }
        let after_walk = cache.counters();
        assert_eq!(after_walk.misses, model.layers.len() as u64);
        assert_eq!(after_walk.evictions, 0, "everything fits: no evictions");
        for i in 0..model.layers.len() {
            cache.get(i).unwrap();
        }
        let after_rewalk = cache.counters();
        assert_eq!(after_rewalk.misses, after_walk.misses);
        assert_eq!(after_rewalk.hits, model.layers.len() as u64);
        assert_eq!(after_rewalk.resident_layers, model.layers.len());
    }

    #[test]
    fn eviction_keeps_resident_bytes_within_budget() {
        let (model, src) = source(10, 0x12);
        let bytes = layer_bytes(&model);
        let largest = *bytes.iter().max().unwrap();
        let total: usize = bytes.iter().sum();
        // A budget around half the model forces evictions on a full walk.
        let budget = largest.max(total / 2);
        let mut cache = LruWeightCache::new(src, budget).unwrap();
        for round in 0..3 {
            for i in 0..model.layers.len() {
                let got = cache.get(i).unwrap();
                let want = decode_layer(&model, i).unwrap();
                assert_eq!(got.symbols.data(), want.symbols.data(), "round {round} layer {i}");
                let c = cache.counters();
                assert!(
                    c.resident_bytes <= budget,
                    "resident {} exceeds budget {budget}",
                    c.resident_bytes
                );
            }
        }
        let c = cache.counters();
        assert!(c.evictions > 0, "budget {budget} < total {total} must evict");
        assert!(c.peak_resident_bytes <= budget);
        assert!(cache.fault_time() > Duration::ZERO);
    }

    #[test]
    fn lru_order_evicts_the_coldest_layer() {
        // Three equal-sized layers, budget for exactly two: touching
        // 0,1 then 2 must evict 0 (the coldest), keep 1 and 2.
        let layers: Vec<(String, crate::tensor::TensorF32)> = (0..3)
            .map(|i| {
                let mut rng = Rng::new(0x20 + i as u64);
                (
                    format!("l{i}"),
                    crate::tensor::TensorF32::new(vec![512], rng.gaussian_vec(512, 0.0, 0.05))
                        .unwrap(),
                )
            })
            .collect();
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let src = Arc::new(SegmentSource::from_model(Arc::new(model)));
        let mut cache = LruWeightCache::new(src, 1024).unwrap();
        cache.get(0).unwrap();
        cache.get(1).unwrap();
        cache.get(0).unwrap(); // 1 is now the coldest
        cache.get(2).unwrap(); // must evict 1
        assert!(cache.is_resident(0));
        assert!(!cache.is_resident(1));
        assert!(cache.is_resident(2));
        assert_eq!(cache.counters().evictions, 1);
    }

    #[test]
    fn out_of_range_index_is_an_error_not_a_panic() {
        let (_, src) = source(4, 0x13);
        let mut cache = LruWeightCache::new(src, usize::MAX / 2).unwrap();
        assert!(cache.get(4).is_err());
    }

    #[test]
    fn property_any_access_pattern_any_budget_is_bitexact() {
        // The eviction-correctness property: whatever the access
        // pattern and budget, every fetched layer is bit-identical to
        // the eager decode, and residency never exceeds the budget.
        let mut rng = Rng::new(0xCAC4E);
        for case in 0..6 {
            let n_layers = 2 + rng.below(10);
            let (model, src) = source(n_layers, 0x9000 + case);
            let bytes = layer_bytes(&model);
            let largest = *bytes.iter().max().unwrap();
            let total: usize = bytes.iter().sum();
            let budget = largest + rng.below(total.saturating_sub(largest) + 1);
            let mut cache = LruWeightCache::new(src, budget).unwrap();
            let eager: Vec<_> = (0..n_layers)
                .map(|i| decode_layer(&model, i).unwrap())
                .collect();
            for _ in 0..60 {
                let i = rng.below(n_layers);
                let got = cache.get(i).unwrap();
                assert_eq!(got.symbols.data(), eager[i].symbols.data());
                assert_eq!(got.params, eager[i].params);
                assert!(cache.counters().resident_bytes <= budget);
            }
            let c = cache.counters();
            assert_eq!(c.hits + c.misses, 60);
            assert!(c.peak_resident_bytes <= budget);
        }
    }
}
