//! Serving over a residency cache: [`ResidentWeightSet`] (the
//! cache-backed analogue of [`crate::runtime::WeightSet`]) and
//! [`ResidentDigestBackend`] (the engine backend that faults layers in
//! during generation). This is the single-model, fault-on-demand
//! baseline; the decode-ahead counterpart lives in
//! [`super::prefetch`], and multi-model serving (several such engines
//! drawing on one shared byte budget) in
//! [`crate::coordinator::MultiModelServer`] over
//! [`super::ledger::ResidencyLedger`].

use super::cache::{CacheCounters, WeightCache};
use crate::coordinator::backend::{
    digest_decode_next, digest_f32_entry, digest_prefill_next, digest_quant_entry, fnv1a64,
    Backend, BackendCfg, FNV1A64_INIT,
};
use crate::quant::QuantizedTensor;
use crate::store::SegmentSource;
use crate::tensor::TensorF32;
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// The weight tensors a serving engine needs, held **partially
/// resident**: quantized layers live in a [`WeightCache`] (pure LRU —
/// the fault-on-demand baseline the decode-ahead
/// [`super::PrefetchingWeightSet`] is measured against) and fault in
/// on access; the fp32 rest (norm tensors — a sliver of the model)
/// stays always-resident like in [`crate::runtime::WeightSet`].
pub struct ResidentWeightSet {
    cache: WeightCache,
    f32s: HashMap<String, TensorF32>,
    /// Layer name → storage-order index (fault-in by name).
    by_name: HashMap<String, usize>,
    /// `(name, index)` in sorted-name order — the digest walk order,
    /// fixed at construction so per-token digests allocate nothing.
    digest_order: Vec<(String, usize)>,
}

impl ResidentWeightSet {
    /// Weight set over `source` with a decoded-byte `budget_bytes` for
    /// the quantized layers, plus the always-resident fp32 rest.
    pub fn new(
        source: Arc<SegmentSource>,
        budget_bytes: usize,
        f32_rest: Vec<(String, TensorF32)>,
    ) -> Result<Self> {
        let by_name: HashMap<String, usize> = source
            .layers()
            .iter()
            .enumerate()
            .map(|(i, m)| (m.name.clone(), i))
            .collect();
        // Walk the deduplicated name map, not the raw manifest, so the
        // digest sees exactly the layers an eager `WeightSet` would.
        let mut digest_order: Vec<(String, usize)> =
            by_name.iter().map(|(n, &i)| (n.clone(), i)).collect();
        digest_order.sort();
        Ok(ResidentWeightSet {
            cache: WeightCache::new(source, budget_bytes)?,
            f32s: f32_rest.into_iter().collect(),
            by_name,
            digest_order,
        })
    }

    /// Cache counter snapshot.
    pub fn counters(&self) -> CacheCounters {
        self.cache.counters()
    }

    /// Borrow the cache (introspection/benches).
    pub fn cache(&self) -> &WeightCache {
        &self.cache
    }

    /// Quantized layer by storage-order index, faulting it in if cold.
    pub fn layer(&mut self, index: usize) -> Result<&QuantizedTensor> {
        self.cache.get(index)
    }

    /// Quantized layer by manifest name, faulting it in if cold.
    pub fn layer_by_name(&mut self, name: &str) -> Result<&QuantizedTensor> {
        let index = *self
            .by_name
            .get(name)
            .ok_or_else(|| Error::InvalidArg(format!("unknown quantized layer {name:?}")))?;
        self.cache.get(index)
    }

    /// Always-resident fp32 tensor by name.
    pub fn f32(&self, name: &str) -> Option<&TensorF32> {
        self.f32s.get(name)
    }

    /// Quantized layer count.
    pub fn n_layers(&self) -> usize {
        self.cache.n_layers()
    }

    /// Digest of the **full** weight set, faulting layers through the
    /// cache in sorted-name order — peak resident decoded bytes stay
    /// within the budget, yet the result equals
    /// [`crate::coordinator::digest_weights`] of the eagerly decoded
    /// set bit for bit. This is the losslessness oracle for serving
    /// models larger than the budget.
    pub fn digest(&mut self) -> Result<u64> {
        let mut h = FNV1A64_INIT;
        h = fnv1a64(h, &(self.digest_order.len() as u64).to_le_bytes());
        for (name, index) in &self.digest_order {
            let q = self.cache.get(*index)?;
            h = digest_quant_entry(h, name, q);
        }
        let mut fnames: Vec<&String> = self.f32s.keys().collect();
        fnames.sort();
        h = fnv1a64(h, &(fnames.len() as u64).to_le_bytes());
        for name in fnames {
            h = digest_f32_entry(h, name, &self.f32s[name]);
        }
        Ok(h)
    }
}

/// Engine backend that serves through a [`ResidentWeightSet`]: every
/// prefill and every decode step walks the full weight set through the
/// cache — exactly the per-layer access pattern of a real forward pass
/// — so generation faults cold layers in (and the hit/miss/evict
/// counters move) *during* serving, not just at load.
///
/// Generation is digest-driven like
/// [`crate::coordinator::DigestBackend`], via the same shared mixers:
/// the two backends emit identical tokens iff their weight sets are
/// bit-identical, which is how tests pin "a model bigger than the
/// budget still serves the right tokens".
pub struct ResidentDigestBackend {
    cfg: BackendCfg,
    weights: ResidentWeightSet,
    /// Decode steps executed.
    pub steps: usize,
    /// Prefills executed.
    pub prefills: usize,
}

impl ResidentDigestBackend {
    /// Backend over a resident weight set.
    pub fn new(weights: ResidentWeightSet, batch: usize, max_seq: usize, vocab: usize) -> Self {
        ResidentDigestBackend {
            cfg: BackendCfg {
                batch,
                max_seq,
                prefill_len: (max_seq / 2).max(1),
                vocab,
            },
            weights,
            steps: 0,
            prefills: 0,
        }
    }

    /// Borrow the resident weight set.
    pub fn weights(&self) -> &ResidentWeightSet {
        &self.weights
    }

    fn onehot(&self, tok: u64) -> Vec<f32> {
        let mut l = vec![0.0f32; self.cfg.vocab];
        l[(tok % self.cfg.vocab as u64) as usize] = 10.0;
        l
    }
}

impl Backend for ResidentDigestBackend {
    fn cfg(&self) -> BackendCfg {
        self.cfg
    }

    fn prefill(&mut self, prompt: &[u32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.prefills += 1;
        // One full weight pass through the cache, like a real prefill.
        let digest = self.weights.digest()?;
        let next = digest_prefill_next(digest, prompt, self.cfg.vocab);
        let kv = vec![next as f32; 8];
        Ok((self.onehot(next), kv.clone(), kv))
    }

    fn set_slot(&mut self, _slot: usize, _k1: &[f32], _v1: &[f32]) -> Result<()> {
        // Generation is digest-driven; there is no KV state to splice.
        Ok(())
    }

    fn decode(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Vec<f32>> {
        assert_eq!(tokens.len(), self.cfg.batch);
        assert_eq!(pos.len(), self.cfg.batch);
        self.steps += 1;
        // Each batched decode step is one more weight pass: every layer
        // is touched, so cold layers fault in mid-generation.
        let digest = self.weights.digest()?;
        let mut out = Vec::with_capacity(self.cfg.batch * self.cfg.vocab);
        for (&t, &p) in tokens.iter().zip(pos) {
            out.extend_from_slice(
                &self.onehot(digest_decode_next(digest, t, p, self.cfg.vocab)),
            );
        }
        Ok(out)
    }

    fn residency(&self) -> Option<CacheCounters> {
        Some(self.weights.counters())
    }

    fn argmax_rows(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Option<Vec<u32>>> {
        self.steps += 1;
        // A verification/proposal block costs one full weight pass,
        // exactly like a decode step — speculative bursts therefore
        // fault and evict through the cache like real decode traffic.
        let digest = self.weights.digest()?;
        Ok(Some(
            tokens
                .iter()
                .zip(pos)
                .map(|(&t, &p)| digest_decode_next(digest, t, p, self.cfg.vocab) as u32)
                .collect(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{digest_weights, DigestBackend, Engine, EngineConfig, Request};
    use crate::pipeline::synthetic_layers;
    use crate::quant::BitWidth;
    use crate::runtime::WeightSet;
    use crate::store::{compress, SegmentSource};

    /// Synthetic model + the eager weight set the residency path must
    /// be indistinguishable from.
    fn fixture(n_layers: usize, seed: u64) -> (Arc<SegmentSource>, WeightSet, usize, usize) {
        let layers = synthetic_layers(n_layers, seed);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let eager = WeightSet::from_elm(&model, 2, Vec::new()).unwrap();
        let bytes: Vec<usize> = model.layers.iter().map(|m| m.n_symbols).collect();
        let largest = *bytes.iter().max().unwrap();
        let total: usize = bytes.iter().sum();
        let src = Arc::new(SegmentSource::from_model(Arc::new(model)));
        (src, eager, largest, total)
    }

    #[test]
    fn resident_digest_equals_eager_digest_under_tight_budget() {
        let (src, eager, largest, total) = fixture(12, 0x77);
        // Budget well below the full model: digesting must evict.
        let budget = largest.max(total / 3);
        assert!(budget < total, "fixture must not fit entirely");
        let mut ws = ResidentWeightSet::new(src, budget, Vec::new()).unwrap();
        let want = digest_weights(&eager);
        assert_eq!(ws.digest().unwrap(), want);
        // Re-digesting (cache now warm-ish) must be stable.
        assert_eq!(ws.digest().unwrap(), want);
        let c = ws.counters();
        assert!(c.evictions > 0, "tight budget must evict");
        assert!(c.peak_resident_bytes <= budget);
    }

    fn run_engine<B: Backend>(mut engine: Engine<B>) -> Vec<(u64, Vec<u32>)> {
        for id in 0..5u64 {
            engine
                .submit(Request::greedy(id, vec![3 + id as u32, 7], 6))
                .unwrap();
        }
        let mut out: Vec<(u64, Vec<u32>)> = engine
            .run_to_completion(1000)
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens))
            .collect();
        out.sort();
        out
    }

    #[test]
    fn resident_backend_generates_identical_tokens_to_eager_backend() {
        let (src, eager, largest, total) = fixture(10, 0x78);
        let budget = largest.max(total / 4);
        let ws = ResidentWeightSet::new(src, budget, Vec::new()).unwrap();

        let resident = run_engine(Engine::new(
            ResidentDigestBackend::new(ws, 2, 32, 64),
            EngineConfig::default(),
        ));
        let full = run_engine(Engine::new(
            DigestBackend::from_weights(&eager, 2, 32, 64),
            EngineConfig::default(),
        ));
        assert_eq!(resident, full, "residency must be invisible in the tokens");
    }

    #[test]
    fn residency_counters_move_during_generation_and_reach_the_engine() {
        let (src, _, largest, total) = fixture(8, 0x79);
        let budget = largest.max(total / 3);
        assert!(budget < total);
        let ws = ResidentWeightSet::new(src, budget, Vec::new()).unwrap();
        let mut engine = Engine::new(
            ResidentDigestBackend::new(ws, 2, 32, 64),
            EngineConfig::default(),
        );
        assert_eq!(engine.residency().unwrap().misses, 0, "cold at start");
        engine.submit(Request::greedy(1, vec![5, 6], 4)).unwrap();
        engine.run_to_completion(100).unwrap();
        let c = engine.residency().expect("resident backend reports counters");
        assert!(c.misses > 0, "cold layers must fault in");
        assert!(c.evictions > 0, "tight budget must evict mid-generation");
        assert!(c.peak_resident_bytes <= budget);
        // A cyclic full pass per step never revisits a layer before LRU
        // drops it (see the module docs on scan behavior), so every
        // access under a below-model budget is a miss.
        assert_eq!(c.hits, 0);
        assert_eq!(c.hit_rate(), 0.0);
    }

    #[test]
    fn budget_covering_the_model_hits_after_warmup() {
        let (src, _, _, total) = fixture(8, 0x7C);
        let ws = ResidentWeightSet::new(src, total, Vec::new()).unwrap();
        let mut engine = Engine::new(
            ResidentDigestBackend::new(ws, 2, 32, 64),
            EngineConfig::default(),
        );
        engine.submit(Request::greedy(1, vec![5, 6], 4)).unwrap();
        engine.run_to_completion(100).unwrap();
        let c = engine.residency().unwrap();
        assert_eq!(c.misses, 8, "one cold fault per layer");
        assert!(c.hits > 0, "later passes are all hits");
        assert_eq!(c.evictions, 0);
        assert!(c.hit_rate() > 0.5);
    }

    #[test]
    fn layer_by_name_faults_and_unknown_name_errors() {
        let (src, eager, _, total) = fixture(6, 0x7A);
        let mut ws = ResidentWeightSet::new(src, total, Vec::new()).unwrap();
        let q = ws.layer_by_name("blocks.2.w").unwrap();
        assert_eq!(
            q.symbols.data(),
            eager.quants["blocks.2.w"].symbols.data()
        );
        assert!(ws.layer_by_name("nope").is_err());
    }

    #[test]
    fn f32_rest_participates_in_the_digest() {
        let (src, mut eager, _, total) = fixture(5, 0x7B);
        let norm = TensorF32::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        eager.f32s.insert("ln.w".into(), norm.clone());
        let mut ws =
            ResidentWeightSet::new(src, total, vec![("ln.w".into(), norm.clone())]).unwrap();
        assert_eq!(ws.digest().unwrap(), digest_weights(&eager));
        assert_eq!(ws.f32("ln.w").unwrap().data(), norm.data());
        assert!(ws.f32("missing").is_none());
    }
}
