//! **Decode-ahead prefetch during generation**: while layer `i` is
//! being consumed in a token step, a background worker pool decodes
//! layer `i+1`'s segment and pins it until consumed — the Huff-LLM
//! overlap (arXiv:2502.00922) that hides the residency cache's
//! per-token fault cost behind compute
//! ([`crate::device::LatencyModel::overlapped_token_gen`] models the
//! effect as `max(compute, decode)` instead of their sum).
//!
//! Concurrency shape: one [`WeightCache`] (scan-resistant
//! [`Policy::SegmentedLru`] by default) plus a prefetch queue behind a
//! single mutex, two condvars (`work` wakes idle workers, `done` wakes
//! a consumer waiting on an in-flight decode), and the re-entrant
//! [`SegmentDecoder`] shared lock-free by every worker. Decodes always
//! run **outside** the lock; only claim/publish/consume touch it.
//!
//! Every piece of worker work is an explicit claim → decode → publish
//! job over **one tile** ([`PrefetchShared::try_claim`],
//! [`PrefetchShared::decode_job`], [`PrefetchShared::publish`]), so
//! several workers can attack the independently decodable tiles of a
//! single hot layer at once (the ELM v2 shape of the paper's parallel
//! entropy decoding), while tests drive interleavings
//! deterministically through a [`TestScheduler`] (no background
//! threads, no sleeps) and production wraps the same three steps in a
//! thread-pool loop. Workers assemble decoded tiles into a per-layer
//! staging buffer under the lock; the publish that seals the last tile
//! inserts the whole layer, bit-identical to a serial decode.
//!
//! Invariants the deterministic tests pin down:
//!
//! * a published (pinned) layer is never evicted before it is consumed;
//! * a layer that is mid-decode on a worker and faulted synchronously
//!   by the consumer is decoded exactly once (the consumer waits on
//!   `done` instead of decoding again);
//! * cancellation (engine drop) wakes and joins every worker and never
//!   poisons the shared lock.

use super::cache::{CacheCounters, Policy, WeightCache};
use super::ledger::ResidencyLedger;
use crate::coordinator::backend::{
    digest_decode_next, digest_f32_entry, digest_prefill_next, digest_quant_entry, fnv1a64,
    Backend, BackendCfg, FNV1A64_INIT,
};
use crate::decode::{SegmentDecoder, ThreadStats};
use crate::quant::QuantizedTensor;
use crate::store::SegmentSource;
use crate::tensor::TensorF32;
use crate::{Error, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, PoisonError, Weak};
use std::time::Duration;

/// Decode-ahead configuration.
#[derive(Debug, Clone, Copy)]
pub struct PrefetchConfig {
    /// How many layers ahead of the consumer to schedule (the window;
    /// clamped to `n_layers - 1`). The budget must hold
    /// `decode_ahead + 1` copies of the largest layer so pinned
    /// prefetches can never wedge the cache.
    pub decode_ahead: usize,
    /// Background decode threads, capped at the effective window times
    /// the largest per-layer tile count (each worker holds at most one
    /// decoded tile outside cache accounting, so the cap keeps true
    /// peak memory within the budget floor while still letting every
    /// worker attack one hot layer's tiles). `0` spawns none —
    /// prefetch jobs then only run when a [`TestScheduler`] steps them
    /// (or the consumer faults synchronously), which is what the
    /// deterministic tests use.
    pub workers: usize,
    /// Replacement policy under the prefetcher.
    pub policy: Policy,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        PrefetchConfig {
            decode_ahead: 2,
            workers: 2,
            policy: Policy::SegmentedLru,
        }
    }
}

/// Observability counters for one prefetch engine — the `prefetch_*`
/// fields of the server's `{"stats":true}` admin line.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PrefetchCounters {
    /// Layers scheduled for prefetch (each expands to one queue entry
    /// per not-yet-decoded tile).
    pub scheduled: u64,
    /// Layers fully assembled from worker-decoded tiles and published.
    pub completed: u64,
    /// Consumer accesses served by a layer a worker decoded ahead
    /// (the entry was still pinned when consumed).
    pub hits: u64,
    /// Times the consumer blocked on an in-flight prefetch decode
    /// instead of decoding the layer again itself.
    pub waits: u64,
    /// Layers the consumer decoded synchronously on its own thread
    /// (the prefetcher never got there).
    pub sync_faults: u64,
    /// Claimed queue entries skipped because the tile's layer was
    /// already resident, or the tile itself was in flight or already
    /// assembled by then.
    pub redundant: u64,
}

/// A claimed prefetch job: one tile of one layer, marked in-flight
/// until the holder hands a decode result back to
/// [`PrefetchShared::publish`].
#[derive(Debug)]
pub struct Job {
    index: usize,
    tile: usize,
}

impl Job {
    /// The layer this job decodes a tile of.
    pub fn index(&self) -> usize {
        self.index
    }

    /// The tile within the layer.
    pub fn tile(&self) -> usize {
        self.tile
    }
}

/// Worker-side staging buffer for a layer being assembled tile by
/// tile. Lives outside the cache's byte accounting until the last tile
/// seals it (bounded by the decode-ahead window, the same overshoot
/// bound the layer-granular pool had).
struct PartialLayer {
    buf: Vec<u8>,
    /// Tiles already copied into `buf`.
    done: Vec<bool>,
    remaining: usize,
}

struct State {
    cache: WeightCache,
    /// `(layer, tile)` prefetch jobs awaiting a claim.
    queue: VecDeque<(usize, usize)>,
    /// Per-layer, per-tile in-flight marks.
    inflight: Vec<Vec<bool>>,
    /// Per-layer tile assembly in progress (worker path only; the
    /// synchronous fault path decodes whole layers and discards any
    /// partial assembly it preempts).
    partial: Vec<Option<PartialLayer>>,
    /// First worker-side failure; delivered once to the next consumer.
    error: Option<Error>,
    cancelled: bool,
    counters: PrefetchCounters,
}

/// Shared core of the decode-ahead engine: cache + queue behind one
/// mutex, decode strictly outside it. Workers and the consumer are
/// symmetric clients of this object, which is what lets tests replace
/// the worker pool with manual stepping.
pub struct PrefetchShared {
    state: Mutex<State>,
    /// Workers wait here for queued work (or cancellation).
    work: Condvar,
    /// Consumers wait here for an in-flight decode to publish.
    done: Condvar,
    decoder: SegmentDecoder,
    /// Decode-ahead window: also the cap on simultaneously pinned
    /// layers, which (with the construction-time budget check) is what
    /// makes "eviction blocked by pins" unreachable.
    window: usize,
    /// Shared byte ledger + this engine's slot, when part of a
    /// multi-model pool (mirrors the cache's handle so peer reclaim can
    /// consult the ledger without taking the state lock).
    ledger: Option<(Arc<ResidencyLedger>, usize)>,
    /// Peer engines in the same shared-ledger pool, indexed by ledger
    /// slot — the shed targets of [`PrefetchShared::reclaim_from_peers`].
    peers: OnceLock<Vec<Weak<PrefetchShared>>>,
    /// Wakeup channel to a shared [`PrefetchPool`], when one drives
    /// this engine's queue instead of a private worker set.
    pool_signal: OnceLock<Arc<PoolSignal>>,
}

impl PrefetchShared {
    fn from_cache(cache: WeightCache, window: usize) -> Result<Arc<Self>> {
        let source = Arc::clone(cache.source());
        let tiles_per: Vec<usize> = source.layers().iter().map(|m| m.tiles.len()).collect();
        let decoder = SegmentDecoder::new(source)?;
        let ledger = cache.ledger_handle();
        Ok(Arc::new(PrefetchShared {
            state: Mutex::new(State {
                cache,
                queue: VecDeque::new(),
                inflight: tiles_per.iter().map(|&t| vec![false; t]).collect(),
                partial: tiles_per.iter().map(|_| None).collect(),
                error: None,
                cancelled: false,
                counters: PrefetchCounters::default(),
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            decoder,
            window,
            ledger,
            peers: OnceLock::new(),
            pool_signal: OnceLock::new(),
        }))
    }

    /// Lock the shared state, **recovering** from poisoning: every
    /// critical section in this module leaves the state consistent, so
    /// one panicked client thread (e.g. a consumer closure that threw)
    /// must not cascade into a server-wide panic via `lock().unwrap()`.
    fn lock_state(&self) -> std::sync::MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Layers the underlying model has.
    pub fn n_layers(&self) -> usize {
        self.lock_state().cache.n_layers()
    }

    /// Residency-cache counter snapshot.
    pub fn cache_counters(&self) -> CacheCounters {
        self.lock_state().cache.counters()
    }

    /// Prefetch counter snapshot.
    pub fn counters(&self) -> PrefetchCounters {
        self.lock_state().counters
    }

    /// Is layer `index` currently resident?
    pub fn is_resident(&self, index: usize) -> bool {
        self.lock_state().cache.is_resident(index)
    }

    /// Is layer `index` resident and pinned (published, unconsumed)?
    pub fn is_pinned(&self, index: usize) -> bool {
        self.lock_state().cache.is_pinned(index)
    }

    /// Has a client panic poisoned the shared lock? Poisoning is
    /// **recovered** everywhere in this module (see
    /// [`PrefetchShared::lock_state`]), so a `true` here is
    /// informational — serving continues — but the cancellation test
    /// still asserts a clean engine drop never trips it.
    pub fn poisoned(&self) -> bool {
        self.state.is_poisoned()
    }

    /// The shared ledger this engine draws from, when budgeted through
    /// one (multi-model pools).
    pub fn ledger(&self) -> Option<&Arc<ResidencyLedger>> {
        self.ledger.as_ref().map(|(l, _)| l)
    }

    /// This engine's slot in the shared ledger.
    pub fn ledger_slot(&self) -> Option<usize> {
        self.ledger.as_ref().map(|(_, s)| *s)
    }

    /// Wire this engine to its shared-ledger peers, indexed by ledger
    /// slot (the coordinator calls this once after building every
    /// engine). Later calls are ignored.
    pub fn link_peers(&self, peers: Vec<Weak<PrefetchShared>>) {
        let _ = self.peers.set(peers);
    }

    /// Attach the wakeup signal of a shared [`PrefetchPool`]. Later
    /// calls are ignored.
    pub(crate) fn attach_pool_signal(&self, signal: Arc<PoolSignal>) {
        let _ = self.pool_signal.set(signal);
    }

    /// Evict unpinned entries from **this** engine's cache until
    /// `bytes` decoded bytes are freed (or nothing evictable remains);
    /// returns the bytes freed. Peers call this to reclaim shared
    /// budget from a colder model.
    pub fn shed(&self, bytes: usize) -> usize {
        self.lock_state().cache.shed(bytes)
    }

    /// Make global headroom for `incoming` decoded bytes by shedding
    /// peers in the ledger's QoS victim order — strictly colder peers
    /// coldest-first, then (for a higher admission weight) hotter
    /// lower-weight holders. Every victim sheds only down to its own
    /// minimum residency reservation (the peer's cache enforces the
    /// floor), and completed sheds move the per-model
    /// `shed_from_peers`/`shed_by_peers` counters. Must be called with
    /// no state lock held (peer shedding takes the peer's lock); a
    /// no-op outside shared-ledger pools, when the ledger already has
    /// room, or when no peer holds reclaimable bytes — in which case
    /// the insert path falls back to evicting this engine's own
    /// entries.
    fn reclaim_from_peers(&self, incoming: usize) {
        let Some((ledger, me)) = &self.ledger else {
            return;
        };
        if !ledger.needs_room(*me, incoming) {
            return;
        }
        let Some(peers) = self.peers.get() else {
            return;
        };
        for slot in ledger.colder_peers(*me) {
            if !ledger.needs_room(*me, incoming) {
                break;
            }
            if let Some(peer) = peers.get(slot).and_then(|w| w.upgrade()) {
                let freed = peer.shed(ledger.shortfall(*me, incoming));
                if freed > 0 {
                    ledger.note_shed(slot, *me, freed);
                }
            }
        }
    }

    /// Enqueue prefetch jobs for `indices`, expanded to one `(layer,
    /// tile)` entry per not-yet-decoded tile (deduplicated against the
    /// queue, resident layers, in-flight tiles, and tiles already
    /// assembled into a partial layer), then wake the workers.
    pub fn schedule(&self, indices: &[usize]) {
        let mut st = self.lock_state();
        if st.cancelled {
            return;
        }
        for &idx in indices {
            if idx >= st.inflight.len() || st.cache.is_resident(idx) {
                continue;
            }
            let mut any = false;
            for t in 0..st.inflight[idx].len() {
                let assembled = st.partial[idx].as_ref().is_some_and(|p| p.done[t]);
                if !st.inflight[idx][t] && !assembled && !st.queue.contains(&(idx, t)) {
                    st.queue.push_back((idx, t));
                    any = true;
                }
            }
            if any {
                st.counters.scheduled += 1;
            }
        }
        drop(st);
        self.work.notify_all();
        if let Some(signal) = self.pool_signal.get() {
            signal.bump();
        }
    }

    fn claim_locked(st: &mut State) -> Option<Job> {
        while let Some((idx, tile)) = st.queue.pop_front() {
            let assembled = st.partial[idx].as_ref().is_some_and(|p| p.done[tile]);
            if st.cache.is_resident(idx) || st.inflight[idx][tile] || assembled {
                st.counters.redundant += 1;
                continue;
            }
            st.inflight[idx][tile] = true;
            return Some(Job { index: idx, tile });
        }
        None
    }

    /// Claim the next useful queued job without blocking, marking its
    /// tile in-flight (exactly what a pool worker does). The manual
    /// half of the scheduler seam.
    pub fn try_claim(&self) -> Option<Job> {
        Self::claim_locked(&mut self.lock_state())
    }

    /// Blocking claim for pool workers: parks on `work` until a job or
    /// cancellation arrives. `None` means shut down.
    fn claim_blocking(&self) -> Option<Job> {
        let mut st = self.lock_state();
        loop {
            if st.cancelled {
                return None;
            }
            if let Some(job) = Self::claim_locked(&mut st) {
                return Some(job);
            }
            st = self.work.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Decode a claimed tile job. Runs on the caller's thread with
    /// **no** lock held — this is the long pole the prefetcher overlaps
    /// with token compute.
    pub fn decode_job(&self, job: &Job, stats: &mut ThreadStats) -> Result<Vec<u8>> {
        let out = self.decoder.decode_tile(job.index, job.tile)?;
        let tile = &self.decoder.source().meta(job.index).tiles[job.tile];
        stats.segments += 1;
        stats.encoded_bytes += tile.encoded_len;
        stats.symbols += tile.n_symbols;
        Ok(out)
    }

    /// Publish a tile decode result: copy it into the layer's staging
    /// buffer, and when this was the last missing tile, insert the
    /// assembled layer **pinned** (so eviction cannot outrun the
    /// consumer), clear the in-flight marks, and wake anyone waiting on
    /// it. Errors are parked for the next consumer access and drop the
    /// staging buffer — sibling tiles cannot seal a layer whose stream
    /// is bad. After cancellation the result is discarded but the
    /// in-flight mark is still cleared, so a blocked consumer can
    /// always make progress.
    pub fn publish(&self, job: Job, result: Result<Vec<u8>>) {
        let meta = self.decoder.source().meta(job.index);
        let mut st = self.lock_state();
        if st.cancelled {
            st.inflight[job.index][job.tile] = false;
            drop(st);
            self.done.notify_all();
            return;
        }
        let mut sealed: Option<Vec<u8>> = None;
        match result {
            Ok(bytes) => {
                let n_tiles = meta.tiles.len();
                let tile = &meta.tiles[job.tile];
                let complete = {
                    let entry = st.partial[job.index].get_or_insert_with(|| PartialLayer {
                        buf: vec![0u8; meta.n_symbols],
                        done: vec![false; n_tiles],
                        remaining: n_tiles,
                    });
                    if !entry.done[job.tile] {
                        entry.buf[tile.sym_offset..tile.sym_offset + tile.n_symbols]
                            .copy_from_slice(&bytes);
                        entry.done[job.tile] = true;
                        entry.remaining -= 1;
                    }
                    entry.remaining == 0
                };
                if complete {
                    sealed = st.partial[job.index].take().map(|p| p.buf);
                    // Hold every tile mark while the seal is in flight,
                    // so no scheduler, worker, or consumer re-decodes
                    // the layer between the unlock below and the pinned
                    // insert.
                    for m in st.inflight[job.index].iter_mut() {
                        *m = true;
                    }
                } else {
                    st.inflight[job.index][job.tile] = false;
                }
            }
            Err(e) => {
                st.inflight[job.index][job.tile] = false;
                st.partial[job.index] = None;
                if st.error.is_none() {
                    st.error = Some(e);
                }
            }
        }
        drop(st);
        let Some(buf) = sealed else {
            self.done.notify_all();
            return;
        };
        // Shared-ledger pools: make global headroom by shedding colder
        // peers *before* taking our own lock (lock ordering: never hold
        // two engines' state locks at once).
        self.reclaim_from_peers(meta.n_symbols);
        let assembled = crate::tensor::TensorU8::new(meta.shape.clone(), buf)
            .map(|symbols| QuantizedTensor {
                symbols,
                params: meta.params,
            });
        let mut st = self.lock_state();
        for m in st.inflight[job.index].iter_mut() {
            *m = false;
        }
        if !st.cancelled {
            // Pin so eviction cannot outrun the consumer — but cap the
            // pinned population at the window, so stale queue entries
            // (scheduled, then evicted again before their claim) can
            // never pin the whole budget.
            let pin = st.cache.counters().pinned_layers < self.window;
            match assembled {
                Ok(t) => match st.cache.insert(job.index, t, pin) {
                    Ok(()) => st.counters.completed += 1,
                    // Under a shared ledger a failed insert means a peer
                    // transiently claimed the headroom between reclaim
                    // and insert. Prefetch is advisory: drop the decoded
                    // layer — the consumer will fault it in with its own
                    // (entry-stamped, therefore always-winning) reclaim.
                    Err(_) if self.ledger.is_some() => {}
                    // With a private budget an insert can only fail when
                    // the pins-block-eviction invariant broke: record it
                    // so the next consumer access surfaces the bug
                    // instead of silently re-decoding every layer.
                    Err(e) => {
                        if st.error.is_none() {
                            st.error = Some(e);
                        }
                    }
                },
                Err(e) => {
                    if st.error.is_none() {
                        st.error = Some(e);
                    }
                }
            }
        }
        drop(st);
        self.done.notify_all();
    }

    /// Consume layer `index`: serve it from residency (a prefetched
    /// layer is unpinned here — consumption is what releases it), wait
    /// for an in-flight decode to publish, or fault it in synchronously
    /// on the calling thread. `f` runs with the state lock held, so the
    /// borrow never escapes; keep it to a digest fold or a copy-out.
    pub fn with_layer<R>(&self, index: usize, f: impl FnOnce(&QuantizedTensor) -> R) -> Result<R> {
        let mut st = self.lock_state();
        if index >= st.inflight.len() {
            return Err(Error::InvalidArg(format!(
                "layer index {index} out of range ({} layers)",
                st.inflight.len()
            )));
        }
        // Shared-ledger pools: stamp this model hot *now*, so a fault a
        // few lines down can reclaim from genuinely idle peers.
        st.cache.touch_shared();
        // Did this access pay for a decode (either by waiting on a
        // worker or by decoding here)? Determines hit/miss accounting.
        let mut faulted = false;
        loop {
            if let Some(e) = st.error.take() {
                return Err(e);
            }
            if st.cancelled {
                return Err(Error::Engine("decode-ahead prefetcher is shut down".into()));
            }
            if st.cache.is_resident(index) {
                let was_pinned = st.cache.is_pinned(index);
                if was_pinned {
                    st.cache.unpin(index);
                    st.counters.hits += 1;
                }
                st.cache.note_access(!faulted);
                // A genuinely warm re-access promotes out of probation;
                // a first touch (sync fault, wait, or fresh prefetch)
                // keeps the `get` path's first-touch semantics.
                let q = if !faulted && !was_pinned {
                    st.cache.lookup(index)
                } else {
                    st.cache.peek_serve(index)
                };
                if let Some(q) = q {
                    return Ok(f(q));
                }
                // Unreachable (resident above), but looping is safe and
                // panicking under the lock is not.
                continue;
            }
            if st.inflight[index].iter().any(|&b| b) {
                // A worker is mid-decode on a tile of exactly this
                // layer: wait for its publish instead of decoding the
                // stream twice. One logical wait per access — `done` is
                // notified by every publish, so re-wakes must not
                // re-count.
                if !faulted {
                    st.counters.waits += 1;
                }
                faulted = true;
                st = self.done.wait(st).unwrap_or_else(PoisonError::into_inner);
                continue;
            }
            // Synchronous fault: claim every tile of the layer ourselves
            // so no worker duplicates the decode (queued tile entries
            // turn redundant at their claim), discard any partial
            // worker-side assembly — the whole-layer decode below
            // re-covers those tiles — release the lock for the decode,
            // then re-enter the loop to serve it.
            for m in st.inflight[index].iter_mut() {
                *m = true;
            }
            st.partial[index] = None;
            st.counters.sync_faults += 1;
            faulted = true;
            drop(st);
            let mut stats = ThreadStats::default();
            let result = self.decoder.decode_layer_stats(index, &mut stats);
            if result.is_ok() {
                // Shared-ledger pools: steal headroom from colder peers
                // while no state lock is held (same ordering rule as
                // the publish path).
                self.reclaim_from_peers(self.decoder.source().meta(index).n_symbols);
            }
            st = self.lock_state();
            for m in st.inflight[index].iter_mut() {
                *m = false;
            }
            // The in-flight mark is cleared either way: wake any waiter
            // before acting on the result.
            self.done.notify_all();
            match result {
                Ok(t) => {
                    st.cache.note_access(false);
                    let out = f(&t);
                    match st.cache.insert(index, t, false) {
                        Ok(()) => {}
                        // Shared-budget contention in the worst instant
                        // (a peer claimed the headroom between our
                        // reclaim and this insert): serve uncached
                        // rather than failing the request.
                        Err(_) if self.ledger.is_some() => {}
                        // Private budget: an insert failure is a broken
                        // pin invariant — surface it.
                        Err(e) => return Err(e),
                    }
                    return Ok(out);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Cancel the engine: stop all workers and unblock any waiter.
    pub fn cancel(&self) {
        let mut st = self.lock_state();
        st.cancelled = true;
        st.queue.clear();
        drop(st);
        self.work.notify_all();
        self.done.notify_all();
        if let Some(signal) = self.pool_signal.get() {
            signal.bump();
        }
    }
}

/// Wakeup channel between [`PrefetchShared::schedule`] and a shared
/// [`PrefetchPool`]'s workers: a ticket counter under a mutex. Workers
/// snapshot the ticket, scan every engine's queue, and only park when
/// the ticket has not moved since the snapshot — so a schedule racing
/// the scan can never be slept through.
pub(crate) struct PoolSignal {
    tickets: Mutex<u64>,
    cond: Condvar,
}

impl PoolSignal {
    fn new() -> Self {
        PoolSignal {
            tickets: Mutex::new(0),
            cond: Condvar::new(),
        }
    }

    fn current(&self) -> u64 {
        *self.tickets.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub(crate) fn bump(&self) {
        let mut t = self.tickets.lock().unwrap_or_else(PoisonError::into_inner);
        *t += 1;
        drop(t);
        self.cond.notify_all();
    }

    /// Park until the ticket moves past `seen` (bounded wait: re-checks
    /// every 50 ms so a missed notify can only cost one tick of
    /// latency, never a hang).
    fn wait_past(&self, seen: u64) {
        let mut t = self.tickets.lock().unwrap_or_else(PoisonError::into_inner);
        while *t == seen {
            let (guard, timeout) = self
                .cond
                .wait_timeout(t, Duration::from_millis(50))
                .unwrap_or_else(PoisonError::into_inner);
            t = guard;
            if timeout.timed_out() {
                break;
            }
        }
    }
}

/// **Shared decode worker pool** over several prefetch engines (one per
/// model): `workers` threads round-robin claim → decode → publish
/// across every engine's queue, so all models in a multi-model server
/// draw on one pool of decode threads instead of spawning a private
/// pool each — the worker count bounds true decode parallelism (and
/// decoded-but-unpublished overshoot) for the whole process.
///
/// Construct the member engines with `workers: 0` in their
/// [`PrefetchConfig`] so no private pool races this one for jobs.
/// Dropping the pool stops and joins every worker.
pub struct PrefetchPool {
    signal: Arc<PoolSignal>,
    stop: Arc<AtomicBool>,
    handles: Vec<std::thread::JoinHandle<ThreadStats>>,
}

impl PrefetchPool {
    /// Pool of `workers` decode threads over `shares` (at least one
    /// worker is always spawned).
    pub fn new(shares: Vec<Arc<PrefetchShared>>, workers: usize) -> Self {
        let signal = Arc::new(PoolSignal::new());
        for share in &shares {
            share.attach_pool_signal(Arc::clone(&signal));
        }
        let stop = Arc::new(AtomicBool::new(false));
        let handles = (0..workers.max(1))
            .map(|_| {
                let shares = shares.clone();
                let signal = Arc::clone(&signal);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || pool_worker(&shares, &signal, &stop))
            })
            .collect();
        PrefetchPool {
            signal,
            stop,
            handles,
        }
    }

    /// Worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for PrefetchPool {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.signal.bump();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn pool_worker(
    shares: &[Arc<PrefetchShared>],
    signal: &PoolSignal,
    stop: &AtomicBool,
) -> ThreadStats {
    let mut stats = ThreadStats::default();
    while !stop.load(Ordering::Relaxed) {
        let seen = signal.current();
        let mut did_work = false;
        for share in shares {
            while let Some(job) = share.try_claim() {
                did_work = true;
                let result = share.decode_job(&job, &mut stats);
                share.publish(job, result);
            }
        }
        if !did_work {
            signal.wait_past(seen);
        }
    }
    stats
}

fn worker(shared: &PrefetchShared) -> ThreadStats {
    let mut stats = ThreadStats::default();
    while let Some(job) = shared.claim_blocking() {
        let result = shared.decode_job(&job, &mut stats);
        shared.publish(job, result);
    }
    stats
}

/// Manual, deterministic driver for prefetch work: claims and executes
/// queued jobs step by step **on the calling thread**, so tests control
/// the exact interleaving of "worker" progress against consumer
/// accesses without real threads or sleeps. Pair it with
/// [`PrefetchConfig`] `workers: 0` so no background pool races for
/// jobs.
pub struct TestScheduler {
    shared: Arc<PrefetchShared>,
    stats: ThreadStats,
}

impl TestScheduler {
    /// Scheduler over a prefetch engine's shared core.
    pub fn new(shared: Arc<PrefetchShared>) -> Self {
        TestScheduler {
            shared,
            stats: ThreadStats::default(),
        }
    }

    /// Claim the next queued tile job, marking its tile in-flight — the
    /// "worker picked it up" step, without decoding anything yet.
    pub fn claim(&mut self) -> Option<Job> {
        self.shared.try_claim()
    }

    /// Decode a claimed tile job on this thread (the "worker is
    /// mid-decode" state lives between this call and
    /// [`TestScheduler::publish`]).
    pub fn decode(&mut self, job: &Job) -> Result<Vec<u8>> {
        self.shared.decode_job(job, &mut self.stats)
    }

    /// Publish a tile decode result, completing the job (and, when it
    /// was the layer's last missing tile, the layer).
    pub fn publish(&mut self, job: Job, result: Result<Vec<u8>>) {
        self.shared.publish(job, result);
    }

    /// Run one whole tile job to completion (claim → decode → publish).
    /// Returns the tile's layer index, or `None` when the queue held no
    /// runnable job.
    pub fn step(&mut self) -> Option<usize> {
        let job = self.claim()?;
        let index = job.index();
        let result = self.decode(&job);
        self.publish(job, result);
        Some(index)
    }

    /// Drain the queue; returns how many jobs actually decoded.
    pub fn run_all(&mut self) -> usize {
        let mut n = 0;
        while self.step().is_some() {
            n += 1;
        }
        n
    }

    /// Decode accounting for the jobs this scheduler executed.
    pub fn stats(&self) -> &ThreadStats {
        &self.stats
    }
}

/// The weight tensors a serving engine needs, held partially resident
/// behind a **decode-ahead prefetcher**: consuming one layer schedules
/// the next `decode_ahead` layers of the walk onto the worker pool, so
/// by the time the consumer arrives they are already decoded and
/// pinned. The fp32 rest (norm tensors) stays always-resident, as in
/// [`crate::runtime::WeightSet`].
pub struct PrefetchingWeightSet {
    shared: Arc<PrefetchShared>,
    handles: Vec<std::thread::JoinHandle<ThreadStats>>,
    f32s: HashMap<String, TensorF32>,
    /// `(name, index)` in sorted-name order — the digest walk order,
    /// fixed at construction so per-token digests allocate nothing.
    digest_order: Vec<(String, usize)>,
    /// Effective decode-ahead window (clamped to `n_layers - 1`).
    window: usize,
}

impl PrefetchingWeightSet {
    /// Weight set over `source` with a decoded-byte `budget_bytes`, the
    /// always-resident fp32 rest, and a decode-ahead `cfg`. Fails up
    /// front when the budget cannot hold the window plus the active
    /// layer — a smaller budget would let pinned prefetches wedge the
    /// cache.
    pub fn new(
        source: Arc<SegmentSource>,
        budget_bytes: usize,
        f32_rest: Vec<(String, TensorF32)>,
        cfg: PrefetchConfig,
    ) -> Result<Self> {
        let window = Self::effective_window(&source, cfg.decode_ahead);
        Self::check_floor(&source, budget_bytes, window)?;
        let cache = WeightCache::with_policy(Arc::clone(&source), budget_bytes, cfg.policy)?;
        Self::assemble(source, cache, window, f32_rest, cfg)
    }

    /// Weight set drawing on a **shared** [`ResidencyLedger`] instead
    /// of a private budget — one member of a multi-model pool
    /// ([`crate::coordinator::MultiModelServer`]). The decode-ahead
    /// floor is checked against the *global* budget here (necessary);
    /// the coordinator additionally checks that the **sum** of every
    /// member's floor fits, which is what makes cross-model
    /// pin-wedging unreachable. Construct with `workers: 0` and drive
    /// the queue through a shared [`PrefetchPool`].
    pub fn with_ledger(
        source: Arc<SegmentSource>,
        ledger: Arc<ResidencyLedger>,
        f32_rest: Vec<(String, TensorF32)>,
        cfg: PrefetchConfig,
    ) -> Result<Self> {
        Self::with_ledger_qos(source, ledger, f32_rest, cfg, 0, 1.0)
    }

    /// [`PrefetchingWeightSet::with_ledger`] with per-model QoS: a
    /// minimum residency `reserve` (bytes peers can never reclaim, and
    /// committed headroom even while unfilled) and an admission
    /// `weight` (shed aggressiveness above everyone's reserve) — the
    /// knobs behind `--model name=path,reserve-mb=N,weight=W`. The
    /// coordinator validates that the *sum* of every member's reserve
    /// fits the global budget; this constructor checks only its own.
    pub fn with_ledger_qos(
        source: Arc<SegmentSource>,
        ledger: Arc<ResidencyLedger>,
        f32_rest: Vec<(String, TensorF32)>,
        cfg: PrefetchConfig,
        reserve: usize,
        weight: f64,
    ) -> Result<Self> {
        let window = Self::effective_window(&source, cfg.decode_ahead);
        Self::check_floor(&source, ledger.budget(), window)?;
        let cache =
            WeightCache::with_ledger_qos(Arc::clone(&source), ledger, cfg.policy, reserve, weight)?;
        Self::assemble(source, cache, window, f32_rest, cfg)
    }

    fn effective_window(source: &SegmentSource, decode_ahead: usize) -> usize {
        decode_ahead.min(source.n_layers().saturating_sub(1))
    }

    fn check_floor(source: &SegmentSource, budget_bytes: usize, window: usize) -> Result<()> {
        let largest = source
            .layers()
            .iter()
            .map(|m| m.n_symbols)
            .max()
            .unwrap_or(0);
        let need = largest.saturating_mul(window + 1);
        if budget_bytes < need {
            return Err(Error::InvalidArg(format!(
                "weight budget {budget_bytes} B cannot hold a decode-ahead window of \
                 {window} layers plus the active layer (needs >= {need} B at \
                 {largest} B/layer) — lower --decode-ahead or raise the budget"
            )));
        }
        Ok(())
    }

    fn assemble(
        source: Arc<SegmentSource>,
        cache: WeightCache,
        window: usize,
        f32_rest: Vec<(String, TensorF32)>,
        cfg: PrefetchConfig,
    ) -> Result<Self> {
        let by_name: HashMap<&str, usize> = source
            .layers()
            .iter()
            .enumerate()
            .map(|(i, m)| (m.name.as_str(), i))
            .collect();
        // Walk the deduplicated name map, not the raw manifest, so the
        // digest sees exactly the layers an eager `WeightSet` would.
        let mut digest_order: Vec<(String, usize)> = by_name
            .into_iter()
            .map(|(n, i)| (n.to_string(), i))
            .collect();
        digest_order.sort();
        let shared = PrefetchShared::from_cache(cache, window)?;
        // Cap the pool at window × tiles-per-layer: each worker holds
        // at most one decoded-but-unpublished *tile* outside cache
        // accounting (staging buffers are bounded by the window), so
        // the cap keeps true peak memory within the same
        // `(window + 1) × largest` floor the constructor just checked —
        // while still letting every worker attack one hot layer's
        // tiles (more decode threads than the window can feed tiles to
        // is waste).
        let workers = cfg
            .workers
            .min(window.saturating_mul(source.max_tiles_per_layer()));
        let handles = (0..workers)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker(&shared))
            })
            .collect();
        Ok(PrefetchingWeightSet {
            shared,
            handles,
            f32s: f32_rest.into_iter().collect(),
            digest_order,
            window,
        })
    }

    /// The shared prefetch core (tests and benches drive it directly).
    pub fn shared(&self) -> &Arc<PrefetchShared> {
        &self.shared
    }

    /// Residency-cache counter snapshot.
    pub fn counters(&self) -> CacheCounters {
        self.shared.cache_counters()
    }

    /// Prefetch counter snapshot.
    pub fn prefetch_counters(&self) -> PrefetchCounters {
        self.shared.counters()
    }

    /// Quantized layer count.
    pub fn n_layers(&self) -> usize {
        self.digest_order.len()
    }

    /// Effective decode-ahead window.
    pub fn decode_ahead(&self) -> usize {
        self.window
    }

    /// Worker threads actually spawned (`cfg.workers` capped at the
    /// window times the largest per-layer tile count).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Always-resident fp32 tensor by name.
    pub fn f32(&self, name: &str) -> Option<&TensorF32> {
        self.f32s.get(name)
    }

    /// Schedule the `window` layers that follow walk position `pos`
    /// (wrapping — dense generation re-walks the model every token
    /// step, so prefetching past the end warms the next pass).
    fn schedule_ahead(&self, pos: usize) {
        let n = self.digest_order.len();
        if self.window == 0 || n == 0 {
            return;
        }
        let ahead: Vec<usize> = (1..=self.window)
            .map(|k| self.digest_order[(pos + k) % n].1)
            .collect();
        self.shared.schedule(&ahead);
    }

    /// Digest of the full weight set, walking layers through the
    /// prefetching cache in sorted-name order while scheduling each
    /// layer's successors onto the worker pool. Bit-identical to
    /// [`crate::coordinator::digest_weights`] of the eagerly decoded
    /// set and to [`super::ResidentWeightSet::digest`] — the
    /// losslessness oracle that pins "prefetch changes *when* layers
    /// decode, never *what* they decode to".
    pub fn digest(&self) -> Result<u64> {
        let mut h = FNV1A64_INIT;
        h = fnv1a64(h, &(self.digest_order.len() as u64).to_le_bytes());
        for (pos, (name, index)) in self.digest_order.iter().enumerate() {
            self.schedule_ahead(pos);
            h = self
                .shared
                .with_layer(*index, |q| digest_quant_entry(h, name, q))?;
        }
        let mut fnames: Vec<&String> = self.f32s.keys().collect();
        fnames.sort();
        h = fnv1a64(h, &(fnames.len() as u64).to_le_bytes());
        for name in fnames {
            h = digest_f32_entry(h, name, &self.f32s[name]);
        }
        Ok(h)
    }
}

impl Drop for PrefetchingWeightSet {
    fn drop(&mut self) {
        self.shared.cancel();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Engine backend that serves through a [`PrefetchingWeightSet`]:
/// every prefill and decode step walks the full weight set — the
/// per-layer access pattern of a real forward pass — but each consumed
/// layer schedules its successors onto the background pool, so faults
/// overlap with the token's remaining compute instead of serializing
/// in front of it.
///
/// Generation is digest-driven via the same shared mixers as
/// [`crate::coordinator::DigestBackend`] and
/// [`super::ResidentDigestBackend`], so the three backends emit
/// identical tokens iff their weight sets are bit-identical — the
/// property the decode-ahead tests and `benches/decode_ahead.rs` rely
/// on.
pub struct PrefetchingDigestBackend {
    cfg: BackendCfg,
    weights: PrefetchingWeightSet,
    /// Decode steps executed.
    pub steps: usize,
    /// Prefills executed.
    pub prefills: usize,
}

impl PrefetchingDigestBackend {
    /// Backend over a prefetching weight set.
    pub fn new(weights: PrefetchingWeightSet, batch: usize, max_seq: usize, vocab: usize) -> Self {
        PrefetchingDigestBackend {
            cfg: BackendCfg {
                batch,
                max_seq,
                prefill_len: (max_seq / 2).max(1),
                vocab,
            },
            weights,
            steps: 0,
            prefills: 0,
        }
    }

    /// Borrow the prefetching weight set.
    pub fn weights(&self) -> &PrefetchingWeightSet {
        &self.weights
    }

    fn onehot(&self, tok: u64) -> Vec<f32> {
        let mut l = vec![0.0f32; self.cfg.vocab];
        l[(tok % self.cfg.vocab as u64) as usize] = 10.0;
        l
    }
}

impl Backend for PrefetchingDigestBackend {
    fn cfg(&self) -> BackendCfg {
        self.cfg
    }

    fn prefill(&mut self, prompt: &[u32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.prefills += 1;
        // One full weight pass through the prefetching cache.
        let digest = self.weights.digest()?;
        let next = digest_prefill_next(digest, prompt, self.cfg.vocab);
        let kv = vec![next as f32; 8];
        Ok((self.onehot(next), kv.clone(), kv))
    }

    fn set_slot(&mut self, _slot: usize, _k1: &[f32], _v1: &[f32]) -> Result<()> {
        // Generation is digest-driven; there is no KV state to splice.
        Ok(())
    }

    fn decode(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Vec<f32>> {
        assert_eq!(tokens.len(), self.cfg.batch);
        assert_eq!(pos.len(), self.cfg.batch);
        self.steps += 1;
        // Each batched decode step is one more weight pass; layer `i+1`
        // decodes on the pool while layer `i`'s digest fold runs here.
        let digest = self.weights.digest()?;
        let mut out = Vec::with_capacity(self.cfg.batch * self.cfg.vocab);
        for (&t, &p) in tokens.iter().zip(pos) {
            out.extend_from_slice(
                &self.onehot(digest_decode_next(digest, t, p, self.cfg.vocab)),
            );
        }
        Ok(out)
    }

    fn residency(&self) -> Option<CacheCounters> {
        Some(self.weights.counters())
    }

    fn prefetch(&self) -> Option<PrefetchCounters> {
        Some(self.weights.prefetch_counters())
    }

    fn argmax_rows(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Option<Vec<u32>>> {
        self.steps += 1;
        // One full weight pass per verification/proposal block, with
        // decode-ahead workers racing the digest fold just like a plain
        // decode step — speculative bursts stress the shared ledger
        // with the same access pattern real decode traffic produces.
        let digest = self.weights.digest()?;
        Ok(Some(
            tokens
                .iter()
                .zip(pos)
                .map(|(&t, &p)| digest_decode_next(digest, t, p, self.cfg.vocab) as u32)
                .collect(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::super::serve::{ResidentDigestBackend, ResidentWeightSet};
    use super::*;
    use crate::coordinator::{digest_weights, DigestBackend, Engine, EngineConfig, Request};
    use crate::pipeline::synthetic_layers;
    use crate::quant::BitWidth;
    use crate::rng::Rng;
    use crate::runtime::WeightSet;
    use crate::store::{compress, decode_layer, ElmModel};

    fn fixture(n_layers: usize, seed: u64) -> (ElmModel, Arc<SegmentSource>) {
        let layers = synthetic_layers(n_layers, seed);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let src = Arc::new(SegmentSource::from_model(Arc::new(model.clone())));
        (model, src)
    }

    /// `n` equal-size layers (512 decoded bytes each) so budgets count
    /// whole layers exactly.
    fn equal_fixture(n: usize, seed: u64) -> (ElmModel, Arc<SegmentSource>) {
        let layers: Vec<(String, crate::tensor::TensorF32)> = (0..n)
            .map(|i| {
                let mut rng = Rng::new(seed + i as u64);
                (
                    format!("l{i}"),
                    crate::tensor::TensorF32::new(vec![512], rng.gaussian_vec(512, 0.0, 0.05))
                        .unwrap(),
                )
            })
            .collect();
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let src = Arc::new(SegmentSource::from_model(Arc::new(model.clone())));
        (model, src)
    }

    fn manual_set(
        src: Arc<SegmentSource>,
        budget: usize,
        decode_ahead: usize,
    ) -> PrefetchingWeightSet {
        PrefetchingWeightSet::new(
            src,
            budget,
            Vec::new(),
            PrefetchConfig {
                decode_ahead,
                workers: 0,
                policy: Policy::SegmentedLru,
            },
        )
        .unwrap()
    }

    /// Deterministic interleaving (a): a published-but-unconsumed
    /// (pinned) layer survives arbitrary eviction pressure, and
    /// consumption is what releases it.
    #[test]
    fn deterministic_pinned_prefetch_is_never_evicted() {
        let (model, src) = equal_fixture(6, 0x40);
        // Window 1 + active layer = 2 layers; budget holds 3.
        let ws = manual_set(src, 3 * 512, 1);
        let shared = Arc::clone(ws.shared());
        let mut ts = TestScheduler::new(Arc::clone(&shared));

        shared.schedule(&[3]);
        assert_eq!(ts.step(), Some(3), "manual step decodes the scheduled job");
        assert!(shared.is_resident(3));
        assert!(shared.is_pinned(3));

        // Hammer the cache with synchronous faults of every other
        // layer: evictions must happen, but never of the pinned layer.
        for round in 0..3 {
            for i in [0usize, 1, 2, 4, 5] {
                shared.with_layer(i, |_| ()).unwrap();
                assert!(shared.is_pinned(3), "round {round}: pinned layer lost");
            }
        }
        assert!(shared.cache_counters().evictions > 0);

        // Consuming the layer unpins it — and serves the right bytes.
        let want = decode_layer(&model, 3).unwrap();
        let got = shared.with_layer(3, |q| q.symbols.data().to_vec()).unwrap();
        assert_eq!(got, want.symbols.data());
        assert!(!shared.is_pinned(3));
        assert_eq!(shared.counters().hits, 1, "consumption is the prefetch hit");
    }

    /// Deterministic interleaving (b): a layer that is mid-decode on a
    /// "worker" (claimed, not yet published) and faulted synchronously
    /// by the consumer is decoded exactly once — the consumer waits for
    /// the publish instead of decoding the segment again.
    #[test]
    fn deterministic_mid_decode_fault_decodes_exactly_once() {
        let (model, src) = equal_fixture(4, 0x41);
        let ws = manual_set(src, 2 * 512, 1);
        let shared = Arc::clone(ws.shared());
        let mut ts = TestScheduler::new(Arc::clone(&shared));

        shared.schedule(&[2]);
        let job = ts.claim().expect("scheduled job is claimable");
        assert_eq!(job.index(), 2);
        let result = ts.decode(&job);
        // The job is now "mid-decode": in-flight, nothing published.
        assert!(!shared.is_resident(2));

        let want = decode_layer(&model, 2).unwrap();
        std::thread::scope(|s| {
            let consumer =
                s.spawn(|| shared.with_layer(2, |q| q.symbols.data().to_vec()).unwrap());
            // Whether the consumer reaches the wait before or after this
            // publish, the outcome is the same: one decode, right bytes.
            ts.publish(job, result);
            assert_eq!(consumer.join().unwrap(), want.symbols.data());
        });

        let p = shared.counters();
        assert_eq!(p.completed, 1, "exactly one decode published");
        assert_eq!(p.sync_faults, 0, "the consumer never decoded it again");
        assert_eq!(ts.stats().segments, 1, "one segment decoded in total");
        assert_eq!(p.hits, 1, "served as a prefetch hit");
    }

    /// Deterministic interleaving (c): dropping the engine mid-flight
    /// cancels the pool, joins every worker, and leaves the shared lock
    /// unpoisoned; later consumer calls fail cleanly instead of
    /// hanging.
    #[test]
    fn deterministic_cancellation_on_engine_drop_leaves_no_poisoned_lock() {
        let (_, src) = fixture(10, 0x42);
        let total: usize = src.layers().iter().map(|m| m.n_symbols).sum();
        let largest = src.layers().iter().map(|m| m.n_symbols).max().unwrap();
        let ws = PrefetchingWeightSet::new(
            src,
            // Skewed synthetic sizes: keep the budget above the
            // decode-ahead floor whatever the largest layer is.
            total.max(4 * largest),
            Vec::new(),
            PrefetchConfig {
                decode_ahead: 3,
                workers: 2,
                policy: Policy::SegmentedLru,
            },
        )
        .unwrap();
        let shared = Arc::clone(ws.shared());
        let mut engine = Engine::new(
            PrefetchingDigestBackend::new(ws, 2, 32, 64),
            EngineConfig::default(),
        );
        engine.submit(Request::greedy(1, vec![5, 6], 3)).unwrap();
        // One step leaves prefetch jobs scheduled and workers active.
        engine.step().unwrap();
        drop(engine);

        assert!(!shared.poisoned(), "drop must not poison the shared lock");
        let err = shared.with_layer(0, |_| ()).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
        // Scheduling after cancellation is a no-op, not a hang.
        shared.schedule(&[1, 2]);
        assert!(shared.try_claim().is_none());
    }

    #[test]
    fn scheduling_skips_resident_and_inflight_layers() {
        let (_, src) = equal_fixture(5, 0x43);
        let ws = manual_set(src, 3 * 512, 2);
        let shared = Arc::clone(ws.shared());
        let mut ts = TestScheduler::new(Arc::clone(&shared));

        shared.schedule(&[1, 1, 99]); // duplicate + out of range
        assert_eq!(shared.counters().scheduled, 1);
        assert_eq!(ts.step(), Some(1));

        shared.schedule(&[1]); // already resident: not enqueued
        assert_eq!(shared.counters().scheduled, 1);

        shared.schedule(&[2]);
        let job = ts.claim().unwrap(); // 2 is now in flight
        shared.schedule(&[2]); // in flight: not enqueued
        assert_eq!(shared.counters().scheduled, 2);
        let r = ts.decode(&job);
        ts.publish(job, r);

        // A queued layer that becomes resident before its claim is
        // skipped as redundant.
        shared.with_layer(3, |_| ()).unwrap();
        // 3 resident; enqueue 4 then fault 4 synchronously.
        shared.schedule(&[4]);
        shared.with_layer(4, |_| ()).unwrap();
        assert!(ts.step().is_none(), "stale queue entry must not re-decode");
        assert!(shared.counters().redundant >= 1);
    }

    #[test]
    fn digest_equals_eager_and_resident_under_tight_budget() {
        // Equal-size layers so "budget = 6 of 12 layers" is exact: the
        // walk must evict, and the decode-ahead floor (window 3 + 1
        // layers) still fits.
        let (model, src) = equal_fixture(12, 0x44);
        let eager = WeightSet::from_elm(&model, 2, Vec::new()).unwrap();
        let want = digest_weights(&eager);
        let budget = 6 * 512;

        let mut resident = ResidentWeightSet::new(Arc::clone(&src), budget, Vec::new()).unwrap();
        assert_eq!(resident.digest().unwrap(), want);

        for workers in [0usize, 2] {
            let ws = PrefetchingWeightSet::new(
                Arc::clone(&src),
                budget,
                Vec::new(),
                PrefetchConfig {
                    decode_ahead: 3,
                    workers,
                    policy: Policy::SegmentedLru,
                },
            )
            .unwrap();
            assert_eq!(ws.digest().unwrap(), want, "workers={workers}");
            // Re-digesting (cache warm, queue churned) must be stable.
            assert_eq!(ws.digest().unwrap(), want, "workers={workers} re-digest");
            let c = ws.counters();
            assert!(c.peak_resident_bytes <= budget);
        }
    }

    /// The property satellite: for random (budget, decode-ahead window,
    /// request pattern) triples, the prefetching backend's generation
    /// is bit-identical to the eager digest backend and to the PR 2
    /// fault-on-demand resident backend.
    #[test]
    fn property_prefetching_generation_is_bit_identical_to_eager_and_resident() {
        let mut rng = Rng::new(0xAEAD);
        for case in 0..5 {
            let n_layers = 3 + rng.below(8);
            let (model, src) = fixture(n_layers, 0xB000 + case);
            let eager = WeightSet::from_elm(&model, 2, Vec::new()).unwrap();
            let largest = model.layers.iter().map(|m| m.n_symbols).max().unwrap();
            let total: usize = model.layers.iter().map(|m| m.n_symbols).sum();

            let decode_ahead = 1 + rng.below(3);
            let floor = largest * (decode_ahead.min(n_layers - 1) + 1);
            let budget = floor + rng.below(total.saturating_sub(floor) + 1);
            let workers = rng.below(3);

            // Random request pattern, shared across the three backends.
            let reqs: Vec<Request> = (0..1 + rng.below(4))
                .map(|id| {
                    let prompt: Vec<u32> =
                        (0..1 + rng.below(5)).map(|_| rng.below(60) as u32).collect();
                    Request::greedy(id as u64, prompt, 1 + rng.below(6))
                })
                .collect();

            fn run<B: Backend>(mut engine: Engine<B>, reqs: &[Request]) -> Vec<(u64, Vec<u32>)> {
                for r in reqs {
                    engine.submit(r.clone()).unwrap();
                }
                let mut out: Vec<(u64, Vec<u32>)> = engine
                    .run_to_completion(1000)
                    .unwrap()
                    .into_iter()
                    .map(|r| (r.id, r.tokens))
                    .collect();
                out.sort();
                out
            }

            let golden = run(
                Engine::new(
                    DigestBackend::from_weights(&eager, 2, 32, 64),
                    EngineConfig::default(),
                ),
                &reqs,
            );
            let resident = run(
                Engine::new(
                    ResidentDigestBackend::new(
                        ResidentWeightSet::new(Arc::clone(&src), budget, Vec::new()).unwrap(),
                        2,
                        32,
                        64,
                    ),
                    EngineConfig::default(),
                ),
                &reqs,
            );
            let prefetching = run(
                Engine::new(
                    PrefetchingDigestBackend::new(
                        PrefetchingWeightSet::new(
                            Arc::clone(&src),
                            budget,
                            Vec::new(),
                            PrefetchConfig {
                                decode_ahead,
                                workers,
                                policy: Policy::SegmentedLru,
                            },
                        )
                        .unwrap(),
                        2,
                        32,
                        64,
                    ),
                    EngineConfig::default(),
                ),
                &reqs,
            );
            assert_eq!(golden, resident, "case {case}: resident diverged");
            assert_eq!(
                golden, prefetching,
                "case {case}: decode-ahead (window {decode_ahead}, {workers} workers, \
                 budget {budget}) changed the tokens"
            );
        }
    }

    #[test]
    fn manual_pool_prefetch_converts_misses_into_hits_across_passes() {
        let (_, src) = equal_fixture(8, 0x45);
        // Budget below the model so the walk evicts.
        let ws = manual_set(src, 5 * 512, 2);
        let shared = Arc::clone(ws.shared());
        let mut ts = TestScheduler::new(Arc::clone(&shared));

        // Pass 1: nobody runs the queue, so every access sync-faults.
        let first = ws.digest().unwrap();
        let after_pass1 = shared.counters();
        assert_eq!(after_pass1.completed, 0);
        assert_eq!(after_pass1.sync_faults, 8);
        assert!(after_pass1.scheduled > 0, "walk must schedule ahead");

        // Drain the queue manually (the "workers finally ran" moment),
        // then re-walk: prefetched layers serve as pinned hits.
        ts.run_all();
        let second = ws.digest().unwrap();
        assert_eq!(first, second, "prefetch must not change the digest");
        let after_pass2 = shared.counters();
        assert!(
            after_pass2.hits > 0,
            "published layers must serve as prefetch hits: {after_pass2:?}"
        );
        assert!(shared.cache_counters().peak_resident_bytes <= 5 * 512);
    }

    #[test]
    fn window_too_large_for_budget_is_rejected_up_front() {
        let (_, src) = equal_fixture(6, 0x46);
        let err = PrefetchingWeightSet::new(
            src,
            2 * 512,
            Vec::new(),
            PrefetchConfig {
                decode_ahead: 4,
                workers: 0,
                policy: Policy::SegmentedLru,
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("decode-ahead"), "{err}");
    }

    /// The shared-ledger satellite of multi-model serving: a model
    /// actively faulting (hot) reclaims global budget from a peer that
    /// went quiet (cold), and stealing never changes what either model
    /// decodes to.
    #[test]
    fn hot_model_steals_residency_from_cold_peer_via_shared_ledger() {
        let (model_a, src_a) = equal_fixture(4, 0x60);
        let (model_b, src_b) = equal_fixture(4, 0x61);
        // Each model decodes to 4 × 512 B; the shared pool holds 5
        // layers total, so both cannot be fully resident at once.
        let budget = 5 * 512;
        let ledger = ResidencyLedger::new(budget);
        let cfg = PrefetchConfig {
            decode_ahead: 1,
            workers: 0,
            policy: Policy::SegmentedLru,
        };
        let ws_a = PrefetchingWeightSet::with_ledger(src_a, Arc::clone(&ledger), Vec::new(), cfg)
            .unwrap();
        let ws_b = PrefetchingWeightSet::with_ledger(src_b, Arc::clone(&ledger), Vec::new(), cfg)
            .unwrap();
        let a = Arc::clone(ws_a.shared());
        let b = Arc::clone(ws_b.shared());
        assert_eq!(a.ledger_slot(), Some(0));
        assert_eq!(b.ledger_slot(), Some(1));
        let peers = vec![Arc::downgrade(&a), Arc::downgrade(&b)];
        a.link_peers(peers.clone());
        b.link_peers(peers);

        // Warm B fully, then let A walk: every A fault must steal the
        // shortfall from B (the strictly colder holder) instead of
        // erroring or thrashing its own fresh layers.
        let eager_b = WeightSet::from_elm(&model_b, 2, Vec::new()).unwrap();
        assert_eq!(ws_b.digest().unwrap(), digest_weights(&eager_b));
        assert_eq!(ledger.used_by(1), 4 * 512, "B fully resident after warmup");

        let eager_a = WeightSet::from_elm(&model_a, 2, Vec::new()).unwrap();
        assert_eq!(ws_a.digest().unwrap(), digest_weights(&eager_a));
        let c = ledger.counters();
        assert!(c.used_bytes <= budget, "ledger over budget: {c:?}");
        assert!(c.peak_used_bytes <= budget, "peak over budget: {c:?}");
        assert!(
            ledger.used_by(0) > ledger.used_by(1),
            "hot model must hold more than the cold one (A {} vs B {})",
            ledger.used_by(0),
            ledger.used_by(1)
        );
        assert!(
            b.cache_counters().evictions > 0,
            "stealing must have evicted from the cold peer"
        );
        // And the cold model still serves correctly after being robbed.
        assert_eq!(ws_b.digest().unwrap(), digest_weights(&eager_b));
    }

    /// The QoS tentpole at the engine level: a latency-critical model
    /// with a full reservation keeps every reserved byte resident
    /// under sustained pressure from a batch peer, serves its re-walk
    /// entirely from residency, and neither model's bytes change.
    #[test]
    fn reserved_model_is_never_robbed_below_its_reserve() {
        let (model_lat, src_lat) = equal_fixture(4, 0x65);
        let (model_bat, src_bat) = equal_fixture(4, 0x66);
        // Latency model fully reserved (4 layers); pool holds 6, so the
        // batch model must make do with the 2 unreserved layers.
        let budget = 6 * 512;
        let reserve = 4 * 512;
        let ledger = ResidencyLedger::new(budget);
        let cfg = PrefetchConfig {
            decode_ahead: 1,
            workers: 0,
            policy: Policy::SegmentedLru,
        };
        let ws_lat = PrefetchingWeightSet::with_ledger_qos(
            src_lat,
            Arc::clone(&ledger),
            Vec::new(),
            cfg,
            reserve,
            4.0,
        )
        .unwrap();
        let ws_bat =
            PrefetchingWeightSet::with_ledger(src_bat, Arc::clone(&ledger), Vec::new(), cfg)
                .unwrap();
        let lat = Arc::clone(ws_lat.shared());
        let bat = Arc::clone(ws_bat.shared());
        let peers = vec![Arc::downgrade(&lat), Arc::downgrade(&bat)];
        lat.link_peers(peers.clone());
        bat.link_peers(peers);

        // Warm the latency model into its reserve.
        let eager_lat = WeightSet::from_elm(&model_lat, 2, Vec::new()).unwrap();
        assert_eq!(ws_lat.digest().unwrap(), digest_weights(&eager_lat));
        assert_eq!(ledger.used_by(0), reserve, "reserve filled after warmup");
        let warm_misses = lat.cache_counters().misses;

        // Sustained batch pressure: pass after pass, hot the whole
        // time — and never a byte below the latency model's reserve.
        let eager_bat = WeightSet::from_elm(&model_bat, 2, Vec::new()).unwrap();
        for pass in 0..3 {
            assert_eq!(ws_bat.digest().unwrap(), digest_weights(&eager_bat));
            assert_eq!(
                ledger.used_by(0),
                reserve,
                "pass {pass}: batch peer robbed the reserve"
            );
        }
        assert!(
            bat.cache_counters().evictions > 0,
            "the batch model must thrash in its unreserved slice"
        );
        assert_eq!(
            ledger.model_counters(0).shed_by_peers,
            0,
            "nothing was ever reclaimed from the reserved model"
        );

        // The latency model re-serves entirely from residency: zero
        // new misses, bit-identical bytes.
        assert_eq!(ws_lat.digest().unwrap(), digest_weights(&eager_lat));
        assert_eq!(
            lat.cache_counters().misses,
            warm_misses,
            "reserved re-walk must be all hits"
        );
        let c = ledger.counters();
        assert!(c.peak_used_bytes <= budget, "{c:?}");
    }

    /// A strictly higher admission weight sheds a hotter lower-weight
    /// peer on the publish path (where the requester has no recency
    /// advantage); equal weights drop the advisory prefetch instead —
    /// the PR 4 strictly-colder rule.
    #[test]
    fn higher_weight_sheds_hotter_peer_where_equal_weight_cannot() {
        for (weight, expect_shed) in [(4.0f64, true), (1.0, false)] {
            let (_, src_a) = equal_fixture(4, 0x67);
            let (_, src_b) = equal_fixture(4, 0x68);
            // Budget holds exactly one model; B warms it full.
            let ledger = ResidencyLedger::new(4 * 512);
            let cfg = PrefetchConfig {
                decode_ahead: 1,
                workers: 0,
                policy: Policy::SegmentedLru,
            };
            let ws_a = PrefetchingWeightSet::with_ledger_qos(
                src_a,
                Arc::clone(&ledger),
                Vec::new(),
                cfg,
                0,
                weight,
            )
            .unwrap();
            let ws_b =
                PrefetchingWeightSet::with_ledger(src_b, Arc::clone(&ledger), Vec::new(), cfg)
                    .unwrap();
            let a = Arc::clone(ws_a.shared());
            let b = Arc::clone(ws_b.shared());
            let peers = vec![Arc::downgrade(&a), Arc::downgrade(&b)];
            a.link_peers(peers.clone());
            b.link_peers(peers);
            ws_b.digest().unwrap(); // B resident and hot
            assert_eq!(ledger.used_by(1), 4 * 512);

            // A worker decode for model A publishes while B is the
            // hotter model (A has never been touched).
            let mut ts = TestScheduler::new(Arc::clone(&a));
            a.schedule(&[1]);
            let job = ts.claim().unwrap();
            let result = ts.decode(&job);
            b.with_layer(0, |_| ()).unwrap(); // B re-stamps hottest
            ts.publish(job, result);

            if expect_shed {
                assert!(a.is_resident(1), "weight {weight} must win residency");
                assert_eq!(ledger.model_counters(0).shed_from_peers, 512);
                assert_eq!(ledger.model_counters(1).shed_by_peers, 512);
                assert_eq!(ledger.used_by(1), 3 * 512);
            } else {
                assert!(
                    !a.is_resident(1),
                    "equal weight against a hotter peer: advisory prefetch drops"
                );
                assert_eq!(ledger.model_counters(0).shed_from_peers, 0);
                assert_eq!(ledger.used_by(1), 4 * 512, "peer untouched");
            }
            let c = ledger.counters();
            assert!(c.used_bytes <= c.budget_bytes, "{c:?}");
        }
    }

    /// One [`PrefetchPool`] drains the queues of several engines —
    /// the shared-worker-pool shape of multi-model serving.
    #[test]
    fn shared_pool_drains_queues_of_multiple_engines() {
        let (_, src_a) = equal_fixture(6, 0x62);
        let (_, src_b) = equal_fixture(6, 0x63);
        let ws_a = manual_set(src_a, 4 * 512, 2);
        let ws_b = manual_set(src_b, 4 * 512, 2);
        let a = Arc::clone(ws_a.shared());
        let b = Arc::clone(ws_b.shared());
        let pool = PrefetchPool::new(vec![Arc::clone(&a), Arc::clone(&b)], 2);
        assert_eq!(pool.workers(), 2);

        a.schedule(&[0, 1, 2]);
        b.schedule(&[3]);
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while (a.counters().completed < 3 || b.counters().completed < 1)
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(a.counters().completed, 3, "pool must drain A's queue");
        assert_eq!(b.counters().completed, 1, "pool must drain B's queue");
        assert!(a.is_resident(0) && a.is_resident(1) && a.is_resident(2));
        assert!(b.is_resident(3));
        drop(pool); // must stop and join cleanly
        // Engines still serve after the pool is gone (sync faults).
        ws_a.shared().with_layer(4, |_| ()).unwrap();
    }

    /// The lock-poisoning satellite: a consumer closure that panics
    /// while holding the shared state lock must not cascade into a
    /// server-wide panic — the next access recovers and serves.
    #[test]
    fn poisoned_state_lock_is_recovered_not_cascaded() {
        let (model, src) = equal_fixture(4, 0x64);
        let ws = manual_set(src, 3 * 512, 1);
        let shared = Arc::clone(ws.shared());

        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = shared.with_layer(0, |_| -> () { panic!("consumer bug") });
        }));
        assert!(result.is_err(), "the panic must surface on its own thread");
        assert!(shared.poisoned(), "the state lock was genuinely poisoned");

        // ...and yet serving continues: accesses recover the lock.
        let want = decode_layer(&model, 0).unwrap();
        let got = shared.with_layer(0, |q| q.symbols.data().to_vec()).unwrap();
        assert_eq!(got, want.symbols.data());
        shared.schedule(&[1]);
        let mut ts = TestScheduler::new(Arc::clone(&shared));
        assert_eq!(ts.step(), Some(1));
        assert!(shared.is_resident(1));
    }

    #[test]
    fn f32_rest_participates_in_the_digest() {
        let (model, src) = fixture(5, 0x47);
        let mut eager = WeightSet::from_elm(&model, 2, Vec::new()).unwrap();
        let norm = TensorF32::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        eager.f32s.insert("ln.w".into(), norm.clone());
        let total: usize = model.layers.iter().map(|m| m.n_symbols).sum();
        let largest = model.layers.iter().map(|m| m.n_symbols).max().unwrap();
        let ws = PrefetchingWeightSet::new(
            src,
            total.max(3 * largest),
            vec![("ln.w".into(), norm.clone())],
            PrefetchConfig::default(),
        )
        .unwrap();
        assert_eq!(ws.digest().unwrap(), digest_weights(&eager));
        assert_eq!(ws.f32("ln.w").unwrap().data(), norm.data());
        assert!(ws.f32("missing").is_none());
    }
}
