//! **Weight-residency subsystem**: serve models whose *decoded* weights
//! exceed device RAM (the Huff-LLM / arXiv:2502.00922 direction the
//! paper's edge story leads to, and "On the Compressibility of
//! Quantized LLMs", arXiv:2403.01384, frames as decompression-on-
//! demand).
//!
//! The PR 1 streaming decoder bounded *load-time* memory; this module
//! bounds **serve-time** memory:
//!
//! * [`WeightCache`] — decoded layers under a configurable byte
//!   budget; a miss re-decodes the layer's segment through the
//!   re-entrant [`crate::decode::SegmentDecoder`] (per-segment CRC-32
//!   makes random re-entry safe), evicting victims chosen by a
//!   replacement [`Policy`] (pure LRU, or the scan-resistant segmented
//!   LRU the prefetcher layers on). Peak resident decoded bytes never
//!   exceed the budget.
//! * [`ResidentWeightSet`] — the cache plus the always-resident fp32
//!   rest: the partially-resident analogue of
//!   [`crate::runtime::WeightSet`], with a bounded-memory
//!   [`ResidentWeightSet::digest`] that reproduces the eager
//!   [`crate::coordinator::digest_weights`] bit for bit.
//! * [`ResidentDigestBackend`] — an engine backend whose every prefill
//!   and decode step walks the full weight set through the cache, so
//!   cold layers fault in *during generation* and the
//!   [`CacheCounters`] surface live in the server's `{"stats":true}`
//!   line.
//! * [`prefetch`] — the decode-ahead engine: while layer `i` is being
//!   consumed in a token step, a worker pool decodes layer `i+1` and
//!   **pins** it until consumed ([`PrefetchingWeightSet`],
//!   [`PrefetchingDigestBackend`]), hiding the fault cost the counters
//!   above make visible. Deterministically testable through the
//!   [`TestScheduler`] seam.
//! * [`ledger`] — the shared-budget substrate of **multi-model
//!   serving** ([`crate::coordinator::MultiModelServer`]): several
//!   models' caches draw on one global [`ResidencyLedger`], a hot
//!   model reclaims bytes from strictly colder peers, and one
//!   [`PrefetchPool`] drives every model's decode-ahead queue.
//!
//! Paired with a file-backed [`crate::store::SegmentSource`], total
//! resident state is `O(manifest + cache budget)` — the container's
//! payload stays on disk and the decoded working set stays under the
//! budget, which is what lets a model larger than RAM serve at all.
//!
//! ## Scan behavior (why pure LRU loses, and what replaces it)
//!
//! A dense forward pass touches every layer in the same order each
//! token. Under pure LRU, the residents always form a most-recent
//! suffix of the access sequence, so a strictly cyclic pass over a
//! model bigger than the budget re-decodes **every** layer — the
//! per-token fault cost is the *full* parallel decode, regardless of
//! how much of the model fits
//! ([`crate::device::LatencyModel::fault_in_per_token`] models this as
//! pinned residency: pass `resident_layers = 0` for pure LRU on a
//! cyclic scan). Two mechanisms recover the headroom:
//!
//! * [`Policy::SegmentedLru`] is **scan-resistant**: on a cyclic pass
//!   over `N` equal layers with budget `N-1` it keeps `N-2` layers hot
//!   per pass where LRU keeps zero;
//! * the [`prefetch`] engine **hides** whatever still faults by
//!   decoding layer `i+1` on a worker pool during layer `i`'s compute
//!   and pinning it until consumed
//!   ([`crate::device::LatencyModel::overlapped_token_gen`]:
//!   `max(compute, decode)` per token instead of their sum).
//!
//! ## Example
//!
//! ```
//! use std::sync::Arc;
//! use entrollm::quant::BitWidth;
//! use entrollm::residency::ResidentWeightSet;
//! use entrollm::store::{compress, SegmentSource};
//! use entrollm::tensor::TensorF32;
//!
//! // Three equal-size layers; a budget of exactly one decoded layer
//! // (256 symbol bytes) — the legal minimum.
//! let layers: Vec<(String, TensorF32)> = (0..3)
//!     .map(|i| {
//!         let data = (0..256).map(|j| (j as f32 - 128.0) * 1e-3).collect();
//!         (format!("l{i}"), TensorF32::new(vec![256], data).unwrap())
//!     })
//!     .collect();
//! let (elm, _) = compress(&layers, BitWidth::U4)?;
//! let source = Arc::new(SegmentSource::from_model(Arc::new(elm)));
//! let mut ws = ResidentWeightSet::new(source, 256, Vec::new())?;
//! ws.layer(0)?; // cold: faults the segment in
//! ws.layer(0)?; // warm: served from residency
//! ws.layer(1)?; // evicts layer 0 to stay under budget
//! let c = ws.counters();
//! assert_eq!((c.hits, c.misses, c.evictions), (1, 2, 1));
//! assert!(c.peak_resident_bytes <= 256);
//! # Ok::<(), entrollm::Error>(())
//! ```

#![warn(missing_docs)]

mod cache;
pub mod ledger;
pub mod prefetch;
mod serve;

pub use cache::{CacheCounters, Policy, WeightCache};
pub use ledger::{LedgerCounters, ModelQosCounters, ResidencyLedger};
pub use prefetch::{
    Job, PrefetchConfig, PrefetchCounters, PrefetchPool, PrefetchShared,
    PrefetchingDigestBackend, PrefetchingWeightSet, TestScheduler,
};
pub use serve::{ResidentDigestBackend, ResidentWeightSet};
