//! **Shared residency byte ledger**: one global decoded-byte budget
//! drawn on by several per-model [`super::WeightCache`]s — the
//! accounting substrate of multi-model serving
//! ([`crate::coordinator::MultiModelServer`]).
//!
//! Each cache keeps its own entries, policy, and counters; what they
//! share is the *byte budget*. Every insert charges the ledger, every
//! eviction releases it, and every access stamps the owning model's
//! recency clock — so when the pool is full, a faulting model can ask
//! "which models are colder than me?" and reclaim bytes from them
//! ([`super::PrefetchShared`]'s peer-shed path). That is what lets a
//! hot model steal residency from a cold one instead of thrashing
//! inside a fixed static partition.
//!
//! Locking: the ledger mutex is a **leaf** lock. Cache/prefetch code
//! calls into the ledger while holding a per-model state lock, so the
//! ledger must never call back into any cache — and it cannot: it only
//! does arithmetic. Poisoning is recovered, not propagated: every
//! critical section leaves the counters consistent, so a panicked
//! peer thread must not take the whole serving pool down with it.

use std::sync::{Arc, Mutex, PoisonError};

/// Snapshot of a [`ResidencyLedger`]'s global accounting — surfaced as
/// the `ledger_*` fields of the multi-model server's `{"stats":true}`
/// admin line.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct LedgerCounters {
    /// Configured global byte budget.
    pub budget_bytes: usize,
    /// Decoded bytes currently charged across all models.
    pub used_bytes: usize,
    /// High-water mark of `used_bytes`.
    pub peak_used_bytes: usize,
    /// Registered models.
    pub models: usize,
}

struct ModelUsage {
    /// Decoded bytes this model currently has charged.
    used: usize,
    /// Ledger clock value of this model's most recent access.
    last_access: u64,
}

struct Inner {
    budget: usize,
    used: usize,
    peak: usize,
    /// Logical clock; bumped on every touch.
    clock: u64,
    models: Vec<ModelUsage>,
}

/// One global decoded-byte budget shared by several weight caches.
///
/// See the [module docs](self) for the role it plays and the locking
/// discipline. Constructed once per serving pool
/// ([`ResidencyLedger::new`]), then handed to each cache via
/// [`super::WeightCache::with_ledger`].
pub struct ResidencyLedger {
    inner: Mutex<Inner>,
}

impl ResidencyLedger {
    /// Ledger with a global `budget_bytes` decoded-byte budget.
    pub fn new(budget_bytes: usize) -> Arc<Self> {
        Arc::new(ResidencyLedger {
            inner: Mutex::new(Inner {
                budget: budget_bytes,
                used: 0,
                peak: 0,
                clock: 0,
                models: Vec::new(),
            }),
        })
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// The configured global byte budget.
    pub fn budget(&self) -> usize {
        self.lock().budget
    }

    /// Register one model; returns its ledger slot.
    pub fn register(&self) -> usize {
        let mut st = self.lock();
        st.models.push(ModelUsage {
            used: 0,
            last_access: 0,
        });
        st.models.len() - 1
    }

    /// Atomically charge `bytes` to `slot` **iff** they fit the global
    /// budget; returns whether the charge was made. Check-and-charge is
    /// one critical section, so concurrent inserts from different
    /// models can never both pass a room check and overshoot together.
    pub(crate) fn try_charge(&self, slot: usize, bytes: usize) -> bool {
        let mut st = self.lock();
        if st.used + bytes > st.budget {
            return false;
        }
        st.used += bytes;
        st.peak = st.peak.max(st.used);
        st.models[slot].used += bytes;
        true
    }

    /// Release `bytes` from `slot` (an eviction from that model's cache).
    pub(crate) fn release(&self, slot: usize, bytes: usize) {
        let mut st = self.lock();
        st.used = st.used.saturating_sub(bytes);
        st.models[slot].used = st.models[slot].used.saturating_sub(bytes);
    }

    /// Stamp `slot` as just-accessed (recency for peer-shed victim
    /// selection).
    pub(crate) fn touch(&self, slot: usize) {
        let mut st = self.lock();
        st.clock += 1;
        let clock = st.clock;
        st.models[slot].last_access = clock;
    }

    /// Would charging `extra` more bytes exceed the global budget?
    pub fn needs_room(&self, extra: usize) -> bool {
        let st = self.lock();
        st.used + extra > st.budget
    }

    /// How many bytes over budget a charge of `extra` would land (0
    /// when it fits).
    pub(crate) fn shortfall(&self, extra: usize) -> usize {
        let st = self.lock();
        (st.used + extra).saturating_sub(st.budget)
    }

    /// Slots of models **colder** than `slot` (strictly older
    /// last-access) that currently hold bytes, coldest first — the
    /// peer-shed victim order. Never returns `slot` itself, and never
    /// returns a hotter-or-equal peer, so two equally hot models evict
    /// their own entries instead of ping-ponging each other's.
    pub(crate) fn colder_peers(&self, slot: usize) -> Vec<usize> {
        let st = self.lock();
        let mine = st.models[slot].last_access;
        let mut peers: Vec<(u64, usize)> = st
            .models
            .iter()
            .enumerate()
            .filter(|&(i, m)| i != slot && m.used > 0 && m.last_access < mine)
            .map(|(i, m)| (m.last_access, i))
            .collect();
        peers.sort_unstable();
        peers.into_iter().map(|(_, i)| i).collect()
    }

    /// Decoded bytes currently charged to `slot`.
    pub fn used_by(&self, slot: usize) -> usize {
        self.lock().models[slot].used
    }

    /// Global counter snapshot.
    pub fn counters(&self) -> LedgerCounters {
        let st = self.lock();
        LedgerCounters {
            budget_bytes: st.budget,
            used_bytes: st.used,
            peak_used_bytes: st.peak,
            models: st.models.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_release_track_global_and_per_model_usage() {
        let ledger = ResidencyLedger::new(1000);
        let a = ledger.register();
        let b = ledger.register();
        assert_eq!((a, b), (0, 1));
        assert!(ledger.try_charge(a, 400));
        assert!(ledger.try_charge(b, 500));
        assert_eq!(ledger.used_by(a), 400);
        assert_eq!(ledger.used_by(b), 500);
        let c = ledger.counters();
        assert_eq!(c.used_bytes, 900);
        assert_eq!(c.peak_used_bytes, 900);
        assert_eq!(c.models, 2);
        assert!(!ledger.needs_room(100));
        assert!(ledger.needs_room(101));
        assert_eq!(ledger.shortfall(301), 201);
        // A charge that would overshoot is refused atomically.
        assert!(!ledger.try_charge(a, 101));
        assert_eq!(ledger.counters().used_bytes, 900, "refused charge is free");
        ledger.release(b, 500);
        assert_eq!(ledger.counters().used_bytes, 400);
        assert_eq!(ledger.counters().peak_used_bytes, 900, "peak sticks");
    }

    #[test]
    fn colder_peers_orders_strictly_older_holders_coldest_first() {
        let ledger = ResidencyLedger::new(1000);
        let a = ledger.register();
        let b = ledger.register();
        let c = ledger.register();
        assert!(ledger.try_charge(a, 10));
        assert!(ledger.try_charge(b, 10));
        assert!(ledger.try_charge(c, 10));
        ledger.touch(b); // coldest holder after a
        ledger.touch(c);
        ledger.touch(a); // hottest
        assert_eq!(ledger.colder_peers(a), vec![b, c]);
        // A peer at equal or newer heat is never a victim.
        assert_eq!(ledger.colder_peers(b), Vec::<usize>::new());
        assert_eq!(ledger.colder_peers(c), vec![b]);
        // Peers with no bytes are skipped.
        ledger.release(b, 10);
        assert_eq!(ledger.colder_peers(a), vec![c]);
    }

    #[test]
    fn untouched_models_are_colder_than_touched_ones() {
        let ledger = ResidencyLedger::new(100);
        let a = ledger.register();
        let b = ledger.register();
        assert!(ledger.try_charge(b, 50));
        ledger.touch(a);
        assert_eq!(ledger.colder_peers(a), vec![b]);
    }
}
