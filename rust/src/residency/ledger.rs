//! **Shared residency byte ledger**: one global decoded-byte budget
//! drawn on by several per-model [`super::WeightCache`]s — the
//! accounting substrate of multi-model serving
//! ([`crate::coordinator::MultiModelServer`]).
//!
//! Each cache keeps its own entries, policy, and counters; what they
//! share is the *byte budget*. Every insert charges the ledger, every
//! eviction releases it, and every access stamps the owning model's
//! recency clock — so when the pool is full, a faulting model can ask
//! "which models are colder than me?" and reclaim bytes from them
//! ([`super::PrefetchShared`]'s peer-shed path). That is what lets a
//! hot model steal residency from a cold one instead of thrashing
//! inside a fixed static partition.
//!
//! ## Per-model QoS
//!
//! Two knobs, both fixed at registration, bound how hard models can
//! lean on each other:
//!
//! * a **minimum residency reservation** (`reserve` bytes): headroom
//!   the model is always entitled to. Peers can never reclaim a model
//!   below its reserve, and an *unfilled* reserve counts as committed
//!   budget in every peer's admission check — so a latency-critical
//!   model that went briefly idle still faults straight back into its
//!   guaranteed bytes instead of queueing behind a batch peer's
//!   residency.
//! * an **admission weight** (`weight`): how aggressively the model
//!   may shed peers *above* everyone's reserve. Equal weights keep the
//!   PR 4 rule — only strictly-colder peers are victims, so two
//!   equally hot models never ping-pong each other's entries. A
//!   strictly higher weight additionally lets a model shed
//!   hotter-or-equal lower-weight peers (the asymmetry keeps it
//!   ping-pong-free: the lower-weight peer can never shed back unless
//!   the high-weight model is strictly colder).
//!
//! Locking: the ledger mutex is a **leaf** lock. Cache/prefetch code
//! calls into the ledger while holding a per-model state lock, so the
//! ledger must never call back into any cache — and it cannot: it only
//! does arithmetic. Poisoning is recovered, not propagated: every
//! critical section leaves the counters consistent, so a panicked
//! peer thread must not take the whole serving pool down with it —
//! and reservations, being plain fields, survive the recovery.

use std::sync::{Arc, Mutex, PoisonError};

/// Snapshot of a [`ResidencyLedger`]'s global accounting — surfaced as
/// the `ledger_*` fields of the multi-model server's `{"stats":true}`
/// admin line.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct LedgerCounters {
    /// Configured global byte budget.
    pub budget_bytes: usize,
    /// Decoded bytes currently charged across all models.
    pub used_bytes: usize,
    /// High-water mark of `used_bytes`.
    pub peak_used_bytes: usize,
    /// Registered models.
    pub models: usize,
    /// Sum of every model's minimum residency reservation.
    pub reserved_bytes: usize,
}

/// Per-model QoS snapshot — surfaced as the `reserved_bytes` /
/// `qos_weight` / `shed_from_peers` / `shed_by_peers` fields of each
/// entry in the multi-model `{"stats":true}` `models` array.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct ModelQosCounters {
    /// Configured minimum residency reservation (bytes peers can never
    /// reclaim).
    pub reserved_bytes: usize,
    /// Configured admission weight.
    pub weight: f64,
    /// Decoded bytes currently charged to this model.
    pub used_bytes: usize,
    /// Bytes this model reclaimed from peers (peer-shed path).
    pub shed_from_peers: u64,
    /// Bytes peers reclaimed from this model.
    pub shed_by_peers: u64,
}

struct ModelUsage {
    /// Decoded bytes this model currently has charged.
    used: usize,
    /// Ledger clock value of this model's most recent access.
    last_access: u64,
    /// Minimum residency reservation: peers can never reclaim this
    /// model below `reserve`, and the unfilled part counts as
    /// committed in every peer's admission check.
    reserve: usize,
    /// Admission weight (victim-selection aggressiveness).
    weight: f64,
    /// Bytes this model reclaimed from peers.
    shed_from_peers: u64,
    /// Bytes peers reclaimed from this model.
    shed_by_peers: u64,
}

struct Inner {
    budget: usize,
    used: usize,
    peak: usize,
    /// Logical clock; bumped on every touch.
    clock: u64,
    models: Vec<ModelUsage>,
}

impl Inner {
    /// Bytes every *other* model than `slot` is entitled to but has
    /// not yet used — committed headroom a charge by `slot` must leave
    /// free, so a reserved peer can always fault back into its
    /// guarantee.
    fn peer_unfilled_reserves(&self, slot: usize) -> usize {
        self.models
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != slot)
            .map(|(_, m)| m.reserve.saturating_sub(m.used))
            .fold(0usize, usize::saturating_add)
    }
}

/// One global decoded-byte budget shared by several weight caches.
///
/// See the [module docs](self) for the role it plays, the QoS knobs,
/// and the locking discipline. Constructed once per serving pool
/// ([`ResidencyLedger::new`]), then handed to each cache via
/// [`super::WeightCache::with_ledger`].
pub struct ResidencyLedger {
    inner: Mutex<Inner>,
}

impl ResidencyLedger {
    /// Ledger with a global `budget_bytes` decoded-byte budget.
    pub fn new(budget_bytes: usize) -> Arc<Self> {
        Arc::new(ResidencyLedger {
            inner: Mutex::new(Inner {
                budget: budget_bytes,
                used: 0,
                peak: 0,
                clock: 0,
                models: Vec::new(),
            }),
        })
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// The configured global byte budget.
    pub fn budget(&self) -> usize {
        self.lock().budget
    }

    /// Register one model with no reservation and the default weight;
    /// returns its ledger slot.
    pub fn register(&self) -> usize {
        self.register_with(0, 1.0)
    }

    /// Register one model with a minimum residency `reserve` (bytes)
    /// and an admission `weight`; returns its ledger slot. Non-finite
    /// or non-positive weights are clamped to the default 1.0 — config
    /// validation belongs to the coordinator
    /// ([`crate::coordinator::MultiModelServer::new`] rejects them
    /// loudly); the ledger never panics over a knob.
    pub fn register_with(&self, reserve: usize, weight: f64) -> usize {
        let weight = if weight.is_finite() && weight > 0.0 {
            weight
        } else {
            1.0
        };
        let mut st = self.lock();
        st.models.push(ModelUsage {
            used: 0,
            last_access: 0,
            reserve,
            weight,
            shed_from_peers: 0,
            shed_by_peers: 0,
        });
        st.models.len() - 1
    }

    /// Atomically charge `bytes` to `slot` **iff** they fit the global
    /// budget *minus every peer's unfilled reservation*; returns
    /// whether the charge was made. Check-and-charge is one critical
    /// section, so concurrent inserts from different models can never
    /// both pass a room check and overshoot together — and a reserved
    /// peer's guaranteed headroom can never be claimed out from under
    /// it mid-fault.
    pub(crate) fn try_charge(&self, slot: usize, bytes: usize) -> bool {
        let mut st = self.lock();
        // Saturating throughout: an absurd reserve (the coordinator
        // validates, but the ledger is pub API) must refuse charges,
        // never wrap into admitting them.
        let committed = st.used.saturating_add(st.peer_unfilled_reserves(slot));
        if committed.saturating_add(bytes) > st.budget {
            return false;
        }
        st.used += bytes;
        st.peak = st.peak.max(st.used);
        st.models[slot].used += bytes;
        true
    }

    /// Release `bytes` from `slot` (an eviction from that model's cache).
    pub(crate) fn release(&self, slot: usize, bytes: usize) {
        let mut st = self.lock();
        st.used = st.used.saturating_sub(bytes);
        st.models[slot].used = st.models[slot].used.saturating_sub(bytes);
    }

    /// Stamp `slot` as just-accessed (recency for peer-shed victim
    /// selection).
    pub(crate) fn touch(&self, slot: usize) {
        let mut st = self.lock();
        st.clock += 1;
        let clock = st.clock;
        st.models[slot].last_access = clock;
    }

    /// Would charging `extra` more bytes to `slot` exceed the global
    /// budget (counting every peer's unfilled reservation as already
    /// committed)?
    pub fn needs_room(&self, slot: usize, extra: usize) -> bool {
        let st = self.lock();
        st.used
            .saturating_add(st.peer_unfilled_reserves(slot))
            .saturating_add(extra)
            > st.budget
    }

    /// How many bytes over budget a charge of `extra` to `slot` would
    /// land, counting peers' unfilled reservations (0 when it fits).
    pub(crate) fn shortfall(&self, slot: usize, extra: usize) -> usize {
        let st = self.lock();
        st.used
            .saturating_add(st.peer_unfilled_reserves(slot))
            .saturating_add(extra)
            .saturating_sub(st.budget)
    }

    /// Peer-shed victim order for `slot`: peers holding **reclaimable**
    /// bytes (used above their own reserve) that are either *strictly
    /// colder* (older last-access — the PR 4 rule, weight ties), or —
    /// when `slot`'s admission weight is strictly higher — any
    /// lower-weight holder regardless of heat. Strictly-colder victims
    /// come first (coldest first), then the weight-outranked ones
    /// (coldest first). Never returns `slot` itself, never a peer at
    /// or below its reserve, and never a hotter-or-equal peer of equal
    /// or higher weight — so equally weighted, equally hot models
    /// evict their own entries instead of ping-ponging each other's.
    pub(crate) fn colder_peers(&self, slot: usize) -> Vec<usize> {
        let st = self.lock();
        let mine = st.models[slot].last_access;
        let my_weight = st.models[slot].weight;
        let mut colder: Vec<(u64, usize)> = Vec::new();
        let mut outranked: Vec<(u64, usize)> = Vec::new();
        for (i, m) in st.models.iter().enumerate() {
            if i == slot || m.used <= m.reserve {
                continue;
            }
            if m.last_access < mine {
                colder.push((m.last_access, i));
            } else if my_weight > m.weight {
                outranked.push((m.last_access, i));
            }
        }
        colder.sort_unstable();
        outranked.sort_unstable();
        colder
            .into_iter()
            .chain(outranked)
            .map(|(_, i)| i)
            .collect()
    }

    /// Decoded bytes currently charged to `slot`.
    pub fn used_by(&self, slot: usize) -> usize {
        self.lock().models[slot].used
    }

    /// `slot`'s configured minimum residency reservation.
    pub fn reserve_of(&self, slot: usize) -> usize {
        self.lock().models[slot].reserve
    }

    /// `slot`'s configured admission weight.
    pub fn weight_of(&self, slot: usize) -> f64 {
        self.lock().models[slot].weight
    }

    /// Atomically replace several models' reservations — the live
    /// analogue of startup's reserve configuration, driving the admin
    /// line's `{"reserve":{model:mb}}` verb. Validates inside ONE
    /// critical section that the *new* total reserve sum fits the
    /// budget, so two concurrent re-tunes can never both pass a stale
    /// check and overshoot together; on error nothing changes. Slots
    /// absent from `updates` keep their current reserve. Floor
    /// validation (decode-ahead working sets) belongs to the
    /// coordinator, which layers it on top before calling this.
    pub fn set_reserves(&self, updates: &[(usize, usize)]) -> Result<(), String> {
        let mut st = self.lock();
        for &(slot, _) in updates {
            if slot >= st.models.len() {
                return Err(format!("ledger slot {slot} out of range"));
            }
        }
        let mut new_total: usize = 0;
        for (i, m) in st.models.iter().enumerate() {
            let reserve = updates
                .iter()
                .rev()
                .find(|&&(slot, _)| slot == i)
                .map(|&(_, r)| r)
                .unwrap_or(m.reserve);
            new_total = new_total.saturating_add(reserve);
        }
        if new_total > st.budget {
            return Err(format!(
                "reservations sum to {new_total} bytes, over the {} byte budget — \
                 a set of guarantees that cannot all be honored",
                st.budget
            ));
        }
        for &(slot, reserve) in updates {
            st.models[slot].reserve = reserve;
        }
        Ok(())
    }

    /// Record a completed peer shed: `requester` reclaimed `bytes`
    /// from `victim` (QoS observability; the byte accounting itself
    /// moved through [`ResidencyLedger::release`] during the shed).
    pub(crate) fn note_shed(&self, victim: usize, requester: usize, bytes: usize) {
        let mut st = self.lock();
        st.models[victim].shed_by_peers += bytes as u64;
        st.models[requester].shed_from_peers += bytes as u64;
    }

    /// Per-model QoS counter snapshot.
    pub fn model_counters(&self, slot: usize) -> ModelQosCounters {
        let st = self.lock();
        let m = &st.models[slot];
        ModelQosCounters {
            reserved_bytes: m.reserve,
            weight: m.weight,
            used_bytes: m.used,
            shed_from_peers: m.shed_from_peers,
            shed_by_peers: m.shed_by_peers,
        }
    }

    /// Global counter snapshot.
    pub fn counters(&self) -> LedgerCounters {
        let st = self.lock();
        LedgerCounters {
            budget_bytes: st.budget,
            used_bytes: st.used,
            peak_used_bytes: st.peak,
            models: st.models.len(),
            reserved_bytes: st.models.iter().map(|m| m.reserve).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_release_track_global_and_per_model_usage() {
        let ledger = ResidencyLedger::new(1000);
        let a = ledger.register();
        let b = ledger.register();
        assert_eq!((a, b), (0, 1));
        assert!(ledger.try_charge(a, 400));
        assert!(ledger.try_charge(b, 500));
        assert_eq!(ledger.used_by(a), 400);
        assert_eq!(ledger.used_by(b), 500);
        let c = ledger.counters();
        assert_eq!(c.used_bytes, 900);
        assert_eq!(c.peak_used_bytes, 900);
        assert_eq!(c.models, 2);
        assert!(!ledger.needs_room(a, 100));
        assert!(ledger.needs_room(a, 101));
        assert_eq!(ledger.shortfall(a, 301), 201);
        // A charge that would overshoot is refused atomically.
        assert!(!ledger.try_charge(a, 101));
        assert_eq!(ledger.counters().used_bytes, 900, "refused charge is free");
        ledger.release(b, 500);
        assert_eq!(ledger.counters().used_bytes, 400);
        assert_eq!(ledger.counters().peak_used_bytes, 900, "peak sticks");
    }

    /// Boundary satellite: a charge landing *exactly* at the budget is
    /// admitted; one byte more is refused.
    #[test]
    fn try_charge_exactly_at_budget_is_admitted() {
        let ledger = ResidencyLedger::new(1000);
        let a = ledger.register();
        assert!(ledger.try_charge(a, 1000), "exact fill must be admitted");
        assert_eq!(ledger.counters().used_bytes, 1000);
        assert!(!ledger.try_charge(a, 1), "one byte over must be refused");
        assert!(!ledger.needs_room(a, 0), "exactly full is not over");
        assert!(ledger.needs_room(a, 1));
        assert_eq!(ledger.shortfall(a, 0), 0);
    }

    /// Over-release satellite: releasing more bytes than a slot has
    /// charged saturates both counters at zero instead of underflowing
    /// (a double-release in a recovering shed path must not wedge the
    /// ledger into a bogus near-usize::MAX "usage").
    #[test]
    fn release_of_more_than_charged_saturates_at_zero() {
        let ledger = ResidencyLedger::new(1000);
        let a = ledger.register();
        let b = ledger.register();
        assert!(ledger.try_charge(a, 100));
        assert!(ledger.try_charge(b, 200));
        ledger.release(a, 500); // 400 more than a ever held
        assert_eq!(ledger.used_by(a), 0);
        // The global counter saturates too (it cannot go below zero
        // even though b still holds 200 — the per-slot view stays
        // truthful and the next charge re-syncs the peak).
        assert!(ledger.counters().used_bytes <= 200);
        assert_eq!(ledger.used_by(b), 200);
        // The ledger still admits new work afterwards.
        assert!(ledger.try_charge(a, 300));
        assert_eq!(ledger.used_by(a), 300);
    }

    #[test]
    fn colder_peers_orders_strictly_older_holders_coldest_first() {
        let ledger = ResidencyLedger::new(1000);
        let a = ledger.register();
        let b = ledger.register();
        let c = ledger.register();
        assert!(ledger.try_charge(a, 10));
        assert!(ledger.try_charge(b, 10));
        assert!(ledger.try_charge(c, 10));
        ledger.touch(b); // coldest holder after a
        ledger.touch(c);
        ledger.touch(a); // hottest
        assert_eq!(ledger.colder_peers(a), vec![b, c]);
        // A peer at equal or newer heat is never a victim.
        assert_eq!(ledger.colder_peers(b), Vec::<usize>::new());
        assert_eq!(ledger.colder_peers(c), vec![b]);
        // Peers with no bytes are skipped.
        ledger.release(b, 10);
        assert_eq!(ledger.colder_peers(a), vec![c]);
    }

    #[test]
    fn untouched_models_are_colder_than_touched_ones() {
        let ledger = ResidencyLedger::new(100);
        let a = ledger.register();
        let b = ledger.register();
        assert!(ledger.try_charge(b, 50));
        ledger.touch(a);
        assert_eq!(ledger.colder_peers(a), vec![b]);
    }

    /// An unfilled reservation counts as committed in every *peer's*
    /// admission check — but not in the owner's own.
    #[test]
    fn unfilled_reserve_blocks_peers_but_not_its_owner() {
        let ledger = ResidencyLedger::new(1000);
        let latency = ledger.register_with(600, 1.0);
        let batch = ledger.register();
        // The batch model sees only 400 B of headroom even though the
        // pool is empty: the latency model's reserve is committed.
        assert!(!ledger.try_charge(batch, 401));
        assert!(ledger.needs_room(batch, 401));
        assert_eq!(ledger.shortfall(batch, 401), 1);
        assert!(ledger.try_charge(batch, 400));
        // The latency model can always fill its own reserve...
        assert!(ledger.try_charge(latency, 600));
        // ...and once filled, the commitment is spent: the ledger is
        // exactly full.
        assert_eq!(ledger.counters().used_bytes, 1000);
        assert!(!ledger.try_charge(batch, 1));
        // Releasing latency bytes re-arms the reservation: batch still
        // cannot take the freed headroom.
        ledger.release(latency, 200);
        assert!(!ledger.try_charge(batch, 1));
        assert!(ledger.try_charge(latency, 200));
        assert_eq!(ledger.counters().reserved_bytes, 600);
    }

    /// Satellite: when every peer sits at (or below) its reserve there
    /// is nothing reclaimable — `colder_peers` must return empty so
    /// the shed loop terminates immediately instead of spinning over
    /// un-sheddable victims.
    #[test]
    fn colder_peers_is_empty_when_all_peers_are_at_reserve() {
        let ledger = ResidencyLedger::new(1000);
        let a = ledger.register();
        let b = ledger.register_with(300, 1.0);
        let c = ledger.register_with(200, 1.0);
        // Both peers exactly at their reserves, both colder than a.
        assert!(ledger.try_charge(b, 300));
        assert!(ledger.try_charge(c, 150)); // below reserve
        ledger.touch(a);
        assert_eq!(
            ledger.colder_peers(a),
            Vec::<usize>::new(),
            "peers at/below reserve hold nothing reclaimable"
        );
        // One byte above the reserve and the peer is a victim again.
        assert!(ledger.try_charge(b, 1));
        assert_eq!(ledger.colder_peers(a), vec![b]);
    }

    /// A strictly higher admission weight may shed hotter lower-weight
    /// holders; equal weights keep the strictly-colder-only rule.
    #[test]
    fn higher_weight_outranks_hotter_lower_weight_peers() {
        let ledger = ResidencyLedger::new(1000);
        let latency = ledger.register_with(0, 4.0);
        let batch = ledger.register_with(0, 1.0);
        assert!(ledger.try_charge(latency, 100));
        assert!(ledger.try_charge(batch, 100));
        ledger.touch(latency);
        ledger.touch(batch); // batch is now strictly hotter
        // Weight 4 sheds the hotter weight-1 peer anyway...
        assert_eq!(ledger.colder_peers(latency), vec![batch]);
        // ...but never the other way around (batch would need latency
        // to be strictly colder, and it is).
        assert_eq!(ledger.colder_peers(batch), vec![latency]);
        ledger.touch(latency); // latency hottest again
        assert_eq!(ledger.colder_peers(batch), Vec::<usize>::new());
        // Strictly-colder victims come before weight-outranked ones.
        let idle = ledger.register_with(0, 2.0);
        assert!(ledger.try_charge(idle, 50));
        ledger.touch(batch);
        // For latency (hot, weight 4): idle (untouched, lower weight)
        // is strictly colder; batch (hotter than idle, weight 1) is
        // colder than latency too. Coldest first.
        assert_eq!(ledger.colder_peers(latency), vec![idle, batch]);
    }

    /// Bad weights are clamped at registration, never panicked over.
    #[test]
    fn non_finite_or_non_positive_weights_fall_back_to_default() {
        let ledger = ResidencyLedger::new(100);
        for w in [f64::NAN, f64::INFINITY, 0.0, -3.0] {
            let slot = ledger.register_with(0, w);
            assert_eq!(ledger.weight_of(slot), 1.0, "weight {w} must clamp");
        }
    }

    /// Live reservation re-tuning: sum-validated atomically, effective
    /// immediately, refused without side effects when over budget.
    #[test]
    fn set_reserves_validates_the_new_sum_atomically() {
        let ledger = ResidencyLedger::new(1000);
        let a = ledger.register_with(600, 1.0);
        let b = ledger.register();
        // Shifting the guarantee from a to b is fine.
        ledger.set_reserves(&[(a, 100), (b, 700)]).unwrap();
        assert_eq!(ledger.reserve_of(a), 100);
        assert_eq!(ledger.reserve_of(b), 700);
        assert_eq!(ledger.counters().reserved_bytes, 800);
        // An update that would overshoot — counting slots NOT in the
        // update at their current reserve — is refused wholesale.
        let err = ledger.set_reserves(&[(a, 400)]).unwrap_err();
        assert!(err.contains("cannot all be honored"), "{err}");
        assert_eq!(ledger.reserve_of(a), 100, "refused update changes nothing");
        assert_eq!(ledger.reserve_of(b), 700);
        // Out-of-range slots are refused before any mutation.
        assert!(ledger.set_reserves(&[(a, 0), (99, 1)]).is_err());
        assert_eq!(ledger.reserve_of(a), 100);
        // The new reserve constrains admission right away.
        assert!(!ledger.try_charge(a, 201), "b's unfilled 700 committed");
        assert!(ledger.try_charge(a, 200));
    }

    /// Shed bookkeeping: `note_shed` moves both directional counters.
    #[test]
    fn note_shed_tracks_both_directions() {
        let ledger = ResidencyLedger::new(1000);
        let a = ledger.register();
        let b = ledger.register();
        ledger.note_shed(b, a, 300);
        ledger.note_shed(b, a, 200);
        let qa = ledger.model_counters(a);
        let qb = ledger.model_counters(b);
        assert_eq!(qa.shed_from_peers, 500);
        assert_eq!(qa.shed_by_peers, 0);
        assert_eq!(qb.shed_by_peers, 500);
        assert_eq!(qb.shed_from_peers, 0);
    }

    /// Satellite: reservations (and all QoS state) survive a
    /// poisoned-lock recovery — a panicked thread holding the ledger
    /// mutex must not erase anyone's guarantee.
    #[test]
    fn reservations_survive_poisoned_lock_recovery() {
        let ledger = ResidencyLedger::new(1000);
        let latency = ledger.register_with(600, 2.0);
        let batch = ledger.register();
        assert!(ledger.try_charge(latency, 400));
        assert!(ledger.try_charge(batch, 100));

        // Poison the mutex: a thread panics while holding the guard.
        let arc = Arc::clone(&ledger);
        let t = std::thread::spawn(move || {
            let _guard = arc.inner.lock().unwrap();
            panic!("holder bug");
        });
        assert!(t.join().is_err(), "the panic must surface on its thread");
        assert!(ledger.inner.is_poisoned(), "lock genuinely poisoned");

        // Every accessor recovers, and the QoS state is intact.
        assert_eq!(ledger.reserve_of(latency), 600);
        assert_eq!(ledger.weight_of(latency), 2.0);
        assert_eq!(ledger.used_by(latency), 400);
        assert_eq!(ledger.used_by(batch), 100);
        // The reservation still constrains the batch peer: 600 - 400
        // unfilled reserve leaves 1000 - 500 - 200 = 300 of headroom.
        assert!(!ledger.try_charge(batch, 301));
        assert!(ledger.try_charge(batch, 300));
        assert_eq!(ledger.counters().reserved_bytes, 600);
    }
}
