//! Bit-level I/O: the substrate under the Huffman codec and the
//! fixed-width bit-packing baselines.
//!
//! Conventions:
//!
//! * **MSB-first** within each byte — the first bit written is the most
//!   significant bit of byte 0. This matches the canonical-Huffman LUT
//!   decoder in [`crate::huffman`], which peeks a fixed-width window of
//!   upcoming bits as an integer.
//! * Streams are **byte-aligned at segment boundaries**: every encoded
//!   tensor segment starts on a fresh byte (padding bits are zero). This
//!   is precisely what makes the paper's §III-C parallel decoding
//!   possible — segment starts are known in advance.

use crate::{Error, Result};

/// Maximum number of bits a single `write_bits`/`read_bits` call may move.
pub const MAX_BITS: u8 = 57; // keeps the 64-bit accumulator simple

/// Append-only MSB-first bit writer.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits used in the final byte (0 ⇒ byte-aligned).
    partial_bits: u8,
}

impl BitWriter {
    /// New empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// New writer with reserved capacity (in bytes).
    pub fn with_capacity(bytes: usize) -> Self {
        BitWriter {
            buf: Vec::with_capacity(bytes),
            partial_bits: 0,
        }
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> usize {
        if self.partial_bits == 0 {
            self.buf.len() * 8
        } else {
            (self.buf.len() - 1) * 8 + self.partial_bits as usize
        }
    }

    /// Write the low `len` bits of `code`, MSB of the field first.
    ///
    /// `len == 0` is a no-op. Panics if `len > MAX_BITS` or `code` has
    /// bits above `len` (that would silently corrupt the stream).
    #[inline]
    pub fn write_bits(&mut self, code: u64, len: u8) {
        debug_assert!(len <= MAX_BITS, "write_bits len {len}");
        debug_assert!(
            len == 64 || code < (1u64 << len),
            "code {code:#x} wider than {len} bits"
        );
        let mut remaining = len;
        while remaining > 0 {
            if self.partial_bits == 0 {
                self.buf.push(0);
            }
            let free = 8 - self.partial_bits;
            let take = free.min(remaining);
            // Bits of `code` we are emitting now: the `take` bits just
            // below position `remaining`.
            let chunk = ((code >> (remaining - take)) & ((1u64 << take) - 1)) as u8;
            let last = self.buf.last_mut().unwrap();
            *last |= chunk << (free - take);
            self.partial_bits = (self.partial_bits + take) % 8;
            remaining -= take;
        }
    }

    /// Write a single bit.
    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Pad with zero bits to the next byte boundary.
    pub fn align_byte(&mut self) {
        self.partial_bits = 0;
    }

    /// Finish and return the underlying bytes (zero-padded to a byte).
    pub fn into_bytes(mut self) -> Vec<u8> {
        self.align_byte();
        self.buf
    }

    /// Borrow the bytes written so far (final byte may be partial).
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }
}

/// MSB-first bit reader over a byte slice.
///
/// Maintains a 64-bit look-ahead accumulator so the Huffman LUT decoder
/// can `peek` up to 32 bits and `consume` a variable count in O(1).
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    bytes: &'a [u8],
    /// Index of the next byte to refill from.
    next_byte: usize,
    /// Accumulator: upcoming bits left-aligned (bit 63 = next bit).
    acc: u64,
    /// Number of valid bits in `acc`.
    acc_bits: u8,
    /// Total bits consumed so far.
    consumed: usize,
}

impl<'a> BitReader<'a> {
    /// Reader over the whole slice.
    pub fn new(bytes: &'a [u8]) -> Self {
        let mut r = BitReader {
            bytes,
            next_byte: 0,
            acc: 0,
            acc_bits: 0,
            consumed: 0,
        };
        r.refill();
        r
    }

    /// Total bits in the underlying slice.
    pub fn total_bits(&self) -> usize {
        self.bytes.len() * 8
    }

    /// Bits consumed so far.
    pub fn bit_pos(&self) -> usize {
        self.consumed
    }

    /// Bits remaining.
    pub fn remaining_bits(&self) -> usize {
        self.total_bits() - self.consumed
    }

    #[inline]
    fn refill(&mut self) {
        while self.acc_bits <= 56 && self.next_byte < self.bytes.len() {
            self.acc |= (self.bytes[self.next_byte] as u64) << (56 - self.acc_bits);
            self.next_byte += 1;
            self.acc_bits += 8;
        }
    }

    /// Peek the next `n` bits (MSB-first) as an integer **without**
    /// consuming. If fewer than `n` bits remain, the missing low bits
    /// read as zero (the Huffman decoder relies on this for its final
    /// symbols). `n <= 32`.
    #[inline]
    pub fn peek_bits(&self, n: u8) -> u32 {
        debug_assert!(n <= 32);
        if n == 0 {
            return 0;
        }
        (self.acc >> (64 - n as u64)) as u32
    }

    /// Consume `n` bits. Returns an error if that overruns the stream.
    #[inline]
    pub fn consume(&mut self, n: u8) -> Result<()> {
        if n as usize > self.remaining_bits() {
            return Err(Error::Format(format!(
                "bitstream overrun: consume {n} with {} left",
                self.remaining_bits()
            )));
        }
        self.acc <<= n;
        self.acc_bits -= n;
        self.consumed += n as usize;
        self.refill();
        Ok(())
    }

    /// Read `n <= 32` bits MSB-first.
    #[inline]
    pub fn read_bits(&mut self, n: u8) -> Result<u32> {
        let v = self.peek_bits(n);
        self.consume(n)?;
        Ok(v)
    }

    /// Read one bit.
    #[inline]
    pub fn read_bit(&mut self) -> Result<bool> {
        Ok(self.read_bits(1)? != 0)
    }
}

/// Pack a slice of 4-bit symbols (values `< 16`, one per byte) into
/// nibbles, high nibble first. This is the *uncompressed* uint4 layout
/// used by the no-Huffman baseline and by the PJRT weight buffers.
pub fn pack_u4(symbols: &[u8]) -> Result<Vec<u8>> {
    if let Some(&bad) = symbols.iter().find(|&&s| s >= 16) {
        return Err(Error::InvalidArg(format!("pack_u4: symbol {bad} >= 16")));
    }
    let mut out = Vec::with_capacity(symbols.len().div_ceil(2));
    for pair in symbols.chunks(2) {
        let hi = pair[0] << 4;
        let lo = if pair.len() == 2 { pair[1] } else { 0 };
        out.push(hi | lo);
    }
    Ok(out)
}

/// Inverse of [`pack_u4`]; `n` is the original symbol count (needed
/// because an odd count leaves a padding nibble).
pub fn unpack_u4(packed: &[u8], n: usize) -> Result<Vec<u8>> {
    if n.div_ceil(2) != packed.len() {
        return Err(Error::InvalidArg(format!(
            "unpack_u4: {} bytes cannot hold {n} nibbles",
            packed.len()
        )));
    }
    let mut out = Vec::with_capacity(n);
    for (i, &b) in packed.iter().enumerate() {
        out.push(b >> 4);
        if 2 * i + 1 < n {
            out.push(b & 0x0F);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn single_bits_roundtrip() {
        let bits = [true, false, true, true, false, false, true, false, true];
        let mut w = BitWriter::new();
        for &b in &bits {
            w.write_bit(b);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &b in &bits {
            assert_eq!(r.read_bit().unwrap(), b);
        }
    }

    #[test]
    fn msb_first_layout() {
        let mut w = BitWriter::new();
        w.write_bits(0b1, 1);
        w.write_bits(0b01, 2);
        w.write_bits(0b10011, 5);
        // 1 01 10011 => 0b1011_0011
        assert_eq!(w.into_bytes(), vec![0b1011_0011]);
    }

    #[test]
    fn cross_byte_fields() {
        let mut w = BitWriter::new();
        w.write_bits(0x3FF, 10); // ten 1-bits
        w.write_bits(0, 6);
        let bytes = w.into_bytes();
        assert_eq!(bytes, vec![0xFF, 0xC0]);
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(10).unwrap(), 0x3FF);
        assert_eq!(r.read_bits(6).unwrap(), 0);
    }

    #[test]
    fn align_byte_pads_with_zeros() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.align_byte();
        w.write_bits(0xAB, 8);
        let bytes = w.into_bytes();
        assert_eq!(bytes, vec![0b1010_0000, 0xAB]);
    }

    #[test]
    fn bit_len_tracks_partial_bytes() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.write_bits(0b11, 2);
        assert_eq!(w.bit_len(), 2);
        w.write_bits(0x7F, 7);
        assert_eq!(w.bit_len(), 9);
    }

    #[test]
    fn peek_does_not_consume() {
        let bytes = [0b1100_1010, 0b0101_0101];
        let r = BitReader::new(&bytes);
        assert_eq!(r.peek_bits(4), 0b1100);
        assert_eq!(r.peek_bits(4), 0b1100);
        assert_eq!(r.peek_bits(12), 0b1100_1010_0101);
    }

    #[test]
    fn peek_past_end_reads_zero() {
        let bytes = [0b1000_0000];
        let mut r = BitReader::new(&bytes);
        r.consume(7).unwrap();
        assert_eq!(r.peek_bits(8), 0); // 1 real bit (0) + 7 phantom zeros
        assert_eq!(r.remaining_bits(), 1);
    }

    #[test]
    fn consume_overrun_errors() {
        let bytes = [0xFF];
        let mut r = BitReader::new(&bytes);
        r.consume(8).unwrap();
        assert!(r.consume(1).is_err());
    }

    #[test]
    fn random_field_roundtrip_property() {
        // Property: any sequence of (value, width) fields roundtrips.
        let mut rng = Rng::new(0xB17);
        for _case in 0..200 {
            let n_fields = 1 + rng.below(64);
            let fields: Vec<(u64, u8)> = (0..n_fields)
                .map(|_| {
                    let len = 1 + rng.below(32) as u8;
                    let val = rng.next_u64() & ((1u64 << len) - 1);
                    (val, len)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, l) in &fields {
                w.write_bits(v, l);
            }
            let total_bits = w.bit_len();
            let bytes = w.into_bytes();
            assert_eq!(bytes.len(), total_bits.div_ceil(8));
            let mut r = BitReader::new(&bytes);
            for &(v, l) in &fields {
                assert_eq!(r.read_bits(l).unwrap() as u64, v, "field len {l}");
            }
        }
    }

    #[test]
    fn pack_unpack_u4_roundtrip() {
        let mut rng = Rng::new(0x44);
        for n in [0usize, 1, 2, 3, 7, 8, 1023] {
            let syms: Vec<u8> = (0..n).map(|_| rng.below(16) as u8).collect();
            let packed = pack_u4(&syms).unwrap();
            assert_eq!(packed.len(), n.div_ceil(2));
            assert_eq!(unpack_u4(&packed, n).unwrap(), syms);
        }
    }

    #[test]
    fn pack_u4_rejects_wide_symbols() {
        assert!(pack_u4(&[3, 16]).is_err());
    }

    #[test]
    fn unpack_u4_rejects_bad_length() {
        assert!(unpack_u4(&[0xAB], 3).is_err());
    }
}
