//! ETW1 weight container loader (written by `python/compile/train.py`).
//!
//! Layout (little-endian): `"ETW1" | u32 count | per tensor: u16
//! name_len, name, u8 rank, rank × u64 dims, f32 row-major data`.

use crate::tensor::TensorF32;
use crate::{Error, Result};
use std::io::Read;
use std::path::Path;

/// Load all tensors from a `weights.bin` file, in storage order.
pub fn load_weights_bin(path: impl AsRef<Path>) -> Result<Vec<(String, TensorF32)>> {
    let file = std::fs::File::open(path.as_ref())?;
    let mut r = std::io::BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != b"ETW1" {
        return Err(Error::Format(format!("weights.bin: bad magic {magic:02x?}")));
    }
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let count = u32::from_le_bytes(b4) as usize;
    if count > 1_000_000 {
        return Err(Error::Format(format!("implausible tensor count {count}")));
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let mut b2 = [0u8; 2];
        r.read_exact(&mut b2)?;
        let name_len = u16::from_le_bytes(b2) as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name)
            .map_err(|_| Error::Format("tensor name not utf-8".into()))?;
        let mut b1 = [0u8; 1];
        r.read_exact(&mut b1)?;
        let rank = b1[0] as usize;
        if rank > 8 {
            return Err(Error::Format(format!("tensor {name:?}: implausible rank {rank}")));
        }
        let mut dims = Vec::with_capacity(rank);
        let mut b8 = [0u8; 8];
        for _ in 0..rank {
            r.read_exact(&mut b8)?;
            dims.push(u64::from_le_bytes(b8) as usize);
        }
        let n: usize = dims.iter().product();
        let mut bytes = vec![0u8; n * 4];
        r.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push((name, TensorF32::new(dims, data)?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_sample(path: &std::path::Path) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"ETW1").unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        // tensor "a": shape [2,2]
        f.write_all(&1u16.to_le_bytes()).unwrap();
        f.write_all(b"a").unwrap();
        f.write_all(&[2u8]).unwrap();
        f.write_all(&2u64.to_le_bytes()).unwrap();
        f.write_all(&2u64.to_le_bytes()).unwrap();
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        // tensor "b": scalar-ish shape [3]
        f.write_all(&1u16.to_le_bytes()).unwrap();
        f.write_all(b"b").unwrap();
        f.write_all(&[1u8]).unwrap();
        f.write_all(&3u64.to_le_bytes()).unwrap();
        for v in [5.0f32, 6.0, 7.0] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn loads_etw1_tensors() {
        let dir = std::env::temp_dir().join(format!("etw1_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        write_sample(&p);
        let ws = load_weights_bin(&p).unwrap();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0].0, "a");
        assert_eq!(ws[0].1.shape().dims(), &[2, 2]);
        assert_eq!(ws[0].1.data(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ws[1].0, "b");
        assert_eq!(ws[1].1.data(), &[5.0, 6.0, 7.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("etw1bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE\x00\x00\x00\x00").unwrap();
        assert!(load_weights_bin(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_truncation() {
        let dir = std::env::temp_dir().join(format!("etw1tr_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        write_sample(&p);
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() - 5]).unwrap();
        assert!(load_weights_bin(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_weights_load_if_artifacts_exist() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/weights.bin");
        if p.exists() {
            let ws = load_weights_bin(&p).unwrap();
            assert!(ws.iter().any(|(n, _)| n == "embed"));
            let total: usize = ws.iter().map(|(_, t)| t.numel()).sum();
            assert!(total > 500_000, "trained model should have ~0.8M params");
        }
    }
}
