//! PJRT runtime: loads the AOT HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//!
//! Python never runs here — the contract between the layers is
//! `artifacts/manifest.json` (argument names/shapes/dtypes per
//! executable, in PJRT calling-convention order) plus the HLO text
//! files. [`ModelRuntime`] owns:
//!
//! * the PJRT CPU client and the compiled prefill/decode executables,
//! * the **device-resident weight buffers** (uploaded once — the weight
//!   tensors come from parallel-decoding the ELM container, exactly the
//!   paper's edge flow: load compressed → decode once → serve),
//! * KV-cache upload/download helpers for the coordinator's slot
//!   management.

mod artifacts;
mod weights;

pub use artifacts::{ArgSpec, ExecSpec, Manifest, ModelConfig};
pub use weights::load_weights_bin;

use crate::quant::QuantizedTensor;
use crate::store::ElmModel;
use crate::tensor::TensorF32;
use crate::xla;
use crate::{Error, Result};
use std::collections::HashMap;
use std::path::Path;

/// Which weight flavor an executable consumes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// fp32 baseline (`*_f32.hlo.txt`).
    F32,
    /// Quantized symbols + (scale, zero_point) (`*_quant.hlo.txt`).
    /// Serves both uint8 and uint4 ELM models (uint4 symbols are u8
    /// values < 16 with their own scales).
    Quant,
}

impl Variant {
    fn tag(self) -> &'static str {
        match self {
            Variant::F32 => "f32",
            Variant::Quant => "quant",
        }
    }
}

/// A device buffer pinned to the host memory backing it.
///
/// `BufferFromHostLiteral` on the TFRT CPU client is **asynchronous**:
/// the transfer may read the host literal after the call returns. The
/// `xla` crate's own `execute()` awaits buffer readiness for exactly
/// this reason, but `execute_b` / `buffer_from_host_literal` offer no
/// such hook — dropping the literal early causes the intermittent
/// SIGSEGV / "Unhandled primitive type" crashes we bisected. Pinning
/// the literal to the buffer's lifetime makes the pair sound.
pub struct DeviceBuffer {
    buf: xla::PjRtBuffer,
    _backing: Option<xla::Literal>,
}

impl DeviceBuffer {
    /// Wrap a buffer whose backing memory the client copied
    /// synchronously (e.g. `buffer_from_host_buffer`, which uses
    /// `kImmutableOnlyDuringCall` semantics).
    pub fn owned(buf: xla::PjRtBuffer) -> Self {
        DeviceBuffer {
            buf,
            _backing: None,
        }
    }

    /// Wrap a buffer created from a literal, keeping the literal alive.
    pub fn pinned(buf: xla::PjRtBuffer, backing: xla::Literal) -> Self {
        DeviceBuffer {
            buf,
            _backing: Some(backing),
        }
    }

    /// Borrow the underlying PJRT buffer.
    pub fn as_buf(&self) -> &xla::PjRtBuffer {
        &self.buf
    }
}

/// One step's decode output: logits plus the updated KV caches
/// (device-resident; feed them to the next step).
pub struct DecodeOut {
    /// Logits `[B, vocab]`, row-major on host.
    pub logits: Vec<f32>,
    /// Updated K cache.
    pub k_cache: DeviceBuffer,
    /// Updated V cache.
    pub v_cache: DeviceBuffer,
}

/// Prefill output: logits plus the single-slot KV caches on host
/// (the coordinator splices them into a batch slot).
pub struct PrefillOut {
    /// Logits `[1, vocab]`.
    pub logits: Vec<f32>,
    /// K cache `[L, 1, MS, H, HD]` flattened.
    pub k_cache: Vec<f32>,
    /// V cache `[L, 1, MS, H, HD]` flattened.
    pub v_cache: Vec<f32>,
}

/// The compiled model: client + executables + uploaded weights.
pub struct ModelRuntime {
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    score_exe: xla::PjRtLoadedExecutable,
    /// Weight argument buffers, in manifest order, device-resident.
    weight_bufs: Vec<DeviceBuffer>,
    /// Parsed manifest (shapes for KV allocation etc.).
    pub manifest: Manifest,
    /// Which variant was loaded.
    pub variant: Variant,
}

impl ModelRuntime {
    /// Load + compile a variant from the artifacts directory, uploading
    /// the given weight tensors (must match the manifest's weight spec).
    pub fn load(
        artifacts_dir: impl AsRef<Path>,
        variant: Variant,
        weights: &WeightSet,
    ) -> Result<Self> {
        let dir = artifacts_dir.as_ref();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;

        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let spec = manifest
                .executables
                .get(name)
                .ok_or_else(|| Error::Format(format!("manifest lacks executable {name:?}")))?;
            let proto = xla::HloModuleProto::from_text_file(dir.join(&spec.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let prefill_exe = compile(&format!("prefill_{}", variant.tag()))?;
        let decode_exe = compile(&format!("decode_{}", variant.tag()))?;
        let score_exe = compile(&format!("score_{}", variant.tag()))?;

        // Upload weights once, in manifest argument order (weights follow
        // the 2 fixed prefill args; decode shares the same weight tail).
        let spec = &manifest.executables[&format!("prefill_{}", variant.tag())];
        let mut weight_bufs = Vec::new();
        for arg in &spec.args[2..] {
            weight_bufs.push(weights.upload(&client, arg)?);
        }
        Ok(ModelRuntime {
            client,
            prefill_exe,
            decode_exe,
            score_exe,
            weight_bufs,
            manifest,
            variant,
        })
    }

    /// Model configuration from the manifest.
    pub fn config(&self) -> &ModelConfig {
        &self.manifest.config
    }

    /// Flattened element count of one full KV cache `[L,B,MS,H,HD]`.
    pub fn kv_numel(&self) -> usize {
        let c = &self.manifest.config;
        c.n_layers * c.decode_batch * c.max_seq * c.n_heads * c.head_dim
    }

    /// Run a prompt through prefill. `prompt` is truncated/padded to
    /// `prefill_len`; must be non-empty.
    pub fn prefill(&self, prompt: &[u32]) -> Result<PrefillOut> {
        let cfg = &self.manifest.config;
        let s = cfg.prefill_len;
        if prompt.is_empty() {
            return Err(Error::InvalidArg("empty prompt".into()));
        }
        let length = prompt.len().min(s);
        let mut toks = vec![0i32; s];
        for (i, &t) in prompt.iter().take(length).enumerate() {
            toks[i] = t as i32;
        }
        let tok_buf = self.client.buffer_from_host_buffer(&toks, &[1, s], None)?;
        let len_buf = self
            .client
            .buffer_from_host_buffer(&[length as i32], &[], None)?;

        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &len_buf];
        args.extend(self.weight_bufs.iter().map(|b| b.as_buf()));
        let outs = self.prefill_exe.execute_b(&args)?;
        let tuple = outs[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != 3 {
            return Err(Error::Xla(format!("prefill returned {} outputs", parts.len())));
        }
        Ok(PrefillOut {
            logits: parts[0].to_vec::<f32>()?,
            k_cache: parts[1].to_vec::<f32>()?,
            v_cache: parts[2].to_vec::<f32>()?,
        })
    }

    /// Upload host KV caches `[L, B, MS, H, HD]` to device buffers.
    pub fn upload_kv(&self, k: &[f32], v: &[f32]) -> Result<(DeviceBuffer, DeviceBuffer)> {
        let c = &self.manifest.config;
        let dims = [c.n_layers, c.decode_batch, c.max_seq, c.n_heads, c.head_dim];
        let expect: usize = dims.iter().product();
        if k.len() != expect || v.len() != expect {
            return Err(Error::InvalidArg(format!(
                "kv size {} vs expected {expect}",
                k.len()
            )));
        }
        let kb = self.client.buffer_from_host_buffer(k, &dims, None)?;
        let vb = self.client.buffer_from_host_buffer(v, &dims, None)?;
        Ok((DeviceBuffer::owned(kb), DeviceBuffer::owned(vb)))
    }

    /// Download device KV caches to host vectors.
    pub fn download_kv(
        &self,
        k: &DeviceBuffer,
        v: &DeviceBuffer,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        Ok((
            k.as_buf().to_literal_sync()?.to_vec::<f32>()?,
            v.as_buf().to_literal_sync()?.to_vec::<f32>()?,
        ))
    }

    /// Teacher-forced scoring: full logits `[1, S, vocab]` for a window
    /// of `prefill_len` tokens (flattened row-major).
    pub fn score(&self, tokens: &[u32]) -> Result<Vec<f32>> {
        let cfg = &self.manifest.config;
        let s = cfg.prefill_len;
        if tokens.len() != s {
            return Err(Error::InvalidArg(format!(
                "score wants exactly {s} tokens, got {}",
                tokens.len()
            )));
        }
        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tok_buf = self.client.buffer_from_host_buffer(&toks, &[1, s], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf];
        args.extend(self.weight_bufs.iter().map(|b| b.as_buf()));
        let outs = self.score_exe.execute_b(&args)?;
        let tuple = outs[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        Ok(parts[0].to_vec::<f32>()?)
    }

    /// Perplexity over up to `max_windows` consecutive windows of a
    /// text (byte-level tokens). Returns (nll nats/char, char ppl) —
    /// the Table I quality metric.
    pub fn score_ppl(&self, text: &str, max_windows: usize) -> Result<(f64, f64)> {
        let cfg = &self.manifest.config;
        let s = cfg.prefill_len;
        let toks: Vec<u32> = text
            .bytes()
            .map(|b| if b < 128 { b as u32 } else { b'?' as u32 })
            .collect();
        let n_windows = ((toks.len().saturating_sub(1)) / s).min(max_windows);
        if n_windows == 0 {
            return Err(Error::InvalidArg("text too short for one window".into()));
        }
        let vocab = cfg.vocab;
        let mut nll_sum = 0.0f64;
        let mut count = 0usize;
        for w in 0..n_windows {
            let start = w * s;
            let window = &toks[start..start + s];
            let targets = &toks[start + 1..start + s + 1];
            let logits = self.score(window)?; // [1, S, V]
            for (i, &t) in targets.iter().enumerate() {
                let row = &logits[i * vocab..(i + 1) * vocab];
                // log-softmax at the target index.
                let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let lse: f32 = row.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
                nll_sum += (lse - row[t as usize]) as f64;
                count += 1;
            }
        }
        let nll = nll_sum / count as f64;
        Ok((nll, nll.exp()))
    }

    /// One decode step for the whole batch.
    pub fn decode_step(
        &self,
        tokens: &[u32],
        pos: &[u32],
        k_cache: &DeviceBuffer,
        v_cache: &DeviceBuffer,
    ) -> Result<DecodeOut> {
        let c = &self.manifest.config;
        let b = c.decode_batch;
        if tokens.len() != b || pos.len() != b {
            return Err(Error::InvalidArg(format!(
                "decode_step wants batch {b}, got {}/{}",
                tokens.len(),
                pos.len()
            )));
        }
        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let poss: Vec<i32> = pos.iter().map(|&p| p as i32).collect();
        let tok_buf = self.client.buffer_from_host_buffer(&toks, &[b], None)?;
        let pos_buf = self.client.buffer_from_host_buffer(&poss, &[b], None)?;
        let mut args: Vec<&xla::PjRtBuffer> =
            vec![&tok_buf, &pos_buf, k_cache.as_buf(), v_cache.as_buf()];
        args.extend(self.weight_bufs.iter().map(|b| b.as_buf()));
        let outs = self.decode_exe.execute_b(&args)?;
        // xla 0.1.6 exposes tuple outputs as one buffer; destructure via
        // a host literal. KV round-trips through host per step — measured
        // acceptable at this model scale (see EXPERIMENTS.md §Perf).
        let tuple = outs[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != 3 {
            return Err(Error::Xla(format!("decode returned {} outputs", parts.len())));
        }
        let logits = parts[0].to_vec::<f32>()?;
        let mut it = parts.into_iter();
        let _logits_lit = it.next().unwrap();
        let k_lit = it.next().unwrap();
        let v_lit = it.next().unwrap();
        // Pin each literal to its buffer: the CPU-client transfer is
        // async (see DeviceBuffer docs).
        let k_buf = self.client.buffer_from_host_literal(None, &k_lit)?;
        let v_buf = self.client.buffer_from_host_literal(None, &v_lit)?;
        Ok(DecodeOut {
            logits,
            k_cache: DeviceBuffer::pinned(k_buf, k_lit),
            v_cache: DeviceBuffer::pinned(v_buf, v_lit),
        })
    }
}

/// The weight tensors an executable variant needs, keyed by name.
#[derive(Default)]
pub struct WeightSet {
    /// fp32 tensors (norms always; everything for the F32 variant).
    pub f32s: HashMap<String, TensorF32>,
    /// Quantized tensors (Quant variant only).
    pub quants: HashMap<String, QuantizedTensor>,
}

impl WeightSet {
    /// Build the fp32 weight set from a raw weights.bin load.
    pub fn from_f32(tensors: Vec<(String, TensorF32)>) -> Self {
        WeightSet {
            f32s: tensors.into_iter().collect(),
            quants: HashMap::new(),
        }
    }

    /// Build the quantized weight set: decoded ELM tensors for the
    /// quantized names + fp32 tensors for the rest (norms).
    pub fn from_quantized(
        decoded: Vec<(String, QuantizedTensor)>,
        f32_rest: Vec<(String, TensorF32)>,
    ) -> Self {
        WeightSet {
            f32s: f32_rest.into_iter().collect(),
            quants: decoded.into_iter().collect(),
        }
    }

    /// The paper's edge flow in one call: **parallel-decode** a whole
    /// ELM container (§III-C) and pair it with the fp32 norm tensors.
    pub fn from_elm(
        model: &ElmModel,
        threads: usize,
        f32_rest: Vec<(String, TensorF32)>,
    ) -> Result<Self> {
        let (tensors, _) = crate::decode::ParallelDecoder::new(threads).decode_model(model)?;
        let named = model
            .layers
            .iter()
            .map(|m| m.name.clone())
            .zip(tensors)
            .collect();
        Ok(Self::from_quantized(named, f32_rest))
    }

    /// Start a weight set holding only the fp32 rest (norms); quantized
    /// layers are then installed one at a time via
    /// [`WeightSet::insert_quantized`] as a streaming decoder hands them
    /// over — the incremental-arrival half of the streaming deploy path.
    pub fn begin_streaming(f32_rest: Vec<(String, TensorF32)>) -> Self {
        WeightSet {
            f32s: f32_rest.into_iter().collect(),
            quants: HashMap::new(),
        }
    }

    /// Install one decoded layer the moment it becomes available.
    pub fn insert_quantized(&mut self, name: String, tensor: QuantizedTensor) {
        self.quants.insert(name, tensor);
    }

    /// Quantized layers currently resident.
    pub fn quant_layers(&self) -> usize {
        self.quants.len()
    }

    /// Drain a [`crate::decode::LayerStream`] into a weight set,
    /// installing each layer as it arrives so ELM decode overlaps weight
    /// staging instead of strictly preceding it (§III-C pipelined onto
    /// the load path).
    pub fn from_layer_stream(
        stream: &mut crate::decode::LayerStream,
        f32_rest: Vec<(String, TensorF32)>,
    ) -> Result<Self> {
        let mut ws = Self::begin_streaming(f32_rest);
        while let Some(layer) = stream.next_layer() {
            let layer = layer?;
            ws.insert_quantized(layer.name, layer.tensor);
        }
        Ok(ws)
    }

    /// Upload the tensor for one manifest argument.
    fn upload(&self, client: &xla::PjRtClient, arg: &ArgSpec) -> Result<DeviceBuffer> {
        if let Some(base) = arg.name.strip_suffix(".sym") {
            let q = self.quant(base)?;
            if q.symbols.numel() != arg.numel() {
                return Err(Error::InvalidArg(format!(
                    "weight {:?}: {} symbols, manifest wants {:?}",
                    arg.name,
                    q.symbols.numel(),
                    arg.shape
                )));
            }
            // NB: buffer_from_host_raw_bytes mis-sizes U8 buffers in the
            // published xla crate (elements counted as 8 bytes); the
            // literal path sizes correctly.
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::U8,
                &arg.shape,
                q.symbols.data(),
            )?;
            let buf = client.buffer_from_host_literal(None, &lit)?;
            // Pin: the host->device copy is async (see DeviceBuffer).
            return Ok(DeviceBuffer::pinned(buf, lit));
        }
        if let Some(base) = arg.name.strip_suffix(".scale") {
            let q = self.quant(base)?;
            let buf = client.buffer_from_host_buffer(&[q.params.scale], &[], None)?;
            return Ok(DeviceBuffer::owned(buf));
        }
        if let Some(base) = arg.name.strip_suffix(".zp") {
            let q = self.quant(base)?;
            let buf = client.buffer_from_host_buffer(&[q.params.zero_point], &[], None)?;
            return Ok(DeviceBuffer::owned(buf));
        }
        let t = self
            .f32s
            .get(&arg.name)
            .ok_or_else(|| Error::InvalidArg(format!("missing f32 weight {:?}", arg.name)))?;
        if t.numel() != arg.numel() {
            return Err(Error::InvalidArg(format!(
                "weight {:?} has {} elements, manifest wants {:?}",
                arg.name,
                t.numel(),
                arg.shape
            )));
        }
        Ok(DeviceBuffer::owned(client.buffer_from_host_buffer(
            t.data(),
            &arg.shape,
            None,
        )?))
    }

    fn quant(&self, name: &str) -> Result<&QuantizedTensor> {
        self.quants
            .get(name)
            .ok_or_else(|| Error::InvalidArg(format!("missing quantized weight {name:?}")))
    }
}
