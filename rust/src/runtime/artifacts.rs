//! `artifacts/manifest.json` parsing — the cross-language calling
//! convention between `python/compile/aot.py` and the rust runtime.

use crate::json::Value;
use crate::{Error, Result};
use std::collections::HashMap;
use std::path::Path;

/// Model hyper-parameters (mirror of python `model.Config`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    /// Vocabulary size (byte-level tokenizer).
    pub vocab: usize,
    /// Hidden dimension.
    pub dim: usize,
    /// Transformer blocks.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// MLP hidden dimension.
    pub ffn: usize,
    /// KV-cache capacity in tokens.
    pub max_seq: usize,
    /// Prompt buffer length (prefill executable's fixed S).
    pub prefill_len: usize,
    /// Decode executable's fixed batch.
    pub decode_batch: usize,
    /// Total parameter count.
    pub n_params: usize,
}

/// One PJRT argument: name, shape, dtype tag ("f32" | "u8" | "i32").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgSpec {
    /// Argument name (quant triples use `<layer>.sym/.scale/.zp`).
    pub name: String,
    /// Dimensions (empty = scalar).
    pub shape: Vec<usize>,
    /// Element type tag.
    pub dtype: String,
}

impl ArgSpec {
    /// Element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One executable: HLO file + argument order.
#[derive(Debug, Clone)]
pub struct ExecSpec {
    /// HLO text file name (relative to the artifacts dir).
    pub file: String,
    /// Arguments in calling order.
    pub args: Vec<ArgSpec>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Model configuration.
    pub config: ModelConfig,
    /// Names of the weight tensors that are quantized.
    pub quantized_names: Vec<String>,
    /// Executable name → spec (e.g. `"prefill_quant"`).
    pub executables: HashMap<String, ExecSpec>,
}

impl Manifest {
    /// Load and validate `manifest.json`.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::parse(&text)
    }

    /// Parse from JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        if v.get("format")?.as_usize()? != 1 {
            return Err(Error::Format("unsupported manifest format".into()));
        }
        let c = v.get("config")?;
        let config = ModelConfig {
            vocab: c.get("vocab")?.as_usize()?,
            dim: c.get("dim")?.as_usize()?,
            n_layers: c.get("n_layers")?.as_usize()?,
            n_heads: c.get("n_heads")?.as_usize()?,
            head_dim: c.get("head_dim")?.as_usize()?,
            ffn: c.get("ffn")?.as_usize()?,
            max_seq: c.get("max_seq")?.as_usize()?,
            prefill_len: c.get("prefill_len")?.as_usize()?,
            decode_batch: c.get("decode_batch")?.as_usize()?,
            n_params: c.get("n_params")?.as_usize()?,
        };
        let quantized_names = v
            .get("quantized_names")?
            .as_array()?
            .iter()
            .map(|s| s.as_str().map(|s| s.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let mut executables = HashMap::new();
        for (name, spec) in v.get("executables")?.as_object()? {
            let args = spec
                .get("args")?
                .as_array()?
                .iter()
                .map(|a| {
                    Ok(ArgSpec {
                        name: a.get("name")?.as_str()?.to_string(),
                        shape: a
                            .get("shape")?
                            .as_array()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<Vec<_>>>()?,
                        dtype: a.get("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            executables.insert(
                name.clone(),
                ExecSpec {
                    file: spec.get("file")?.as_str()?.to_string(),
                    args,
                },
            );
        }
        let m = Manifest {
            config,
            quantized_names,
            executables,
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        let c = &self.config;
        if c.dim != c.n_heads * c.head_dim {
            return Err(Error::Format("dim != heads*head_dim".into()));
        }
        if c.prefill_len > c.max_seq {
            return Err(Error::Format("prefill_len > max_seq".into()));
        }
        for name in [
            "prefill_f32",
            "prefill_quant",
            "decode_f32",
            "decode_quant",
            "score_f32",
            "score_quant",
        ] {
            let e = self
                .executables
                .get(name)
                .ok_or_else(|| Error::Format(format!("manifest lacks {name}")))?;
            let n_fixed = if name.starts_with("prefill") {
                2
            } else if name.starts_with("score") {
                1
            } else {
                4
            };
            if e.args.len() <= n_fixed {
                return Err(Error::Format(format!("{name}: no weight args")));
            }
        }
        Ok(())
    }

    /// Golden-output file content, parsed (integration tests).
    pub fn load_golden(dir: impl AsRef<Path>) -> Result<Value> {
        let text = std::fs::read_to_string(dir.as_ref().join("golden.json"))?;
        Ok(Value::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> String {
        r#"{
          "format": 1,
          "config": {"vocab":128,"dim":128,"n_layers":4,"n_heads":4,
                     "head_dim":32,"ffn":512,"max_seq":160,"prefill_len":64,
                     "decode_batch":4,"n_params":803968},
          "quantized_names": ["embed"],
          "executables": {
            "prefill_f32": {"file":"p.hlo.txt","args":[
               {"name":"tokens","shape":[1,64],"dtype":"i32"},
               {"name":"length","shape":[],"dtype":"i32"},
               {"name":"embed","shape":[128,128],"dtype":"f32"}]},
            "prefill_quant": {"file":"pq.hlo.txt","args":[
               {"name":"tokens","shape":[1,64],"dtype":"i32"},
               {"name":"length","shape":[],"dtype":"i32"},
               {"name":"embed.sym","shape":[128,128],"dtype":"u8"},
               {"name":"embed.scale","shape":[],"dtype":"f32"},
               {"name":"embed.zp","shape":[],"dtype":"f32"}]},
            "decode_f32": {"file":"d.hlo.txt","args":[
               {"name":"tokens","shape":[4],"dtype":"i32"},
               {"name":"pos","shape":[4],"dtype":"i32"},
               {"name":"k_cache","shape":[4,4,160,4,32],"dtype":"f32"},
               {"name":"v_cache","shape":[4,4,160,4,32],"dtype":"f32"},
               {"name":"embed","shape":[128,128],"dtype":"f32"}]},
            "decode_quant": {"file":"dq.hlo.txt","args":[
               {"name":"tokens","shape":[4],"dtype":"i32"},
               {"name":"pos","shape":[4],"dtype":"i32"},
               {"name":"k_cache","shape":[4,4,160,4,32],"dtype":"f32"},
               {"name":"v_cache","shape":[4,4,160,4,32],"dtype":"f32"},
               {"name":"embed.sym","shape":[128,128],"dtype":"u8"},
               {"name":"embed.scale","shape":[],"dtype":"f32"},
               {"name":"embed.zp","shape":[],"dtype":"f32"}]},
            "score_f32": {"file":"s.hlo.txt","args":[
               {"name":"tokens","shape":[1,64],"dtype":"i32"},
               {"name":"embed","shape":[128,128],"dtype":"f32"}]},
            "score_quant": {"file":"sq.hlo.txt","args":[
               {"name":"tokens","shape":[1,64],"dtype":"i32"},
               {"name":"embed.sym","shape":[128,128],"dtype":"u8"},
               {"name":"embed.scale","shape":[],"dtype":"f32"},
               {"name":"embed.zp","shape":[],"dtype":"f32"}]}
          }
        }"#
        .to_string()
    }

    #[test]
    fn parses_valid_manifest() {
        let m = Manifest::parse(&sample_manifest()).unwrap();
        assert_eq!(m.config.dim, 128);
        assert_eq!(m.config.head_dim, 32);
        assert_eq!(m.executables["prefill_quant"].args.len(), 5);
        assert_eq!(m.executables["prefill_quant"].args[2].numel(), 128 * 128);
        assert_eq!(m.quantized_names, vec!["embed"]);
    }

    #[test]
    fn rejects_bad_format_version() {
        let bad = sample_manifest().replace("\"format\": 1", "\"format\": 9");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_inconsistent_dims() {
        let bad = sample_manifest().replace("\"n_heads\":4", "\"n_heads\":3");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_executable() {
        let bad = sample_manifest().replace("decode_quant", "decode_other");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn real_manifest_parses_if_artifacts_exist() {
        // Integration-ish: run only when `make artifacts` has run.
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(m.config.n_params > 0);
            assert!(m.executables.len() >= 4);
        }
    }
}
