//! Readiness waiting for the sharded front door.
//!
//! Offline, zero-dependency build: on unix the shard loops block in
//! `poll(2)` through an in-tree FFI declaration (std already links the
//! platform C library, so no crate is added), watching every
//! connection plus the shard's wake socket. Elsewhere a portable
//! fallback blocks briefly on the wake socket alone and reports every
//! connection "ready" — the caller's nonblocking reads/writes discover
//! the true state via `WouldBlock`. The `#[cfg(unix)]` /
//! `#[cfg(not(unix))]` split mirrors `store::SharedFile`'s positioned
//! reads: the fast path is unix-specific, the fallback is correct
//! everywhere.

use std::net::TcpStream;
use std::time::Duration;

/// Readiness of one polled socket. On the non-unix fallback both
/// flags are optimistically `true` (level-triggered "try everything").
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct Readiness {
    /// Data (or EOF/error) can be read without blocking.
    pub readable: bool,
    /// The send buffer can accept bytes without blocking.
    pub writable: bool,
}

/// Block until one of `socks` is ready, the `wake` socket is written
/// to, or `timeout` elapses. Each entry pairs a stream with its write
/// interest (read interest is always on). Pending wake bytes are
/// drained here, so one call also acts as the wake acknowledgment.
pub(crate) fn wait(wake: &TcpStream, socks: &[(&TcpStream, bool)], timeout: Duration) -> Vec<Readiness> {
    sys::wait(wake, socks, timeout)
}

#[cfg(unix)]
mod sys {
    use super::Readiness;
    use std::io::Read;
    use std::net::TcpStream;
    use std::os::raw::{c_int, c_short};
    use std::os::unix::io::AsRawFd;
    use std::time::Duration;

    /// `struct pollfd` from `<poll.h>` — identical layout on every
    /// unix this crate targets.
    #[repr(C)]
    struct PollFd {
        fd: c_int,
        events: c_short,
        revents: c_short,
    }

    const POLLIN: c_short = 0x001;
    const POLLOUT: c_short = 0x004;
    const POLLERR: c_short = 0x008;
    const POLLHUP: c_short = 0x010;

    // `nfds_t` is `unsigned long` on linux/android and `unsigned int`
    // on the BSD family (macOS included).
    #[cfg(any(target_os = "linux", target_os = "android"))]
    type Nfds = std::os::raw::c_ulong;
    #[cfg(not(any(target_os = "linux", target_os = "android")))]
    type Nfds = std::os::raw::c_uint;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: Nfds, timeout: c_int) -> c_int;
    }

    pub(super) fn wait(
        wake: &TcpStream,
        socks: &[(&TcpStream, bool)],
        timeout: Duration,
    ) -> Vec<Readiness> {
        let mut fds = Vec::with_capacity(socks.len() + 1);
        fds.push(PollFd {
            fd: wake.as_raw_fd(),
            events: POLLIN,
            revents: 0,
        });
        for (s, want_write) in socks {
            let mut events = POLLIN;
            if *want_write {
                events |= POLLOUT;
            }
            fds.push(PollFd {
                fd: s.as_raw_fd(),
                events,
                revents: 0,
            });
        }
        let ms = timeout.as_millis().min(c_int::MAX as u128) as c_int;
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as Nfds, ms) };
        if rc < 0 {
            // EINTR or similar: report nothing ready; the caller loops.
            return vec![Readiness::default(); socks.len()];
        }
        if fds[0].revents & (POLLIN | POLLERR | POLLHUP) != 0 {
            drain_wake(wake);
        }
        fds[1..]
            .iter()
            .map(|f| Readiness {
                // Error/hangup surface through a read (EOF or error),
                // and must unblock a pending write too.
                readable: f.revents & (POLLIN | POLLERR | POLLHUP) != 0,
                writable: f.revents & (POLLOUT | POLLERR | POLLHUP) != 0,
            })
            .collect()
    }

    /// Swallow pending wake bytes (the wake socket is nonblocking).
    fn drain_wake(wake: &TcpStream) {
        let mut buf = [0u8; 256];
        let mut r: &TcpStream = wake;
        while matches!(r.read(&mut buf), Ok(n) if n > 0) {}
    }
}

#[cfg(not(unix))]
mod sys {
    use super::Readiness;
    use std::io::Read;
    use std::net::TcpStream;
    use std::time::Duration;

    /// Portable fallback: no readiness syscall, so block (briefly) on
    /// the wake socket alone — a pushed reply or a new connection cuts
    /// the sleep short — and report every connection ready. The shard's
    /// nonblocking reads/writes turn "optimistically ready" back into
    /// `WouldBlock` where it was not true. The sleep is clamped low so
    /// connection data (which cannot interrupt it) waits at most a few
    /// milliseconds.
    pub(super) fn wait(
        wake: &TcpStream,
        socks: &[(&TcpStream, bool)],
        timeout: Duration,
    ) -> Vec<Readiness> {
        let nap = timeout
            .min(Duration::from_millis(3))
            .max(Duration::from_millis(1));
        wake.set_read_timeout(Some(nap)).ok();
        let mut buf = [0u8; 256];
        let mut r: &TcpStream = wake;
        let _ = r.read(&mut buf); // data or timeout — either way, proceed
        vec![
            Readiness {
                readable: true,
                writable: true,
            };
            socks.len()
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::TcpListener;

    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let tx = TcpStream::connect(addr).unwrap();
        let (rx, _) = l.accept().unwrap();
        (rx, tx)
    }

    #[test]
    fn wake_byte_cuts_the_wait_short() {
        let (wake_rx, wake_tx) = pair();
        #[cfg(unix)]
        wake_rx.set_nonblocking(true).unwrap();
        let mut tx = &wake_tx;
        tx.write_all(&[1]).unwrap();
        let t0 = std::time::Instant::now();
        let ready = wait(&wake_rx, &[], Duration::from_secs(5));
        assert!(ready.is_empty());
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "a pending wake byte must not wait out the full timeout"
        );
    }

    #[test]
    fn readable_socket_reports_ready() {
        let (wake_rx, _wake_tx) = pair();
        #[cfg(unix)]
        wake_rx.set_nonblocking(true).unwrap();
        let (conn_rx, conn_tx) = pair();
        let mut tx = &conn_tx;
        tx.write_all(b"x").unwrap();
        let ready = wait(&wake_rx, &[(&conn_rx, false)], Duration::from_secs(5));
        assert_eq!(ready.len(), 1);
        assert!(ready[0].readable);
    }

    #[test]
    fn idle_wait_times_out() {
        let (wake_rx, _wake_tx) = pair();
        #[cfg(unix)]
        wake_rx.set_nonblocking(true).unwrap();
        let (conn_rx, _conn_tx) = pair();
        let t0 = std::time::Instant::now();
        let ready = wait(&wake_rx, &[(&conn_rx, false)], Duration::from_millis(20));
        assert!(t0.elapsed() >= Duration::from_millis(1));
        assert_eq!(ready.len(), 1);
        #[cfg(unix)]
        assert!(!ready[0].readable, "nothing was written to the socket");
    }
}
