//! Line-protocol TCP server + client for the serving example.
//!
//! Offline build: no tokio, so the front door is a hand-rolled sharded
//! event loop over `std::net` — a small *fixed* number of I/O threads
//! (`--io-shards` shard loops plus one acceptor, see [`frontdoor`])
//! multiplexing every connection through poll-based readiness
//! ([`poll`]), feeding the engine loop on the calling thread through a
//! bounded channel. Thread count is O(shards), not O(connections),
//! and backpressure is explicit at every seam:
//!
//! * per-connection reply queues are byte-capped
//!   (`--max-conn-buffered-kb`) — a client that stops reading is shed
//!   and disconnected instead of ballooning server memory;
//! * a full [`crate::coordinator::batcher::AdmissionQueue`] or a full
//!   shard→engine channel earns a *distinguishable* load-shed error
//!   line `{"error":…,"shed":true}` so clients can back off;
//! * shutdown drains (`--drain-timeout-ms`): the acceptor stops,
//!   in-flight generations finish or are answered with
//!   `{"error":"shutting down"}`, replies flush, then the loops exit.
//!
//! Protocol: one JSON object per line (at most [`MAX_LINE_BYTES`]
//! bytes — longer lines earn an error reply and a dropped connection,
//! never unbounded buffering).
//!
//! ```text
//! → {"id": 1, "prompt": "the model", "max_tokens": 32, "temperature": 0.8}
//! ← {"id": 1, "text": "...", "tokens": 32, "finish": "length",
//!    "first_token_ms": 12.3, "decode_ms": 45.6}
//! ```
//!
//! A multi-model server ([`serve_multi`], over
//! [`crate::coordinator::MultiModelServer`]) additionally routes by an
//! optional `"model"` field: the first hosted model serves requests
//! that omit it, unknown names earn an error line, and the
//! `{"stats":true}` reply grows a `models` array (per-model serving +
//! `cache_*`/`prefetch_*` counters) plus `ledger_*` fields for the
//! shared byte budget. Single-model servers reject the field so a
//! misrouted client fails loudly instead of silently getting the
//! wrong model. Both variants surface the front door's connection and
//! shed counters ([`FrontDoorCounters`]) in the same stats line.

mod frontdoor;
mod poll;

pub use frontdoor::{
    process_thread_count, FrontDoorCounters, ReplyHandle, SendOutcome, ServeConfig,
};

use frontdoor::FrontDoor;

use crate::coordinator::{
    Backend, Engine, MultiModelServer, Request, Response, PRIORITY_MAX, PRIORITY_MIN,
};
use crate::corpus::ByteTokenizer;
use crate::json::{self, Value};
use crate::{Error, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Hard cap on one protocol line. A line that exceeds it is answered
/// with an error and the connection is dropped — the reader never
/// buffers an unbounded line, so one hostile client cannot balloon
/// server memory.
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// Parse one request line. Public for tests and the client.
pub fn parse_request(line: &str, next_id: u64) -> Result<Request> {
    let v = Value::parse(line)?;
    parse_request_value(&v, next_id)
}

/// Build a [`Request`] from an already-parsed line (the connection
/// reader parses each line exactly once and branches on the result).
pub fn parse_request_value(v: &Value, next_id: u64) -> Result<Request> {
    let prompt_text = v.get("prompt")?.as_str()?.to_string();
    let prompt = ByteTokenizer.encode(&prompt_text);
    if prompt.is_empty() {
        return Err(Error::InvalidArg("empty prompt".into()));
    }
    // Strict id parse: `as_f64()? as u64` would silently truncate a
    // fractional id, wrap a negative one, and round ids at/beyond 2^53
    // — three ways for distinct clients to collide on one id and steal
    // each other's replies. Reject instead.
    let id = match v.get_opt("id") {
        None => next_id,
        Some(x) => x.as_u64().map_err(|_| {
            Error::InvalidArg("\"id\" must be a non-negative integer below 2^53".into())
        })?,
    };
    // Same strictness for the request class: a fractional or
    // out-of-range priority silently clamped would reorder *other*
    // clients' requests. Reject instead.
    let priority = match v.get_opt("priority") {
        None => 0,
        Some(x) => {
            let bad = || {
                Error::InvalidArg(format!(
                    "\"priority\" must be an integer in [{PRIORITY_MIN}, {PRIORITY_MAX}]"
                ))
            };
            let n = x.as_f64().map_err(|_| bad())?;
            if n.fract() != 0.0 || n < PRIORITY_MIN as f64 || n > PRIORITY_MAX as f64 {
                return Err(bad());
            }
            n as i32
        }
    };
    let deadline = v
        .get_opt("deadline_ms")
        .map(|x| {
            x.as_u64().map(Duration::from_millis).map_err(|_| {
                Error::InvalidArg(
                    "\"deadline_ms\" must be a non-negative integer below 2^53".into(),
                )
            })
        })
        .transpose()?;
    Ok(Request {
        id,
        prompt,
        max_new_tokens: v
            .get_opt("max_tokens")
            .map(|x| x.as_usize())
            .transpose()?
            .unwrap_or(32),
        temperature: v
            .get_opt("temperature")
            .map(|x| x.as_f64())
            .transpose()?
            .unwrap_or(0.0) as f32,
        top_k: v
            .get_opt("top_k")
            .map(|x| x.as_usize())
            .transpose()?
            .unwrap_or(0),
        stop_token: Some(u32::from(b'.')),
        enqueued_at: None,
        priority,
        deadline,
        resume: None,
    })
}

/// Serialize a response line.
pub fn format_response(r: &Response) -> String {
    let text = ByteTokenizer.decode(&r.tokens);
    json::obj(vec![
        ("id", json::num(r.id as f64)),
        ("text", json::s(&text)),
        ("tokens", json::num(r.tokens.len() as f64)),
        (
            "finish",
            json::s(match r.finish_reason {
                crate::coordinator::request::FinishReason::Length => "length",
                crate::coordinator::request::FinishReason::Stop => "stop",
                crate::coordinator::request::FinishReason::Capacity => "capacity",
                crate::coordinator::request::FinishReason::Expired => "expired",
            }),
        ),
        (
            "first_token_ms",
            json::num(r.timing.first_token.as_secs_f64() * 1e3),
        ),
        ("decode_ms", json::num(r.timing.decode.as_secs_f64() * 1e3)),
    ])
    .to_json()
}

/// One classified protocol line, in flight from a shard to the engine
/// loop through the bounded channel.
pub(crate) enum Incoming {
    /// A generation request plus its optional `"model"` routing name.
    Req(Request, Option<String>, ReplyHandle),
    Stats(ReplyHandle),
    /// The admin line's live reservation retune
    /// (`{"reserve":{model: mb}}`), already parsed into
    /// (name, bytes) pairs.
    Reserve(Vec<(String, usize)>, ReplyHandle),
    Bad(String, ReplyHandle),
}

/// Build one error reply line through the real JSON serializer:
/// quotes, backslashes, and control characters (including newlines) are
/// escaped losslessly, so hostile content echoed inside an error — a
/// weird model name, a parser message quoting the input — can never
/// corrupt the line protocol or smuggle a fake reply.
fn error_line(msg: &str) -> String {
    json::obj(vec![("error", json::s(msg))]).to_json()
}

/// An error reply that marks deliberate load shedding (`"shed": true`):
/// the request was well-formed but refused because a bounded queue was
/// full. Clients distinguish it from protocol errors and back off.
fn shed_line(msg: &str) -> String {
    json::obj(vec![("error", json::s(msg)), ("shed", Value::Bool(true))]).to_json()
}

/// The reply for a request whose deadline passed before it finished —
/// while still queued or mid-generation (`"expired": true`). It never
/// ran to completion — any tokens on the line are the prefix generated
/// (or checkpointed by preemption) before the deadline hit — so clients
/// distinguish it from protocol errors (no marker) and load shedding
/// (`"shed": true`).
fn expired_line(resp: &Response) -> String {
    json::obj(vec![
        ("id", json::num(resp.id as f64)),
        ("error", json::s("deadline expired")),
        ("expired", Value::Bool(true)),
        ("text", json::s(&ByteTokenizer.decode(&resp.tokens))),
        ("tokens", json::num(resp.tokens.len() as f64)),
    ])
    .to_json()
}

/// Serialize one engine response for its waiter: the normal response
/// line, or the distinguishable expired line for a queued request
/// whose deadline passed before it ran.
fn reply_line(resp: &Response) -> String {
    if matches!(
        resp.finish_reason,
        crate::coordinator::request::FinishReason::Expired
    ) {
        expired_line(resp)
    } else {
        format_response(resp)
    }
}

/// Parse the admin line's `{"reserve":{model: mb}}` verb: each value is
/// the model's new reservation in MiB (matching the `reserve-mb=N`
/// startup syntax), strictly parsed like request ids.
fn parse_reserve(v: &Value) -> Result<Vec<(String, usize)>> {
    let map = v.get("reserve")?.as_object().map_err(|_| {
        Error::InvalidArg("\"reserve\" must be an object mapping model names to MiB".into())
    })?;
    let mut updates = Vec::with_capacity(map.len());
    for (name, mb) in map {
        let mb = mb.as_u64().map_err(|_| {
            Error::InvalidArg(format!(
                "\"reserve\".{name:?} must be a non-negative integer (MiB)"
            ))
        })?;
        updates.push((name.clone(), (mb as usize).saturating_mul(1 << 20)));
    }
    Ok(updates)
}

/// Extract the optional `"model"` routing field (must be a string when
/// present).
fn parse_model(v: &Value) -> Result<Option<String>> {
    match v.get_opt("model") {
        None => Ok(None),
        Some(Value::Str(name)) => Ok(Some(name.clone())),
        Some(other) => Err(Error::InvalidArg(format!(
            "\"model\" must be a string, got {other:?}"
        ))),
    }
}

/// Serialize an engine-stats snapshot (the `{"stats": true}` admin
/// line's reply): serving counters plus live occupancy, so an operator
/// can watch a streaming-loaded server warm up without a side channel.
/// When the backend serves weights through a residency cache
/// ([`crate::residency`]), the cache's hit/miss/evict counters and
/// byte occupancy ride along under `cache_*` keys; when it prefetches
/// decode-ahead ([`crate::residency::prefetch`]), the prefetcher's
/// scheduled/completed/hit/wait counters ride along under `prefetch_*`
/// keys.
pub fn format_stats<B: Backend>(engine: &Engine<B>) -> String {
    json::obj(engine_stats_fields(engine)).to_json()
}

/// [`format_stats`] plus the front door's connection/shed counters —
/// what a live single-model server actually answers on the admin line.
pub fn format_stats_with<B: Backend>(engine: &Engine<B>, front: &FrontDoorCounters) -> String {
    let mut fields = engine_stats_fields(engine);
    fields.extend(front_door_fields(front));
    json::obj(fields).to_json()
}

/// The per-engine stats fields of the admin line — shared by the
/// single-model reply ([`format_stats`]) and each entry of the
/// multi-model `models` array ([`format_multi_stats`]).
fn engine_stats_fields<B: Backend>(engine: &Engine<B>) -> Vec<(&'static str, Value)> {
    let s = engine.stats();
    let q = engine.queue_stats();
    let mut fields = vec![
        ("completed", json::num(s.completed as f64)),
        ("tokens", json::num(s.tokens as f64)),
        ("decode_steps", json::num(s.decode_steps as f64)),
        ("mean_occupancy", json::num(s.mean_occupancy())),
        ("active_slots", json::num(engine.active() as f64)),
        ("queue_depth", json::num(q.depth as f64)),
        ("admitted", json::num(q.admitted as f64)),
        ("rejected", json::num(q.rejected as f64)),
        ("cancelled", json::num(s.cancelled as f64)),
        ("preemptions", json::num(s.preemptions as f64)),
        ("expired", json::num(s.expired as f64)),
        ("aging_promotions", json::num(q.aging_promotions as f64)),
        // Queue composition by *static* request class (highest first in
        // the source, sorted by the JSON object's key order on the
        // wire), so an operator can see who is waiting behind whom.
        (
            "queue_by_class",
            Value::Object(
                q.by_class
                    .iter()
                    .map(|&(class, n)| (class.to_string(), json::num(n as f64)))
                    .collect(),
            ),
        ),
    ];
    if let Some(c) = engine.residency() {
        fields.push(("cache_hits", json::num(c.hits as f64)));
        fields.push(("cache_misses", json::num(c.misses as f64)));
        fields.push(("cache_evictions", json::num(c.evictions as f64)));
        fields.push(("cache_resident_bytes", json::num(c.resident_bytes as f64)));
        fields.push((
            "cache_peak_resident_bytes",
            json::num(c.peak_resident_bytes as f64),
        ));
        fields.push(("cache_budget_bytes", json::num(c.budget_bytes as f64)));
        fields.push(("cache_pinned_layers", json::num(c.pinned_layers as f64)));
    }
    if let Some(p) = engine.prefetch() {
        fields.push(("prefetch_scheduled", json::num(p.scheduled as f64)));
        fields.push(("prefetch_completed", json::num(p.completed as f64)));
        fields.push(("prefetch_hits", json::num(p.hits as f64)));
        fields.push(("prefetch_waits", json::num(p.waits as f64)));
        fields.push(("prefetch_sync_faults", json::num(p.sync_faults as f64)));
    }
    fields
}

/// The front door's connection/shed counter family, appended to the
/// admin line so overload behavior is observable without a side
/// channel.
fn front_door_fields(c: &FrontDoorCounters) -> Vec<(&'static str, Value)> {
    let n = |a: &AtomicU64| json::num(a.load(Ordering::Relaxed) as f64);
    vec![
        ("conns_accepted", n(&c.accepted)),
        ("conns_open", n(&c.open)),
        ("conns_closed", n(&c.closed)),
        ("shed_queue_full", n(&c.shed_queue_full)),
        ("shed_incoming_full", n(&c.shed_incoming_full)),
        ("shed_output_overflow", n(&c.shed_output_overflow)),
        ("shed_shutdown", n(&c.shed_shutdown)),
        ("dead_waiters_cancelled", n(&c.dead_waiters_cancelled)),
        ("io_threads", n(&c.io_threads)),
    ]
}

/// The multi-model admin-line reply: the existing global fields
/// (summed across engines), the shared ledger's `ledger_*` fields, and
/// a `models` array carrying each model's full per-engine snapshot —
/// serving counters plus its `cache_*`/`prefetch_*` families.
pub fn format_multi_stats(multi: &MultiModelServer) -> String {
    json::obj(multi_stats_fields(multi)).to_json()
}

/// [`format_multi_stats`] plus the front door's connection/shed
/// counters — what a live multi-model server answers on the admin line.
pub fn format_multi_stats_with(multi: &MultiModelServer, front: &FrontDoorCounters) -> String {
    let mut fields = multi_stats_fields(multi);
    fields.extend(front_door_fields(front));
    json::obj(fields).to_json()
}

fn multi_stats_fields(multi: &MultiModelServer) -> Vec<(&'static str, Value)> {
    let mut completed = 0u64;
    let mut tokens = 0u64;
    let mut decode_steps = 0u64;
    let mut occupancy_sum = 0u64;
    let mut cancelled = 0u64;
    let mut preemptions = 0u64;
    let mut expired = 0u64;
    let mut active = 0usize;
    let mut depth = 0usize;
    let mut admitted = 0u64;
    let mut rejected = 0u64;
    let mut models = Vec::with_capacity(multi.n_models());
    for i in 0..multi.n_models() {
        let engine = multi.engine(i);
        let s = engine.stats();
        let q = engine.queue_stats();
        completed += s.completed;
        tokens += s.tokens;
        decode_steps += s.decode_steps;
        occupancy_sum += s.occupancy_sum;
        cancelled += s.cancelled;
        preemptions += s.preemptions;
        expired += s.expired;
        active += engine.active();
        depth += q.depth;
        admitted += q.admitted;
        rejected += q.rejected;
        let mut fields = vec![("model", json::s(multi.name(i)))];
        fields.extend(engine_stats_fields(engine));
        // Per-model QoS under the shared ledger: the configured
        // reservation/weight plus the shed traffic in both directions,
        // so an operator can see who is leaning on whom.
        let q = multi.model_counters(i);
        fields.push(("reserved_bytes", json::num(q.reserved_bytes as f64)));
        fields.push(("qos_weight", json::num(q.weight)));
        fields.push(("shed_from_peers", json::num(q.shed_from_peers as f64)));
        fields.push(("shed_by_peers", json::num(q.shed_by_peers as f64)));
        models.push(json::obj(fields));
    }
    let mean_occupancy = if decode_steps == 0 {
        0.0
    } else {
        occupancy_sum as f64 / decode_steps as f64
    };
    let ledger = multi.ledger().counters();
    let mut fields = vec![
        ("completed", json::num(completed as f64)),
        ("tokens", json::num(tokens as f64)),
        ("decode_steps", json::num(decode_steps as f64)),
        ("mean_occupancy", json::num(mean_occupancy)),
        ("active_slots", json::num(active as f64)),
        ("queue_depth", json::num(depth as f64)),
        ("admitted", json::num(admitted as f64)),
        ("rejected", json::num(rejected as f64)),
        ("cancelled", json::num(cancelled as f64)),
        ("preemptions", json::num(preemptions as f64)),
        ("expired", json::num(expired as f64)),
        ("ledger_budget_bytes", json::num(ledger.budget_bytes as f64)),
        ("ledger_used_bytes", json::num(ledger.used_bytes as f64)),
        (
            "ledger_peak_used_bytes",
            json::num(ledger.peak_used_bytes as f64),
        ),
        (
            "ledger_reserved_bytes",
            json::num(ledger.reserved_bytes as f64),
        ),
        ("models", json::arr(models)),
    ];
    if let Some((draft, target, k, st)) = multi.speculation() {
        fields.extend([
            ("spec_draft", json::s(draft)),
            ("spec_target", json::s(target)),
            ("spec_k", json::num(k as f64)),
            ("spec_steps", json::num(st.steps as f64)),
            ("spec_proposed", json::num(st.proposed as f64)),
            ("spec_accepted", json::num(st.accepted as f64)),
            ("spec_emitted", json::num(st.emitted as f64)),
            ("spec_fallback_steps", json::num(st.fallback_steps as f64)),
            ("spec_acceptance_rate", json::num(st.acceptance_rate())),
            ("spec_emitted_per_step", json::num(st.emitted_per_step())),
        ]);
    }
    fields
}

/// Classify one complete protocol line: the `{"stats": true}` admin
/// line, a generation request (with its optional `"model"` routing
/// name), or a malformed line that earns an error reply. `None` for
/// blank lines.
fn classify_line(line: &[u8], reply: &ReplyHandle) -> Option<Incoming> {
    let Ok(text) = std::str::from_utf8(line) else {
        return Some(Incoming::Bad(
            "request line is not valid utf-8".into(),
            reply.clone(),
        ));
    };
    let trimmed = text.trim();
    if trimmed.is_empty() {
        return None;
    }
    // Parse once; `{"stats": true}` and `{"reserve": {...}}` are admin
    // lines, anything else is a generation request.
    match Value::parse(trimmed) {
        Ok(ref v) if matches!(v.get_opt("stats"), Some(Value::Bool(true))) => {
            Some(Incoming::Stats(reply.clone()))
        }
        Ok(ref v) if v.get_opt("reserve").is_some() => match parse_reserve(v) {
            Ok(updates) => Some(Incoming::Reserve(updates, reply.clone())),
            Err(e) => Some(Incoming::Bad(e.to_string(), reply.clone())),
        },
        Ok(ref v) => match parse_model(v)
            .and_then(|model| parse_request_value(v, 0).map(|req| (req, model)))
        {
            Ok((req, model)) => Some(Incoming::Req(req, model, reply.clone())),
            Err(e) => Some(Incoming::Bad(e.to_string(), reply.clone())),
        },
        Err(e) => Some(Incoming::Bad(e.to_string(), reply.clone())),
    }
}

// ------------------------------------------------------- single-model

/// Serve an engine over TCP until `stop` flips, with default front-door
/// tuning ([`ServeConfig::default`]). Returns total requests served.
pub fn serve<B: Backend>(
    engine: &mut Engine<B>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
) -> Result<u64> {
    serve_with(engine, listener, stop, &ServeConfig::default())
}

/// [`serve`] with explicit front-door tuning. The engine loop runs on
/// the calling thread; I/O runs on `cfg.io_shards + 1` fixed threads.
/// When `stop` flips the server drains gracefully: the acceptor exits,
/// new lines are refused with `{"error":"shutting down"}`, in-flight
/// generations finish (bounded by `cfg.drain_timeout`, stragglers are
/// cancelled and answered explicitly), replies flush, then all I/O
/// threads are joined.
pub fn serve_with<B: Backend>(
    engine: &mut Engine<B>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    cfg: &ServeConfig,
) -> Result<u64> {
    let (tx, rx) = mpsc::sync_channel::<Incoming>(cfg.incoming_capacity.max(1));
    let front = FrontDoor::spawn(listener, tx, cfg)?;
    let counters = front.counters();

    let mut next_id: u64 = 1;
    let mut waiters: Vec<(u64, ReplyHandle)> = Vec::new();
    let mut served = 0u64;
    while !stop.load(Ordering::Relaxed) {
        let mut idle = true;
        while let Ok(msg) = rx.try_recv() {
            idle = false;
            admit_single(engine, msg, &mut next_id, &mut waiters, &counters);
        }
        sweep_dead_waiters(engine, &mut waiters, &counters);
        if engine.has_work() {
            idle = false;
            for resp in engine.step()? {
                served += 1;
                route_reply(&mut waiters, &resp);
            }
        }
        if idle {
            // Park on the channel instead of spinning; the timeout
            // bounds stop-flag latency.
            match rx.recv_timeout(Duration::from_millis(2)) {
                Ok(msg) => admit_single(engine, msg, &mut next_id, &mut waiters, &counters),
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {}
            }
        }
    }

    // Graceful drain: stop accepting, refuse new lines, finish (or at
    // the deadline, cancel + answer) in-flight work, flush, exit.
    front.drain();
    let deadline = Instant::now() + cfg.drain_timeout;
    loop {
        while let Ok(msg) = rx.try_recv() {
            refuse_during_drain(engine, msg, &counters);
        }
        sweep_dead_waiters(engine, &mut waiters, &counters);
        if !engine.has_work() || Instant::now() >= deadline {
            break;
        }
        for resp in engine.step()? {
            served += 1;
            route_reply(&mut waiters, &resp);
        }
    }
    for (id, reply) in waiters.drain(..) {
        // Past the deadline with work still in flight: cancel and tell
        // the client explicitly instead of silently dropping its reply.
        engine.cancel(id);
        reply.send(error_line("shutting down"));
    }
    let flush = deadline
        .saturating_duration_since(Instant::now())
        .max(Duration::from_millis(100));
    front.shutdown(flush);
    Ok(served)
}

fn admit_single<B: Backend>(
    engine: &mut Engine<B>,
    msg: Incoming,
    next_id: &mut u64,
    waiters: &mut Vec<(u64, ReplyHandle)>,
    counters: &FrontDoorCounters,
) {
    match msg {
        Incoming::Req(req, model, reply) => {
            if let Some(name) = model {
                // One unnamed model here: failing loudly beats
                // silently serving the wrong model to a client
                // that believes it reached a multi-model host.
                reply.send(error_line(&format!(
                    "this server hosts a single unnamed model; drop the \
                     'model' field (got {name:?})"
                )));
                return;
            }
            // Ids may be remapped upward so they stay unique across all
            // connections; the reply's id field is authoritative.
            let id = req.id.max(*next_id);
            *next_id = id + 1;
            let mut req = req;
            req.id = id;
            match engine.submit(req) {
                Ok(()) => waiters.push((id, reply)),
                Err(e) => {
                    // `submit` fails only on a full AdmissionQueue:
                    // answer with the distinguishable load-shed line.
                    counters.shed_queue_full.fetch_add(1, Ordering::Relaxed);
                    reply.send(shed_line(&e.to_string()));
                }
            }
        }
        Incoming::Stats(reply) => {
            reply.send(format_stats_with(engine, counters));
        }
        Incoming::Reserve(_, reply) => {
            reply.send(error_line(
                "this server hosts a single unnamed model; live reservation \
                 re-tuning needs the multi-model server (--model name=path)",
            ));
        }
        Incoming::Bad(err, reply) => {
            reply.send(error_line(&err));
        }
    }
}

/// Drop waiters whose client is gone and cancel their queued or active
/// generation, freeing the batch slot for live traffic — the fix for
/// the dead-waiter leak where an abandoned generation ran to completion
/// for nobody.
fn sweep_dead_waiters<B: Backend>(
    engine: &mut Engine<B>,
    waiters: &mut Vec<(u64, ReplyHandle)>,
    counters: &FrontDoorCounters,
) {
    waiters.retain(|(id, reply)| {
        if !reply.is_closed() {
            return true;
        }
        if engine.cancel(*id) {
            counters.dead_waiters_cancelled.fetch_add(1, Ordering::Relaxed);
        }
        false
    });
}

fn route_reply(waiters: &mut Vec<(u64, ReplyHandle)>, resp: &Response) {
    if let Some(i) = waiters.iter().position(|(id, _)| *id == resp.id) {
        let (_, reply) = waiters.swap_remove(i);
        reply.send(reply_line(resp));
    }
}

/// Answer channel backlog during the drain phase: requests are refused
/// (the shards refuse new ones at the door; these were already in
/// flight toward the engine), stats and errors still answer.
fn refuse_during_drain<B: Backend>(
    engine: &Engine<B>,
    msg: Incoming,
    counters: &FrontDoorCounters,
) {
    match msg {
        Incoming::Req(_, _, reply) => {
            counters.shed_shutdown.fetch_add(1, Ordering::Relaxed);
            reply.send(error_line("shutting down"));
        }
        Incoming::Stats(reply) => {
            reply.send(format_stats_with(engine, counters));
        }
        Incoming::Reserve(_, reply) => {
            reply.send(error_line("shutting down"));
        }
        Incoming::Bad(err, reply) => {
            reply.send(error_line(&err));
        }
    }
}

// -------------------------------------------------------- multi-model

/// Serve a [`MultiModelServer`] over TCP until `stop` flips — the
/// multi-model counterpart of [`serve`], on the same sharded front
/// door. Requests route by their optional `"model"` field (first
/// hosted model when omitted, error line for unknown names), every
/// model's engine steps in the same loop so a busy model never
/// starves an idle one's admissions, and `{"stats":true}` answers
/// with the aggregated + per-model snapshot ([`format_multi_stats`]).
/// Returns total requests served across all models.
pub fn serve_multi(
    multi: &mut MultiModelServer,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
) -> Result<u64> {
    serve_multi_with(multi, listener, stop, &ServeConfig::default())
}

/// [`serve_multi`] with explicit front-door tuning — same drain
/// semantics as [`serve_with`].
pub fn serve_multi_with(
    multi: &mut MultiModelServer,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    cfg: &ServeConfig,
) -> Result<u64> {
    let (tx, rx) = mpsc::sync_channel::<Incoming>(cfg.incoming_capacity.max(1));
    let front = FrontDoor::spawn(listener, tx, cfg)?;
    let counters = front.counters();

    let mut next_id: u64 = 1;
    let mut waiters: Vec<(usize, u64, ReplyHandle)> = Vec::new();
    let mut served = 0u64;
    while !stop.load(Ordering::Relaxed) {
        let mut idle = true;
        while let Ok(msg) = rx.try_recv() {
            idle = false;
            admit_multi(multi, msg, &mut next_id, &mut waiters, &counters);
        }
        sweep_dead_waiters_multi(multi, &mut waiters, &counters);
        for mi in 0..multi.n_models() {
            if !multi.engine(mi).has_work() {
                continue;
            }
            idle = false;
            for resp in multi.step_model(mi)? {
                served += 1;
                route_reply_multi(&mut waiters, mi, &resp);
            }
        }
        if idle {
            match rx.recv_timeout(Duration::from_millis(2)) {
                Ok(msg) => admit_multi(multi, msg, &mut next_id, &mut waiters, &counters),
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {}
            }
        }
    }

    front.drain();
    let deadline = Instant::now() + cfg.drain_timeout;
    loop {
        while let Ok(msg) = rx.try_recv() {
            refuse_during_drain_multi(multi, msg, &counters);
        }
        sweep_dead_waiters_multi(multi, &mut waiters, &counters);
        if !multi.has_work() || Instant::now() >= deadline {
            break;
        }
        for mi in 0..multi.n_models() {
            if !multi.engine(mi).has_work() {
                continue;
            }
            for resp in multi.step_model(mi)? {
                served += 1;
                route_reply_multi(&mut waiters, mi, &resp);
            }
        }
    }
    for (m, id, reply) in waiters.drain(..) {
        multi.cancel(m, id);
        reply.send(error_line("shutting down"));
    }
    let flush = deadline
        .saturating_duration_since(Instant::now())
        .max(Duration::from_millis(100));
    front.shutdown(flush);
    Ok(served)
}

fn admit_multi(
    multi: &mut MultiModelServer,
    msg: Incoming,
    next_id: &mut u64,
    waiters: &mut Vec<(usize, u64, ReplyHandle)>,
    counters: &FrontDoorCounters,
) {
    match msg {
        Incoming::Req(req, model, reply) => {
            let target = match multi.resolve(model.as_deref()) {
                Ok(i) => i,
                Err(e) => {
                    reply.send(error_line(&e.to_string()));
                    return;
                }
            };
            // Ids may be remapped upward so they stay unique across all
            // connections (two clients reusing id 1 would otherwise
            // steal each other's replies); the reply's id field is
            // authoritative — documented in docs/SERVING.md.
            let id = req.id.max(*next_id);
            *next_id = id + 1;
            let mut req = req;
            req.id = id;
            match multi.engine_mut(target).submit(req) {
                Ok(()) => waiters.push((target, id, reply)),
                Err(e) => {
                    counters.shed_queue_full.fetch_add(1, Ordering::Relaxed);
                    reply.send(shed_line(&e.to_string()));
                }
            }
        }
        Incoming::Stats(reply) => {
            reply.send(format_multi_stats_with(multi, counters));
        }
        Incoming::Reserve(updates, reply) => match multi.retune_reserves(&updates) {
            Ok(()) => {
                // Echo the full post-retune assignment so the operator
                // sees exactly what is now guaranteed, per model.
                let reserved: std::collections::BTreeMap<String, Value> = (0..multi.n_models())
                    .map(|i| {
                        (
                            multi.name(i).to_string(),
                            json::num(multi.model_counters(i).reserved_bytes as f64),
                        )
                    })
                    .collect();
                reply.send(
                    json::obj(vec![
                        ("ok", Value::Bool(true)),
                        ("reserved_bytes", Value::Object(reserved)),
                    ])
                    .to_json(),
                );
            }
            Err(e) => reply.send(error_line(&e.to_string())),
        },
        Incoming::Bad(err, reply) => {
            reply.send(error_line(&err));
        }
    }
}

fn sweep_dead_waiters_multi(
    multi: &mut MultiModelServer,
    waiters: &mut Vec<(usize, u64, ReplyHandle)>,
    counters: &FrontDoorCounters,
) {
    waiters.retain(|(m, id, reply)| {
        if !reply.is_closed() {
            return true;
        }
        if multi.cancel(*m, *id) {
            counters.dead_waiters_cancelled.fetch_add(1, Ordering::Relaxed);
        }
        false
    });
}

fn route_reply_multi(waiters: &mut Vec<(usize, u64, ReplyHandle)>, model: usize, resp: &Response) {
    if let Some(i) = waiters
        .iter()
        .position(|(m, id, _)| *m == model && *id == resp.id)
    {
        let (_, _, reply) = waiters.swap_remove(i);
        reply.send(reply_line(resp));
    }
}

fn refuse_during_drain_multi(
    multi: &MultiModelServer,
    msg: Incoming,
    counters: &FrontDoorCounters,
) {
    match msg {
        Incoming::Req(_, _, reply) => {
            counters.shed_shutdown.fetch_add(1, Ordering::Relaxed);
            reply.send(error_line("shutting down"));
        }
        Incoming::Stats(reply) => {
            reply.send(format_multi_stats_with(multi, counters));
        }
        Incoming::Reserve(_, reply) => {
            reply.send(error_line("shutting down"));
        }
        Incoming::Bad(err, reply) => {
            reply.send(error_line(&err));
        }
    }
}

// ------------------------------------------------------------- client

/// Blocking client for the line protocol (used by examples/benches).
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to a server.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one request line and wait for the reply line.
    pub fn request(&mut self, prompt: &str, max_tokens: usize, temperature: f32) -> Result<Value> {
        let line = json::obj(vec![
            ("prompt", json::s(prompt)),
            ("max_tokens", json::num(max_tokens as f64)),
            ("temperature", json::num(temperature as f64)),
        ])
        .to_json();
        self.roundtrip(&line)
    }

    /// [`Client::request`] with an explicit `"model"` routing name (for
    /// multi-model servers).
    pub fn request_model(
        &mut self,
        model: &str,
        prompt: &str,
        max_tokens: usize,
        temperature: f32,
    ) -> Result<Value> {
        let line = json::obj(vec![
            ("model", json::s(model)),
            ("prompt", json::s(prompt)),
            ("max_tokens", json::num(max_tokens as f64)),
            ("temperature", json::num(temperature as f64)),
        ])
        .to_json();
        self.roundtrip(&line)
    }

    /// Request the server's engine-stats snapshot.
    pub fn stats(&mut self) -> Result<Value> {
        self.roundtrip(r#"{"stats":true}"#)
    }

    fn roundtrip(&mut self, line: &str) -> Result<Value> {
        self.stream.write_all(line.as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        if reply.is_empty() {
            return Err(Error::Engine("server closed connection".into()));
        }
        Value::parse(reply.trim())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BackendCfg, EngineConfig, MockBackend};

    #[test]
    fn parse_request_accepts_minimal_and_full() {
        let r = parse_request(r#"{"prompt":"hi"}"#, 42).unwrap();
        assert_eq!(r.id, 42);
        assert_eq!(r.prompt, vec![104, 105]);
        assert_eq!(r.max_new_tokens, 32);
        let r = parse_request(
            r#"{"id":7,"prompt":"x","max_tokens":5,"temperature":0.5,"top_k":3}"#,
            0,
        )
        .unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.max_new_tokens, 5);
        assert!((r.temperature - 0.5).abs() < 1e-6);
        assert_eq!(r.top_k, 3);
        // Class fields default to normal priority, no deadline.
        assert_eq!(r.priority, 0);
        assert_eq!(r.deadline, None);
        let r = parse_request(r#"{"prompt":"x","priority":4,"deadline_ms":250}"#, 1).unwrap();
        assert_eq!(r.priority, 4);
        assert_eq!(r.deadline, Some(Duration::from_millis(250)));
    }

    /// The class fields parse with the same strictness as ids: a
    /// fractional, out-of-range, or mistyped priority/deadline is
    /// rejected, never silently clamped into someone else's class.
    #[test]
    fn parse_request_rejects_bad_class_fields() {
        for line in [
            r#"{"prompt":"x","priority":1.5}"#,
            r#"{"prompt":"x","priority":9}"#,
            r#"{"prompt":"x","priority":-9}"#,
            r#"{"prompt":"x","priority":"high"}"#,
            r#"{"prompt":"x","priority":1e20}"#,
            r#"{"prompt":"x","deadline_ms":-1}"#,
            r#"{"prompt":"x","deadline_ms":1.5}"#,
            r#"{"prompt":"x","deadline_ms":"soon"}"#,
        ] {
            let err = parse_request(line, 1).unwrap_err();
            assert!(
                err.to_string().contains("priority") || err.to_string().contains("deadline"),
                "{line}: {err}"
            );
        }
        // The extreme legal classes parse unchanged.
        let hi = parse_request(r#"{"prompt":"x","priority":8}"#, 1).unwrap();
        assert_eq!(hi.priority, PRIORITY_MAX);
        let lo = parse_request(r#"{"prompt":"x","priority":-8}"#, 1).unwrap();
        assert_eq!(lo.priority, PRIORITY_MIN);
        // deadline_ms: 0 is legal — "already due" — and distinct from
        // absent.
        let due = parse_request(r#"{"prompt":"x","deadline_ms":0}"#, 1).unwrap();
        assert_eq!(due.deadline, Some(Duration::ZERO));
    }

    #[test]
    fn parse_request_rejects_garbage() {
        assert!(parse_request("not json", 1).is_err());
        assert!(parse_request(r#"{"prompt":""}"#, 1).is_err());
        assert!(parse_request(r#"{"no_prompt":1}"#, 1).is_err());
    }

    /// Regression for the id-truncation bug: `as_f64()? as u64` turned
    /// negative ids into huge ones, fractional ids into their floor,
    /// and ≥2^53 ids into rounded collisions — all silently. Every such
    /// id must now be rejected.
    #[test]
    fn parse_request_rejects_non_integer_ids() {
        for line in [
            r#"{"id":-1,"prompt":"x"}"#,
            r#"{"id":1.25,"prompt":"x"}"#,
            r#"{"id":1e20,"prompt":"x"}"#,
            r#"{"id":9007199254740993,"prompt":"x"}"#,
            r#"{"id":"7","prompt":"x"}"#,
        ] {
            let err = parse_request(line, 1).unwrap_err();
            assert!(err.to_string().contains("id"), "{line}: {err}");
        }
        // The largest exactly-representable id is accepted unchanged.
        let r = parse_request(r#"{"id":9007199254740991,"prompt":"x"}"#, 1).unwrap();
        assert_eq!(r.id, 9_007_199_254_740_991);
    }

    #[test]
    fn format_response_roundtrips_as_json() {
        let r = Response {
            id: 3,
            tokens: vec![104, 105],
            finish_reason: crate::coordinator::request::FinishReason::Length,
            timing: Default::default(),
        };
        let v = Value::parse(&format_response(&r)).unwrap();
        assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("text").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "length");
    }

    /// The load-shed reply is ordinary JSON with an `"error"` field —
    /// old clients keep working — plus `"shed": true` so backoff logic
    /// can tell overload apart from protocol errors.
    #[test]
    fn shed_line_is_distinguishable_json() {
        let v = Value::parse(&shed_line("queue full (capacity 2)")).unwrap();
        assert!(v
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("queue full"));
        assert!(matches!(v.get_opt("shed"), Some(Value::Bool(true))));
        // Ordinary error lines carry no shed marker.
        let v = Value::parse(&error_line("nope")).unwrap();
        assert!(v.get_opt("shed").is_none());
    }

    /// The expired reply is a third distinguishable line shape: an
    /// error with `"expired": true` plus the preempted prefix, distinct
    /// from both protocol errors and load shedding.
    #[test]
    fn expired_replies_are_distinguishable_json() {
        let r = Response {
            id: 9,
            tokens: vec![104, 105],
            finish_reason: crate::coordinator::request::FinishReason::Expired,
            timing: Default::default(),
        };
        let v = Value::parse(&expired_line(&r)).unwrap();
        assert!(matches!(v.get_opt("expired"), Some(Value::Bool(true))));
        assert!(v.get("error").unwrap().as_str().unwrap().contains("deadline"), "{v:?}");
        assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 9);
        // A preempted-then-expired request's prefix rides along.
        assert_eq!(v.get("text").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.get("tokens").unwrap().as_usize().unwrap(), 2);
        // `reply_line` picks the expired shape from the finish reason;
        // the plain serializer names it too.
        assert!(Value::parse(&reply_line(&r)).unwrap().get_opt("expired").is_some());
        let v = Value::parse(&format_response(&r)).unwrap();
        assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "expired");
        // Shed lines and ordinary errors carry no expired marker.
        assert!(Value::parse(&shed_line("x")).unwrap().get_opt("expired").is_none());
        assert!(Value::parse(&error_line("x")).unwrap().get_opt("expired").is_none());
    }

    #[test]
    fn end_to_end_over_loopback_with_mock_backend() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut c = Client::connect(&addr).unwrap();
        let reply = c.request("ab", 4, 0.0).unwrap();
        assert_eq!(reply.get("tokens").unwrap().as_usize().unwrap(), 4);
        let reply2 = c.request("cd", 2, 0.0).unwrap();
        assert_eq!(reply2.get("tokens").unwrap().as_usize().unwrap(), 2);

        // Admin stats line reports the two completed requests.
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("completed").unwrap().as_usize().unwrap(), 2);
        assert_eq!(stats.get("tokens").unwrap().as_usize().unwrap(), 6);
        assert_eq!(stats.get("active_slots").unwrap().as_usize().unwrap(), 0);
        assert_eq!(stats.get("rejected").unwrap().as_usize().unwrap(), 0);
        // The front-door counter family rides along on the live line.
        assert!(stats.get("conns_accepted").unwrap().as_usize().unwrap() >= 1);
        assert_eq!(stats.get("shed_output_overflow").unwrap().as_usize().unwrap(), 0);
        assert_eq!(
            stats.get("io_threads").unwrap().as_usize().unwrap(),
            ServeConfig::default().io_shards + 1
        );

        // `"stats": false` is NOT the admin line: it falls through to
        // request parsing and earns an error (no prompt), not a snapshot.
        let not_stats = c.roundtrip(r#"{"stats":false}"#).unwrap();
        assert!(not_stats.get_opt("error").is_some(), "{not_stats:?}");

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert_eq!(served, 2);
    }

    #[test]
    fn format_stats_is_valid_json_with_counters() {
        let engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
        let v = Value::parse(&format_stats(&engine)).unwrap();
        assert_eq!(v.get("completed").unwrap().as_usize().unwrap(), 0);
        assert_eq!(v.get("queue_depth").unwrap().as_usize().unwrap(), 0);
        assert!(v.get("mean_occupancy").unwrap().as_f64().unwrap() >= 0.0);
        // Fully-resident backends have no residency cache to report.
        assert!(v.get_opt("cache_hits").is_none());
    }

    /// The acceptance loop for the weight-residency subsystem: a model
    /// whose decoded weights exceed the byte budget serves over TCP,
    /// and the `{"stats":true}` admin line carries the cache counters.
    #[test]
    fn stats_line_surfaces_residency_counters_over_loopback() {
        use crate::pipeline::synthetic_layers;
        use crate::quant::BitWidth;
        use crate::residency::{ResidentDigestBackend, ResidentWeightSet};
        use crate::store::{compress, SegmentSource};

        let layers = synthetic_layers(8, 0xFEED);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let bytes: Vec<usize> = model.layers.iter().map(|m| m.n_symbols).collect();
        let largest = *bytes.iter().max().unwrap();
        let total: usize = bytes.iter().sum();
        let budget = largest.max(total / 2);
        assert!(budget < total, "model must exceed the budget");
        let src = Arc::new(SegmentSource::from_model(Arc::new(model)));
        let ws = ResidentWeightSet::new(src, budget, Vec::new()).unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(
                ResidentDigestBackend::new(ws, 2, 32, 256),
                EngineConfig::default(),
            );
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut c = Client::connect(&addr).unwrap();
        let reply = c.request("residency", 4, 0.0).unwrap();
        // Token values are digest-driven, so generation may stop early
        // on the protocol's '.' stop token; at least one token arrives.
        assert!(reply.get("tokens").unwrap().as_usize().unwrap() >= 1);

        let stats = c.stats().unwrap();
        assert!(stats.get("cache_misses").unwrap().as_usize().unwrap() > 0);
        assert!(
            stats.get("cache_evictions").unwrap().as_usize().unwrap() > 0,
            "under-budget serving must evict"
        );
        let peak = stats
            .get("cache_peak_resident_bytes")
            .unwrap()
            .as_usize()
            .unwrap();
        assert!(peak <= budget, "peak {peak} must respect budget {budget}");
        assert_eq!(
            stats.get("cache_budget_bytes").unwrap().as_usize().unwrap(),
            budget
        );

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert_eq!(served, 1);
    }

    /// The same contract at the socket level: a client that reads its
    /// first response line, queues more requests, and disconnects
    /// *between* response lines must only cost its own connection —
    /// the server keeps serving a healthy neighbor.
    #[test]
    fn client_disconnecting_between_response_lines_leaves_server_healthy() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut healthy = Client::connect(&addr).unwrap();
        assert_eq!(
            healthy.request("ab", 2, 0.0).unwrap().get("tokens").unwrap().as_usize().unwrap(),
            2
        );

        // The flaky client: one full round trip, then two queued
        // requests whose replies it will never read.
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).ok();
            s.write_all(b"{\"prompt\":\"ab\",\"max_tokens\":2}\n").unwrap();
            let mut reader = BufReader::new(s.try_clone().unwrap());
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            assert!(reply.contains("tokens"), "{reply:?}");
            s.write_all(
                b"{\"prompt\":\"cd\",\"max_tokens\":2}\n{\"prompt\":\"ef\",\"max_tokens\":2}\n",
            )
            .unwrap();
            // Dropped here: the connection dies between response lines,
            // with replies still owed.
        }

        // The neighbor never notices: same connection, fresh
        // connection, and the admin line all still answer. (The flaky
        // client's abandoned requests may complete or be cancelled by
        // the dead-waiter sweep, depending on timing — either is
        // correct; what matters is the slots come back.)
        for prompt in ["cd", "ef", "gh"] {
            let ok = healthy.request(prompt, 2, 0.0).unwrap();
            assert_eq!(ok.get("tokens").unwrap().as_usize().unwrap(), 2);
        }
        let mut fresh = Client::connect(&addr).unwrap();
        let stats = fresh.stats().unwrap();
        let completed = stats.get("completed").unwrap().as_usize().unwrap();
        let cancelled = stats.get("cancelled").unwrap().as_usize().unwrap();
        assert!(
            completed + cancelled >= 5,
            "completed {completed} + cancelled {cancelled}"
        );
        assert!(completed >= 5, "healthy traffic must all complete");

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert!(served >= 5, "server must keep serving after the disconnect, served {served}");
    }

    /// Adversarial line-protocol suite, part 1: every malformed line on
    /// a live connection earns an error line, and the connection stays
    /// usable afterwards.
    #[test]
    fn adversarial_lines_earn_error_replies_without_killing_the_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut c = Client::connect(&addr).unwrap();
        for line in [
            "{not json",
            r#"[1,2,3]"#,
            r#"{"id":-1,"prompt":"x"}"#,
            r#"{"id":1.5,"prompt":"x"}"#,
            r#"{"id":1e20,"prompt":"x"}"#,
            r#"{"model":"m","prompt":"x"}"#, // single-model server: no routing
            r#"{"model":3,"prompt":"x"}"#,   // model must be a string
            r#"{"prompt":""}"#,
            r#"{"prompt":"x","priority":99}"#, // out-of-range class
            r#"{"prompt":"x","deadline_ms":-5}"#, // negative deadline
            r#"{"reserve":{"m":1}}"#, // retune verb: multi-model only
            r#"{"reserve":{"m":1.5}}"#, // fractional MiB
            r#"{"reserve":[1]}"#,     // reserve must be an object
        ] {
            let reply = c.roundtrip(line).unwrap();
            assert!(
                reply.get_opt("error").is_some(),
                "{line} must earn an error line, got {reply:?}"
            );
        }
        // The "model" rejection tells the client what went wrong.
        let reply = c.roundtrip(r#"{"model":"m","prompt":"x"}"#).unwrap();
        assert!(reply.get("error").unwrap().as_str().unwrap().contains("single"), "{reply:?}");
        // So does the reserve-verb rejection: this host has no named
        // models to retune.
        let reply = c.roundtrip(r#"{"reserve":{"m":1}}"#).unwrap();
        assert!(reply.get("error").unwrap().as_str().unwrap().contains("single"), "{reply:?}");

        // After all that abuse, the same connection still serves.
        let ok = c.request("ab", 2, 0.0).unwrap();
        assert_eq!(ok.get("tokens").unwrap().as_usize().unwrap(), 2);

        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    /// Adversarial suite, part 2: an oversized line is answered and the
    /// connection dropped with bounded buffering; a mid-write
    /// disconnect evaporates; neither disturbs another client.
    #[test]
    fn oversized_lines_and_midwrite_disconnects_leave_other_clients_unaffected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
            serve(&mut engine, listener, stop2).unwrap()
        });

        // A well-behaved client connects first and must stay healthy
        // throughout.
        let mut healthy = Client::connect(&addr).unwrap();
        assert_eq!(
            healthy.request("ab", 2, 0.0).unwrap().get("tokens").unwrap().as_usize().unwrap(),
            2
        );

        // Hostile client 1: one line far beyond the cap, never
        // newline-terminated. The server must reply with an error (or
        // just close) without ever buffering the whole thing.
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).ok();
            let chunk = vec![b'a'; 64 * 1024];
            let mut sent = 0usize;
            while sent <= MAX_LINE_BYTES {
                if s.write_all(&chunk).is_err() {
                    break; // server already hung up — equally fine
                }
                sent += chunk.len();
            }
            let mut reader = BufReader::new(s);
            let mut reply = String::new();
            let _ = reader.read_line(&mut reply);
            assert!(
                reply.is_empty() || reply.contains("exceeds"),
                "oversized line must be refused, got {reply:?}"
            );
            // Connection is closed: the next read sees EOF.
            let mut rest = String::new();
            let closed = matches!(reader.read_line(&mut rest), Ok(0));
            assert!(closed || rest.is_empty(), "server must drop the connection");
        }

        // Hostile client 2: writes half a JSON object, then vanishes.
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(br#"{"prompt":"interru"#).unwrap();
            // dropped here, mid-line, no newline
        }

        // The healthy client never noticed either neighbor.
        let ok = healthy.request("cd", 3, 0.0).unwrap();
        assert_eq!(ok.get("tokens").unwrap().as_usize().unwrap(), 3);
        let stats = healthy.stats().unwrap();
        assert_eq!(stats.get("completed").unwrap().as_usize().unwrap(), 2);

        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    /// Adversarial suite, part 3 (the lock-poisoning satellite at the
    /// server level): a thread that panics while holding the serving
    /// backend's shared state lock must not cascade — the server keeps
    /// answering on live and new connections.
    #[test]
    fn panicking_handler_thread_does_not_take_the_server_down() {
        use crate::pipeline::synthetic_layers;
        use crate::quant::BitWidth;
        use crate::residency::{PrefetchConfig, PrefetchingDigestBackend, PrefetchingWeightSet};
        use crate::store::{compress, SegmentSource};

        let layers = synthetic_layers(6, 0xFACE);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let total: usize = model.layers.iter().map(|m| m.n_symbols).sum();
        let largest = model.layers.iter().map(|m| m.n_symbols).max().unwrap();
        let budget = total.max(3 * largest);
        let src = Arc::new(SegmentSource::from_model(Arc::new(model)));
        let ws = PrefetchingWeightSet::new(src, budget, Vec::new(), PrefetchConfig::default())
            .unwrap();
        let shared = Arc::clone(ws.shared());

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(
                PrefetchingDigestBackend::new(ws, 2, 32, 256),
                EngineConfig::default(),
            );
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut c = Client::connect(&addr).unwrap();
        let first = c.request("first", 3, 0.0).unwrap();
        assert!(first.get("tokens").unwrap().as_usize().unwrap() >= 1);

        // A handler thread panics while holding the backend's shared
        // state lock (the cascading-poison scenario).
        let poisoner = Arc::clone(&shared);
        std::thread::spawn(move || {
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _ = poisoner.with_layer(0, |_| -> () { panic!("handler bug") });
            }));
        })
        .join()
        .unwrap();

        // Existing connection still serves…
        let reply = c.request("still alive", 3, 0.0).unwrap();
        assert!(reply.get("tokens").unwrap().as_usize().unwrap() >= 1);
        // …and so does a fresh one, stats included.
        let mut c2 = Client::connect(&addr).unwrap();
        let stats = c2.stats().unwrap();
        assert!(stats.get("completed").unwrap().as_usize().unwrap() >= 2);

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert_eq!(served, 2);
    }

    /// The tentpole acceptance over loopback: two models on one port
    /// produce token streams bit-identical to two isolated
    /// single-model engines at the same per-model budget, with routing
    /// by `"model"`, a default model, error lines for unknown names,
    /// and per-model + ledger fields in `{"stats":true}`.
    #[test]
    fn two_models_one_port_bit_identical_with_per_model_stats() {
        use crate::coordinator::{ModelSpec, MultiModelConfig};
        use crate::pipeline::synthetic_layers;
        use crate::quant::BitWidth;
        use crate::residency::{
            Policy, PrefetchConfig, PrefetchingDigestBackend, PrefetchingWeightSet,
        };
        use crate::store::{compress, SegmentSource};

        let build = |n: usize, seed: u64| {
            let (m, _) = compress(&synthetic_layers(n, seed), BitWidth::U8).unwrap();
            Arc::new(SegmentSource::from_model(Arc::new(m)))
        };
        let src_a = build(6, 0xA0);
        let src_b = build(8, 0xB0);
        let per_budget = |s: &SegmentSource| {
            let largest = s.layers().iter().map(|m| m.n_symbols).max().unwrap();
            (s.n_params() / 2).max(3 * largest)
        };
        let (budget_a, budget_b) = (per_budget(&src_a), per_budget(&src_b));
        let prompts_a = ["alpha one", "alpha two"];
        let prompts_b = ["beta one", "beta two"];

        // Isolated per-model references at the same per-model budget,
        // fed through `parse_request` so request shape (stop token,
        // defaults) is exactly what the server builds. Requests run one
        // at a time: a TCP client blocks on each reply, so the serving
        // engine sees them sequentially too. (Decode digests are
        // slot-independent — sequence state, not physical slot, drives
        // each token — so this matches pacing, not token values.)
        let isolated = |src: &Arc<SegmentSource>, budget: usize, prompts: &[&str]| {
            let ws = PrefetchingWeightSet::new(
                Arc::clone(src),
                budget,
                Vec::new(),
                PrefetchConfig {
                    decode_ahead: 2,
                    workers: 2,
                    policy: Policy::SegmentedLru,
                },
            )
            .unwrap();
            let mut engine = Engine::new(
                PrefetchingDigestBackend::new(ws, 2, 64, 256),
                EngineConfig::default(),
            );
            let mut texts = Vec::new();
            for (i, p) in prompts.iter().enumerate() {
                let line = json::obj(vec![
                    ("prompt", json::s(p)),
                    ("max_tokens", json::num(6.0)),
                ])
                .to_json();
                engine.submit(parse_request(&line, 1 + i as u64).unwrap()).unwrap();
                let rs = engine.run_to_completion(10_000).unwrap();
                assert_eq!(rs.len(), 1);
                texts.push(ByteTokenizer.decode(&rs[0].tokens));
            }
            texts
        };
        let want_a = isolated(&src_a, budget_a, &prompts_a);
        let want_b = isolated(&src_b, budget_b, &prompts_b);

        // One multi-model server, one port, same total budget. Alpha
        // carries a QoS reservation + weight — which must change
        // residency pressure only, never tokens (the bit-identical
        // assertions below hold regardless).
        let mut multi = MultiModelServer::new(
            vec![
                ModelSpec::new("alpha", src_a).with_qos(budget_a, 2.0),
                ModelSpec::new("beta", src_b),
            ],
            MultiModelConfig {
                budget_bytes: budget_a + budget_b,
                ..MultiModelConfig::default()
            },
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let served = serve_multi(&mut multi, listener, stop2).unwrap();
            (served, multi)
        });

        let mut ca = Client::connect(&addr).unwrap();
        let mut cb = Client::connect(&addr).unwrap();
        // Interleaved load across the two models on two connections.
        let mut got_a = Vec::new();
        let mut got_b = Vec::new();
        for i in 0..2 {
            let ra = ca.request_model("alpha", prompts_a[i], 6, 0.0).unwrap();
            let rb = cb.request_model("beta", prompts_b[i], 6, 0.0).unwrap();
            got_a.push(ra.get("text").unwrap().as_str().unwrap().to_string());
            got_b.push(rb.get("text").unwrap().as_str().unwrap().to_string());
        }
        assert_eq!(got_a, want_a, "alpha's stream must match its isolated engine");
        assert_eq!(got_b, want_b, "beta's stream must match its isolated engine");

        // Omitting "model" routes to the first (default) model.
        let r = ca.request(prompts_a[0], 6, 0.0).unwrap();
        assert_eq!(r.get("text").unwrap().as_str().unwrap(), want_a[0]);

        // Unknown model: error line naming the hosted set; the
        // connection stays usable.
        let bad = ca.roundtrip(r#"{"model":"gamma","prompt":"x"}"#).unwrap();
        let msg = bad.get("error").unwrap().as_str().unwrap().to_string();
        assert!(msg.contains("unknown model"), "{msg}");
        assert!(msg.contains("alpha") && msg.contains("beta"), "{msg}");
        let ok = ca.request_model("beta", prompts_b[0], 6, 0.0).unwrap();
        assert_eq!(ok.get("text").unwrap().as_str().unwrap(), want_b[0]);

        // Admin line: global aggregates + per-model counter families +
        // shared-ledger fields + the front-door family.
        let stats = ca.stats().unwrap();
        assert_eq!(stats.get("completed").unwrap().as_usize().unwrap(), 6);
        assert!(stats.get("conns_accepted").unwrap().as_usize().unwrap() >= 2);
        assert!(stats.get("io_threads").unwrap().as_usize().unwrap() >= 2);
        let models = stats.get("models").unwrap().as_array().unwrap().to_vec();
        assert_eq!(models.len(), 2);
        assert_eq!(models[0].get("model").unwrap().as_str().unwrap(), "alpha");
        assert_eq!(models[1].get("model").unwrap().as_str().unwrap(), "beta");
        for m in &models {
            assert_eq!(m.get("completed").unwrap().as_usize().unwrap(), 3);
            assert!(m.get("cache_misses").unwrap().as_usize().unwrap() > 0);
            assert!(m.get("prefetch_scheduled").unwrap().as_usize().unwrap() > 0);
            // The QoS family rides along on every model entry.
            for key in ["reserved_bytes", "qos_weight", "shed_from_peers", "shed_by_peers"] {
                assert!(m.get(key).is_ok(), "missing {key}: {m:?}");
            }
        }
        assert_eq!(
            models[0].get("reserved_bytes").unwrap().as_usize().unwrap(),
            budget_a,
            "alpha's reservation must surface in its stats entry"
        );
        assert_eq!(
            models[1].get("reserved_bytes").unwrap().as_usize().unwrap(),
            0
        );
        let budget = stats.get("ledger_budget_bytes").unwrap().as_usize().unwrap();
        assert_eq!(budget, budget_a + budget_b);
        assert_eq!(
            stats.get("ledger_reserved_bytes").unwrap().as_usize().unwrap(),
            budget_a
        );
        assert!(stats.get("ledger_used_bytes").unwrap().as_usize().unwrap() <= budget);
        assert!(
            stats.get("ledger_peak_used_bytes").unwrap().as_usize().unwrap() <= budget,
            "shared budget must hold under interleaved load"
        );

        // Live reservation retune over the admin line: dropping alpha's
        // guarantee to zero answers `{"ok":true}` with the post-retune
        // assignment, and the next stats line reflects it. (These test
        // models are far smaller than 1 MiB, so zero is the only
        // interesting in-budget value at the verb's MiB granularity.)
        let ok = ca.roundtrip(r#"{"reserve":{"alpha":0}}"#).unwrap();
        assert!(matches!(ok.get_opt("ok"), Some(Value::Bool(true))), "{ok:?}");
        let reserved = ok.get("reserved_bytes").unwrap();
        assert_eq!(reserved.get("alpha").unwrap().as_usize().unwrap(), 0);
        assert_eq!(reserved.get("beta").unwrap().as_usize().unwrap(), 0);
        let stats = ca.stats().unwrap();
        assert_eq!(
            stats.get("ledger_reserved_bytes").unwrap().as_usize().unwrap(),
            0,
            "retune must land in the shared ledger"
        );
        // Unknown names and over-budget retunes are refused with the
        // connection intact.
        let bad = ca.roundtrip(r#"{"reserve":{"gamma":1}}"#).unwrap();
        assert!(bad.get("error").unwrap().as_str().unwrap().contains("unknown model"), "{bad:?}");
        let bad = ca.roundtrip(r#"{"reserve":{"alpha":4096}}"#).unwrap();
        assert!(bad.get("error").unwrap().as_str().unwrap().contains("reservations"), "{bad:?}");
        let ok = ca.request_model("beta", prompts_b[1], 6, 0.0).unwrap();
        assert_eq!(ok.get("text").unwrap().as_str().unwrap(), want_b[1]);

        stop.store(true, Ordering::Relaxed);
        let (served, multi) = server.join().unwrap();
        assert_eq!(served, 7);
        drop(multi);
    }

    /// The decode-ahead acceptance loop: a prefetching backend serves
    /// over TCP and the `{"stats":true}` admin line carries both the
    /// `cache_*` and the `prefetch_*` counter families.
    #[test]
    fn stats_line_surfaces_prefetch_counters_over_loopback() {
        use crate::pipeline::synthetic_layers;
        use crate::quant::BitWidth;
        use crate::residency::{PrefetchConfig, PrefetchingDigestBackend, PrefetchingWeightSet};
        use crate::store::{compress, SegmentSource};

        let layers = synthetic_layers(8, 0xFEED);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let total: usize = model.layers.iter().map(|m| m.n_symbols).sum();
        let largest = model.layers.iter().map(|m| m.n_symbols).max().unwrap();
        // Whole model plus the decode-ahead floor (window 2 + active).
        let budget = total.max(3 * largest);
        let src = Arc::new(SegmentSource::from_model(Arc::new(model)));
        let ws = PrefetchingWeightSet::new(src, budget, Vec::new(), PrefetchConfig::default())
            .unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(
                PrefetchingDigestBackend::new(ws, 2, 32, 256),
                EngineConfig::default(),
            );
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut c = Client::connect(&addr).unwrap();
        let reply = c.request("decode ahead", 4, 0.0).unwrap();
        assert!(reply.get("tokens").unwrap().as_usize().unwrap() >= 1);

        let stats = c.stats().unwrap();
        // Residency family still present…
        assert!(stats.get("cache_misses").unwrap().as_usize().unwrap() > 0);
        // …and the prefetch family rides along. The walk schedules
        // ahead on every consumed layer; how many jobs the pool won
        // against the consumer is timing-dependent, so only
        // `scheduled` has a guaranteed floor.
        assert!(stats.get("prefetch_scheduled").unwrap().as_usize().unwrap() > 0);
        for key in [
            "prefetch_completed",
            "prefetch_hits",
            "prefetch_waits",
            "prefetch_sync_faults",
        ] {
            assert!(stats.get(key).is_ok(), "missing {key}: {stats:?}");
        }

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert_eq!(served, 1);
    }

    // ------------------------------------------ new front-door suite

    /// A mock backend whose decode step takes real wall-clock time, so
    /// tests can race disconnects and shutdown against generations that
    /// are reliably still in flight.
    struct SlowBackend {
        inner: MockBackend,
        delay: Duration,
    }

    impl Backend for SlowBackend {
        fn cfg(&self) -> BackendCfg {
            self.inner.cfg()
        }
        fn prefill(&mut self, prompt: &[u32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
            self.inner.prefill(prompt)
        }
        fn set_slot(&mut self, slot: usize, k1: &[f32], v1: &[f32]) -> Result<()> {
            self.inner.set_slot(slot, k1, v1)
        }
        fn decode(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Vec<f32>> {
            std::thread::sleep(self.delay);
            self.inner.decode(tokens, pos)
        }
    }

    /// Regression for the acceptor JoinHandle leak (the old
    /// `spawn_acceptor` pushed 2 thread handles per connection into a
    /// vec it only joined at shutdown): many sequential short-lived
    /// connections plus a pile of held-open idle ones must leave the
    /// process thread count O(io_shards), not O(connections).
    #[test]
    fn sequential_connections_keep_thread_count_bounded() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let cfg = ServeConfig {
            io_shards: 3,
            ..ServeConfig::default()
        };
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
            serve_with(&mut engine, listener, stop2, &cfg).unwrap()
        });

        // Warm up (front door fully spawned) before the baseline count.
        let mut warm = Client::connect(&addr).unwrap();
        warm.request("warm", 1, 0.0).unwrap();
        let t_before = process_thread_count();

        // 40 sequential short-lived connections…
        for i in 0..40 {
            let mut c = Client::connect(&addr).unwrap();
            let r = c.request(&format!("conn {i}"), 1, 0.0).unwrap();
            assert_eq!(r.get("tokens").unwrap().as_usize().unwrap(), 1);
        }
        // …plus 64 concurrently-held idle connections.
        let held: Vec<TcpStream> = (0..64)
            .map(|_| TcpStream::connect(&addr).unwrap())
            .collect();
        std::thread::sleep(Duration::from_millis(50));

        // The deterministic assertion: the server's own I/O thread
        // count off the admin line is exactly shards + acceptor.
        let stats = warm.stats().unwrap();
        assert_eq!(stats.get("io_threads").unwrap().as_usize().unwrap(), 4);
        assert!(
            stats.get("conns_accepted").unwrap().as_usize().unwrap() >= 105,
            "{stats:?}"
        );

        // Process-wide count (linux): with 104 extra connections the
        // old design held 100+ extra threads; the slack only absorbs
        // unrelated test threads in this shared process.
        if let (Some(before), Some(during)) = (t_before, process_thread_count()) {
            let delta = during.saturating_sub(before);
            assert!(
                delta <= 32,
                "thread count must be O(io_shards), not O(connections): \
                 before {before}, during {during}"
            );
        }

        drop(held);
        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert_eq!(served, 41);
    }

    /// Regression for the dead-waiter leak: a client that disconnects
    /// mid-generation must have its request cancelled and the batch
    /// slot freed — with batch=1 the healthy request below can only
    /// complete if cancellation actually released the slot.
    #[test]
    fn dead_waiter_is_cancelled_and_frees_the_batch_slot() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(
                SlowBackend {
                    inner: MockBackend::new(1, 128, 128),
                    delay: Duration::from_millis(5),
                },
                EngineConfig::default(),
            );
            serve(&mut engine, listener, stop2).unwrap()
        });

        // The victim: starts a long generation (~125 slow steps to the
        // capacity bound), then vanishes mid-flight.
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(b"{\"prompt\":\"A~\",\"max_tokens\":1000}\n").unwrap();
            std::thread::sleep(Duration::from_millis(60));
            // dropped here with the generation still running
        }

        // The sweep must cancel it (freeing the only slot). Poll the
        // admin line until the counters show it.
        let mut healthy = Client::connect(&addr).unwrap();
        let t0 = Instant::now();
        loop {
            let stats = healthy.stats().unwrap();
            if stats.get("cancelled").unwrap().as_usize().unwrap() >= 1 {
                assert!(
                    stats
                        .get("dead_waiters_cancelled")
                        .unwrap()
                        .as_usize()
                        .unwrap()
                        >= 1
                );
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "dead waiter was never cancelled: {stats:?}"
            );
            std::thread::sleep(Duration::from_millis(10));
        }

        // With batch=1, this request needs the victim's slot back.
        let ok = healthy.request("ab", 4, 0.0).unwrap();
        assert_eq!(ok.get("tokens").unwrap().as_usize().unwrap(), 4);

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert_eq!(served, 1, "only the healthy request completes");
    }

    /// Request-level deadlines over loopback: a request whose deadline
    /// passes while it waits behind a same-class blocker (equal classes
    /// never preempt) is answered with the distinguishable
    /// `{"error":…,"expired":true}` line, and the admin line's new
    /// counters record it.
    #[test]
    fn queued_deadline_requests_expire_with_distinguishable_replies() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(
                SlowBackend {
                    inner: MockBackend::new(1, 256, 128),
                    delay: Duration::from_millis(5),
                },
                EngineConfig::default(),
            );
            serve(&mut engine, listener, stop2).unwrap()
        });

        // The blocker holds the only slot. Prompt "." sums to 46, so
        // the mock's first token is 47 and the +1-per-step chain takes
        // 128 steps to wrap back to the protocol stop token '.' (46) —
        // all 60 tokens run, ~300 ms of wall clock.
        let addr2 = addr.clone();
        let blocker = std::thread::spawn(move || {
            let mut c = Client::connect(&addr2).unwrap();
            c.request(".", 60, 0.0).unwrap()
        });
        std::thread::sleep(Duration::from_millis(40));

        // Deadline far below the blocker's remaining runtime: the
        // request expires in the queue and never runs.
        let mut c = Client::connect(&addr).unwrap();
        let reply = c
            .roundtrip(r#"{"prompt":"urgent","max_tokens":4,"deadline_ms":1}"#)
            .unwrap();
        assert!(matches!(reply.get_opt("expired"), Some(Value::Bool(true))), "{reply:?}");
        assert!(reply.get("error").unwrap().as_str().unwrap().contains("deadline"), "{reply:?}");

        let stats = c.stats().unwrap();
        assert!(stats.get("expired").unwrap().as_usize().unwrap() >= 1);
        // The new QoS counter family rides along on the admin line.
        for key in ["preemptions", "aging_promotions"] {
            assert!(stats.get(key).is_ok(), "missing {key}: {stats:?}");
        }
        assert!(stats.get_opt("queue_by_class").is_some(), "{stats:?}");

        let b = blocker.join().unwrap();
        assert_eq!(b.get("tokens").unwrap().as_usize().unwrap(), 60);
        stop.store(true, Ordering::Relaxed);
        // Both reply lines (completion + expiry) count as served.
        let served = server.join().unwrap();
        assert_eq!(served, 2);
    }

    /// Regression for shutdown dropping in-flight work: a request
    /// mid-generation when `stop` flips must still be answered — the
    /// drain finishes the generation and flushes the reply.
    #[test]
    fn graceful_drain_answers_in_flight_requests() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(
                SlowBackend {
                    inner: MockBackend::new(2, 64, 128),
                    delay: Duration::from_millis(10),
                },
                EngineConfig::default(),
            );
            serve(&mut engine, listener, stop2).unwrap()
        });

        // ~8 tokens × 10 ms/step: still generating when stop flips.
        let addr2 = addr.clone();
        let client = std::thread::spawn(move || {
            let mut c = Client::connect(&addr2).unwrap();
            c.request("ab", 8, 0.0).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        stop.store(true, Ordering::Relaxed);

        let served = server.join().unwrap();
        assert_eq!(served, 1, "the in-flight request must be served, not dropped");
        let reply = client.join().unwrap();
        assert_eq!(
            reply.get("tokens").unwrap().as_usize().unwrap(),
            8,
            "{reply:?}"
        );
    }

    /// The drain deadline's other edge: with a zero drain budget the
    /// in-flight request cannot finish, so it must be cancelled and
    /// answered with an explicit `{"error":"shutting down"}` — never
    /// silently dropped.
    #[test]
    fn zero_drain_budget_answers_in_flight_requests_with_explicit_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let cfg = ServeConfig {
            drain_timeout: Duration::ZERO,
            ..ServeConfig::default()
        };
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(
                SlowBackend {
                    inner: MockBackend::new(2, 128, 128),
                    delay: Duration::from_millis(10),
                },
                EngineConfig::default(),
            );
            serve_with(&mut engine, listener, stop2, &cfg).unwrap()
        });

        let addr2 = addr.clone();
        let client = std::thread::spawn(move || {
            let mut c = Client::connect(&addr2).unwrap();
            c.request("ab", 50, 0.0).unwrap()
        });
        std::thread::sleep(Duration::from_millis(40));
        stop.store(true, Ordering::Relaxed);

        let served = server.join().unwrap();
        assert_eq!(served, 0);
        let reply = client.join().unwrap();
        assert!(
            reply
                .get("error")
                .unwrap()
                .as_str()
                .unwrap()
                .contains("shutting down"),
            "{reply:?}"
        );
    }

    /// Slow-loris satellite: a client trickling a request one byte at a
    /// time must cost only its own connection. With a single I/O shard,
    /// a healthy neighbor's round trips complete while the trickler is
    /// still mid-line — impossible if the trickler blocked the shard.
    #[test]
    fn slow_loris_trickler_does_not_block_its_shard() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let cfg = ServeConfig {
            io_shards: 1,
            ..ServeConfig::default()
        };
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
            serve_with(&mut engine, listener, stop2, &cfg).unwrap()
        });

        // Trickler: 31 bytes at 25 ms/byte ≈ 775 ms before its request
        // even assembles; then it expects a real reply.
        let addr2 = addr.clone();
        let trickler = std::thread::spawn(move || {
            let mut s = TcpStream::connect(&addr2).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).ok();
            let line = b"{\"prompt\":\"ab\",\"max_tokens\":2}\n";
            for &b in line.iter() {
                s.write_all(&[b]).unwrap();
                std::thread::sleep(Duration::from_millis(25));
            }
            let mut reader = BufReader::new(s);
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            reply
        });

        // Healthy neighbor on the SAME (only) shard: five round trips
        // must finish well before the trickler finishes writing.
        let mut healthy = Client::connect(&addr).unwrap();
        let t0 = Instant::now();
        for _ in 0..5 {
            let ok = healthy.request("cd", 2, 0.0).unwrap();
            assert_eq!(ok.get("tokens").unwrap().as_usize().unwrap(), 2);
        }
        assert!(
            t0.elapsed() < Duration::from_millis(700),
            "healthy round trips stalled behind the trickler: {:?}",
            t0.elapsed()
        );

        // The trickled request is served once it finally assembles.
        let reply = trickler.join().unwrap();
        assert!(reply.contains("tokens"), "{reply:?}");

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert_eq!(served, 6);
    }

    /// Non-reading-client satellite: a client that floods the admin
    /// line and never reads replies must hit its per-connection output
    /// cap and be shed (`shed_output_overflow`), with bounded server
    /// memory — while a healthy neighbor keeps serving.
    #[test]
    fn non_reading_client_is_shed_at_its_output_cap() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let cfg = ServeConfig {
            io_shards: 2,
            max_conn_buffered_bytes: 8 * 1024,
            ..ServeConfig::default()
        };
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
            serve_with(&mut engine, listener, stop2, &cfg).unwrap()
        });

        // Flood: tens of thousands of stats lines, never reading a
        // byte back. Replies (~400 B each) overrun the kernel socket
        // buffers, then the 8 KiB queue cap — at which point the
        // server sheds the connection and later writes fail.
        let addr2 = addr.clone();
        let flood = std::thread::spawn(move || {
            let mut s = TcpStream::connect(&addr2).unwrap();
            let line = b"{\"stats\":true}\n";
            'outer: for _ in 0..150 {
                for _ in 0..200 {
                    if s.write_all(line).is_err() {
                        break 'outer; // shed: server closed on us
                    }
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        });

        let mut healthy = Client::connect(&addr).unwrap();
        let t0 = Instant::now();
        loop {
            let stats = healthy.stats().unwrap();
            if stats
                .get("shed_output_overflow")
                .unwrap()
                .as_usize()
                .unwrap()
                >= 1
            {
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(15),
                "non-reading client was never shed: {stats:?}"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        flood.join().unwrap();

        // The neighbor was never disturbed.
        let ok = healthy.request("ab", 2, 0.0).unwrap();
        assert_eq!(ok.get("tokens").unwrap().as_usize().unwrap(), 2);

        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }
}
