//! The sharded event-loop front door: a small *fixed* number of I/O
//! threads owning every connection, replacing the old
//! thread-per-connection + thread-per-writer design.
//!
//! Thread budget is `io_shards + 1` (the shard loops plus one
//! acceptor) regardless of how many clients connect — O(shards), not
//! O(connections). Each shard owns a slab of nonblocking connections
//! and multiplexes them through [`super::poll`]: read bytes, split
//! complete protocol lines, hand them to the engine loop through the
//! bounded `Incoming` channel, and flush reply bytes back out.
//!
//! Backpressure is explicit at every seam:
//!
//! * **Per-connection output queues are byte-capped** ([`ConnOutput`]).
//!   A client that stops reading gets a `shed_output_overflow` count
//!   and its connection closed, instead of ballooning server memory.
//! * **The `Incoming` channel is bounded.** When the engine loop falls
//!   behind, the shard answers with a distinguishable load-shed error
//!   line (`{"error":…,"shed":true}`) rather than queueing without
//!   limit (`shed_incoming_full`).
//! * **Shutdown drains instead of dropping**: the acceptor stops, new
//!   lines are refused with `{"error":"shutting down"}`, in-flight
//!   generations finish (or are answered at the drain deadline), and
//!   pending reply bytes are flushed before the shards exit.

use super::poll;
use super::{classify_line, error_line, shed_line, Incoming, MAX_LINE_BYTES};
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{SyncSender, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Front-door tuning knobs, surfaced on the CLI as `--io-shards`,
/// `--max-conn-buffered-kb`, and `--drain-timeout-ms`.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Number of I/O shard threads (clamped to 1..=64). Total I/O
    /// thread count is `io_shards + 1` (one acceptor).
    pub io_shards: usize,
    /// Byte cap on one connection's queued reply lines. A connection
    /// whose queue would exceed it is shed and closed.
    pub max_conn_buffered_bytes: usize,
    /// How long graceful shutdown may spend finishing in-flight
    /// generations and flushing replies before forcing the exit.
    pub drain_timeout: Duration,
    /// Capacity of the bounded shard→engine `Incoming` channel.
    pub incoming_capacity: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            io_shards: 2,
            max_conn_buffered_bytes: 256 * 1024,
            drain_timeout: Duration::from_secs(5),
            incoming_capacity: 1024,
        }
    }
}

/// Result of pushing one reply line toward a connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SendOutcome {
    /// Queued for delivery.
    Sent,
    /// The connection's output cap would be exceeded; the line was
    /// dropped and the connection is being closed.
    Shed,
    /// The connection is already closed (client gone).
    Closed,
}

/// Monotonic front-door counters (plus the `open` gauge), surfaced in
/// the `{"stats":true}` admin line so overload behavior is observable
/// without a side channel.
#[derive(Debug, Default)]
pub struct FrontDoorCounters {
    /// Connections accepted since startup.
    pub accepted: AtomicU64,
    /// Currently open connections (gauge).
    pub open: AtomicU64,
    /// Connections closed (any reason).
    pub closed: AtomicU64,
    /// Submissions refused because the `AdmissionQueue` was full.
    pub shed_queue_full: AtomicU64,
    /// Lines refused because the shard→engine channel was full.
    pub shed_incoming_full: AtomicU64,
    /// Connections shed because their reply queue hit its byte cap.
    pub shed_output_overflow: AtomicU64,
    /// Lines refused because the server was draining for shutdown.
    pub shed_shutdown: AtomicU64,
    /// In-flight requests cancelled because their client disconnected.
    pub dead_waiters_cancelled: AtomicU64,
    /// Fixed I/O thread count (`io_shards + 1`), so a bench/test can
    /// assert O(shards) threading straight off the admin line.
    pub io_threads: AtomicU64,
}

/// Wakes a shard blocked in [`poll::wait`]. A loopback socketpair
/// stands in for a pipe so the mechanism is portable; the write side is
/// nonblocking and a full kernel buffer just means a wake is already
/// pending.
#[derive(Clone)]
pub(crate) struct Wake(Arc<TcpStream>);

impl Wake {
    pub(crate) fn wake(&self) {
        // An error (e.g. WouldBlock on a full buffer) means a wake is
        // already pending, which is all this byte signals anyway.
        let _ = (&*self.0).write_all(&[1]);
    }
}

/// Build the (wake-sender, wake-receiver) loopback pair for one shard.
fn wake_pair() -> std::io::Result<(Wake, TcpStream)> {
    let l = TcpListener::bind("127.0.0.1:0")?;
    let addr = l.local_addr()?;
    let tx = TcpStream::connect(addr)?;
    let (rx, _) = l.accept()?;
    tx.set_nonblocking(true)?;
    let _ = tx.set_nodelay(true);
    // On unix the receive side is polled nonblocking; the portable
    // fallback instead reads it with a timeout, so it stays blocking.
    #[cfg(unix)]
    rx.set_nonblocking(true)?;
    Ok((Wake(Arc::new(tx)), rx))
}

struct OutInner {
    queue: VecDeque<String>,
    queued_bytes: usize,
    closed: bool,
    overflowed: bool,
}

/// One connection's bounded reply queue, shared between the engine
/// loop (producer, via [`ReplyHandle`]) and the owning shard
/// (consumer). The byte cap counts each line plus its newline.
pub(crate) struct ConnOutput {
    cap: usize,
    wake: Option<Wake>,
    counters: Option<Arc<FrontDoorCounters>>,
    inner: Mutex<OutInner>,
}

impl ConnOutput {
    fn new(cap: usize, wake: Option<Wake>, counters: Option<Arc<FrontDoorCounters>>) -> Self {
        ConnOutput {
            cap: cap.max(1),
            wake,
            counters,
            inner: Mutex::new(OutInner {
                queue: VecDeque::new(),
                queued_bytes: 0,
                closed: false,
                overflowed: false,
            }),
        }
    }

    /// Poison-tolerant lock: a panicking producer must not take every
    /// later reply down with it (same recovery idiom as `store`).
    fn lock(&self) -> MutexGuard<'_, OutInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn push(&self, line: String) -> SendOutcome {
        let mut g = self.lock();
        if g.closed || g.overflowed {
            return SendOutcome::Closed;
        }
        let add = line.len() + 1;
        if g.queued_bytes + add > self.cap {
            // Cap breached: mark the connection shed. The shard closes
            // it on its next tick — the client was not reading anyway,
            // so pending lines are forfeit by construction.
            g.overflowed = true;
            drop(g);
            if let Some(c) = &self.counters {
                c.shed_output_overflow.fetch_add(1, Ordering::Relaxed);
            }
            if let Some(w) = &self.wake {
                w.wake();
            }
            return SendOutcome::Shed;
        }
        g.queued_bytes += add;
        g.queue.push_back(line);
        drop(g);
        if let Some(w) = &self.wake {
            w.wake();
        }
        SendOutcome::Sent
    }

    /// Move queued lines (newline-terminated) into `buf`, up to `max`
    /// buffered bytes.
    fn drain_into(&self, buf: &mut Vec<u8>, max: usize) {
        let mut g = self.lock();
        while buf.len() < max {
            let Some(line) = g.queue.pop_front() else { break };
            g.queued_bytes -= line.len() + 1;
            buf.extend_from_slice(line.as_bytes());
            buf.push(b'\n');
        }
    }

    fn has_pending(&self) -> bool {
        !self.lock().queue.is_empty()
    }

    fn close(&self) {
        self.lock().closed = true;
    }

    fn overflowed(&self) -> bool {
        self.lock().overflowed
    }

    fn is_dead(&self) -> bool {
        let g = self.lock();
        g.closed || g.overflowed
    }
}

/// The engine loop's handle to one connection's reply queue — the
/// replacement for the old unbounded `mpsc::Sender<String>` per
/// waiter. Cloneable; every clone writes into the same capped queue.
#[derive(Clone)]
pub struct ReplyHandle(Arc<ConnOutput>);

impl ReplyHandle {
    pub(crate) fn from_output(out: Arc<ConnOutput>) -> Self {
        ReplyHandle(out)
    }

    /// A handle with no socket behind it, for unit tests: lines queue
    /// up to `cap` bytes and can be inspected with
    /// [`ReplyHandle::drain_lines`].
    pub fn detached(cap: usize) -> Self {
        ReplyHandle(Arc::new(ConnOutput::new(cap, None, None)))
    }

    /// Queue one reply line (without trailing newline).
    pub fn send(&self, line: String) -> SendOutcome {
        self.0.push(line)
    }

    /// True once the connection is gone (closed or shed) — the signal
    /// the engine loop's dead-waiter sweep keys off.
    pub fn is_closed(&self) -> bool {
        self.0.is_dead()
    }

    /// Pop every queued line (tests; a live shard drains bytes
    /// instead).
    pub fn drain_lines(&self) -> Vec<String> {
        let mut g = self.0.lock();
        let lines: Vec<String> = g.queue.drain(..).collect();
        g.queued_bytes = 0;
        lines
    }
}

const PHASE_RUNNING: u8 = 0;
const PHASE_DRAINING: u8 = 1;
const PHASE_HALT: u8 = 2;

/// State shared between the acceptor and one shard.
struct ShardShared {
    /// Connections accepted but not yet adopted by the shard loop.
    new_conns: Mutex<Vec<TcpStream>>,
    wake: Wake,
    /// Set at shutdown: how long the shard may keep flushing pending
    /// reply bytes before closing everything.
    flush_deadline: Mutex<Option<Instant>>,
}

impl ShardShared {
    fn add(&self, stream: TcpStream) {
        self.new_conns
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(stream);
        self.wake.wake();
    }

    fn take_new(&self) -> Vec<TcpStream> {
        std::mem::take(&mut *self.new_conns.lock().unwrap_or_else(|e| e.into_inner()))
    }

    fn flush_deadline(&self) -> Option<Instant> {
        *self.flush_deadline.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn set_flush_deadline(&self, d: Instant) {
        *self.flush_deadline.lock().unwrap_or_else(|e| e.into_inner()) = Some(d);
    }
}

/// One connection in a shard's slab.
struct Conn {
    stream: TcpStream,
    /// Partial-line read buffer (bounded by [`MAX_LINE_BYTES`]).
    rbuf: Vec<u8>,
    out: Arc<ConnOutput>,
    /// Bytes drained from `out` but not yet written to the socket.
    wbuf: Vec<u8>,
    wpos: usize,
    /// Reading has stopped (EOF, protocol violation, or drain); the
    /// connection closes once its pending output flushes.
    closing: bool,
}

impl Conn {
    fn wants_write(&self) -> bool {
        self.wpos < self.wbuf.len() || self.out.has_pending()
    }
}

/// Per-tick read budget per connection: one greedy client cannot
/// monopolize its shard's loop.
const READ_BUDGET: usize = 64 * 1024;
/// Per-refill cap on a connection's write staging buffer.
const WRITE_CHUNK: usize = 64 * 1024;
/// Idle poll tick (shutdown/adoption latency bound on unix; the
/// non-unix fallback clamps it lower internally).
const POLL_TICK: Duration = Duration::from_millis(25);

enum ReadOutcome {
    Open,
    Eof,
    Err,
}

fn read_some(conn: &mut Conn) -> ReadOutcome {
    let mut buf = [0u8; 8192];
    let mut taken = 0usize;
    loop {
        match (&conn.stream).read(&mut buf) {
            Ok(0) => return ReadOutcome::Eof,
            Ok(n) => {
                conn.rbuf.extend_from_slice(&buf[..n]);
                taken += n;
                if taken >= READ_BUDGET || conn.rbuf.len() > MAX_LINE_BYTES {
                    return ReadOutcome::Open;
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => return ReadOutcome::Open,
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return ReadOutcome::Err,
        }
    }
}

/// Split complete lines out of `conn.rbuf` and dispatch each. Marks
/// the connection closing on an oversized line (the reply is queued
/// first, matching the old reader's contract).
fn consume_lines(
    conn: &mut Conn,
    tx: &SyncSender<Incoming>,
    phase: &AtomicU8,
    counters: &FrontDoorCounters,
) {
    loop {
        let Some(pos) = conn.rbuf.iter().position(|&b| b == b'\n') else {
            break;
        };
        if pos > MAX_LINE_BYTES {
            oversize(conn);
            return;
        }
        let line: Vec<u8> = conn.rbuf.drain(..=pos).take(pos).collect();
        handle_line(conn, &line, tx, phase, counters);
        if conn.closing {
            return;
        }
    }
    if conn.rbuf.len() > MAX_LINE_BYTES {
        oversize(conn);
    }
}

fn oversize(conn: &mut Conn) {
    conn.out.push(error_line(&format!(
        "request line exceeds {MAX_LINE_BYTES} bytes; closing connection"
    )));
    conn.rbuf.clear();
    conn.closing = true;
}

fn handle_line(
    conn: &mut Conn,
    line: &[u8],
    tx: &SyncSender<Incoming>,
    phase: &AtomicU8,
    counters: &FrontDoorCounters,
) {
    let reply = ReplyHandle::from_output(Arc::clone(&conn.out));
    if phase.load(Ordering::Acquire) != PHASE_RUNNING {
        // Draining: only non-blank lines earn the refusal.
        if !line.iter().all(|b| b.is_ascii_whitespace()) {
            counters.shed_shutdown.fetch_add(1, Ordering::Relaxed);
            reply.send(error_line("shutting down"));
        }
        return;
    }
    let Some(msg) = classify_line(line, &reply) else {
        return;
    };
    match tx.try_send(msg) {
        Ok(()) => {}
        Err(TrySendError::Full(_)) => {
            // The engine loop is saturated: shed at the door with a
            // distinguishable error so clients can back off, instead
            // of queueing without bound.
            counters.shed_incoming_full.fetch_add(1, Ordering::Relaxed);
            reply.send(shed_line("server overloaded: incoming queue full"));
        }
        Err(TrySendError::Disconnected(_)) => {
            reply.send(error_line("shutting down"));
        }
    }
}

/// Flush pending reply bytes. Returns `false` when the connection must
/// close now (overflowed cap, write failure, or `closing` with nothing
/// left to flush).
fn flush_some(conn: &mut Conn) -> bool {
    if conn.out.overflowed() {
        return false;
    }
    loop {
        if conn.wpos == conn.wbuf.len() {
            conn.wbuf.clear();
            conn.wpos = 0;
            conn.out.drain_into(&mut conn.wbuf, WRITE_CHUNK);
            if conn.wbuf.is_empty() {
                break;
            }
        }
        match (&conn.stream).write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => return false,
            Ok(n) => conn.wpos += n,
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => return true,
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
    !(conn.closing && !conn.out.has_pending())
}

fn close_slot(slot: &mut Option<Conn>, counters: &FrontDoorCounters) {
    if let Some(conn) = slot.take() {
        conn.out.close();
        counters.open.fetch_sub(1, Ordering::Relaxed);
        counters.closed.fetch_add(1, Ordering::Relaxed);
    }
}

fn shard_loop(
    shared: Arc<ShardShared>,
    wake_rx: TcpStream,
    tx: SyncSender<Incoming>,
    phase: Arc<AtomicU8>,
    counters: Arc<FrontDoorCounters>,
    max_buffered: usize,
) {
    let mut conns: Vec<Option<Conn>> = Vec::new();
    loop {
        // Adopt connections handed over by the acceptor.
        for stream in shared.take_new() {
            if phase.load(Ordering::Acquire) != PHASE_RUNNING
                || stream.set_nonblocking(true).is_err()
            {
                counters.open.fetch_sub(1, Ordering::Relaxed);
                counters.closed.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let _ = stream.set_nodelay(true);
            let out = Arc::new(ConnOutput::new(
                max_buffered,
                Some(shared.wake.clone()),
                Some(Arc::clone(&counters)),
            ));
            let conn = Conn {
                stream,
                rbuf: Vec::new(),
                out,
                wbuf: Vec::new(),
                wpos: 0,
                closing: false,
            };
            match conns.iter_mut().find(|c| c.is_none()) {
                Some(slot) => *slot = Some(conn),
                None => conns.push(Some(conn)),
            }
        }

        if phase.load(Ordering::Acquire) == PHASE_HALT {
            halt_flush(&mut conns, &shared, &counters);
            return;
        }

        // Wait for readiness. The immutable stream borrows live only
        // inside this block, so the mutation below is borrow-clean.
        let (ready, idxs) = {
            let mut socks: Vec<(&TcpStream, bool)> = Vec::new();
            let mut idxs: Vec<usize> = Vec::new();
            for (i, c) in conns.iter().enumerate() {
                if let Some(c) = c {
                    socks.push((&c.stream, c.wants_write()));
                    idxs.push(i);
                }
            }
            (poll::wait(&wake_rx, &socks, POLL_TICK), idxs)
        };

        for (k, &i) in idxs.iter().enumerate() {
            let Some(conn) = conns[i].as_mut() else { continue };
            if ready[k].readable && !conn.closing {
                match read_some(conn) {
                    ReadOutcome::Open => {}
                    // EOF/error: stop reading; any queued replies still
                    // flush before the close below.
                    ReadOutcome::Eof | ReadOutcome::Err => conn.closing = true,
                }
                consume_lines(conn, &tx, &phase, &counters);
            }
            // Always attempt the flush: a reply pushed after the poll
            // call would otherwise wait a full tick.
            if !flush_some(conn) {
                close_slot(&mut conns[i], &counters);
            }
        }
    }
}

/// Final flush pass at shutdown: keep writing pending reply bytes
/// until everything drains or the deadline passes, then close all.
fn halt_flush(
    conns: &mut [Option<Conn>],
    shared: &ShardShared,
    counters: &FrontDoorCounters,
) {
    // Late arrivals the acceptor queued before it stopped.
    for _ in shared.take_new() {
        counters.open.fetch_sub(1, Ordering::Relaxed);
        counters.closed.fetch_add(1, Ordering::Relaxed);
    }
    let deadline = shared.flush_deadline().unwrap_or_else(Instant::now);
    loop {
        let mut pending = false;
        for slot in conns.iter_mut() {
            let Some(conn) = slot.as_mut() else { continue };
            conn.closing = true;
            if !flush_some(conn) {
                close_slot(slot, counters);
            } else if slot.is_some() {
                pending = true;
            }
        }
        if !pending || Instant::now() >= deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    for slot in conns.iter_mut() {
        close_slot(slot, counters);
    }
}

fn acceptor_loop(
    listener: TcpListener,
    shards: Vec<Arc<ShardShared>>,
    phase: Arc<AtomicU8>,
    counters: Arc<FrontDoorCounters>,
) {
    let mut next = 0usize;
    while phase.load(Ordering::Acquire) == PHASE_RUNNING {
        match listener.accept() {
            Ok((stream, _)) => {
                counters.accepted.fetch_add(1, Ordering::Relaxed);
                counters.open.fetch_add(1, Ordering::Relaxed);
                shards[next].add(stream);
                next = (next + 1) % shards.len();
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                // Transient accept failure (e.g. fd exhaustion under a
                // connection storm): back off briefly instead of dying.
                // The phase flag — not an error — ends this loop.
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// Handle to the running front door: the shard threads, the acceptor,
/// and the shared phase/counters. `serve`/`serve_multi` drive it
/// through [`FrontDoor::drain`] and [`FrontDoor::shutdown`].
pub(crate) struct FrontDoor {
    counters: Arc<FrontDoorCounters>,
    phase: Arc<AtomicU8>,
    shards: Vec<Arc<ShardShared>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl FrontDoor {
    /// Spawn `cfg.io_shards` shard loops plus the acceptor over a
    /// nonblocking listener.
    pub(crate) fn spawn(
        listener: TcpListener,
        tx: SyncSender<Incoming>,
        cfg: &ServeConfig,
    ) -> std::io::Result<FrontDoor> {
        listener.set_nonblocking(true)?;
        let counters = Arc::new(FrontDoorCounters::default());
        let phase = Arc::new(AtomicU8::new(PHASE_RUNNING));
        let n = cfg.io_shards.clamp(1, 64);
        let mut shards = Vec::with_capacity(n);
        let mut threads = Vec::with_capacity(n + 1);
        for i in 0..n {
            let (wake, wake_rx) = wake_pair()?;
            let shared = Arc::new(ShardShared {
                new_conns: Mutex::new(Vec::new()),
                wake,
                flush_deadline: Mutex::new(None),
            });
            let (sh, tx, ph, ct) = (
                Arc::clone(&shared),
                tx.clone(),
                Arc::clone(&phase),
                Arc::clone(&counters),
            );
            let cap = cfg.max_conn_buffered_bytes;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("elm-io-{i}"))
                    .spawn(move || shard_loop(sh, wake_rx, tx, ph, ct, cap))?,
            );
            shards.push(shared);
        }
        {
            let (sh, ph, ct) = (shards.clone(), Arc::clone(&phase), Arc::clone(&counters));
            threads.push(
                std::thread::Builder::new()
                    .name("elm-accept".into())
                    .spawn(move || acceptor_loop(listener, sh, ph, ct))?,
            );
        }
        counters.io_threads.store(n as u64 + 1, Ordering::Relaxed);
        Ok(FrontDoor {
            counters,
            phase,
            shards,
            threads,
        })
    }

    pub(crate) fn counters(&self) -> Arc<FrontDoorCounters> {
        Arc::clone(&self.counters)
    }

    /// Enter the draining phase: the acceptor exits and shards answer
    /// new lines with `{"error":"shutting down"}`. Existing replies
    /// keep flowing.
    pub(crate) fn drain(&self) {
        self.phase.store(PHASE_DRAINING, Ordering::Release);
        for s in &self.shards {
            s.wake.wake();
        }
    }

    /// Flush pending replies for up to `flush_timeout`, close every
    /// connection, and join all I/O threads.
    pub(crate) fn shutdown(self, flush_timeout: Duration) {
        let deadline = Instant::now() + flush_timeout;
        for s in &self.shards {
            s.set_flush_deadline(deadline);
        }
        self.phase.store(PHASE_HALT, Ordering::Release);
        for s in &self.shards {
            s.wake.wake();
        }
        for t in self.threads {
            let _ = t.join();
        }
    }
}

/// Best-effort OS thread count of this process (Linux: the `Threads:`
/// line of `/proc/self/status`; `None` elsewhere). The storm bench and
/// the thread-ceiling gate use it to prove O(shards) threading.
pub fn process_thread_count() -> Option<usize> {
    #[cfg(target_os = "linux")]
    {
        let status = std::fs::read_to_string("/proc/self/status").ok()?;
        status
            .lines()
            .find_map(|l| l.strip_prefix("Threads:"))
            .and_then(|rest| rest.trim().parse().ok())
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conn_output_caps_queued_bytes_and_sheds() {
        let reply = ReplyHandle::detached(32);
        assert_eq!(reply.send("0123456789".into()), SendOutcome::Sent); // 11 bytes
        assert_eq!(reply.send("0123456789".into()), SendOutcome::Sent); // 22 bytes
        // 33 bytes would exceed the 32-byte cap: shed, and the handle
        // reports closed from then on.
        assert_eq!(reply.send("0123456789".into()), SendOutcome::Shed);
        assert!(reply.is_closed());
        assert_eq!(reply.send("x".into()), SendOutcome::Closed);
    }

    #[test]
    fn conn_output_reports_closed_after_close() {
        let out = Arc::new(ConnOutput::new(1024, None, None));
        let reply = ReplyHandle::from_output(Arc::clone(&out));
        assert_eq!(reply.send("a".into()), SendOutcome::Sent);
        assert!(!reply.is_closed());
        out.close();
        assert!(reply.is_closed());
        assert_eq!(reply.send("b".into()), SendOutcome::Closed);
    }

    #[test]
    fn conn_output_drain_frees_cap_space() {
        let out = Arc::new(ConnOutput::new(16, None, None));
        let reply = ReplyHandle::from_output(Arc::clone(&out));
        assert_eq!(reply.send("0123456789".into()), SendOutcome::Sent);
        let mut buf = Vec::new();
        out.drain_into(&mut buf, 1024);
        assert_eq!(buf, b"0123456789\n");
        // The drained bytes no longer count against the cap.
        assert_eq!(reply.send("0123456789".into()), SendOutcome::Sent);
    }

    #[test]
    fn overflow_counts_once_on_the_shared_counters() {
        let counters = Arc::new(FrontDoorCounters::default());
        let out = Arc::new(ConnOutput::new(4, None, Some(Arc::clone(&counters))));
        let reply = ReplyHandle::from_output(out);
        assert_eq!(reply.send("way too long".into()), SendOutcome::Shed);
        assert_eq!(reply.send("again".into()), SendOutcome::Closed);
        assert_eq!(counters.shed_output_overflow.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn serve_config_default_is_sane() {
        let cfg = ServeConfig::default();
        assert!(cfg.io_shards >= 1);
        assert!(cfg.max_conn_buffered_bytes >= 1024);
        assert!(cfg.incoming_capacity >= 1);
    }
}
