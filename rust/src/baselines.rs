//! Comparators for the paper's evaluation:
//!
//! * [`fixed_pack`] / [`fixed_unpack`] — plain fixed-width bit packing
//!   (the "w/o Huffman" arm of Table II and the uint8/uint4 columns of
//!   Table I before entropy coding);
//! * [`CodebookCoder`] — a QMoE-style fixed-dictionary coder (§II-C's
//!   related work). It maps frequent symbol *pairs* to fixed-width
//!   dictionary indices; because every codeword has the same length it
//!   is **not Shannon-rate optimal**, which is exactly the paper's
//!   argument for Huffman. The `baseline_codebook` bench regenerates
//!   that comparison;
//! * [`gzip_bytes`] — a generic self-contained entropy-coded baseline
//!   over the packed weights. **Not DEFLATE**: the offline build has no
//!   DEFLATE library, so this is the crate's own order-0 Huffman codec
//!   with an embedded code table (name kept for API continuity). Real
//!   gzip adds LZ77 matching and would compress *harder*, so treat this
//!   row as a lower bound on what a general-purpose compressor achieves
//!   — never as evidence of ELM's advantage over real gzip.

use crate::bitio::{pack_u4, unpack_u4, BitReader, BitWriter};
use crate::huffman::{CodeSpec, Decoder, Encoder, FreqTable};
use crate::quant::BitWidth;
use crate::{Error, Result};
use std::collections::HashMap;

/// Pack quantization symbols at their fixed width (no entropy coding).
pub fn fixed_pack(symbols: &[u8], bits: BitWidth) -> Result<Vec<u8>> {
    match bits {
        BitWidth::U8 => Ok(symbols.to_vec()),
        BitWidth::U4 => pack_u4(symbols),
    }
}

/// Inverse of [`fixed_pack`].
pub fn fixed_unpack(packed: &[u8], bits: BitWidth, n: usize) -> Result<Vec<u8>> {
    match bits {
        BitWidth::U8 => {
            if packed.len() != n {
                return Err(Error::InvalidArg(format!(
                    "fixed_unpack: {} bytes for {n} u8 symbols",
                    packed.len()
                )));
            }
            Ok(packed.to_vec())
        }
        BitWidth::U4 => unpack_u4(packed, n),
    }
}

/// Generic entropy-coded compression of a byte buffer — an **order-0
/// Huffman stand-in for gzip**, not DEFLATE (see module docs: it
/// under-compresses vs real gzip, so it bounds the generic baseline
/// from below). Layout: `"EGZ1" | u64 n | 256 code lengths | huffman
/// payload` (header omitted entirely for empty input beyond the count).
pub fn gzip_bytes(data: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(data.len() / 2 + 270);
    out.extend_from_slice(b"EGZ1");
    out.extend_from_slice(&(data.len() as u64).to_le_bytes());
    if data.is_empty() {
        return Ok(out);
    }
    let freq = FreqTable::from_symbols(data);
    let spec = CodeSpec::build(&freq)?;
    out.extend_from_slice(spec.lengths());
    let payload = Encoder::new(&spec).encode_to_vec(data)?;
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Decompress [`gzip_bytes`] output.
pub fn gunzip_bytes(data: &[u8]) -> Result<Vec<u8>> {
    if data.len() < 12 || &data[..4] != b"EGZ1" {
        return Err(Error::Format("bad EGZ1 header".into()));
    }
    let n = u64::from_le_bytes(data[4..12].try_into().expect("8 bytes"));
    if n == 0 {
        return Ok(Vec::new());
    }
    if data.len() < 12 + 256 {
        return Err(Error::Format("EGZ1 truncated before code table".into()));
    }
    // Every symbol costs at least one bit, so a header claiming more
    // symbols than the payload has bits is corrupt — reject while the
    // count is still u64 (casting first would silently truncate on
    // 32-bit targets and bypass this guard), and before allocating the
    // output buffer.
    let payload_bits = (data.len() as u64 - 268) * 8;
    if n > payload_bits {
        return Err(Error::Format(format!(
            "EGZ1 claims {n} symbols but payload holds only {payload_bits} bits"
        )));
    }
    let spec = CodeSpec::from_lengths(&data[12..268])?;
    let dec = Decoder::new(&spec)?;
    dec.decode(&data[268..], n as usize)
}

/// Number of dictionary slots for symbol pairs.
const PAIR_SLOTS: usize = 4096;
/// Codeword width: 1 flag bit + 12-bit payload.
const CW_BITS: u8 = 13;

/// QMoE-style fixed-dictionary coder over symbol pairs.
///
/// Codewords are all [`CW_BITS`] wide: `0 | pair_index` emits two symbols
/// from the dictionary; `1 | symbol | 4 zero pad` escapes one literal
/// symbol. Frequent pairs therefore cost 6.5 bits/symbol and escapes 13 —
/// fixed-length codes cannot track the source entropy the way Huffman's
/// variable-length codes do.
#[derive(Debug, Clone)]
pub struct CodebookCoder {
    /// Dictionary: pair → index.
    index_of: HashMap<(u8, u8), u16>,
    /// Inverse dictionary.
    pairs: Vec<(u8, u8)>,
}

impl CodebookCoder {
    /// Build the dictionary from training symbols: the [`PAIR_SLOTS`]
    /// most frequent adjacent pairs.
    pub fn train(symbols: &[u8]) -> Self {
        let mut counts: HashMap<(u8, u8), u64> = HashMap::new();
        for w in symbols.windows(2) {
            *counts.entry((w[0], w[1])).or_insert(0) += 1;
        }
        let mut ranked: Vec<((u8, u8), u64)> = counts.into_iter().collect();
        ranked.sort_by_key(|&(p, c)| (std::cmp::Reverse(c), p));
        let pairs: Vec<(u8, u8)> = ranked
            .into_iter()
            .take(PAIR_SLOTS)
            .map(|(p, _)| p)
            .collect();
        let index_of = pairs
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i as u16))
            .collect();
        CodebookCoder { index_of, pairs }
    }

    /// Greedy encode: consume a dictionary pair when possible, else
    /// escape one literal.
    pub fn encode(&self, symbols: &[u8]) -> Vec<u8> {
        let mut w = BitWriter::with_capacity(symbols.len());
        let mut i = 0;
        while i < symbols.len() {
            if i + 1 < symbols.len() {
                if let Some(&idx) = self.index_of.get(&(symbols[i], symbols[i + 1])) {
                    w.write_bits(idx as u64, CW_BITS); // flag bit 0 implicit in 13-bit value < 4096
                    i += 2;
                    continue;
                }
            }
            // Escape: 1 | symbol | 4 pad bits.
            w.write_bits((1 << 12) | ((symbols[i] as u64) << 4), CW_BITS);
            i += 1;
        }
        w.into_bytes()
    }

    /// Decode exactly `n` symbols.
    pub fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<u8>> {
        let mut r = BitReader::new(bytes);
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            if r.remaining_bits() < CW_BITS as usize {
                return Err(Error::Format("codebook stream exhausted".into()));
            }
            let cw = r.read_bits(CW_BITS)?;
            if cw & (1 << 12) != 0 {
                out.push(((cw >> 4) & 0xFF) as u8);
            } else {
                let idx = (cw & 0xFFF) as usize;
                let &(a, b) = self
                    .pairs
                    .get(idx)
                    .ok_or_else(|| Error::Format(format!("codebook index {idx} out of range")))?;
                out.push(a);
                if out.len() < n {
                    out.push(b);
                } else {
                    return Err(Error::Format("codebook pair overruns output".into()));
                }
            }
        }
        Ok(out)
    }

    /// Encoded bits per symbol for a stream (without materializing it).
    pub fn bits_per_symbol(&self, symbols: &[u8]) -> f64 {
        if symbols.is_empty() {
            return 0.0;
        }
        let mut bits = 0u64;
        let mut i = 0;
        while i < symbols.len() {
            if i + 1 < symbols.len() && self.index_of.contains_key(&(symbols[i], symbols[i + 1])) {
                bits += CW_BITS as u64;
                i += 2;
            } else {
                bits += CW_BITS as u64;
                i += 1;
            }
        }
        bits as f64 / symbols.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::huffman::{encode_with_own_code, FreqTable};
    use crate::rng::Rng;

    fn gaussian_symbols(n: usize, levels: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let g = rng.gaussian_f32(levels as f32 / 2.0, levels as f32 / 8.0);
                (g.round().max(0.0) as usize).min(levels - 1) as u8
            })
            .collect()
    }

    #[test]
    fn fixed_pack_roundtrips_both_widths() {
        let mut rng = Rng::new(1);
        let u8s: Vec<u8> = (0..999).map(|_| rng.below(256) as u8).collect();
        let u4s: Vec<u8> = (0..999).map(|_| rng.below(16) as u8).collect();
        assert_eq!(
            fixed_unpack(&fixed_pack(&u8s, BitWidth::U8).unwrap(), BitWidth::U8, 999).unwrap(),
            u8s
        );
        assert_eq!(
            fixed_unpack(&fixed_pack(&u4s, BitWidth::U4).unwrap(), BitWidth::U4, 999).unwrap(),
            u4s
        );
    }

    #[test]
    fn gzip_roundtrip() {
        let data = gaussian_symbols(10_000, 256, 2);
        let z = gzip_bytes(&data).unwrap();
        assert_eq!(gunzip_bytes(&z).unwrap(), data);
        assert!(z.len() < data.len());
    }

    #[test]
    fn gzip_handles_empty_and_rejects_garbage() {
        let z = gzip_bytes(&[]).unwrap();
        assert_eq!(gunzip_bytes(&z).unwrap(), Vec::<u8>::new());
        assert!(gunzip_bytes(b"NOPE").is_err());
        assert!(gunzip_bytes(&z[..3]).is_err());
        // Truncated code table is rejected.
        let full = gzip_bytes(&[1, 2, 3, 1, 2, 3]).unwrap();
        assert!(gunzip_bytes(&full[..20]).is_err());
        // A header claiming an absurd symbol count must error cleanly
        // instead of attempting the allocation.
        let mut bomb = b"EGZ1".to_vec();
        bomb.extend_from_slice(&u64::MAX.to_le_bytes());
        bomb.extend_from_slice(&full[12..]);
        assert!(gunzip_bytes(&bomb).is_err());
    }

    #[test]
    fn codebook_roundtrips() {
        let syms = gaussian_symbols(20_000, 16, 3);
        let cb = CodebookCoder::train(&syms);
        let enc = cb.encode(&syms);
        assert_eq!(cb.decode(&enc, syms.len()).unwrap(), syms);
    }

    #[test]
    fn codebook_roundtrips_odd_lengths_and_escapes() {
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let n = 1 + rng.below(500);
            let syms: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            // Train on different data so escapes are exercised.
            let cb = CodebookCoder::train(&gaussian_symbols(5000, 16, 5));
            let enc = cb.encode(&syms);
            assert_eq!(cb.decode(&enc, n).unwrap(), syms, "n={n}");
        }
    }

    #[test]
    fn huffman_beats_codebook_on_gaussian_weights() {
        // The paper's §II-C argument: fixed-length dictionary codes are
        // not Shannon-optimal. On a Gaussian uint4 histogram Huffman must
        // achieve fewer bits/symbol.
        let syms = gaussian_symbols(100_000, 16, 6);
        let cb = CodebookCoder::train(&syms);
        let cb_bits = cb.bits_per_symbol(&syms);
        let freq = FreqTable::from_symbols(&syms);
        let (spec, _) = encode_with_own_code(&syms).unwrap();
        let hf_bits = spec.expected_bits(&freq);
        assert!(
            hf_bits < cb_bits,
            "huffman {hf_bits} must beat codebook {cb_bits}"
        );
    }

    #[test]
    fn codebook_bits_estimate_matches_encoding() {
        let syms = gaussian_symbols(9_999, 16, 7);
        let cb = CodebookCoder::train(&syms);
        let bits_est = cb.bits_per_symbol(&syms) * syms.len() as f64;
        let enc = cb.encode(&syms);
        let actual_bits = enc.len() as f64 * 8.0;
        assert!((actual_bits - bits_est).abs() < 8.0, "padding only");
    }
}
