//! Synthetic text corpus + byte-level tokenizer.
//!
//! The paper evaluates on WikiText2 / HellaSwag / GSM8K, none of which
//! are available offline; DESIGN.md §Substitutions replaces them with a
//! seeded synthetic English-like corpus. The python build path
//! (`python/compile/train.py`) trains the tiny LM on *its own* seeded
//! corpus; this module provides matching request/prompt generation for
//! the rust serving engine plus the byte tokenizer both sides share.

use crate::rng::Rng;

/// Vocabulary size of the byte-level tokenizer. The python model uses
/// the same value (`python/compile/model.py :: VOCAB`).
pub const VOCAB_SIZE: usize = 128;

/// Byte-level tokenizer: token id = ASCII byte (7-bit); bytes ≥ 128 map
/// to `?`. Trivially reversible, identical in python and rust, and
/// sidesteps any BPE-vocabulary interchange problem.
#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    /// Encode text to token ids.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.bytes()
            .map(|b| if b < 128 { b as u32 } else { b'?' as u32 })
            .collect()
    }

    /// Decode token ids to text (lossy for non-ASCII ids).
    pub fn decode(&self, tokens: &[u32]) -> String {
        tokens
            .iter()
            .map(|&t| {
                if t < 128 {
                    t as u8 as char
                } else {
                    '?'
                }
            })
            .collect()
    }
}

/// Word-level Markov text generator.
///
/// A fixed word list with seeded order-1 transitions produces text with
/// a realistic (Zipf-ish) token distribution — enough structure for a
/// char-LM to learn, while being fully reproducible.
#[derive(Debug, Clone)]
pub struct MarkovCorpus {
    words: Vec<&'static str>,
    /// transition[i][j] ∝ P(word j | word i)
    transition: Vec<Vec<f32>>,
    rng: Rng,
    state: usize,
}

const WORDS: &[&str] = &[
    "the", "model", "edge", "device", "weight", "memory", "bandwidth", "token", "layer",
    "quantized", "entropy", "huffman", "decode", "encode", "parallel", "thread", "cache",
    "inference", "latency", "storage", "compression", "symbol", "stream", "segment", "tensor",
    "matrix", "vector", "scale", "zero", "point", "bits", "fast", "small", "large", "runs",
    "loads", "stores", "maps", "reduces", "achieves", "requires", "and", "of", "on", "with",
    "for", "to", "a", "in", "is",
];

impl MarkovCorpus {
    /// Seeded generator. Transitions are themselves sampled from the
    /// seed so different seeds give different (but stable) languages.
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let n = WORDS.len();
        let transition: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                // Sparse-ish rows: a few favored successors (Zipf flavor).
                let mut row: Vec<f32> = (0..n).map(|_| rng.f32() * 0.05).collect();
                for _ in 0..4 {
                    let j = rng.below(n);
                    row[j] += rng.f32() * 2.0;
                }
                row
            })
            .collect();
        MarkovCorpus {
            words: WORDS.to_vec(),
            transition,
            rng,
            state: 0,
        }
    }

    /// Generate `n_words` of text.
    pub fn generate_words(&mut self, n_words: usize) -> String {
        let mut out = String::new();
        for i in 0..n_words {
            if i > 0 {
                out.push(' ');
            }
            out.push_str(self.words[self.state]);
            let row = &self.transition[self.state];
            self.state = self.rng.categorical(row);
            // Sentence breaks.
            if i > 0 && i % 12 == 0 {
                out.push('.');
            }
        }
        out
    }

    /// Generate text of (at least) `n_chars` characters.
    pub fn generate_chars(&mut self, n_chars: usize) -> String {
        let mut out = String::new();
        while out.len() < n_chars {
            out = self.generate_words(n_chars / 4 + 8);
        }
        out.truncate(n_chars);
        out
    }

    /// A batch of prompts for the serving benches.
    pub fn prompts(&mut self, count: usize, words_each: usize) -> Vec<String> {
        (0..count).map(|_| self.generate_words(words_each)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_roundtrips_ascii() {
        let t = ByteTokenizer;
        let text = "the model runs on the edge.";
        assert_eq!(t.decode(&t.encode(text)), text);
    }

    #[test]
    fn tokenizer_maps_non_ascii_to_question_mark() {
        let t = ByteTokenizer;
        let ids = t.encode("naïve");
        assert!(ids.iter().all(|&i| i < VOCAB_SIZE as u32));
        assert!(t.decode(&ids).contains('?'));
    }

    #[test]
    fn corpus_is_deterministic_per_seed() {
        let a = MarkovCorpus::new(7).generate_words(50);
        let b = MarkovCorpus::new(7).generate_words(50);
        let c = MarkovCorpus::new(8).generate_words(50);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn corpus_tokens_fit_vocab() {
        let text = MarkovCorpus::new(1).generate_chars(5000);
        let ids = ByteTokenizer.encode(&text);
        assert_eq!(ids.len(), 5000);
        assert!(ids.iter().all(|&i| i < VOCAB_SIZE as u32));
    }

    #[test]
    fn generate_chars_hits_requested_length() {
        let text = MarkovCorpus::new(2).generate_chars(1234);
        assert_eq!(text.len(), 1234);
    }

    #[test]
    fn prompts_are_distinct() {
        let ps = MarkovCorpus::new(3).prompts(5, 10);
        assert_eq!(ps.len(), 5);
        assert!(ps.iter().any(|p| p != &ps[0]), "state advances");
    }

    #[test]
    fn corpus_has_skewed_word_distribution() {
        // Zipf-ish skew is what makes the LM learnable; sanity check the
        // most common word is clearly more common than the median.
        let text = MarkovCorpus::new(4).generate_words(20_000);
        let mut counts = std::collections::HashMap::new();
        for w in text.split(|c: char| !c.is_alphanumeric()) {
            if !w.is_empty() {
                *counts.entry(w).or_insert(0usize) += 1;
            }
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by_key(|&c| std::cmp::Reverse(c));
        assert!(freqs[0] > 2 * freqs[freqs.len() / 2]);
    }
}
