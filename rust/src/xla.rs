//! Compile-time stand-in for the `xla` PJRT bindings.
//!
//! The offline build has no PJRT shared library and no crates.io access,
//! yet [`crate::runtime`] is written against the `xla` crate's API so
//! the real bindings can be swapped back in with a one-line change (drop
//! this module, add the dependency). This module reproduces exactly the
//! API surface the runtime compiles against:
//!
//! * host-side types ([`Literal`], [`HloModuleProto`],
//!   [`XlaComputation`]) are functional — they hold real bytes / HLO
//!   text, so manifests and artifacts can be loaded and inspected;
//! * device-side entry points fail at **client creation**
//!   ([`PjRtClient::cpu`]) with a clear diagnostic, so every load path
//!   errors once, early, and with an actionable message instead of
//!   segfaulting into a missing `libpjrt`.
//!
//! Everything that does not need PJRT — compression, streaming decode,
//! the serving engine over [`crate::coordinator::MockBackend`] /
//! [`crate::coordinator::DigestBackend`], the cost model, the CLI tools
//! — runs fully under this stub.

use std::path::Path;

/// Error type mirroring `xla::Error` (the runtime converts it into
/// [`crate::Error::Xla`] via `to_string`).
#[derive(Debug, Clone)]
pub struct Error(String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: PJRT is unavailable in this offline build (in-tree xla stub); \
         link the real xla bindings to execute AOT artifacts"
    )))
}

/// Element types the runtime uploads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    /// Unsigned 8-bit (quantization symbols).
    U8,
    /// 32-bit float.
    F32,
    /// Signed 32-bit int (token ids).
    S32,
}

impl ElementType {
    fn size_bytes(self) -> usize {
        match self {
            ElementType::U8 => 1,
            ElementType::F32 | ElementType::S32 => 4,
        }
    }
}

/// Host element types accepted by [`PjRtClient::buffer_from_host_buffer`].
pub trait NativeType: Copy {
    /// The PJRT element type tag.
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}
impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}
impl NativeType for u8 {
    const TY: ElementType = ElementType::U8;
}

/// A host literal: element type, dims, raw bytes.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    /// Build a literal from raw bytes (must match `ty`/`dims`).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal, Error> {
        let numel: usize = dims.iter().product();
        if numel * ty.size_bytes() != data.len() {
            return Err(Error(format!(
                "literal shape {dims:?} ({ty:?}) wants {} bytes, got {}",
                numel * ty.size_bytes(),
                data.len()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.to_vec(),
            bytes: data.to_vec(),
        })
    }

    /// Element count.
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    /// Host-side size in bytes.
    pub fn size_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Element type.
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    /// Destructure a tuple literal. Only ever produced by executing a
    /// compiled program, which the stub cannot do.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }

    /// Download typed host data. Only ever meaningful for buffers
    /// produced by execution, which the stub cannot do.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module (text form is kept verbatim).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text file from disk.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("read {}: {e}", path.display())))?;
        Ok(HloModuleProto { text })
    }

    /// The HLO text.
    pub fn text(&self) -> &str {
        &self.text
    }
}

/// A computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    hlo: HloModuleProto,
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { hlo: proto.clone() }
    }

    /// The wrapped module's HLO text.
    pub fn hlo_text(&self) -> &str {
        self.hlo.text()
    }
}

/// Device buffer handle. Never constructible under the stub (requires a
/// client, and client creation fails).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Synchronously download the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle. Never constructible under the stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed argument buffers.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create the CPU client. This is the single failure point of the
    /// stub: it errors immediately so callers never get half a runtime.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }

    /// Upload a typed host slice to a device buffer.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    /// Upload a host literal to a device buffer.
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer, Error> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_with_actionable_message() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("PJRT"), "{msg}");
        assert!(msg.contains("stub"), "{msg}");
    }

    #[test]
    fn literal_checks_shape_against_bytes() {
        let ok = Literal::create_from_shape_and_untyped_data(ElementType::U8, &[2, 3], &[0u8; 6]);
        assert!(ok.is_ok());
        assert_eq!(ok.as_ref().unwrap().element_count(), 6);
        assert_eq!(ok.unwrap().size_bytes(), 6);
        let f32_short =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4]);
        assert!(f32_short.is_err());
    }

    #[test]
    fn hlo_text_roundtrips_through_computation() {
        let dir = std::env::temp_dir().join(format!("xla_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.hlo.txt");
        std::fs::write(&p, "HloModule test").unwrap();
        let proto = HloModuleProto::from_text_file(&p).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        assert_eq!(comp.hlo_text(), "HloModule test");
        std::fs::remove_dir_all(&dir).ok();
    }
}
