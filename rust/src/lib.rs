//! # EntroLLM
//!
//! A reproduction of *EntroLLM: Entropy Encoded Weight Compression for
//! Efficient Large Language Model Inference on Edge Devices* (CS.LG 2025)
//! as a three-layer rust + JAX + Pallas system:
//!
//! * **L1 (Pallas, build-time python)** — fused dequantize-matmul and
//!   attention kernels (`python/compile/kernels/`), lowered with the rest
//!   of the model into HLO text.
//! * **L2 (JAX, build-time python)** — a decoder-only transformer whose
//!   matmuls consume *quantized* integer weights plus `(scale, zero_point)`
//!   metadata (`python/compile/model.py`), AOT-lowered by
//!   `python/compile/aot.py` into `artifacts/*.hlo.txt`.
//! * **L3 (this crate)** — the edge coordinator: mixed quantization,
//!   model-global Huffman coding, the ELM compressed container, segmented
//!   **parallel Huffman decoding**, an edge-device cost model, and a
//!   serving engine that executes the AOT artifacts through PJRT.
//!
//! Python never runs on the request path: after `make artifacts` the
//! `entrollm` binary is self-contained.
//!
//! ## Crate map
//!
//! | module | paper section | role |
//! |---|---|---|
//! | [`quant`] | §III-A | mixed symmetric-unsigned / asymmetric quantization |
//! | [`huffman`] | §III-B | canonical, length-limited Huffman codec |
//! | [`ans`] | §III-B | tANS codec arm (closes the Huffman-to-Shannon gap) |
//! | [`codec`] | §III-B | per-segment codec ids + the `TileCodec` decode seam |
//! | [`decode`] | §III-C | parameter-space segmentation + parallel decoding |
//! | [`decode::stream`] | §III-C | streaming layer-ahead decode with a bounded prefetch window |
//! | [`store`] | §III-B | ELM compressed-model container (eager + lazy segment access) |
//! | [`residency`] | — | weight-residency cache (scan-resistant policies) + decode-ahead prefetch: serve models larger than device RAM |
//! | [`entropy`] | §IV-A | Shannon entropy / effective-bits / histograms |
//! | [`device`] | §IV-C/D | Jetson-class bandwidth/compute cost model |
//! | [`runtime`] | — | PJRT executor for the AOT artifacts |
//! | [`coordinator`] | §IV | batching, KV-cache, generation engine |
//! | [`baselines`] | §II-C | codebook coder, gzip, raw bit-packing |
//!
//! Support modules ([`bitio`], [`tensor`], [`json`], [`rng`], [`corpus`],
//! [`metrics`], [`bench`], [`prop`], [`cli`], [`crc32`], and the [`xla`]
//! PJRT stub) are implemented in-tree because this build is fully
//! offline.

pub mod ans;
pub mod baselines;
pub mod bench;
pub mod bitio;
pub mod cli;
pub mod codec;
pub mod coordinator;
pub mod corpus;
pub mod crc32;
pub mod decode;
pub mod device;
pub mod entropy;
pub mod error;
pub mod huffman;
pub mod json;
pub mod metrics;
pub mod pipeline;
pub mod prop;
pub mod quant;
pub mod residency;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod store;
pub mod tensor;
pub mod xla;

pub use error::{Error, Result};
