//! In-tree micro-benchmark harness (offline build: no criterion).
//!
//! `cargo bench` targets declare `harness = false` and drive this module
//! directly. The harness does what criterion's core loop does — warmup,
//! repeated timed batches, robust statistics — without the dependency.
//! Results print as aligned text and accumulate into
//! `bench_results/*.csv` for EXPERIMENTS.md.

use std::time::{Duration, Instant};

/// Result statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Case name.
    pub name: String,
    /// Median batch time per iteration.
    pub median: Duration,
    /// 10th percentile.
    pub p10: Duration,
    /// 90th percentile.
    pub p90: Duration,
    /// Iterations per batch used.
    pub iters_per_batch: u64,
    /// Batches measured.
    pub batches: usize,
}

impl Stats {
    /// Iterations/second at the median.
    pub fn per_sec(&self) -> f64 {
        1.0 / self.median.as_secs_f64().max(1e-12)
    }
}

/// Benchmark runner with a time budget per case.
#[derive(Debug, Clone)]
pub struct Bench {
    /// Target measurement time per case.
    pub measure_for: Duration,
    /// Warmup time per case.
    pub warmup_for: Duration,
    /// Batches to split the measurement into.
    pub batches: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            measure_for: Duration::from_millis(800),
            warmup_for: Duration::from_millis(200),
            batches: 15,
        }
    }
}

/// Is the quick/smoke parameterization requested? Set `BENCH_QUICK=1`
/// (any value but `0`) in the environment, or pass `--quick` on the
/// bench command line. CI's bench-smoke job runs every bench this way,
/// so bench code is compiled AND executed on every push without paying
/// full measurement time — quick runs shrink workloads and timing
/// windows but still execute every code path and assertion.
pub fn quick_mode() -> bool {
    std::env::var_os("BENCH_QUICK").is_some_and(|v| v != "0")
        || std::env::args().any(|a| a == "--quick")
}

/// `quick` when [`quick_mode`] is on, else `full` — the one-liner for
/// sizing a bench workload constant.
pub fn quick_or<T>(quick: T, full: T) -> T {
    if quick_mode() {
        quick
    } else {
        full
    }
}

impl Bench {
    /// Default-configured runner.
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick configuration for slow end-to-end cases.
    pub fn slow() -> Self {
        Bench {
            measure_for: Duration::from_secs(2),
            warmup_for: Duration::from_millis(300),
            batches: 7,
        }
    }

    /// Smoke-test configuration: tiny warmup/measure windows for CI's
    /// bench-smoke job (statistics are meaningless at this size — the
    /// point is that the code ran).
    pub fn quick() -> Self {
        Bench {
            measure_for: Duration::from_millis(60),
            warmup_for: Duration::from_millis(10),
            batches: 3,
        }
    }

    /// [`Bench::quick`] under [`quick_mode`], the given config
    /// otherwise — what every bench's `main` should start from.
    pub fn auto(full: Bench) -> Self {
        if quick_mode() {
            Self::quick()
        } else {
            full
        }
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    /// Returns robust per-iteration statistics and prints a line.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        // Warmup + calibration: how many iters fit in a batch?
        let cal_start = Instant::now();
        let mut cal_iters = 0u64;
        while cal_start.elapsed() < self.warmup_for || cal_iters == 0 {
            f();
            cal_iters += 1;
            if cal_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = cal_start.elapsed().as_secs_f64() / cal_iters as f64;
        let batch_time = self.measure_for.as_secs_f64() / self.batches as f64;
        let iters_per_batch = ((batch_time / per_iter.max(1e-9)) as u64).clamp(1, 10_000_000);

        let mut samples: Vec<Duration> = Vec::with_capacity(self.batches);
        for _ in 0..self.batches {
            let t0 = Instant::now();
            for _ in 0..iters_per_batch {
                f();
            }
            samples.push(t0.elapsed() / iters_per_batch as u32);
        }
        samples.sort_unstable();
        let q = |frac: f64| samples[((samples.len() - 1) as f64 * frac) as usize];
        let stats = Stats {
            name: name.to_string(),
            median: q(0.5),
            p10: q(0.1),
            p90: q(0.9),
            iters_per_batch,
            batches: self.batches,
        };
        println!(
            "{:<48} median {:>12?}  p10 {:>12?}  p90 {:>12?}  ({} it/batch)",
            stats.name, stats.median, stats.p10, stats.p90, stats.iters_per_batch
        );
        stats
    }

    /// Time a single execution of `f` (for expensive one-shot phases
    /// like whole-model decode).
    pub fn once<T>(&self, name: &str, f: impl FnOnce() -> T) -> (T, Duration) {
        let t0 = Instant::now();
        let out = f();
        let d = t0.elapsed();
        println!("{name:<48} once   {d:>12?}");
        (out, d)
    }
}

/// Format seconds human-readably for table cells.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} µs", s * 1e6)
    }
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_produces_ordered_quantiles() {
        let b = Bench {
            measure_for: Duration::from_millis(30),
            warmup_for: Duration::from_millis(5),
            batches: 5,
        };
        let mut x = 0u64;
        let stats = b.run("noop-ish", || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(stats.p10 <= stats.median && stats.median <= stats.p90);
        assert!(stats.per_sec() > 0.0);
    }

    #[test]
    fn once_returns_value_and_duration() {
        let b = Bench::new();
        let (v, d) = b.once("compute", || 21 * 2);
        assert_eq!(v, 42);
        assert!(d > Duration::ZERO);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_secs(2.5), "2.50 s");
        assert_eq!(fmt_secs(0.0025), "2.50 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.50 µs");
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
