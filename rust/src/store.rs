//! The **ELM container** — EntroLLM's on-device compressed model format
//! (Algorithm 1 line 16: "Store model metadata: H, P, {W_c}^k, preserving
//! the weight tensor packing structure").
//!
//! Layout (little-endian):
//!
//! ```text
//! magic "ELM1" | version u32 | bitwidth u8 | n_layers u32
//! global canonical code lengths: 256 × u8      (this is "H" — canonical
//!                                               codes rebuild from lengths)
//! per layer:
//!   name_len u16 | name utf-8
//!   rank u8 | dims: rank × u64
//!   scheme u8 | scale f32 | zero_point f32
//!   n_symbols u64 | encoded_len u64 | crc32 u32
//! payload: concatenated byte-aligned encoded segments (one per layer)
//! ```
//!
//! Crucially the payload keeps **one independently decodable, byte-aligned
//! segment per weight tensor** — the "parameter space segmentation" that
//! makes §III-C parallel decoding possible: segment starts/ends are known
//! from the manifest before any bit is decoded.
//!
//! The byte-level specification third parties need to write their own
//! encoders/decoders lives in `docs/FORMAT.md` at the repository root;
//! this module is the reference implementation.
//!
//! Two access modes:
//!
//! * [`ElmModel`] holds the whole payload in memory (the cloud/build
//!   side, and small models).
//! * [`SegmentSource`] abstracts *where the payload bytes live*: opened
//!   with [`SegmentSource::open`] it parses only the header + manifest
//!   and reads each segment from disk on demand, so a streaming or
//!   cache-resident consumer ([`crate::decode::StreamingDecoder`],
//!   [`crate::residency::WeightCache`]) never pays `O(model)` RSS.

use crate::entropy::shannon_entropy;
use crate::huffman::{CodeSpec, Decoder, Encoder, FreqTable};
use crate::quant::{quantize_mixed, BitWidth, QuantParams, QuantizedTensor, Scheme};
use crate::tensor::{Shape, TensorF32, TensorU8};
use crate::{Error, Result};
use std::borrow::Cow;
use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

const MAGIC: &[u8; 4] = b"ELM1";
const VERSION: u32 = 1;

/// Per-layer manifest entry.
#[derive(Debug, Clone)]
pub struct LayerMeta {
    /// Layer name (e.g. `"blocks.3.mlp.w_in"`).
    pub name: String,
    /// Tensor shape.
    pub shape: Shape,
    /// Quantization grid parameters.
    pub params: QuantParams,
    /// Number of weight symbols in this tensor.
    pub n_symbols: usize,
    /// Byte offset of this layer's segment within the payload.
    pub offset: usize,
    /// Encoded segment length in bytes.
    pub encoded_len: usize,
    /// CRC32 of the encoded segment.
    pub crc32: u32,
}

/// A compressed model: manifest + global code + payload.
#[derive(Debug, Clone)]
pub struct ElmModel {
    /// Quantization bit width all layers share.
    pub bits: BitWidth,
    /// The model-global canonical Huffman code.
    pub code: CodeSpec,
    /// Layer manifest, in storage order.
    pub layers: Vec<LayerMeta>,
    /// Concatenated encoded segments.
    pub payload: Vec<u8>,
}

/// Storage accounting produced by [`compress`] — the Table I numbers.
#[derive(Debug, Clone)]
pub struct CompressionReport {
    /// Total parameters.
    pub n_params: usize,
    /// fp16 baseline size (2 bytes/param) — the paper's reference point.
    pub fp16_bytes: usize,
    /// Fixed-width quantized size (bit-packed, no entropy coding).
    pub fixed_bytes: usize,
    /// Huffman payload size.
    pub encoded_bytes: usize,
    /// Shannon entropy of the pooled symbol histogram (bits/param).
    pub entropy_bits: f64,
    /// Achieved effective bits/param (encoded bits / params).
    pub effective_bits: f64,
    /// Per-layer scheme chosen by the mixed rule.
    pub schemes: Vec<(String, Scheme)>,
}

impl ElmModel {
    /// Segment bytes for layer `i`.
    pub fn segment(&self, i: usize) -> &[u8] {
        let m = &self.layers[i];
        &self.payload[m.offset..m.offset + m.encoded_len]
    }

    /// Check layer `i`'s segment against its stored CRC32.
    pub fn verify_segment(&self, i: usize) -> Result<()> {
        let m = &self.layers[i];
        if crate::crc32::hash(self.segment(i)) != m.crc32 {
            return Err(Error::Format(format!(
                "layer {:?}: segment CRC mismatch",
                m.name
            )));
        }
        Ok(())
    }

    /// Cursor over the container's segments in execution (storage)
    /// order — the walk order of the streaming decoder
    /// ([`crate::decode::StreamingDecoder`]).
    pub fn segments(&self) -> SegmentCursor<'_> {
        SegmentCursor {
            model: self,
            next: 0,
        }
    }

    /// Total parameters across layers.
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.n_symbols).sum()
    }

    /// Effective bits/param of the stored payload (0 for a zero-layer
    /// container — no params, no payload).
    pub fn effective_bits(&self) -> f64 {
        let n = self.n_params();
        if n == 0 {
            return 0.0;
        }
        8.0 * self.payload.len() as f64 / n as f64
    }

    /// Serialized container size in bytes (manifest + payload).
    pub fn container_bytes(&self) -> usize {
        header_bytes(&self.layers) + self.payload.len()
    }
}

/// Serialized size of everything **before** the payload: magic, version,
/// bit width, layer count, the 256-byte code-length table, and the layer
/// manifest. This is also the payload's byte offset within a container
/// file, which is what lazy segment reads seek relative to.
pub fn header_bytes(layers: &[LayerMeta]) -> usize {
    let manifest: usize = layers
        .iter()
        .map(|l| 2 + l.name.len() + 1 + 8 * l.shape.rank() + 1 + 4 + 4 + 8 + 8 + 4)
        .sum();
    4 + 4 + 1 + 4 + 256 + manifest
}

/// One independently decodable, byte-aligned segment of an
/// [`ElmModel`]: the §III-C unit of parallel and streaming decode.
#[derive(Debug, Clone, Copy)]
pub struct SegmentRef<'a> {
    /// Layer index in execution (storage) order.
    pub index: usize,
    /// The layer's manifest entry.
    pub meta: &'a LayerMeta,
    /// The encoded segment bytes.
    pub bytes: &'a [u8],
}

/// Iterator/cursor over a container's segments in execution order.
///
/// Unlike a plain iterator it can be repositioned ([`SegmentCursor::seek`]),
/// which is what a resuming or window-refilling consumer needs.
#[derive(Debug, Clone)]
pub struct SegmentCursor<'a> {
    model: &'a ElmModel,
    next: usize,
}

impl<'a> SegmentCursor<'a> {
    /// Reposition the cursor to layer `index`.
    pub fn seek(&mut self, index: usize) {
        self.next = index;
    }

    /// Index of the next segment the cursor will yield.
    pub fn position(&self) -> usize {
        self.next
    }

    /// Segments left to yield.
    pub fn remaining(&self) -> usize {
        self.model.layers.len().saturating_sub(self.next)
    }
}

impl<'a> Iterator for SegmentCursor<'a> {
    type Item = SegmentRef<'a>;

    fn next(&mut self) -> Option<SegmentRef<'a>> {
        if self.next >= self.model.layers.len() {
            return None;
        }
        let index = self.next;
        self.next += 1;
        Some(SegmentRef {
            index,
            meta: &self.model.layers[index],
            bytes: self.model.segment(index),
        })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.remaining();
        (n, Some(n))
    }
}

impl<'a> ExactSizeIterator for SegmentCursor<'a> {}

/// Where a [`SegmentSource`]'s payload bytes live.
#[derive(Debug)]
enum Backing {
    /// Whole payload resident in memory (wraps an [`ElmModel`]).
    Memory(Arc<ElmModel>),
    /// Payload left on disk; each segment is read on demand.
    File {
        file: SharedFile,
        /// Byte offset of the payload within the file (= header size).
        payload_base: u64,
    },
}

/// A container file shared by concurrent readers.
///
/// On unix every read is a *positioned* read (`pread`), so prefetch
/// workers and fault-on-demand consumers never serialize on a seek
/// lock — each call carries its own offset and the kernel handles the
/// concurrency. Elsewhere the portable fallback serializes seek+read
/// behind a mutex (recovering, not panicking, if a reader thread ever
/// poisoned it: the cursor is repositioned on every read, so there is
/// no state to corrupt).
#[derive(Debug)]
struct SharedFile {
    #[cfg(unix)]
    file: std::fs::File,
    #[cfg(not(unix))]
    file: std::sync::Mutex<std::fs::File>,
}

impl SharedFile {
    fn new(file: std::fs::File) -> Self {
        #[cfg(unix)]
        {
            SharedFile { file }
        }
        #[cfg(not(unix))]
        {
            SharedFile {
                file: std::sync::Mutex::new(file),
            }
        }
    }

    /// Fill `buf` from absolute file offset `offset`.
    fn read_exact_at(&self, buf: &mut [u8], offset: u64) -> Result<()> {
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt as _;
            self.file.read_exact_at(buf, offset)?;
        }
        #[cfg(not(unix))]
        {
            use std::io::Seek as _;
            let mut f = self
                .file
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            f.seek(std::io::SeekFrom::Start(offset))?;
            f.read_exact(buf)?;
        }
        Ok(())
    }
}

/// Random-access segment provider that decouples *what the manifest
/// says* from *where the payload bytes live*.
///
/// [`SegmentSource::open`] parses only the header + manifest and keeps
/// the file handle, reading each encoded segment from disk the moment a
/// consumer touches it — so loading a model costs `O(manifest)` resident
/// bytes, not `O(payload)`. [`SegmentSource::from_model`] wraps an
/// in-memory container behind the same interface, which is what the
/// streaming decoder and the weight-residency cache program against.
///
/// Thread-safe: `&self` methods only, so an `Arc<SegmentSource>` can be
/// shared across decode workers. File reads are *positioned* (each call
/// carries its own offset — `pread` on unix), so concurrent prefetch
/// workers never serialize on a shared cursor.
#[derive(Debug)]
pub struct SegmentSource {
    bits: BitWidth,
    code: CodeSpec,
    layers: Vec<LayerMeta>,
    backing: Backing,
}

impl SegmentSource {
    /// Source over an in-memory container (shares the payload, never
    /// copies it).
    pub fn from_model(model: Arc<ElmModel>) -> Self {
        SegmentSource {
            bits: model.bits,
            code: model.code.clone(),
            layers: model.layers.clone(),
            backing: Backing::Memory(model),
        }
    }

    /// Open a container file **lazily**: parse header + manifest,
    /// validate the file length against the manifest, and leave the
    /// payload on disk for on-demand [`SegmentSource::read_segment`]
    /// calls.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let mut file = std::fs::File::open(path.as_ref())?;
        let head = {
            let mut r = Reader {
                inner: std::io::BufReader::new(&mut file),
            };
            read_manifest(&mut r)?
        };
        let payload_base = header_bytes(&head.layers) as u64;
        // Checked: a forged manifest can push the claimed payload length
        // near u64::MAX, and an overflowing sum here would panic (debug)
        // or wrap into a bogus comparison (release) instead of erroring.
        let expect = payload_base
            .checked_add(head.payload_len as u64)
            .ok_or_else(|| Error::Format("manifest payload length overflows".into()))?;
        let actual = file.metadata()?.len();
        if actual != expect {
            return Err(Error::Format(format!(
                "container is {actual} bytes, header + manifest claims {expect}"
            )));
        }
        Ok(SegmentSource {
            bits: head.bits,
            code: head.code,
            layers: head.layers,
            backing: Backing::File {
                file: SharedFile::new(file),
                payload_base,
            },
        })
    }

    /// Quantization bit width all layers share.
    pub fn bits(&self) -> BitWidth {
        self.bits
    }

    /// The model-global canonical Huffman code.
    pub fn code(&self) -> &CodeSpec {
        &self.code
    }

    /// Layer manifest, in storage order.
    pub fn layers(&self) -> &[LayerMeta] {
        &self.layers
    }

    /// Manifest entry for layer `index`.
    pub fn meta(&self, index: usize) -> &LayerMeta {
        &self.layers[index]
    }

    /// Number of layers.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total parameters across layers.
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.n_symbols).sum()
    }

    /// Encoded payload bytes this source keeps resident (0 for a
    /// file-backed source — that is the lazy-load win).
    pub fn resident_payload_bytes(&self) -> usize {
        match &self.backing {
            Backing::Memory(model) => model.payload.len(),
            Backing::File { .. } => 0,
        }
    }

    /// Read layer `index`'s encoded segment: borrowed from the resident
    /// payload, or a positioned read of exactly `encoded_len` bytes from
    /// disk. Concurrent callers never serialize on a seek lock (each
    /// read carries its own offset), so a prefetch worker pool scales
    /// with threads instead of queuing behind one file cursor. The
    /// allocation here is safe against adversarial manifests because
    /// [`SegmentSource::open`] has already proven every offset/length
    /// against the actual file size.
    pub fn read_segment(&self, index: usize) -> Result<Cow<'_, [u8]>> {
        let m = &self.layers[index];
        match &self.backing {
            Backing::Memory(model) => Ok(Cow::Borrowed(model.segment(index))),
            Backing::File { file, payload_base } => {
                let mut buf = vec![0u8; m.encoded_len];
                file.read_exact_at(&mut buf, payload_base + m.offset as u64)?;
                Ok(Cow::Owned(buf))
            }
        }
    }

    /// Read layer `index`'s segment and check it against the stored
    /// CRC-32 — the guard every decode path goes through, and what makes
    /// random re-entry (cache fault-in) safe against torn/corrupt reads.
    pub fn verified_segment(&self, index: usize) -> Result<Cow<'_, [u8]>> {
        let seg = self.read_segment(index)?;
        let m = &self.layers[index];
        if crate::crc32::hash(&seg) != m.crc32 {
            return Err(Error::Format(format!(
                "layer {:?}: segment CRC mismatch",
                m.name
            )));
        }
        Ok(seg)
    }
}

/// Compress a set of named fp32 layers: mixed quantization (§III-A) →
/// pooled frequency table → model-global Huffman code (§III-B) →
/// per-layer byte-aligned segments (§III-C). This is Algorithm 1's
/// `CLOUD PROCESSING` procedure end-to-end.
pub fn compress(layers: &[(String, TensorF32)], bits: BitWidth) -> Result<(ElmModel, CompressionReport)> {
    if layers.is_empty() {
        return Err(Error::InvalidArg("compress: no layers".into()));
    }
    // 1. Quantize each layer with the mixed rule.
    let quantized: Vec<QuantizedTensor> =
        layers.iter().map(|(_, w)| quantize_mixed(w, bits)).collect();

    // 2. Pool symbol frequencies across the whole model (line 11).
    let mut freq = FreqTable::new();
    for q in &quantized {
        freq.add_symbols(q.symbols.data());
    }

    // 3. One global canonical code (line 12).
    let code = CodeSpec::build(&freq)?;
    let encoder = Encoder::new(&code);

    // 4. Encode each tensor as its own byte-aligned segment (lines 13–15).
    let mut payload = Vec::new();
    let mut metas = Vec::with_capacity(layers.len());
    for ((name, _), q) in layers.iter().zip(&quantized) {
        let seg = encoder.encode_to_vec(q.symbols.data())?;
        let crc = crate::crc32::hash(&seg);
        metas.push(LayerMeta {
            name: name.clone(),
            shape: q.symbols.shape().clone(),
            params: q.params,
            n_symbols: q.symbols.numel(),
            offset: payload.len(),
            encoded_len: seg.len(),
            crc32: crc,
        });
        payload.extend_from_slice(&seg);
    }

    let n_params: usize = metas.iter().map(|m| m.n_symbols).sum();
    let report = CompressionReport {
        n_params,
        fp16_bytes: n_params * 2,
        fixed_bytes: (n_params * bits.bits() as usize).div_ceil(8),
        encoded_bytes: payload.len(),
        entropy_bits: shannon_entropy(freq.counts()),
        effective_bits: 8.0 * payload.len() as f64 / n_params as f64,
        schemes: layers
            .iter()
            .zip(&quantized)
            .map(|((n, _), q)| (n.clone(), q.params.scheme))
            .collect(),
    };
    let model = ElmModel {
        bits,
        code,
        layers: metas,
        payload,
    };
    Ok((model, report))
}

/// Decode a single layer of a model (serial path; the parallel path
/// lives in [`crate::decode`]).
pub fn decode_layer(model: &ElmModel, i: usize) -> Result<QuantizedTensor> {
    let meta = &model.layers[i];
    model.verify_segment(i)?;
    let seg = model.segment(i);
    let dec = Decoder::new(&model.code)?;
    let symbols = dec.decode(seg, meta.n_symbols)?;
    Ok(QuantizedTensor {
        symbols: TensorU8::new(meta.shape.clone(), symbols)?,
        params: meta.params,
    })
}

// ---------------------------------------------------------------- binary io

struct Writer<W: Write> {
    inner: W,
}

impl<W: Write> Writer<W> {
    fn u8(&mut self, v: u8) -> Result<()> {
        self.inner.write_all(&[v])?;
        Ok(())
    }
    fn u16(&mut self, v: u16) -> Result<()> {
        self.inner.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn u32(&mut self, v: u32) -> Result<()> {
        self.inner.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn u64(&mut self, v: u64) -> Result<()> {
        self.inner.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn f32(&mut self, v: f32) -> Result<()> {
        self.inner.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn bytes(&mut self, v: &[u8]) -> Result<()> {
        self.inner.write_all(v)?;
        Ok(())
    }
}

struct Reader<R: Read> {
    inner: R,
}

impl<R: Read> Reader<R> {
    fn u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.inner.read_exact(&mut b)?;
        Ok(b[0])
    }
    fn u16(&mut self) -> Result<u16> {
        let mut b = [0u8; 2];
        self.inner.read_exact(&mut b)?;
        Ok(u16::from_le_bytes(b))
    }
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.inner.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.inner.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn f32(&mut self) -> Result<f32> {
        let mut b = [0u8; 4];
        self.inner.read_exact(&mut b)?;
        Ok(f32::from_le_bytes(b))
    }
    fn bytes(&mut self, n: usize) -> Result<Vec<u8>> {
        let mut v = vec![0u8; n];
        self.inner.read_exact(&mut v)?;
        Ok(v)
    }
}

/// Everything a container stores *before* the payload, parsed and
/// validated: the shared decode state plus the layer manifest (with
/// per-layer payload offsets already accumulated).
struct ManifestHead {
    bits: BitWidth,
    code: CodeSpec,
    layers: Vec<LayerMeta>,
    /// Total payload length the manifest claims.
    payload_len: usize,
}

/// Parse the header + manifest off a reader, leaving it positioned at
/// the first payload byte. Shared by the eager loader
/// ([`ElmModel::read_from`]) and the lazy one ([`SegmentSource::open`]),
/// so the two paths can never diverge on validation.
fn read_manifest<R: Read>(r: &mut Reader<R>) -> Result<ManifestHead> {
    let magic = r.bytes(4)?;
    if magic != MAGIC {
        return Err(Error::Format(format!("bad magic {magic:02x?}")));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(Error::Format(format!("unsupported ELM version {version}")));
    }
    let bits = match r.u8()? {
        4 => BitWidth::U4,
        8 => BitWidth::U8,
        other => return Err(Error::Format(format!("bad bit width {other}"))),
    };
    let n_layers = r.u32()? as usize;
    if n_layers > 1_000_000 {
        return Err(Error::Format(format!("implausible layer count {n_layers}")));
    }
    let lengths = r.bytes(256)?;
    // A zero-layer container is legal (an empty weight set decompresses
    // to an empty EQW dump); it has no symbols, so an all-zero length
    // table is accepted by substituting the degenerate one-symbol code
    // — nothing will ever be decoded with it.
    let code = if n_layers == 0 && lengths.iter().all(|&l| l == 0) {
        let mut one = [0u8; 256];
        one[0] = 1;
        CodeSpec::from_lengths(&one)?
    } else {
        CodeSpec::from_lengths(&lengths)?
    };
    let mut layers = Vec::with_capacity(n_layers);
    let mut offset = 0usize;
    for _ in 0..n_layers {
        let name_len = r.u16()? as usize;
        let name = String::from_utf8(r.bytes(name_len)?)
            .map_err(|_| Error::Format("layer name not utf-8".into()))?;
        let rank = r.u8()? as usize;
        if rank > 8 {
            return Err(Error::Format(format!("implausible rank {rank}")));
        }
        let mut dims = Vec::with_capacity(rank);
        // Checked product: `Shape::numel` is an unchecked multiply, so
        // adversarial dims must be proven non-overflowing *here*, before
        // anything downstream trusts the shape.
        let mut numel: usize = 1;
        for _ in 0..rank {
            let d = r.u64()? as usize;
            numel = numel.checked_mul(d).ok_or_else(|| {
                Error::Format(format!("layer {name:?}: dimension product overflows"))
            })?;
            dims.push(d);
        }
        let shape = Shape(dims);
        let scheme = Scheme::from_tag(r.u8()?)?;
        let scale = r.f32()?;
        let zero_point = r.f32()?;
        let n_symbols = r.u64()? as usize;
        if numel != n_symbols {
            return Err(Error::Format(format!(
                "layer {name:?}: shape {shape} != {n_symbols} symbols"
            )));
        }
        let encoded_len = r.u64()? as usize;
        // Every coded symbol costs at least one bit, so a segment can
        // never decode to more than 8× its encoded bytes. Rejecting the
        // claim here caps the decode-side allocation at O(file size) —
        // without it a corrupt/adversarial manifest could demand a
        // terabyte-scale symbol buffer (and OOM the server) before any
        // CRC check ever runs.
        if n_symbols > encoded_len.saturating_mul(8) {
            return Err(Error::Format(format!(
                "layer {name:?}: {n_symbols} symbols cannot fit in {encoded_len} \
                 encoded bytes (minimum one bit per symbol)"
            )));
        }
        let crc32 = r.u32()?;
        layers.push(LayerMeta {
            name,
            shape,
            params: QuantParams {
                scheme,
                bits,
                scale,
                zero_point,
            },
            n_symbols,
            offset,
            encoded_len,
            crc32,
        });
        offset = offset
            .checked_add(encoded_len)
            .ok_or_else(|| Error::Format("payload offset overflow".into()))?;
    }
    Ok(ManifestHead {
        bits,
        code,
        layers,
        payload_len: offset,
    })
}

impl ElmModel {
    /// Serialize to a writer.
    pub fn write_to<W: Write>(&self, w: W) -> Result<()> {
        let mut w = Writer { inner: w };
        w.bytes(MAGIC)?;
        w.u32(VERSION)?;
        w.u8(self.bits.bits() as u8)?;
        w.u32(self.layers.len() as u32)?;
        w.bytes(self.code.lengths())?;
        for m in &self.layers {
            if m.name.len() > u16::MAX as usize {
                return Err(Error::InvalidArg(format!("layer name too long: {}", m.name.len())));
            }
            w.u16(m.name.len() as u16)?;
            w.bytes(m.name.as_bytes())?;
            w.u8(m.shape.rank() as u8)?;
            for &d in m.shape.dims() {
                w.u64(d as u64)?;
            }
            w.u8(m.params.scheme.tag())?;
            w.f32(m.params.scale)?;
            w.f32(m.params.zero_point)?;
            w.u64(m.n_symbols as u64)?;
            w.u64(m.encoded_len as u64)?;
            w.u32(m.crc32)?;
        }
        w.bytes(&self.payload)?;
        Ok(())
    }

    /// Save to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let f = std::fs::File::create(path)?;
        let mut buf = std::io::BufWriter::new(f);
        self.write_to(&mut buf)?;
        buf.flush()?;
        Ok(())
    }

    /// Deserialize from a reader, validating magic/version/lengths.
    pub fn read_from<R: Read>(r: R) -> Result<Self> {
        let mut r = Reader { inner: r };
        let head = read_manifest(&mut r)?;
        let mut payload = Vec::new();
        r.inner.read_to_end(&mut payload)?;
        if payload.len() != head.payload_len {
            return Err(Error::Format(format!(
                "payload is {} bytes, manifest claims {}",
                payload.len(),
                head.payload_len
            )));
        }
        Ok(ElmModel {
            bits: head.bits,
            code: head.code,
            layers: head.layers,
            payload,
        })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let f = std::fs::File::open(path)?;
        Self::read_from(std::io::BufReader::new(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::dequantize;
    use crate::rng::Rng;

    fn make_layers(seed: u64) -> Vec<(String, TensorF32)> {
        let mut rng = Rng::new(seed);
        vec![
            (
                "attn.wq".into(),
                TensorF32::new(vec![32, 64], rng.gaussian_vec(2048, 0.0, 0.04)).unwrap(),
            ),
            (
                "attn.wk".into(),
                TensorF32::new(vec![32, 64], rng.gaussian_vec(2048, 0.01, 0.03)).unwrap(),
            ),
            (
                // Single-signed layer → symmetric-unsigned branch.
                "mlp.gate_bias".into(),
                TensorF32::new(
                    vec![128],
                    (0..128).map(|_| rng.range_f32(0.0, 0.2)).collect(),
                )
                .unwrap(),
            ),
        ]
    }

    #[test]
    fn compress_then_decode_layers_is_lossless() {
        for bits in [BitWidth::U4, BitWidth::U8] {
            let layers = make_layers(1);
            let (model, report) = compress(&layers, bits).unwrap();
            assert_eq!(report.n_params, 2048 + 2048 + 128);
            for i in 0..layers.len() {
                let q = decode_layer(&model, i).unwrap();
                // Decoded symbols must equal a fresh quantization of the
                // source layer (lossless beyond quantization).
                let direct = quantize_mixed(&layers[i].1, bits);
                assert_eq!(q.symbols.data(), direct.symbols.data());
                assert_eq!(q.params, direct.params);
                // And dequantization stays within half a step.
                let dq = dequantize(&q);
                let bound = crate::quant::max_error_bound(&q.params);
                for (a, b) in layers[i].1.data().iter().zip(dq.data()) {
                    assert!((a - b).abs() <= bound);
                }
            }
        }
    }

    #[test]
    fn report_accounts_for_compression() {
        let layers = make_layers(2);
        let (model, report) = compress(&layers, BitWidth::U8).unwrap();
        assert_eq!(report.encoded_bytes, model.payload.len());
        assert!(report.effective_bits < 8.0, "huffman beats fixed width");
        assert!(report.effective_bits >= report.entropy_bits - 1e-9);
        assert!(report.fixed_bytes < report.fp16_bytes);
        assert_eq!(report.schemes.len(), 3);
        assert_eq!(report.schemes[2].1, Scheme::SymmetricUnsigned);
    }

    #[test]
    fn save_load_roundtrip_bitexact() {
        let layers = make_layers(3);
        let (model, _) = compress(&layers, BitWidth::U4).unwrap();
        let dir = std::env::temp_dir().join(format!("elm_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.elm");
        model.save(&path).unwrap();
        let loaded = ElmModel::load(&path).unwrap();
        assert_eq!(loaded.payload, model.payload);
        assert_eq!(loaded.layers.len(), model.layers.len());
        for (a, b) in loaded.layers.iter().zip(&model.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.params, b.params);
            assert_eq!(a.crc32, b.crc32);
        }
        assert_eq!(loaded.code.lengths(), model.code.lengths());
        for i in 0..layers.len() {
            assert_eq!(
                decode_layer(&loaded, i).unwrap().symbols.data(),
                decode_layer(&model, i).unwrap().symbols.data()
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_payload_is_detected_by_crc() {
        let layers = make_layers(4);
        let (mut model, _) = compress(&layers, BitWidth::U8).unwrap();
        let mid = model.layers[1].offset + model.layers[1].encoded_len / 2;
        model.payload[mid] ^= 0xFF;
        assert!(decode_layer(&model, 1).is_err());
        // Other segments unaffected.
        assert!(decode_layer(&model, 0).is_ok());
    }

    #[test]
    fn truncated_file_rejected() {
        let layers = make_layers(5);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        for cut in [3usize, 8, 12, 260, buf.len() - 1] {
            assert!(
                ElmModel::read_from(&buf[..cut]).is_err(),
                "cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = b"NOPE".to_vec();
        buf.extend_from_slice(&[0u8; 64]);
        assert!(ElmModel::read_from(buf.as_slice()).is_err());
    }

    #[test]
    fn segment_cursor_walks_execution_order_and_seeks() {
        let layers = make_layers(6);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let mut cursor = model.segments();
        assert_eq!(cursor.len(), 3);
        assert_eq!(cursor.position(), 0);
        let mut total = 0usize;
        for (i, seg) in model.segments().enumerate() {
            assert_eq!(seg.index, i);
            assert_eq!(seg.meta.name, model.layers[i].name);
            assert_eq!(seg.bytes, model.segment(i));
            assert_eq!(crate::crc32::hash(seg.bytes), seg.meta.crc32);
            total += seg.bytes.len();
        }
        assert_eq!(total, model.payload.len());
        // Seek back to the middle and re-walk the tail.
        cursor.seek(2);
        assert_eq!(cursor.remaining(), 1);
        assert_eq!(cursor.next().unwrap().index, 2);
        assert!(cursor.next().is_none());
    }

    #[test]
    fn verify_segment_catches_corruption() {
        let layers = make_layers(7);
        let (mut model, _) = compress(&layers, BitWidth::U8).unwrap();
        for i in 0..model.layers.len() {
            model.verify_segment(i).unwrap();
        }
        let off = model.layers[1].offset;
        model.payload[off] ^= 0x01;
        assert!(model.verify_segment(1).is_err());
        assert!(model.verify_segment(0).is_ok());
    }

    #[test]
    fn segment_source_memory_and_file_backings_agree() {
        let layers = make_layers(8);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let dir = std::env::temp_dir().join(format!("elm_src_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.elm");
        model.save(&path).unwrap();

        let model = Arc::new(model);
        let mem = SegmentSource::from_model(Arc::clone(&model));
        let lazy = SegmentSource::open(&path).unwrap();

        assert_eq!(mem.n_layers(), lazy.n_layers());
        assert_eq!(mem.n_params(), lazy.n_params());
        assert_eq!(mem.bits(), lazy.bits());
        assert_eq!(mem.code().lengths(), lazy.code().lengths());
        assert!(mem.resident_payload_bytes() > 0);
        assert_eq!(lazy.resident_payload_bytes(), 0, "lazy source must not slurp");

        // Random re-entry order: reads must agree byte-for-byte and pass
        // CRC verification on both backings.
        for &i in &[2usize, 0, 2, 1, 0] {
            let a = mem.verified_segment(i).unwrap();
            let b = lazy.verified_segment(i).unwrap();
            assert_eq!(a.as_ref(), b.as_ref());
            assert_eq!(a.as_ref(), model.segment(i));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn segment_source_file_corruption_caught_by_crc() {
        let layers = make_layers(9);
        let (model, _) = compress(&layers, BitWidth::U4).unwrap();
        let dir = std::env::temp_dir().join(format!("elm_srcbad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.elm");
        model.save(&path).unwrap();

        // Flip one byte inside layer 1's segment on disk.
        let mut bytes = std::fs::read(&path).unwrap();
        let base = header_bytes(&model.layers);
        bytes[base + model.layers[1].offset] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();

        let lazy = SegmentSource::open(&path).unwrap();
        assert!(lazy.verified_segment(1).is_err());
        assert!(lazy.verified_segment(0).is_ok());
        assert!(lazy.verified_segment(2).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn segment_source_rejects_wrong_file_length() {
        let layers = make_layers(10);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let dir = std::env::temp_dir().join(format!("elm_srctr_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.elm");
        model.save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();

        // Truncated payload: manifest parses, length check must fail.
        std::fs::write(&path, &full[..full.len() - 1]).unwrap();
        assert!(SegmentSource::open(&path).is_err());

        // Trailing garbage is equally rejected.
        let mut padded = full.clone();
        padded.push(0);
        std::fs::write(&path, &padded).unwrap();
        assert!(SegmentSource::open(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn header_bytes_matches_serialized_prefix() {
        let layers = make_layers(11);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        assert_eq!(buf.len(), header_bytes(&model.layers) + model.payload.len());
        assert_eq!(buf.len(), model.container_bytes());
        // The bytes at the computed payload base are the payload itself.
        assert_eq!(&buf[header_bytes(&model.layers)..], &model.payload[..]);
    }

    #[test]
    fn zero_layer_container_roundtrips_on_both_readers() {
        // `compress` refuses empty inputs, but the format allows an
        // empty weight set (e.g. a model whose every tensor stays fp32)
        // — both readers must accept it so `decompress` can emit a
        // valid empty EQW dump.
        let mut one = [0u8; 256];
        one[0] = 1;
        let model = ElmModel {
            bits: BitWidth::U8,
            code: CodeSpec::from_lengths(&one).unwrap(),
            layers: Vec::new(),
            payload: Vec::new(),
        };
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        assert_eq!(buf.len(), header_bytes(&[]));

        let loaded = ElmModel::read_from(buf.as_slice()).unwrap();
        assert!(loaded.layers.is_empty());
        assert!(loaded.payload.is_empty());
        assert_eq!(loaded.n_params(), 0);
        assert_eq!(loaded.effective_bits(), 0.0, "no params: defined, not NaN");

        let dir = std::env::temp_dir().join(format!("elm_zero_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("zero.elm");
        model.save(&path).unwrap();
        let lazy = SegmentSource::open(&path).unwrap();
        assert_eq!(lazy.n_layers(), 0);
        assert_eq!(lazy.n_params(), 0);

        // An all-zero codebook is accepted for zero layers only.
        let mut zero_code = buf.clone();
        for b in zero_code[13..13 + 256].iter_mut() {
            *b = 0;
        }
        assert!(ElmModel::read_from(zero_code.as_slice()).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adversarial_symbol_claim_rejected_before_any_allocation() {
        // Forge one layer's shape + n_symbols to demand a terabyte-scale
        // decode buffer while keeping every other field (offsets,
        // lengths, payload) intact. Both readers must reject the
        // manifest up front — long before any decode path would
        // allocate `n_symbols` bytes.
        let layers = make_layers(13);
        let (mut model, _) = compress(&layers, BitWidth::U8).unwrap();
        let huge = 1usize << 41; // ~2.2e12 symbols decoded
        model.layers[1].shape = Shape(vec![huge]);
        model.layers[1].n_symbols = huge;
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        let err = ElmModel::read_from(buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("cannot fit"), "{err}");

        let dir = std::env::temp_dir().join(format!("elm_adv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("forged.elm");
        std::fs::write(&path, &buf).unwrap();
        let err = SegmentSource::open(&path).unwrap_err();
        assert!(err.to_string().contains("cannot fit"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adversarial_payload_length_overflow_rejected_at_open() {
        // A claimed payload length within a header's distance of
        // u64::MAX would overflow the `payload_base + payload_len`
        // file-size check — that must be a clean Format error, not a
        // debug-mode panic or a release-mode wrap.
        let layers = make_layers(16);
        let (mut model, _) = compress(&layers, BitWidth::U8).unwrap();
        let prev: usize = model.layers[..2].iter().map(|m| m.encoded_len).sum();
        model.layers[2].encoded_len = usize::MAX - prev - 200;
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();

        let dir = std::env::temp_dir().join(format!("elm_adv_ov_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("forged.elm");
        std::fs::write(&path, &buf).unwrap();
        let err = SegmentSource::open(&path).unwrap_err();
        assert!(err.to_string().contains("overflows"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adversarial_dim_product_overflow_rejected() {
        // Dims whose product overflows usize must be rejected by the
        // manifest parser itself — `Shape::numel` is an unchecked
        // multiply, so nothing downstream may ever see such a shape.
        let layers = make_layers(14);
        let (mut model, _) = compress(&layers, BitWidth::U8).unwrap();
        model.layers[0].shape = Shape(vec![1usize << 40, 1usize << 40]);
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        let err = ElmModel::read_from(buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("overflows"), "{err}");
    }

    #[test]
    fn concurrent_file_backed_segment_reads_are_bitexact() {
        // Positioned reads: many threads hammering the same file-backed
        // source (no shared cursor) must each see exactly their own
        // segment's bytes, CRC-clean.
        let layers = make_layers(15);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let dir = std::env::temp_dir().join(format!("elm_conc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.elm");
        model.save(&path).unwrap();
        let lazy = Arc::new(SegmentSource::open(&path).unwrap());

        std::thread::scope(|s| {
            for t in 0..4 {
                let lazy = Arc::clone(&lazy);
                let model = &model;
                s.spawn(move || {
                    for round in 0..8 {
                        let i = (t + round) % model.layers.len();
                        let got = lazy.verified_segment(i).unwrap();
                        assert_eq!(got.as_ref(), model.segment(i));
                    }
                });
            }
        });
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn nonzero_layers_with_empty_codebook_still_rejected() {
        let layers = make_layers(12);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        // Zero out the codebook: with layers present this cannot code
        // anything and must be rejected.
        for b in buf[13..13 + 256].iter_mut() {
            *b = 0;
        }
        assert!(ElmModel::read_from(buf.as_slice()).is_err());
    }

    #[test]
    fn property_save_load_many_shapes() {
        let mut rng = Rng::new(0x57E);
        for case in 0..20 {
            let n_layers = 1 + rng.below(6);
            let layers: Vec<(String, TensorF32)> = (0..n_layers)
                .map(|i| {
                    let n = 1 + rng.below(500);
                    (
                        format!("l{case}.{i}"),
                        TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.1)).unwrap(),
                    )
                })
                .collect();
            let bits = if rng.below(2) == 0 { BitWidth::U4 } else { BitWidth::U8 };
            let (model, _) = compress(&layers, bits).unwrap();
            let mut buf = Vec::new();
            model.write_to(&mut buf).unwrap();
            let loaded = ElmModel::read_from(buf.as_slice()).unwrap();
            for i in 0..n_layers {
                assert_eq!(
                    decode_layer(&loaded, i).unwrap().symbols.data(),
                    quantize_mixed(&layers[i].1, bits).symbols.data()
                );
            }
        }
    }
}
