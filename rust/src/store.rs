//! The **ELM container** — EntroLLM's on-device compressed model format
//! (Algorithm 1 line 16: "Store model metadata: H, P, {W_c}^k, preserving
//! the weight tensor packing structure").
//!
//! Layout (little-endian):
//!
//! ```text
//! magic "ELM1" | version u32 | bitwidth u8 | n_layers u32
//! global canonical code lengths: 256 × u8      (this is "H" — canonical
//!                                               codes rebuild from lengths)
//! per layer:
//!   name_len u16 | name utf-8
//!   rank u8 | dims: rank × u64
//!   scheme u8 | scale f32 | zero_point f32
//!   n_symbols u64 | encoded_len u64 | crc32 u32
//! payload: concatenated byte-aligned encoded segments (one per layer)
//! ```
//!
//! Crucially the payload keeps **one independently decodable, byte-aligned
//! segment per weight tensor** — the "parameter space segmentation" that
//! makes §III-C parallel decoding possible: segment starts/ends are known
//! from the manifest before any bit is decoded.

use crate::entropy::shannon_entropy;
use crate::huffman::{CodeSpec, Decoder, Encoder, FreqTable};
use crate::quant::{quantize_mixed, BitWidth, QuantParams, QuantizedTensor, Scheme};
use crate::tensor::{Shape, TensorF32, TensorU8};
use crate::{Error, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"ELM1";
const VERSION: u32 = 1;

/// Per-layer manifest entry.
#[derive(Debug, Clone)]
pub struct LayerMeta {
    /// Layer name (e.g. `"blocks.3.mlp.w_in"`).
    pub name: String,
    /// Tensor shape.
    pub shape: Shape,
    /// Quantization grid parameters.
    pub params: QuantParams,
    /// Number of weight symbols in this tensor.
    pub n_symbols: usize,
    /// Byte offset of this layer's segment within the payload.
    pub offset: usize,
    /// Encoded segment length in bytes.
    pub encoded_len: usize,
    /// CRC32 of the encoded segment.
    pub crc32: u32,
}

/// A compressed model: manifest + global code + payload.
#[derive(Debug, Clone)]
pub struct ElmModel {
    /// Quantization bit width all layers share.
    pub bits: BitWidth,
    /// The model-global canonical Huffman code.
    pub code: CodeSpec,
    /// Layer manifest, in storage order.
    pub layers: Vec<LayerMeta>,
    /// Concatenated encoded segments.
    pub payload: Vec<u8>,
}

/// Storage accounting produced by [`compress`] — the Table I numbers.
#[derive(Debug, Clone)]
pub struct CompressionReport {
    /// Total parameters.
    pub n_params: usize,
    /// fp16 baseline size (2 bytes/param) — the paper's reference point.
    pub fp16_bytes: usize,
    /// Fixed-width quantized size (bit-packed, no entropy coding).
    pub fixed_bytes: usize,
    /// Huffman payload size.
    pub encoded_bytes: usize,
    /// Shannon entropy of the pooled symbol histogram (bits/param).
    pub entropy_bits: f64,
    /// Achieved effective bits/param (encoded bits / params).
    pub effective_bits: f64,
    /// Per-layer scheme chosen by the mixed rule.
    pub schemes: Vec<(String, Scheme)>,
}

impl ElmModel {
    /// Segment bytes for layer `i`.
    pub fn segment(&self, i: usize) -> &[u8] {
        let m = &self.layers[i];
        &self.payload[m.offset..m.offset + m.encoded_len]
    }

    /// Check layer `i`'s segment against its stored CRC32.
    pub fn verify_segment(&self, i: usize) -> Result<()> {
        let m = &self.layers[i];
        if crate::crc32::hash(self.segment(i)) != m.crc32 {
            return Err(Error::Format(format!(
                "layer {:?}: segment CRC mismatch",
                m.name
            )));
        }
        Ok(())
    }

    /// Cursor over the container's segments in execution (storage)
    /// order — the walk order of the streaming decoder
    /// ([`crate::decode::StreamingDecoder`]).
    pub fn segments(&self) -> SegmentCursor<'_> {
        SegmentCursor {
            model: self,
            next: 0,
        }
    }

    /// Total parameters across layers.
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.n_symbols).sum()
    }

    /// Effective bits/param of the stored payload.
    pub fn effective_bits(&self) -> f64 {
        8.0 * self.payload.len() as f64 / self.n_params() as f64
    }

    /// Serialized container size in bytes (manifest + payload).
    pub fn container_bytes(&self) -> usize {
        let manifest: usize = self
            .layers
            .iter()
            .map(|l| 2 + l.name.len() + 1 + 8 * l.shape.rank() + 1 + 4 + 4 + 8 + 8 + 4)
            .sum();
        4 + 4 + 1 + 4 + 256 + manifest + self.payload.len()
    }
}

/// One independently decodable, byte-aligned segment of an
/// [`ElmModel`]: the §III-C unit of parallel and streaming decode.
#[derive(Debug, Clone, Copy)]
pub struct SegmentRef<'a> {
    /// Layer index in execution (storage) order.
    pub index: usize,
    /// The layer's manifest entry.
    pub meta: &'a LayerMeta,
    /// The encoded segment bytes.
    pub bytes: &'a [u8],
}

/// Iterator/cursor over a container's segments in execution order.
///
/// Unlike a plain iterator it can be repositioned ([`SegmentCursor::seek`]),
/// which is what a resuming or window-refilling consumer needs.
#[derive(Debug, Clone)]
pub struct SegmentCursor<'a> {
    model: &'a ElmModel,
    next: usize,
}

impl<'a> SegmentCursor<'a> {
    /// Reposition the cursor to layer `index`.
    pub fn seek(&mut self, index: usize) {
        self.next = index;
    }

    /// Index of the next segment the cursor will yield.
    pub fn position(&self) -> usize {
        self.next
    }

    /// Segments left to yield.
    pub fn remaining(&self) -> usize {
        self.model.layers.len().saturating_sub(self.next)
    }
}

impl<'a> Iterator for SegmentCursor<'a> {
    type Item = SegmentRef<'a>;

    fn next(&mut self) -> Option<SegmentRef<'a>> {
        if self.next >= self.model.layers.len() {
            return None;
        }
        let index = self.next;
        self.next += 1;
        Some(SegmentRef {
            index,
            meta: &self.model.layers[index],
            bytes: self.model.segment(index),
        })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.remaining();
        (n, Some(n))
    }
}

impl<'a> ExactSizeIterator for SegmentCursor<'a> {}

/// Compress a set of named fp32 layers: mixed quantization (§III-A) →
/// pooled frequency table → model-global Huffman code (§III-B) →
/// per-layer byte-aligned segments (§III-C). This is Algorithm 1's
/// `CLOUD PROCESSING` procedure end-to-end.
pub fn compress(layers: &[(String, TensorF32)], bits: BitWidth) -> Result<(ElmModel, CompressionReport)> {
    if layers.is_empty() {
        return Err(Error::InvalidArg("compress: no layers".into()));
    }
    // 1. Quantize each layer with the mixed rule.
    let quantized: Vec<QuantizedTensor> =
        layers.iter().map(|(_, w)| quantize_mixed(w, bits)).collect();

    // 2. Pool symbol frequencies across the whole model (line 11).
    let mut freq = FreqTable::new();
    for q in &quantized {
        freq.add_symbols(q.symbols.data());
    }

    // 3. One global canonical code (line 12).
    let code = CodeSpec::build(&freq)?;
    let encoder = Encoder::new(&code);

    // 4. Encode each tensor as its own byte-aligned segment (lines 13–15).
    let mut payload = Vec::new();
    let mut metas = Vec::with_capacity(layers.len());
    for ((name, _), q) in layers.iter().zip(&quantized) {
        let seg = encoder.encode_to_vec(q.symbols.data())?;
        let crc = crate::crc32::hash(&seg);
        metas.push(LayerMeta {
            name: name.clone(),
            shape: q.symbols.shape().clone(),
            params: q.params,
            n_symbols: q.symbols.numel(),
            offset: payload.len(),
            encoded_len: seg.len(),
            crc32: crc,
        });
        payload.extend_from_slice(&seg);
    }

    let n_params: usize = metas.iter().map(|m| m.n_symbols).sum();
    let report = CompressionReport {
        n_params,
        fp16_bytes: n_params * 2,
        fixed_bytes: (n_params * bits.bits() as usize).div_ceil(8),
        encoded_bytes: payload.len(),
        entropy_bits: shannon_entropy(freq.counts()),
        effective_bits: 8.0 * payload.len() as f64 / n_params as f64,
        schemes: layers
            .iter()
            .zip(&quantized)
            .map(|((n, _), q)| (n.clone(), q.params.scheme))
            .collect(),
    };
    let model = ElmModel {
        bits,
        code,
        layers: metas,
        payload,
    };
    Ok((model, report))
}

/// Decode a single layer of a model (serial path; the parallel path
/// lives in [`crate::decode`]).
pub fn decode_layer(model: &ElmModel, i: usize) -> Result<QuantizedTensor> {
    let meta = &model.layers[i];
    model.verify_segment(i)?;
    let seg = model.segment(i);
    let dec = Decoder::new(&model.code)?;
    let symbols = dec.decode(seg, meta.n_symbols)?;
    Ok(QuantizedTensor {
        symbols: TensorU8::new(meta.shape.clone(), symbols)?,
        params: meta.params,
    })
}

// ---------------------------------------------------------------- binary io

struct Writer<W: Write> {
    inner: W,
}

impl<W: Write> Writer<W> {
    fn u8(&mut self, v: u8) -> Result<()> {
        self.inner.write_all(&[v])?;
        Ok(())
    }
    fn u16(&mut self, v: u16) -> Result<()> {
        self.inner.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn u32(&mut self, v: u32) -> Result<()> {
        self.inner.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn u64(&mut self, v: u64) -> Result<()> {
        self.inner.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn f32(&mut self, v: f32) -> Result<()> {
        self.inner.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn bytes(&mut self, v: &[u8]) -> Result<()> {
        self.inner.write_all(v)?;
        Ok(())
    }
}

struct Reader<R: Read> {
    inner: R,
}

impl<R: Read> Reader<R> {
    fn u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.inner.read_exact(&mut b)?;
        Ok(b[0])
    }
    fn u16(&mut self) -> Result<u16> {
        let mut b = [0u8; 2];
        self.inner.read_exact(&mut b)?;
        Ok(u16::from_le_bytes(b))
    }
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.inner.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.inner.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn f32(&mut self) -> Result<f32> {
        let mut b = [0u8; 4];
        self.inner.read_exact(&mut b)?;
        Ok(f32::from_le_bytes(b))
    }
    fn bytes(&mut self, n: usize) -> Result<Vec<u8>> {
        let mut v = vec![0u8; n];
        self.inner.read_exact(&mut v)?;
        Ok(v)
    }
}

impl ElmModel {
    /// Serialize to a writer.
    pub fn write_to<W: Write>(&self, w: W) -> Result<()> {
        let mut w = Writer { inner: w };
        w.bytes(MAGIC)?;
        w.u32(VERSION)?;
        w.u8(self.bits.bits() as u8)?;
        w.u32(self.layers.len() as u32)?;
        w.bytes(self.code.lengths())?;
        for m in &self.layers {
            if m.name.len() > u16::MAX as usize {
                return Err(Error::InvalidArg(format!("layer name too long: {}", m.name.len())));
            }
            w.u16(m.name.len() as u16)?;
            w.bytes(m.name.as_bytes())?;
            w.u8(m.shape.rank() as u8)?;
            for &d in m.shape.dims() {
                w.u64(d as u64)?;
            }
            w.u8(m.params.scheme.tag())?;
            w.f32(m.params.scale)?;
            w.f32(m.params.zero_point)?;
            w.u64(m.n_symbols as u64)?;
            w.u64(m.encoded_len as u64)?;
            w.u32(m.crc32)?;
        }
        w.bytes(&self.payload)?;
        Ok(())
    }

    /// Save to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let f = std::fs::File::create(path)?;
        let mut buf = std::io::BufWriter::new(f);
        self.write_to(&mut buf)?;
        buf.flush()?;
        Ok(())
    }

    /// Deserialize from a reader, validating magic/version/lengths.
    pub fn read_from<R: Read>(r: R) -> Result<Self> {
        let mut r = Reader { inner: r };
        let magic = r.bytes(4)?;
        if magic != MAGIC {
            return Err(Error::Format(format!("bad magic {magic:02x?}")));
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(Error::Format(format!("unsupported ELM version {version}")));
        }
        let bits = match r.u8()? {
            4 => BitWidth::U4,
            8 => BitWidth::U8,
            other => return Err(Error::Format(format!("bad bit width {other}"))),
        };
        let n_layers = r.u32()? as usize;
        if n_layers == 0 || n_layers > 1_000_000 {
            return Err(Error::Format(format!("implausible layer count {n_layers}")));
        }
        let lengths = r.bytes(256)?;
        let code = CodeSpec::from_lengths(&lengths)?;
        let mut layers = Vec::with_capacity(n_layers);
        let mut offset = 0usize;
        for _ in 0..n_layers {
            let name_len = r.u16()? as usize;
            let name = String::from_utf8(r.bytes(name_len)?)
                .map_err(|_| Error::Format("layer name not utf-8".into()))?;
            let rank = r.u8()? as usize;
            if rank > 8 {
                return Err(Error::Format(format!("implausible rank {rank}")));
            }
            let mut dims = Vec::with_capacity(rank);
            for _ in 0..rank {
                dims.push(r.u64()? as usize);
            }
            let shape = Shape(dims);
            let scheme = Scheme::from_tag(r.u8()?)?;
            let scale = r.f32()?;
            let zero_point = r.f32()?;
            let n_symbols = r.u64()? as usize;
            if shape.numel() != n_symbols {
                return Err(Error::Format(format!(
                    "layer {name:?}: shape {shape} != {n_symbols} symbols"
                )));
            }
            let encoded_len = r.u64()? as usize;
            let crc32 = r.u32()?;
            layers.push(LayerMeta {
                name,
                shape,
                params: QuantParams {
                    scheme,
                    bits,
                    scale,
                    zero_point,
                },
                n_symbols,
                offset,
                encoded_len,
                crc32,
            });
            offset = offset
                .checked_add(encoded_len)
                .ok_or_else(|| Error::Format("payload offset overflow".into()))?;
        }
        let mut payload = Vec::new();
        r.inner.read_to_end(&mut payload)?;
        if payload.len() != offset {
            return Err(Error::Format(format!(
                "payload is {} bytes, manifest claims {offset}",
                payload.len()
            )));
        }
        Ok(ElmModel {
            bits,
            code,
            layers,
            payload,
        })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let f = std::fs::File::open(path)?;
        Self::read_from(std::io::BufReader::new(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::dequantize;
    use crate::rng::Rng;

    fn make_layers(seed: u64) -> Vec<(String, TensorF32)> {
        let mut rng = Rng::new(seed);
        vec![
            (
                "attn.wq".into(),
                TensorF32::new(vec![32, 64], rng.gaussian_vec(2048, 0.0, 0.04)).unwrap(),
            ),
            (
                "attn.wk".into(),
                TensorF32::new(vec![32, 64], rng.gaussian_vec(2048, 0.01, 0.03)).unwrap(),
            ),
            (
                // Single-signed layer → symmetric-unsigned branch.
                "mlp.gate_bias".into(),
                TensorF32::new(
                    vec![128],
                    (0..128).map(|_| rng.range_f32(0.0, 0.2)).collect(),
                )
                .unwrap(),
            ),
        ]
    }

    #[test]
    fn compress_then_decode_layers_is_lossless() {
        for bits in [BitWidth::U4, BitWidth::U8] {
            let layers = make_layers(1);
            let (model, report) = compress(&layers, bits).unwrap();
            assert_eq!(report.n_params, 2048 + 2048 + 128);
            for i in 0..layers.len() {
                let q = decode_layer(&model, i).unwrap();
                // Decoded symbols must equal a fresh quantization of the
                // source layer (lossless beyond quantization).
                let direct = quantize_mixed(&layers[i].1, bits);
                assert_eq!(q.symbols.data(), direct.symbols.data());
                assert_eq!(q.params, direct.params);
                // And dequantization stays within half a step.
                let dq = dequantize(&q);
                let bound = crate::quant::max_error_bound(&q.params);
                for (a, b) in layers[i].1.data().iter().zip(dq.data()) {
                    assert!((a - b).abs() <= bound);
                }
            }
        }
    }

    #[test]
    fn report_accounts_for_compression() {
        let layers = make_layers(2);
        let (model, report) = compress(&layers, BitWidth::U8).unwrap();
        assert_eq!(report.encoded_bytes, model.payload.len());
        assert!(report.effective_bits < 8.0, "huffman beats fixed width");
        assert!(report.effective_bits >= report.entropy_bits - 1e-9);
        assert!(report.fixed_bytes < report.fp16_bytes);
        assert_eq!(report.schemes.len(), 3);
        assert_eq!(report.schemes[2].1, Scheme::SymmetricUnsigned);
    }

    #[test]
    fn save_load_roundtrip_bitexact() {
        let layers = make_layers(3);
        let (model, _) = compress(&layers, BitWidth::U4).unwrap();
        let dir = std::env::temp_dir().join(format!("elm_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.elm");
        model.save(&path).unwrap();
        let loaded = ElmModel::load(&path).unwrap();
        assert_eq!(loaded.payload, model.payload);
        assert_eq!(loaded.layers.len(), model.layers.len());
        for (a, b) in loaded.layers.iter().zip(&model.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.params, b.params);
            assert_eq!(a.crc32, b.crc32);
        }
        assert_eq!(loaded.code.lengths(), model.code.lengths());
        for i in 0..layers.len() {
            assert_eq!(
                decode_layer(&loaded, i).unwrap().symbols.data(),
                decode_layer(&model, i).unwrap().symbols.data()
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_payload_is_detected_by_crc() {
        let layers = make_layers(4);
        let (mut model, _) = compress(&layers, BitWidth::U8).unwrap();
        let mid = model.layers[1].offset + model.layers[1].encoded_len / 2;
        model.payload[mid] ^= 0xFF;
        assert!(decode_layer(&model, 1).is_err());
        // Other segments unaffected.
        assert!(decode_layer(&model, 0).is_ok());
    }

    #[test]
    fn truncated_file_rejected() {
        let layers = make_layers(5);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        for cut in [3usize, 8, 12, 260, buf.len() - 1] {
            assert!(
                ElmModel::read_from(&buf[..cut]).is_err(),
                "cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = b"NOPE".to_vec();
        buf.extend_from_slice(&[0u8; 64]);
        assert!(ElmModel::read_from(buf.as_slice()).is_err());
    }

    #[test]
    fn segment_cursor_walks_execution_order_and_seeks() {
        let layers = make_layers(6);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let mut cursor = model.segments();
        assert_eq!(cursor.len(), 3);
        assert_eq!(cursor.position(), 0);
        let mut total = 0usize;
        for (i, seg) in model.segments().enumerate() {
            assert_eq!(seg.index, i);
            assert_eq!(seg.meta.name, model.layers[i].name);
            assert_eq!(seg.bytes, model.segment(i));
            assert_eq!(crate::crc32::hash(seg.bytes), seg.meta.crc32);
            total += seg.bytes.len();
        }
        assert_eq!(total, model.payload.len());
        // Seek back to the middle and re-walk the tail.
        cursor.seek(2);
        assert_eq!(cursor.remaining(), 1);
        assert_eq!(cursor.next().unwrap().index, 2);
        assert!(cursor.next().is_none());
    }

    #[test]
    fn verify_segment_catches_corruption() {
        let layers = make_layers(7);
        let (mut model, _) = compress(&layers, BitWidth::U8).unwrap();
        for i in 0..model.layers.len() {
            model.verify_segment(i).unwrap();
        }
        let off = model.layers[1].offset;
        model.payload[off] ^= 0x01;
        assert!(model.verify_segment(1).is_err());
        assert!(model.verify_segment(0).is_ok());
    }

    #[test]
    fn property_save_load_many_shapes() {
        let mut rng = Rng::new(0x57E);
        for case in 0..20 {
            let n_layers = 1 + rng.below(6);
            let layers: Vec<(String, TensorF32)> = (0..n_layers)
                .map(|i| {
                    let n = 1 + rng.below(500);
                    (
                        format!("l{case}.{i}"),
                        TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.1)).unwrap(),
                    )
                })
                .collect();
            let bits = if rng.below(2) == 0 { BitWidth::U4 } else { BitWidth::U8 };
            let (model, _) = compress(&layers, bits).unwrap();
            let mut buf = Vec::new();
            model.write_to(&mut buf).unwrap();
            let loaded = ElmModel::read_from(buf.as_slice()).unwrap();
            for i in 0..n_layers {
                assert_eq!(
                    decode_layer(&loaded, i).unwrap().symbols.data(),
                    quantize_mixed(&layers[i].1, bits).symbols.data()
                );
            }
        }
    }
}
