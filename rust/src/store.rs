//! The **ELM container** — EntroLLM's on-device compressed model format
//! (Algorithm 1 line 16: "Store model metadata: H, P, {W_c}^k, preserving
//! the weight tensor packing structure").
//!
//! Layout (little-endian):
//!
//! ```text
//! magic "ELM1" | version u32 (= 3) | bitwidth u8 | n_layers u32
//! global canonical code lengths: 256 × u8      (this is "H" — canonical
//!                                               codes rebuild from lengths)
//! global tANS slot counts: 256 × u16           (v3 only; all-zero =
//!                                               no tANS table present)
//! per layer:
//!   name_len u16 | name utf-8
//!   rank u8 | dims: rank × u64
//!   scheme u8 | scale f32 | zero_point f32
//!   n_symbols u64 | encoded_len u64 | crc32 u32
//!   codec u8                                     (v3 only: 0=huffman 1=tans)
//!   n_tiles u32                                  (v2+)
//!   per tile: n_symbols u64 | encoded_len u64 | crc32 u32
//!             | codec u8                         (v3 only; must equal
//!                                                 the layer's)
//! payload: concatenated byte-aligned encoded segments (one per layer),
//!          each segment the concatenation of its byte-aligned tiles
//! ```
//!
//! Crucially the payload keeps **one independently decodable, byte-aligned
//! segment per weight tensor** — the "parameter space segmentation" that
//! makes §III-C parallel decoding possible: segment starts/ends are known
//! from the manifest before any bit is decoded.
//!
//! **v2 tiles** carve each layer segment into independently decodable,
//! byte-aligned sub-streams so the unit of parallel decode and
//! decode-ahead prefetch is smaller than a whole layer: every prefetch
//! worker can attack a single hot layer instead of serializing behind
//! it. Tile byte offsets and symbol offsets are derived by accumulation
//! (never stored); each tile carries its own CRC-32 so corruption is
//! isolated to one tile. **v3 codec negotiation** makes the entropy
//! codec a per-layer manifest field ([`crate::codec::Codec`]): a layer
//! is either Huffman- or tANS-coded, chosen at compression time
//! ([`CodecChoice`], with `Auto` picking per layer by measured encoded
//! size). **v1 and v2 containers remain readable forever**:
//! [`read_manifest`] dispatches on the version field, synthesizes one
//! whole-segment tile per layer for v1, and defaults the codec to
//! Huffman for both pre-v3 versions, so every tile-aware consumer sees
//! a uniform model.
//!
//! The byte-level specification third parties need to write their own
//! encoders/decoders lives in `docs/FORMAT.md` at the repository root;
//! this module is the reference implementation.
//!
//! Two access modes:
//!
//! * [`ElmModel`] holds the whole payload in memory (the cloud/build
//!   side, and small models).
//! * [`SegmentSource`] abstracts *where the payload bytes live*: opened
//!   with [`SegmentSource::open`] it parses only the header + manifest
//!   and reads each segment from disk on demand, so a streaming or
//!   cache-resident consumer ([`crate::decode::StreamingDecoder`],
//!   [`crate::residency::WeightCache`]) never pays `O(model)` RSS.

use crate::ans::AnsTable;
use crate::codec::{Codec, CodecSet};
use crate::entropy::shannon_entropy;
use crate::huffman::{CodeSpec, Encoder, FreqTable};
use crate::quant::{quantize_mixed, BitWidth, QuantParams, QuantizedTensor, Scheme};
use crate::tensor::{Shape, TensorF32, TensorU8};
use crate::{Error, Result};
use std::borrow::Cow;
use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

const MAGIC: &[u8; 4] = b"ELM1";
/// Version written by this build (v3: per-layer codec negotiation).
const VERSION: u32 = 3;
/// The tiled, Huffman-only predecessor, still readable.
const VERSION_V2: u32 = 2;
/// The original single-tile-per-layer format, still readable.
const VERSION_V1: u32 = 1;
/// Serialized bytes per v2 tile-table entry: n_symbols u64 +
/// encoded_len u64 + crc32 u32.
const TILE_ENTRY_BYTES: usize = 8 + 8 + 4;
/// v3 tile-table entry: the v2 fields plus a codec id byte (which must
/// equal the layer's).
const TILE_ENTRY_BYTES_V3: usize = TILE_ENTRY_BYTES + 1;
/// Serialized tANS table section (256 × u16 normalized slot counts);
/// all-zero means "no tANS table in this container".
const ANS_TABLE_BYTES: usize = crate::ans::SERIALIZED_BYTES;

/// One independently decodable, byte-aligned **tile** of a layer
/// segment — the v2 unit of parallel decode and prefetch claim.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TileMeta {
    /// First symbol (decoded byte) this tile covers within its layer.
    pub sym_offset: usize,
    /// Symbols decoded from this tile.
    pub n_symbols: usize,
    /// Byte offset of this tile within the **payload** (absolute, not
    /// layer-relative).
    pub offset: usize,
    /// Encoded tile length in bytes.
    pub encoded_len: usize,
    /// CRC32 of the encoded tile bytes.
    pub crc32: u32,
}

/// Per-layer manifest entry.
#[derive(Debug, Clone)]
pub struct LayerMeta {
    /// Layer name (e.g. `"blocks.3.mlp.w_in"`).
    pub name: String,
    /// Tensor shape.
    pub shape: Shape,
    /// Quantization grid parameters.
    pub params: QuantParams,
    /// Number of weight symbols in this tensor.
    pub n_symbols: usize,
    /// Byte offset of this layer's segment within the payload.
    pub offset: usize,
    /// Encoded segment length in bytes.
    pub encoded_len: usize,
    /// CRC32 of the encoded segment.
    pub crc32: u32,
    /// Entropy codec this layer's tiles were encoded with (v3 manifest
    /// field; pre-v3 containers default to [`Codec::Huffman`]). All of
    /// a layer's tiles share one codec — mixing happens *across*
    /// layers (the `Auto` choice), never within one.
    pub codec: Codec,
    /// Independently decodable tiles covering the segment, in symbol
    /// order. Always non-empty: v1 containers get one synthesized
    /// whole-segment tile.
    pub tiles: Vec<TileMeta>,
}

/// A compressed model: manifest + global code + payload.
#[derive(Debug, Clone)]
pub struct ElmModel {
    /// Quantization bit width all layers share.
    pub bits: BitWidth,
    /// The model-global canonical Huffman code.
    pub code: CodeSpec,
    /// The model-global tANS table — present iff at least one layer is
    /// tANS-coded (serialized as the v3 slot-count section).
    pub ans: Option<AnsTable>,
    /// Layer manifest, in storage order.
    pub layers: Vec<LayerMeta>,
    /// Concatenated encoded segments.
    pub payload: Vec<u8>,
}

/// Storage accounting produced by [`compress`] — the Table I numbers.
#[derive(Debug, Clone)]
pub struct CompressionReport {
    /// Total parameters.
    pub n_params: usize,
    /// fp16 baseline size (2 bytes/param) — the paper's reference point.
    pub fp16_bytes: usize,
    /// Fixed-width quantized size (bit-packed, no entropy coding).
    pub fixed_bytes: usize,
    /// Entropy-coded payload size (whichever codecs were chosen).
    pub encoded_bytes: usize,
    /// Shannon entropy of the pooled symbol histogram (bits/param).
    pub entropy_bits: f64,
    /// Achieved effective bits/param (encoded bits / params).
    pub effective_bits: f64,
    /// Per-layer scheme chosen by the mixed rule.
    pub schemes: Vec<(String, Scheme)>,
    /// Per-layer entropy codec actually stored (all Huffman unless the
    /// [`CodecChoice`] said otherwise).
    pub codecs: Vec<(String, Codec)>,
}

impl ElmModel {
    /// Segment bytes for layer `i`.
    pub fn segment(&self, i: usize) -> &[u8] {
        let m = &self.layers[i];
        &self.payload[m.offset..m.offset + m.encoded_len]
    }

    /// Check layer `i`'s segment against its stored CRC32.
    pub fn verify_segment(&self, i: usize) -> Result<()> {
        let m = &self.layers[i];
        if crate::crc32::hash(self.segment(i)) != m.crc32 {
            return Err(Error::Format(format!(
                "layer {:?}: segment CRC mismatch",
                m.name
            )));
        }
        Ok(())
    }

    /// Encoded bytes of tile `t` of layer `i`.
    pub fn tile_bytes(&self, i: usize, t: usize) -> &[u8] {
        let tile = &self.layers[i].tiles[t];
        &self.payload[tile.offset..tile.offset + tile.encoded_len]
    }

    /// Check tile `t` of layer `i` against its own CRC32 — corruption
    /// in one tile never implicates its siblings.
    pub fn verify_tile(&self, i: usize, t: usize) -> Result<()> {
        let m = &self.layers[i];
        if crate::crc32::hash(self.tile_bytes(i, t)) != m.tiles[t].crc32 {
            return Err(Error::Format(format!(
                "layer {:?}: tile {t} CRC mismatch",
                m.name
            )));
        }
        Ok(())
    }

    /// Cursor over the container's segments in execution (storage)
    /// order — the walk order of the streaming decoder
    /// ([`crate::decode::StreamingDecoder`]).
    pub fn segments(&self) -> SegmentCursor<'_> {
        SegmentCursor {
            model: self,
            next: 0,
        }
    }

    /// Total parameters across layers.
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.n_symbols).sum()
    }

    /// Effective bits/param of the stored payload (0 for a zero-layer
    /// container — no params, no payload).
    pub fn effective_bits(&self) -> f64 {
        let n = self.n_params();
        if n == 0 {
            return 0.0;
        }
        8.0 * self.payload.len() as f64 / n as f64
    }

    /// Serialized container size in bytes (manifest + payload).
    pub fn container_bytes(&self) -> usize {
        header_bytes(&self.layers) + self.payload.len()
    }
}

/// Serialized size of everything **before** the payload: magic, version,
/// bit width, layer count, the 256-byte code-length table, the 512-byte
/// tANS slot-count section, and the layer manifest (each layer's codec
/// byte and tile table included). This is also the payload's byte
/// offset within a container file written by this build, which is what
/// lazy segment reads seek relative to. (A *parsed* v1/v2 container's
/// payload base differs — [`SegmentSource::open`] uses the header
/// length accumulated during parsing, not this function.)
pub fn header_bytes(layers: &[LayerMeta]) -> usize {
    let manifest: usize = layers
        .iter()
        .map(|l| {
            2 + l.name.len()
                + 1
                + 8 * l.shape.rank()
                + 1
                + 4
                + 4
                + 8
                + 8
                + 4
                + 1
                + 4
                + TILE_ENTRY_BYTES_V3 * l.tiles.len()
        })
        .sum();
    4 + 4 + 1 + 4 + 256 + ANS_TABLE_BYTES + manifest
}

/// One independently decodable, byte-aligned segment of an
/// [`ElmModel`]: the §III-C unit of parallel and streaming decode.
#[derive(Debug, Clone, Copy)]
pub struct SegmentRef<'a> {
    /// Layer index in execution (storage) order.
    pub index: usize,
    /// The layer's manifest entry.
    pub meta: &'a LayerMeta,
    /// The encoded segment bytes.
    pub bytes: &'a [u8],
}

/// Iterator/cursor over a container's segments in execution order.
///
/// Unlike a plain iterator it can be repositioned ([`SegmentCursor::seek`]),
/// which is what a resuming or window-refilling consumer needs.
#[derive(Debug, Clone)]
pub struct SegmentCursor<'a> {
    model: &'a ElmModel,
    next: usize,
}

impl<'a> SegmentCursor<'a> {
    /// Reposition the cursor to layer `index`.
    pub fn seek(&mut self, index: usize) {
        self.next = index;
    }

    /// Index of the next segment the cursor will yield.
    pub fn position(&self) -> usize {
        self.next
    }

    /// Segments left to yield.
    pub fn remaining(&self) -> usize {
        self.model.layers.len().saturating_sub(self.next)
    }
}

impl<'a> Iterator for SegmentCursor<'a> {
    type Item = SegmentRef<'a>;

    fn next(&mut self) -> Option<SegmentRef<'a>> {
        if self.next >= self.model.layers.len() {
            return None;
        }
        let index = self.next;
        self.next += 1;
        Some(SegmentRef {
            index,
            meta: &self.model.layers[index],
            bytes: self.model.segment(index),
        })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.remaining();
        (n, Some(n))
    }
}

impl<'a> ExactSizeIterator for SegmentCursor<'a> {}

/// Where a [`SegmentSource`]'s payload bytes live.
#[derive(Debug)]
enum Backing {
    /// Whole payload resident in memory (wraps an [`ElmModel`]).
    Memory(Arc<ElmModel>),
    /// Payload left on disk; each segment is read on demand.
    File {
        file: SharedFile,
        /// Byte offset of the payload within the file (= header size).
        payload_base: u64,
    },
}

/// A container file shared by concurrent readers.
///
/// On unix every read is a *positioned* read (`pread`), so prefetch
/// workers and fault-on-demand consumers never serialize on a seek
/// lock — each call carries its own offset and the kernel handles the
/// concurrency. Elsewhere the portable fallback serializes seek+read
/// behind a mutex (recovering, not panicking, if a reader thread ever
/// poisoned it: the cursor is repositioned on every read, so there is
/// no state to corrupt).
#[derive(Debug)]
struct SharedFile {
    #[cfg(unix)]
    file: std::fs::File,
    #[cfg(not(unix))]
    file: std::sync::Mutex<std::fs::File>,
}

impl SharedFile {
    fn new(file: std::fs::File) -> Self {
        #[cfg(unix)]
        {
            SharedFile { file }
        }
        #[cfg(not(unix))]
        {
            SharedFile {
                file: std::sync::Mutex::new(file),
            }
        }
    }

    /// Fill `buf` from absolute file offset `offset`.
    fn read_exact_at(&self, buf: &mut [u8], offset: u64) -> Result<()> {
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt as _;
            self.file.read_exact_at(buf, offset)?;
        }
        #[cfg(not(unix))]
        {
            use std::io::Seek as _;
            let mut f = self
                .file
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            f.seek(std::io::SeekFrom::Start(offset))?;
            f.read_exact(buf)?;
        }
        Ok(())
    }
}

/// Random-access segment provider that decouples *what the manifest
/// says* from *where the payload bytes live*.
///
/// [`SegmentSource::open`] parses only the header + manifest and keeps
/// the file handle, reading each encoded segment from disk the moment a
/// consumer touches it — so loading a model costs `O(manifest)` resident
/// bytes, not `O(payload)`. [`SegmentSource::from_model`] wraps an
/// in-memory container behind the same interface, which is what the
/// streaming decoder and the weight-residency cache program against.
///
/// Thread-safe: `&self` methods only, so an `Arc<SegmentSource>` can be
/// shared across decode workers. File reads are *positioned* (each call
/// carries its own offset — `pread` on unix), so concurrent prefetch
/// workers never serialize on a shared cursor.
#[derive(Debug)]
pub struct SegmentSource {
    bits: BitWidth,
    code: CodeSpec,
    ans: Option<AnsTable>,
    layers: Vec<LayerMeta>,
    backing: Backing,
}

impl SegmentSource {
    /// Source over an in-memory container (shares the payload, never
    /// copies it).
    pub fn from_model(model: Arc<ElmModel>) -> Self {
        SegmentSource {
            bits: model.bits,
            code: model.code.clone(),
            ans: model.ans.clone(),
            layers: model.layers.clone(),
            backing: Backing::Memory(model),
        }
    }

    /// Open a container file **lazily**: parse header + manifest,
    /// validate the file length against the manifest, and leave the
    /// payload on disk for on-demand [`SegmentSource::read_segment`]
    /// calls.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let mut file = std::fs::File::open(path.as_ref())?;
        let head = {
            let mut r = Reader {
                inner: std::io::BufReader::new(&mut file),
            };
            read_manifest(&mut r)?
        };
        // v1 and v2 manifests serialize to different lengths for the
        // same layers, so the payload base is whatever the parser
        // actually consumed, not a recomputation under today's version.
        let payload_base = head.header_len as u64;
        // Checked: a forged manifest can push the claimed payload length
        // near u64::MAX, and an overflowing sum here would panic (debug)
        // or wrap into a bogus comparison (release) instead of erroring.
        let expect = payload_base
            .checked_add(head.payload_len as u64)
            .ok_or_else(|| Error::Format("manifest payload length overflows".into()))?;
        let actual = file.metadata()?.len();
        if actual != expect {
            return Err(Error::Format(format!(
                "container is {actual} bytes, header + manifest claims {expect}"
            )));
        }
        Ok(SegmentSource {
            bits: head.bits,
            code: head.code,
            ans: head.ans,
            layers: head.layers,
            backing: Backing::File {
                file: SharedFile::new(file),
                payload_base,
            },
        })
    }

    /// Quantization bit width all layers share.
    pub fn bits(&self) -> BitWidth {
        self.bits
    }

    /// The model-global canonical Huffman code.
    pub fn code(&self) -> &CodeSpec {
        &self.code
    }

    /// The model-global tANS table, if any layer is tANS-coded — what
    /// [`CodecSet::new`] takes next to [`SegmentSource::code`].
    pub fn ans_table(&self) -> Option<&AnsTable> {
        self.ans.as_ref()
    }

    /// Layer manifest, in storage order.
    pub fn layers(&self) -> &[LayerMeta] {
        &self.layers
    }

    /// Manifest entry for layer `index`.
    pub fn meta(&self, index: usize) -> &LayerMeta {
        &self.layers[index]
    }

    /// Number of layers.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total parameters across layers.
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.n_symbols).sum()
    }

    /// Encoded payload bytes this source keeps resident (0 for a
    /// file-backed source — that is the lazy-load win).
    pub fn resident_payload_bytes(&self) -> usize {
        match &self.backing {
            Backing::Memory(model) => model.payload.len(),
            Backing::File { .. } => 0,
        }
    }

    /// Read layer `index`'s encoded segment: borrowed from the resident
    /// payload, or a positioned read of exactly `encoded_len` bytes from
    /// disk. Concurrent callers never serialize on a seek lock (each
    /// read carries its own offset), so a prefetch worker pool scales
    /// with threads instead of queuing behind one file cursor. The
    /// allocation here is safe against adversarial manifests because
    /// [`SegmentSource::open`] has already proven every offset/length
    /// against the actual file size.
    pub fn read_segment(&self, index: usize) -> Result<Cow<'_, [u8]>> {
        let m = &self.layers[index];
        match &self.backing {
            Backing::Memory(model) => Ok(Cow::Borrowed(model.segment(index))),
            Backing::File { file, payload_base } => {
                let mut buf = vec![0u8; m.encoded_len];
                file.read_exact_at(&mut buf, payload_base + m.offset as u64)?;
                Ok(Cow::Owned(buf))
            }
        }
    }

    /// Read layer `index`'s segment and check it against the stored
    /// CRC-32 — the guard every decode path goes through, and what makes
    /// random re-entry (cache fault-in) safe against torn/corrupt reads.
    pub fn verified_segment(&self, index: usize) -> Result<Cow<'_, [u8]>> {
        let seg = self.read_segment(index)?;
        let m = &self.layers[index];
        if crate::crc32::hash(&seg) != m.crc32 {
            return Err(Error::Format(format!(
                "layer {:?}: segment CRC mismatch",
                m.name
            )));
        }
        Ok(seg)
    }

    /// Read tile `t` of layer `index`: borrowed from the resident
    /// payload, or a positioned read of exactly the tile's bytes from
    /// disk — a prefetch worker attacking one tile never pulls the
    /// whole layer segment.
    pub fn read_tile(&self, index: usize, t: usize) -> Result<Cow<'_, [u8]>> {
        let tile = &self.layers[index].tiles[t];
        match &self.backing {
            Backing::Memory(model) => Ok(Cow::Borrowed(model.tile_bytes(index, t))),
            Backing::File { file, payload_base } => {
                let mut buf = vec![0u8; tile.encoded_len];
                file.read_exact_at(&mut buf, payload_base + tile.offset as u64)?;
                Ok(Cow::Owned(buf))
            }
        }
    }

    /// Read tile `t` of layer `index` and check it against the tile's
    /// own CRC-32: corruption is caught at tile granularity, so one bad
    /// tile never poisons its siblings.
    pub fn verified_tile(&self, index: usize, t: usize) -> Result<Cow<'_, [u8]>> {
        let bytes = self.read_tile(index, t)?;
        let m = &self.layers[index];
        if crate::crc32::hash(&bytes) != m.tiles[t].crc32 {
            return Err(Error::Format(format!(
                "layer {:?}: tile {t} CRC mismatch",
                m.name
            )));
        }
        Ok(bytes)
    }

    /// Largest tile count of any layer (≥ 1 for a non-empty manifest)
    /// — the intra-layer parallelism bound prefetch worker sizing keys
    /// off.
    pub fn max_tiles_per_layer(&self) -> usize {
        self.layers.iter().map(|l| l.tiles.len()).max().unwrap_or(1)
    }
}

/// Default tile sizing: aim for ~6 tiles per layer, but never slice
/// below 1024 symbols — tiny tiles pay padding + manifest overhead for
/// no parallelism a small layer needs.
fn auto_tile_symbols(n_symbols: usize) -> usize {
    n_symbols.div_ceil(6).max(1024)
}

/// How [`compress_with_options`] picks each layer's entropy codec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CodecChoice {
    /// Huffman for every layer (the pre-v3 behavior, still the
    /// default).
    #[default]
    Huffman,
    /// tANS for every layer.
    Ans,
    /// Per layer, encode with both and keep whichever measures smaller
    /// (ties go to Huffman — the simpler decoder).
    Auto,
}

/// Compress a set of named fp32 layers: mixed quantization (§III-A) →
/// pooled frequency table → model-global Huffman code (§III-B) →
/// per-layer byte-aligned segments (§III-C), tiled with the automatic
/// size rule. This is Algorithm 1's `CLOUD PROCESSING` procedure
/// end-to-end.
pub fn compress(layers: &[(String, TensorF32)], bits: BitWidth) -> Result<(ElmModel, CompressionReport)> {
    compress_with_tile_size(layers, bits, None)
}

/// [`compress`] with explicit tile granularity: each layer segment is
/// emitted as independently decodable, byte-aligned tiles of (up to)
/// `tile_symbols` symbols each (`None` → the automatic ~6-tiles-per-
/// layer rule, the CLI's `--tile-kb 0`). Decoded output is bit-identical
/// for any tile size — tiling only changes how much of a layer a single
/// worker must decode serially.
pub fn compress_with_tile_size(
    layers: &[(String, TensorF32)],
    bits: BitWidth,
    tile_symbols: Option<usize>,
) -> Result<(ElmModel, CompressionReport)> {
    compress_with_options(layers, bits, tile_symbols, CodecChoice::Huffman)
}

/// Tile spans `[start, end)` covering `n` symbols in chunks of (up to)
/// `per_tile`; a zero-symbol layer still gets one empty span, so every
/// layer has at least one tile.
fn tile_spans(n: usize, per_tile: usize) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut s = 0usize;
    loop {
        let end = s.saturating_add(per_tile).min(n);
        spans.push((s, end));
        s = end;
        if s >= n {
            break;
        }
    }
    spans
}

/// Encode one layer's symbols as per-span tile streams with whichever
/// encoder the codec choice handed us.
fn encode_tiles<F>(syms: &[u8], spans: &[(usize, usize)], enc: F) -> Result<Vec<Vec<u8>>>
where
    F: Fn(&[u8]) -> Result<Vec<u8>>,
{
    spans.iter().map(|&(a, b)| enc(&syms[a..b])).collect()
}

/// [`compress_with_tile_size`] plus codec negotiation: every layer's
/// tiles are encoded with the codec the [`CodecChoice`] selects, and
/// the choice is recorded per layer in the v3 manifest. Whatever the
/// codec, tiles stay byte-aligned, independently decodable and
/// CRC-guarded, and decoded output is bit-identical — the codec only
/// changes how few bits the same symbols cost.
pub fn compress_with_options(
    layers: &[(String, TensorF32)],
    bits: BitWidth,
    tile_symbols: Option<usize>,
    choice: CodecChoice,
) -> Result<(ElmModel, CompressionReport)> {
    if layers.is_empty() {
        return Err(Error::InvalidArg("compress: no layers".into()));
    }
    // 1. Quantize each layer with the mixed rule.
    let quantized: Vec<QuantizedTensor> =
        layers.iter().map(|(_, w)| quantize_mixed(w, bits)).collect();

    // 2. Pool symbol frequencies across the whole model (line 11).
    let mut freq = FreqTable::new();
    for q in &quantized {
        freq.add_symbols(q.symbols.data());
    }

    // 3. One global canonical code (line 12), and — when tANS is in
    //    play — one global tANS table from the same pooled histogram.
    let code = CodeSpec::build(&freq)?;
    let encoder = Encoder::new(&code);
    let ans = match choice {
        CodecChoice::Huffman => None,
        CodecChoice::Ans | CodecChoice::Auto => {
            let table = AnsTable::build(&freq)?;
            let enc = crate::ans::Encoder::new(&table);
            Some((table, enc))
        }
    };

    // 4. Encode each tensor as its own byte-aligned segment (lines
    //    13–15), carved into independently decodable tiles. Each
    //    `encode_to_vec` call pads to a whole byte, which is exactly
    //    the byte alignment the tile table promises.
    let mut payload = Vec::new();
    let mut metas = Vec::with_capacity(layers.len());
    for ((name, _), q) in layers.iter().zip(&quantized) {
        let syms = q.symbols.data();
        let per_tile = tile_symbols
            .unwrap_or_else(|| auto_tile_symbols(syms.len()))
            .max(1);
        let spans = tile_spans(syms.len(), per_tile);
        let (codec, tile_bytes) = match (choice, &ans) {
            (CodecChoice::Huffman, _) | (_, None) => (
                Codec::Huffman,
                encode_tiles(syms, &spans, |s| encoder.encode_to_vec(s))?,
            ),
            (CodecChoice::Ans, Some((_, aenc))) => (
                Codec::Ans,
                encode_tiles(syms, &spans, |s| aenc.encode_to_vec(s))?,
            ),
            (CodecChoice::Auto, Some((_, aenc))) => {
                let h = encode_tiles(syms, &spans, |s| encoder.encode_to_vec(s))?;
                let a = encode_tiles(syms, &spans, |s| aenc.encode_to_vec(s))?;
                let h_total: usize = h.iter().map(Vec::len).sum();
                let a_total: usize = a.iter().map(Vec::len).sum();
                if a_total < h_total {
                    (Codec::Ans, a)
                } else {
                    (Codec::Huffman, h)
                }
            }
        };

        let layer_off = payload.len();
        let mut tiles = Vec::with_capacity(spans.len());
        for (&(a, b), seg) in spans.iter().zip(&tile_bytes) {
            tiles.push(TileMeta {
                sym_offset: a,
                n_symbols: b - a,
                offset: payload.len(),
                encoded_len: seg.len(),
                crc32: crate::crc32::hash(seg),
            });
            payload.extend_from_slice(seg);
        }
        metas.push(LayerMeta {
            name: name.clone(),
            shape: q.symbols.shape().clone(),
            params: q.params,
            n_symbols: syms.len(),
            offset: layer_off,
            encoded_len: payload.len() - layer_off,
            crc32: crate::crc32::hash(&payload[layer_off..]),
            codec,
            tiles,
        });
    }

    // Keep the table only if some layer actually uses it, so an
    // Auto run that never picks tANS serializes an all-zero section.
    let ans = if metas.iter().any(|m| m.codec == Codec::Ans) {
        ans.map(|(table, _)| table)
    } else {
        None
    };

    let n_params: usize = metas.iter().map(|m| m.n_symbols).sum();
    let report = CompressionReport {
        n_params,
        fp16_bytes: n_params * 2,
        fixed_bytes: (n_params * bits.bits() as usize).div_ceil(8),
        encoded_bytes: payload.len(),
        entropy_bits: shannon_entropy(freq.counts()),
        effective_bits: 8.0 * payload.len() as f64 / n_params as f64,
        schemes: layers
            .iter()
            .zip(&quantized)
            .map(|((n, _), q)| (n.clone(), q.params.scheme))
            .collect(),
        codecs: metas.iter().map(|m| (m.name.clone(), m.codec)).collect(),
    };
    let model = ElmModel {
        bits,
        code,
        ans,
        layers: metas,
        payload,
    };
    Ok((model, report))
}

/// Decode a single layer of a model (serial path; the parallel path
/// lives in [`crate::decode`]). Walks the layer's tiles behind each
/// tile's own CRC with the layer's own codec, so decode output is
/// bit-identical whether the container is v1 (one synthesized tile,
/// Huffman), v2 (many tiles, Huffman) or v3 (either codec).
pub fn decode_layer(model: &ElmModel, i: usize) -> Result<QuantizedTensor> {
    let meta = &model.layers[i];
    let codecs = CodecSet::new(&model.code, model.ans.as_ref())?;
    let dec = codecs.get(meta.codec)?;
    let mut symbols = vec![0u8; meta.n_symbols];
    for (t, tile) in meta.tiles.iter().enumerate() {
        model.verify_tile(i, t)?;
        let out = &mut symbols[tile.sym_offset..tile.sym_offset + tile.n_symbols];
        dec.decode_tile(model.tile_bytes(i, t), out)?;
    }
    Ok(QuantizedTensor {
        symbols: TensorU8::new(meta.shape.clone(), symbols)?,
        params: meta.params,
    })
}

// ---------------------------------------------------------------- binary io

struct Writer<W: Write> {
    inner: W,
}

impl<W: Write> Writer<W> {
    fn u8(&mut self, v: u8) -> Result<()> {
        self.inner.write_all(&[v])?;
        Ok(())
    }
    fn u16(&mut self, v: u16) -> Result<()> {
        self.inner.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn u32(&mut self, v: u32) -> Result<()> {
        self.inner.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn u64(&mut self, v: u64) -> Result<()> {
        self.inner.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn f32(&mut self, v: f32) -> Result<()> {
        self.inner.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn bytes(&mut self, v: &[u8]) -> Result<()> {
        self.inner.write_all(v)?;
        Ok(())
    }
}

struct Reader<R: Read> {
    inner: R,
}

impl<R: Read> Reader<R> {
    fn u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.inner.read_exact(&mut b)?;
        Ok(b[0])
    }
    fn u16(&mut self) -> Result<u16> {
        let mut b = [0u8; 2];
        self.inner.read_exact(&mut b)?;
        Ok(u16::from_le_bytes(b))
    }
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.inner.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.inner.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn f32(&mut self) -> Result<f32> {
        let mut b = [0u8; 4];
        self.inner.read_exact(&mut b)?;
        Ok(f32::from_le_bytes(b))
    }
    fn bytes(&mut self, n: usize) -> Result<Vec<u8>> {
        let mut v = vec![0u8; n];
        self.inner.read_exact(&mut v)?;
        Ok(v)
    }
}

/// Everything a container stores *before* the payload, parsed and
/// validated: the shared decode state plus the layer manifest (with
/// per-layer payload offsets already accumulated).
struct ManifestHead {
    bits: BitWidth,
    code: CodeSpec,
    ans: Option<AnsTable>,
    layers: Vec<LayerMeta>,
    /// Total payload length the manifest claims.
    payload_len: usize,
    /// Bytes the parser consumed before the payload — the payload's
    /// offset in a container file. Depends on the parsed *version* (a
    /// v1 manifest has no tile tables), so it cannot be recomputed from
    /// the layers alone.
    header_len: usize,
}

/// Parse the header + manifest off a reader, leaving it positioned at
/// the first payload byte. Shared by the eager loader
/// ([`ElmModel::read_from`]) and the lazy one ([`SegmentSource::open`]),
/// so the two paths can never diverge on validation.
fn read_manifest<R: Read>(r: &mut Reader<R>) -> Result<ManifestHead> {
    let magic = r.bytes(4)?;
    if magic != MAGIC {
        return Err(Error::Format(format!("bad magic {magic:02x?}")));
    }
    // Versioned dispatch, not equality: v1 containers (one implicit
    // whole-segment tile per layer) stay readable forever; v2 adds the
    // explicit per-layer tile table; v3 adds the tANS table section
    // and per-layer/per-tile codec ids.
    let version = r.u32()?;
    if version != VERSION_V1 && version != VERSION_V2 && version != VERSION {
        return Err(Error::Format(format!("unsupported ELM version {version}")));
    }
    let bits = match r.u8()? {
        4 => BitWidth::U4,
        8 => BitWidth::U8,
        other => return Err(Error::Format(format!("bad bit width {other}"))),
    };
    let n_layers = r.u32()? as usize;
    if n_layers > 1_000_000 {
        return Err(Error::Format(format!("implausible layer count {n_layers}")));
    }
    let lengths = r.bytes(256)?;
    // A zero-layer container is legal (an empty weight set decompresses
    // to an empty EQW dump); it has no symbols, so an all-zero length
    // table is accepted by substituting the degenerate one-symbol code
    // — nothing will ever be decoded with it.
    let code = if n_layers == 0 && lengths.iter().all(|&l| l == 0) {
        let mut one = [0u8; 256];
        one[0] = 1;
        CodeSpec::from_lengths(&one)?
    } else {
        CodeSpec::from_lengths(&lengths)?
    };
    // v3: the tANS slot-count section. All-zero means "no table"; any
    // other content must be a *valid* table (counts summing to the
    // state-space size) or the container is rejected here, before any
    // payload is touched.
    let ans = if version == VERSION {
        let raw = r.bytes(ANS_TABLE_BYTES)?;
        if raw.iter().all(|&b| b == 0) {
            None
        } else {
            let mut sect = [0u8; ANS_TABLE_BYTES];
            sect.copy_from_slice(&raw);
            Some(AnsTable::from_bytes(&sect)?)
        }
    } else {
        None
    };
    let mut layers = Vec::with_capacity(n_layers);
    let mut offset = 0usize;
    // magic + version + bits + n_layers + code lengths (+ the v3 tANS
    // section).
    let mut header_len = 4 + 4 + 1 + 4 + 256;
    if version == VERSION {
        header_len += ANS_TABLE_BYTES;
    }
    for _ in 0..n_layers {
        let name_len = r.u16()? as usize;
        let name = String::from_utf8(r.bytes(name_len)?)
            .map_err(|_| Error::Format("layer name not utf-8".into()))?;
        let rank = r.u8()? as usize;
        if rank > 8 {
            return Err(Error::Format(format!("implausible rank {rank}")));
        }
        let mut dims = Vec::with_capacity(rank);
        // Checked product: `Shape::numel` is an unchecked multiply, so
        // adversarial dims must be proven non-overflowing *here*, before
        // anything downstream trusts the shape.
        let mut numel: usize = 1;
        for _ in 0..rank {
            let d = r.u64()? as usize;
            numel = numel.checked_mul(d).ok_or_else(|| {
                Error::Format(format!("layer {name:?}: dimension product overflows"))
            })?;
            dims.push(d);
        }
        let shape = Shape(dims);
        let scheme = Scheme::from_tag(r.u8()?)?;
        let scale = r.f32()?;
        let zero_point = r.f32()?;
        let n_symbols = r.u64()? as usize;
        if numel != n_symbols {
            return Err(Error::Format(format!(
                "layer {name:?}: shape {shape} != {n_symbols} symbols"
            )));
        }
        let encoded_len = r.u64()? as usize;
        // Every coded symbol costs at least one bit, so a segment can
        // never decode to more than 8× its encoded bytes. Rejecting the
        // claim here caps the decode-side allocation at O(file size) —
        // without it a corrupt/adversarial manifest could demand a
        // terabyte-scale symbol buffer (and OOM the server) before any
        // CRC check ever runs.
        if n_symbols > encoded_len.saturating_mul(8) {
            return Err(Error::Format(format!(
                "layer {name:?}: {n_symbols} symbols cannot fit in {encoded_len} \
                 encoded bytes (minimum one bit per symbol)"
            )));
        }
        let crc32 = r.u32()?;
        header_len += 2 + name_len + 1 + 8 * rank + 1 + 4 + 4 + 8 + 8 + 4;

        // v3: the layer's codec id. Pre-v3 containers predate the
        // field — every one of their layers is Huffman by definition.
        let codec = if version == VERSION {
            header_len += 1;
            Codec::from_tag(r.u8()?)?
        } else {
            Codec::Huffman
        };
        if codec == Codec::Ans && ans.is_none() {
            return Err(Error::Format(format!(
                "layer {name:?} coded with tANS but the container carries \
                 no tANS table"
            )));
        }

        let tiles = if version == VERSION_V1 {
            // v1: the whole segment is the one tile. Synthesizing it
            // here is what lets every downstream consumer be uniformly
            // tile-aware without a version check of its own.
            vec![TileMeta {
                sym_offset: 0,
                n_symbols,
                offset,
                encoded_len,
                crc32,
            }]
        } else {
            let n_tiles = r.u32()? as usize;
            // Every tile costs at least one payload byte unless the
            // layer itself is empty (one empty tile).
            if n_tiles == 0 || n_tiles > encoded_len.max(1) {
                return Err(Error::Format(format!(
                    "layer {name:?}: implausible tile count {n_tiles} for \
                     {encoded_len} encoded bytes"
                )));
            }
            header_len += 4
                + if version == VERSION {
                    TILE_ENTRY_BYTES_V3
                } else {
                    TILE_ENTRY_BYTES
                } * n_tiles;
            let mut tiles = Vec::with_capacity(n_tiles);
            let mut sym_offset = 0usize;
            let mut tile_off = offset;
            for t in 0..n_tiles {
                let t_symbols = r.u64()? as usize;
                let t_len = r.u64()? as usize;
                // Same one-bit-per-symbol bound as the layer check:
                // rejects allocation-bomb tile claims up front. Both
                // codecs honor it — tANS streams are padded to the
                // one-bit-per-symbol floor precisely so this bound
                // stays codec-uniform.
                if t_symbols > t_len.saturating_mul(8) {
                    return Err(Error::Format(format!(
                        "layer {name:?}: tile {t}: {t_symbols} symbols cannot \
                         fit in {t_len} encoded bytes (minimum one bit per \
                         symbol)"
                    )));
                }
                let t_crc = r.u32()?;
                if version == VERSION {
                    // A tile disagreeing with its layer's codec is a
                    // forgery (the writer only ever emits one codec
                    // per layer), not something to "handle".
                    let t_codec = Codec::from_tag(r.u8()?)?;
                    if t_codec != codec {
                        return Err(Error::Format(format!(
                            "layer {name:?}: tile {t} claims codec \
                             {t_codec}, layer claims {codec}"
                        )));
                    }
                }
                tiles.push(TileMeta {
                    sym_offset,
                    n_symbols: t_symbols,
                    offset: tile_off,
                    encoded_len: t_len,
                    crc32: t_crc,
                });
                sym_offset = sym_offset
                    .checked_add(t_symbols)
                    .ok_or_else(|| Error::Format("tile symbol offset overflow".into()))?;
                tile_off = tile_off
                    .checked_add(t_len)
                    .ok_or_else(|| Error::Format("payload offset overflow".into()))?;
            }
            // The tile table must tile the segment exactly: same
            // symbols, same bytes, no gaps or overlap.
            if sym_offset != n_symbols {
                return Err(Error::Format(format!(
                    "layer {name:?}: tiles cover {sym_offset} symbols, \
                     layer claims {n_symbols}"
                )));
            }
            if tile_off - offset != encoded_len {
                return Err(Error::Format(format!(
                    "layer {name:?}: tiles cover {} encoded bytes, layer \
                     claims {encoded_len}",
                    tile_off - offset
                )));
            }
            tiles
        };

        layers.push(LayerMeta {
            name,
            shape,
            params: QuantParams {
                scheme,
                bits,
                scale,
                zero_point,
            },
            n_symbols,
            offset,
            encoded_len,
            crc32,
            codec,
            tiles,
        });
        offset = offset
            .checked_add(encoded_len)
            .ok_or_else(|| Error::Format("payload offset overflow".into()))?;
    }
    Ok(ManifestHead {
        bits,
        code,
        ans,
        layers,
        payload_len: offset,
        header_len,
    })
}

impl ElmModel {
    /// Serialize to a writer.
    pub fn write_to<W: Write>(&self, w: W) -> Result<()> {
        let mut w = Writer { inner: w };
        w.bytes(MAGIC)?;
        w.u32(VERSION)?;
        w.u8(self.bits.bits() as u8)?;
        w.u32(self.layers.len() as u32)?;
        w.bytes(self.code.lengths())?;
        // v3 tANS section: the table's slot counts, or all zeros when
        // every layer is Huffman (zeros are unambiguous — a real table
        // sums to the state-space size).
        match &self.ans {
            Some(table) => w.bytes(&table.to_bytes())?,
            None => w.bytes(&[0u8; ANS_TABLE_BYTES])?,
        }
        for m in &self.layers {
            if m.name.len() > u16::MAX as usize {
                return Err(Error::InvalidArg(format!("layer name too long: {}", m.name.len())));
            }
            w.u16(m.name.len() as u16)?;
            w.bytes(m.name.as_bytes())?;
            w.u8(m.shape.rank() as u8)?;
            for &d in m.shape.dims() {
                w.u64(d as u64)?;
            }
            w.u8(m.params.scheme.tag())?;
            w.f32(m.params.scale)?;
            w.f32(m.params.zero_point)?;
            w.u64(m.n_symbols as u64)?;
            w.u64(m.encoded_len as u64)?;
            w.u32(m.crc32)?;
            w.u8(m.codec.tag())?;
            w.u32(m.tiles.len() as u32)?;
            for t in &m.tiles {
                // Tile symbol/byte offsets are derived by accumulation
                // on read — only the lengths, the CRC and the codec
                // echo are stored.
                w.u64(t.n_symbols as u64)?;
                w.u64(t.encoded_len as u64)?;
                w.u32(t.crc32)?;
                w.u8(m.codec.tag())?;
            }
        }
        w.bytes(&self.payload)?;
        Ok(())
    }

    /// Save to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let f = std::fs::File::create(path)?;
        let mut buf = std::io::BufWriter::new(f);
        self.write_to(&mut buf)?;
        buf.flush()?;
        Ok(())
    }

    /// Deserialize from a reader, validating magic/version/lengths.
    pub fn read_from<R: Read>(r: R) -> Result<Self> {
        let mut r = Reader { inner: r };
        let head = read_manifest(&mut r)?;
        let mut payload = Vec::new();
        r.inner.read_to_end(&mut payload)?;
        if payload.len() != head.payload_len {
            return Err(Error::Format(format!(
                "payload is {} bytes, manifest claims {}",
                payload.len(),
                head.payload_len
            )));
        }
        Ok(ElmModel {
            bits: head.bits,
            code: head.code,
            ans: head.ans,
            layers: head.layers,
            payload,
        })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let f = std::fs::File::open(path)?;
        Self::read_from(std::io::BufReader::new(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::dequantize;
    use crate::rng::Rng;

    fn make_layers(seed: u64) -> Vec<(String, TensorF32)> {
        let mut rng = Rng::new(seed);
        vec![
            (
                "attn.wq".into(),
                TensorF32::new(vec![32, 64], rng.gaussian_vec(2048, 0.0, 0.04)).unwrap(),
            ),
            (
                "attn.wk".into(),
                TensorF32::new(vec![32, 64], rng.gaussian_vec(2048, 0.01, 0.03)).unwrap(),
            ),
            (
                // Single-signed layer → symmetric-unsigned branch.
                "mlp.gate_bias".into(),
                TensorF32::new(
                    vec![128],
                    (0..128).map(|_| rng.range_f32(0.0, 0.2)).collect(),
                )
                .unwrap(),
            ),
        ]
    }

    #[test]
    fn compress_then_decode_layers_is_lossless() {
        for bits in [BitWidth::U4, BitWidth::U8] {
            let layers = make_layers(1);
            let (model, report) = compress(&layers, bits).unwrap();
            assert_eq!(report.n_params, 2048 + 2048 + 128);
            for i in 0..layers.len() {
                let q = decode_layer(&model, i).unwrap();
                // Decoded symbols must equal a fresh quantization of the
                // source layer (lossless beyond quantization).
                let direct = quantize_mixed(&layers[i].1, bits);
                assert_eq!(q.symbols.data(), direct.symbols.data());
                assert_eq!(q.params, direct.params);
                // And dequantization stays within half a step.
                let dq = dequantize(&q);
                let bound = crate::quant::max_error_bound(&q.params);
                for (a, b) in layers[i].1.data().iter().zip(dq.data()) {
                    assert!((a - b).abs() <= bound);
                }
            }
        }
    }

    #[test]
    fn report_accounts_for_compression() {
        let layers = make_layers(2);
        let (model, report) = compress(&layers, BitWidth::U8).unwrap();
        assert_eq!(report.encoded_bytes, model.payload.len());
        assert!(report.effective_bits < 8.0, "huffman beats fixed width");
        assert!(report.effective_bits >= report.entropy_bits - 1e-9);
        assert!(report.fixed_bytes < report.fp16_bytes);
        assert_eq!(report.schemes.len(), 3);
        assert_eq!(report.schemes[2].1, Scheme::SymmetricUnsigned);
    }

    #[test]
    fn save_load_roundtrip_bitexact() {
        let layers = make_layers(3);
        let (model, _) = compress(&layers, BitWidth::U4).unwrap();
        let dir = std::env::temp_dir().join(format!("elm_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.elm");
        model.save(&path).unwrap();
        let loaded = ElmModel::load(&path).unwrap();
        assert_eq!(loaded.payload, model.payload);
        assert_eq!(loaded.layers.len(), model.layers.len());
        for (a, b) in loaded.layers.iter().zip(&model.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.params, b.params);
            assert_eq!(a.crc32, b.crc32);
        }
        assert_eq!(loaded.code.lengths(), model.code.lengths());
        for i in 0..layers.len() {
            assert_eq!(
                decode_layer(&loaded, i).unwrap().symbols.data(),
                decode_layer(&model, i).unwrap().symbols.data()
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_payload_is_detected_by_crc() {
        let layers = make_layers(4);
        let (mut model, _) = compress(&layers, BitWidth::U8).unwrap();
        let mid = model.layers[1].offset + model.layers[1].encoded_len / 2;
        model.payload[mid] ^= 0xFF;
        assert!(decode_layer(&model, 1).is_err());
        // Other segments unaffected.
        assert!(decode_layer(&model, 0).is_ok());
    }

    #[test]
    fn truncated_file_rejected() {
        let layers = make_layers(5);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        for cut in [3usize, 8, 12, 260, buf.len() - 1] {
            assert!(
                ElmModel::read_from(&buf[..cut]).is_err(),
                "cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = b"NOPE".to_vec();
        buf.extend_from_slice(&[0u8; 64]);
        assert!(ElmModel::read_from(buf.as_slice()).is_err());
    }

    #[test]
    fn segment_cursor_walks_execution_order_and_seeks() {
        let layers = make_layers(6);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let mut cursor = model.segments();
        assert_eq!(cursor.len(), 3);
        assert_eq!(cursor.position(), 0);
        let mut total = 0usize;
        for (i, seg) in model.segments().enumerate() {
            assert_eq!(seg.index, i);
            assert_eq!(seg.meta.name, model.layers[i].name);
            assert_eq!(seg.bytes, model.segment(i));
            assert_eq!(crate::crc32::hash(seg.bytes), seg.meta.crc32);
            total += seg.bytes.len();
        }
        assert_eq!(total, model.payload.len());
        // Seek back to the middle and re-walk the tail.
        cursor.seek(2);
        assert_eq!(cursor.remaining(), 1);
        assert_eq!(cursor.next().unwrap().index, 2);
        assert!(cursor.next().is_none());
    }

    #[test]
    fn verify_segment_catches_corruption() {
        let layers = make_layers(7);
        let (mut model, _) = compress(&layers, BitWidth::U8).unwrap();
        for i in 0..model.layers.len() {
            model.verify_segment(i).unwrap();
        }
        let off = model.layers[1].offset;
        model.payload[off] ^= 0x01;
        assert!(model.verify_segment(1).is_err());
        assert!(model.verify_segment(0).is_ok());
    }

    #[test]
    fn segment_source_memory_and_file_backings_agree() {
        let layers = make_layers(8);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let dir = std::env::temp_dir().join(format!("elm_src_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.elm");
        model.save(&path).unwrap();

        let model = Arc::new(model);
        let mem = SegmentSource::from_model(Arc::clone(&model));
        let lazy = SegmentSource::open(&path).unwrap();

        assert_eq!(mem.n_layers(), lazy.n_layers());
        assert_eq!(mem.n_params(), lazy.n_params());
        assert_eq!(mem.bits(), lazy.bits());
        assert_eq!(mem.code().lengths(), lazy.code().lengths());
        assert!(mem.resident_payload_bytes() > 0);
        assert_eq!(lazy.resident_payload_bytes(), 0, "lazy source must not slurp");

        // Random re-entry order: reads must agree byte-for-byte and pass
        // CRC verification on both backings.
        for &i in &[2usize, 0, 2, 1, 0] {
            let a = mem.verified_segment(i).unwrap();
            let b = lazy.verified_segment(i).unwrap();
            assert_eq!(a.as_ref(), b.as_ref());
            assert_eq!(a.as_ref(), model.segment(i));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn segment_source_file_corruption_caught_by_crc() {
        let layers = make_layers(9);
        let (model, _) = compress(&layers, BitWidth::U4).unwrap();
        let dir = std::env::temp_dir().join(format!("elm_srcbad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.elm");
        model.save(&path).unwrap();

        // Flip one byte inside layer 1's segment on disk.
        let mut bytes = std::fs::read(&path).unwrap();
        let base = header_bytes(&model.layers);
        bytes[base + model.layers[1].offset] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();

        let lazy = SegmentSource::open(&path).unwrap();
        assert!(lazy.verified_segment(1).is_err());
        assert!(lazy.verified_segment(0).is_ok());
        assert!(lazy.verified_segment(2).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn segment_source_rejects_wrong_file_length() {
        let layers = make_layers(10);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let dir = std::env::temp_dir().join(format!("elm_srctr_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.elm");
        model.save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();

        // Truncated payload: manifest parses, length check must fail.
        std::fs::write(&path, &full[..full.len() - 1]).unwrap();
        assert!(SegmentSource::open(&path).is_err());

        // Trailing garbage is equally rejected.
        let mut padded = full.clone();
        padded.push(0);
        std::fs::write(&path, &padded).unwrap();
        assert!(SegmentSource::open(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn header_bytes_matches_serialized_prefix() {
        let layers = make_layers(11);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        assert_eq!(buf.len(), header_bytes(&model.layers) + model.payload.len());
        assert_eq!(buf.len(), model.container_bytes());
        // The bytes at the computed payload base are the payload itself.
        assert_eq!(&buf[header_bytes(&model.layers)..], &model.payload[..]);
    }

    #[test]
    fn zero_layer_container_roundtrips_on_both_readers() {
        // `compress` refuses empty inputs, but the format allows an
        // empty weight set (e.g. a model whose every tensor stays fp32)
        // — both readers must accept it so `decompress` can emit a
        // valid empty EQW dump.
        let mut one = [0u8; 256];
        one[0] = 1;
        let model = ElmModel {
            bits: BitWidth::U8,
            code: CodeSpec::from_lengths(&one).unwrap(),
            ans: None,
            layers: Vec::new(),
            payload: Vec::new(),
        };
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        assert_eq!(buf.len(), header_bytes(&[]));

        let loaded = ElmModel::read_from(buf.as_slice()).unwrap();
        assert!(loaded.layers.is_empty());
        assert!(loaded.payload.is_empty());
        assert_eq!(loaded.n_params(), 0);
        assert_eq!(loaded.effective_bits(), 0.0, "no params: defined, not NaN");

        let dir = std::env::temp_dir().join(format!("elm_zero_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("zero.elm");
        model.save(&path).unwrap();
        let lazy = SegmentSource::open(&path).unwrap();
        assert_eq!(lazy.n_layers(), 0);
        assert_eq!(lazy.n_params(), 0);

        // An all-zero codebook is accepted for zero layers only.
        let mut zero_code = buf.clone();
        for b in zero_code[13..13 + 256].iter_mut() {
            *b = 0;
        }
        assert!(ElmModel::read_from(zero_code.as_slice()).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adversarial_symbol_claim_rejected_before_any_allocation() {
        // Forge one layer's shape + n_symbols to demand a terabyte-scale
        // decode buffer while keeping every other field (offsets,
        // lengths, payload) intact. Both readers must reject the
        // manifest up front — long before any decode path would
        // allocate `n_symbols` bytes.
        let layers = make_layers(13);
        let (mut model, _) = compress(&layers, BitWidth::U8).unwrap();
        let huge = 1usize << 41; // ~2.2e12 symbols decoded
        model.layers[1].shape = Shape(vec![huge]);
        model.layers[1].n_symbols = huge;
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        let err = ElmModel::read_from(buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("cannot fit"), "{err}");

        let dir = std::env::temp_dir().join(format!("elm_adv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("forged.elm");
        std::fs::write(&path, &buf).unwrap();
        let err = SegmentSource::open(&path).unwrap_err();
        assert!(err.to_string().contains("cannot fit"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adversarial_payload_length_overflow_rejected_at_open() {
        // A claimed payload length within a header's distance of
        // u64::MAX would overflow the `payload_base + payload_len`
        // file-size check — that must be a clean Format error, not a
        // debug-mode panic or a release-mode wrap.
        let layers = make_layers(16);
        let (mut model, _) = compress(&layers, BitWidth::U8).unwrap();
        let prev: usize = model.layers[..2].iter().map(|m| m.encoded_len).sum();
        let huge = usize::MAX - prev - 200;
        model.layers[2].encoded_len = huge;
        // Keep the tile table self-consistent (layer 2 is single-tile)
        // so the forgery survives tile-sum validation and reaches the
        // file-length overflow check.
        assert_eq!(model.layers[2].tiles.len(), 1);
        model.layers[2].tiles[0].encoded_len = huge;
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();

        let dir = std::env::temp_dir().join(format!("elm_adv_ov_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("forged.elm");
        std::fs::write(&path, &buf).unwrap();
        let err = SegmentSource::open(&path).unwrap_err();
        assert!(err.to_string().contains("overflows"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adversarial_dim_product_overflow_rejected() {
        // Dims whose product overflows usize must be rejected by the
        // manifest parser itself — `Shape::numel` is an unchecked
        // multiply, so nothing downstream may ever see such a shape.
        let layers = make_layers(14);
        let (mut model, _) = compress(&layers, BitWidth::U8).unwrap();
        model.layers[0].shape = Shape(vec![1usize << 40, 1usize << 40]);
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        let err = ElmModel::read_from(buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("overflows"), "{err}");
    }

    #[test]
    fn concurrent_file_backed_segment_reads_are_bitexact() {
        // Positioned reads: many threads hammering the same file-backed
        // source (no shared cursor) must each see exactly their own
        // segment's bytes, CRC-clean.
        let layers = make_layers(15);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let dir = std::env::temp_dir().join(format!("elm_conc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.elm");
        model.save(&path).unwrap();
        let lazy = Arc::new(SegmentSource::open(&path).unwrap());

        std::thread::scope(|s| {
            for t in 0..4 {
                let lazy = Arc::clone(&lazy);
                let model = &model;
                s.spawn(move || {
                    for round in 0..8 {
                        let i = (t + round) % model.layers.len();
                        let got = lazy.verified_segment(i).unwrap();
                        assert_eq!(got.as_ref(), model.segment(i));
                    }
                });
            }
        });
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn nonzero_layers_with_empty_codebook_still_rejected() {
        let layers = make_layers(12);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        // Zero out the codebook: with layers present this cannot code
        // anything and must be rejected.
        for b in buf[13..13 + 256].iter_mut() {
            *b = 0;
        }
        assert!(ElmModel::read_from(buf.as_slice()).is_err());
    }

    /// Serialize a single-tile-per-layer model in the **v1** wire
    /// format (no tile tables) — what every pre-v2 build wrote.
    fn write_v1(model: &ElmModel) -> Vec<u8> {
        let mut w = Writer { inner: Vec::new() };
        w.bytes(MAGIC).unwrap();
        w.u32(VERSION_V1).unwrap();
        w.u8(model.bits.bits() as u8).unwrap();
        w.u32(model.layers.len() as u32).unwrap();
        w.bytes(model.code.lengths()).unwrap();
        for m in &model.layers {
            w.u16(m.name.len() as u16).unwrap();
            w.bytes(m.name.as_bytes()).unwrap();
            w.u8(m.shape.rank() as u8).unwrap();
            for &d in m.shape.dims() {
                w.u64(d as u64).unwrap();
            }
            w.u8(m.params.scheme.tag()).unwrap();
            w.f32(m.params.scale).unwrap();
            w.f32(m.params.zero_point).unwrap();
            w.u64(m.n_symbols as u64).unwrap();
            w.u64(m.encoded_len as u64).unwrap();
            w.u32(m.crc32).unwrap();
        }
        w.bytes(&model.payload).unwrap();
        w.inner
    }

    #[test]
    fn v1_container_reads_back_compat_and_decodes_bitexact() {
        // A v1 writer only ever produced whole-segment encodings, which
        // single-tile v2 compression reproduces byte for byte.
        let layers = make_layers(20);
        let (flat, _) = compress_with_tile_size(&layers, BitWidth::U8, Some(usize::MAX)).unwrap();
        assert!(flat.layers.iter().all(|l| l.tiles.len() == 1));
        let buf = write_v1(&flat);

        let loaded = ElmModel::read_from(buf.as_slice()).unwrap();
        assert_eq!(loaded.payload, flat.payload);
        for (i, m) in loaded.layers.iter().enumerate() {
            // v1 parse synthesizes exactly one whole-segment tile.
            assert_eq!(m.tiles.len(), 1);
            let t = &m.tiles[0];
            assert_eq!(t.sym_offset, 0);
            assert_eq!(t.n_symbols, m.n_symbols);
            assert_eq!(t.offset, m.offset);
            assert_eq!(t.encoded_len, m.encoded_len);
            assert_eq!(t.crc32, m.crc32);
            // The tile-aware decode path reproduces the source symbols.
            assert_eq!(
                decode_layer(&loaded, i).unwrap().symbols.data(),
                quantize_mixed(&layers[i].1, BitWidth::U8).symbols.data()
            );
        }

        // File-backed open must honor the shorter v1 header length.
        let dir = std::env::temp_dir().join(format!("elm_v1_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.elm");
        std::fs::write(&path, &buf).unwrap();
        let lazy = SegmentSource::open(&path).unwrap();
        assert_eq!(lazy.max_tiles_per_layer(), 1);
        for i in 0..layers.len() {
            assert_eq!(
                lazy.verified_tile(i, 0).unwrap().as_ref(),
                flat.segment(i)
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn explicit_tile_size_roundtrips_and_tables_are_contiguous() {
        let layers = make_layers(21);
        let (model, _) = compress_with_tile_size(&layers, BitWidth::U4, Some(256)).unwrap();
        assert_eq!(model.layers[0].tiles.len(), 8, "2048 syms / 256 per tile");
        for (i, l) in model.layers.iter().enumerate() {
            let mut syms = 0usize;
            let mut off = l.offset;
            for t in &l.tiles {
                assert_eq!(t.sym_offset, syms);
                assert_eq!(t.offset, off);
                syms += t.n_symbols;
                off += t.encoded_len;
            }
            assert_eq!(syms, l.n_symbols);
            assert_eq!(off - l.offset, l.encoded_len);
            model.verify_segment(i).unwrap();
        }

        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        assert_eq!(buf.len(), header_bytes(&model.layers) + model.payload.len());
        let loaded = ElmModel::read_from(buf.as_slice()).unwrap();
        for (a, b) in loaded.layers.iter().zip(&model.layers) {
            assert_eq!(a.tiles, b.tiles);
        }
        for i in 0..layers.len() {
            assert_eq!(
                decode_layer(&loaded, i).unwrap().symbols.data(),
                quantize_mixed(&layers[i].1, BitWidth::U4).symbols.data()
            );
        }
    }

    #[test]
    fn tile_size_never_changes_decoded_symbols() {
        // Tiling re-carves the bitstream (each tile is byte-aligned and
        // independently padded) but decoded output must be invariant.
        let layers = make_layers(23);
        let want: Vec<Vec<u8>> = layers
            .iter()
            .map(|(_, w)| quantize_mixed(w, BitWidth::U8).symbols.data().to_vec())
            .collect();
        for tile in [Some(1), Some(100), Some(1000), Some(usize::MAX), None] {
            let (model, _) = compress_with_tile_size(&layers, BitWidth::U8, tile).unwrap();
            for i in 0..layers.len() {
                assert_eq!(
                    decode_layer(&model, i).unwrap().symbols.data(),
                    &want[i][..],
                    "tile size {tile:?}, layer {i}"
                );
            }
        }
    }

    #[test]
    fn corrupt_tile_caught_by_own_crc_without_poisoning_siblings() {
        let layers = make_layers(22);
        let (mut model, _) = compress(&layers, BitWidth::U8).unwrap();
        let li = model
            .layers
            .iter()
            .position(|l| l.tiles.len() > 1)
            .expect("auto tiling must split a 2048-symbol layer");
        let n_tiles = model.layers[li].tiles.len();
        let bad = n_tiles - 1;
        let off = model.layers[li].tiles[bad].offset;
        model.payload[off] ^= 0xFF;

        // The corrupt tile fails its own CRC; every sibling verifies.
        let err = model.verify_tile(li, bad).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
        for t in (0..n_tiles).filter(|&t| t != bad) {
            model.verify_tile(li, t).unwrap();
        }
        // Whole-layer decode surfaces the tile error; other layers are
        // untouched.
        assert!(decode_layer(&model, li).is_err());
        for i in (0..model.layers.len()).filter(|&i| i != li) {
            decode_layer(&model, i).unwrap();
        }
    }

    #[test]
    fn adversarial_tile_table_rejected() {
        let layers = make_layers(24);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();

        // Tile symbol sum disagrees with the layer claim.
        let mut forged = model.clone();
        forged.layers[0].tiles[0].n_symbols += 1;
        let mut buf = Vec::new();
        forged.write_to(&mut buf).unwrap();
        let err = ElmModel::read_from(buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("tiles cover"), "{err}");

        // Tile byte sum disagrees with the layer claim.
        let mut forged = model.clone();
        forged.layers[0].tiles[0].encoded_len += 1;
        let mut buf = Vec::new();
        forged.write_to(&mut buf).unwrap();
        let err = ElmModel::read_from(buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("tiles cover"), "{err}");

        // A tile claiming more symbols than its bytes can hold is an
        // allocation bomb — rejected before the sums are even checked.
        let mut forged = model.clone();
        let t0_len = forged.layers[0].tiles[0].encoded_len;
        forged.layers[0].tiles[0].n_symbols = t0_len * 8 + 1;
        let mut buf = Vec::new();
        forged.write_to(&mut buf).unwrap();
        let err = ElmModel::read_from(buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("cannot fit"), "{err}");

        // Implausible tile counts (0, or more tiles than payload bytes).
        let mut forged = model.clone();
        forged.layers[0].tiles.clear();
        let mut buf = Vec::new();
        forged.write_to(&mut buf).unwrap();
        let err = ElmModel::read_from(buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("implausible tile count"), "{err}");
    }

    /// Serialize a model in the **v2** wire format (tiled manifest, no
    /// tANS section, no codec bytes) — what every pre-v3 build wrote.
    /// Only valid for all-Huffman models, which is all v2 could hold.
    fn write_v2(model: &ElmModel) -> Vec<u8> {
        assert!(model.layers.iter().all(|m| m.codec == Codec::Huffman));
        let mut w = Writer { inner: Vec::new() };
        w.bytes(MAGIC).unwrap();
        w.u32(VERSION_V2).unwrap();
        w.u8(model.bits.bits() as u8).unwrap();
        w.u32(model.layers.len() as u32).unwrap();
        w.bytes(model.code.lengths()).unwrap();
        for m in &model.layers {
            w.u16(m.name.len() as u16).unwrap();
            w.bytes(m.name.as_bytes()).unwrap();
            w.u8(m.shape.rank() as u8).unwrap();
            for &d in m.shape.dims() {
                w.u64(d as u64).unwrap();
            }
            w.u8(m.params.scheme.tag()).unwrap();
            w.f32(m.params.scale).unwrap();
            w.f32(m.params.zero_point).unwrap();
            w.u64(m.n_symbols as u64).unwrap();
            w.u64(m.encoded_len as u64).unwrap();
            w.u32(m.crc32).unwrap();
            w.u32(m.tiles.len() as u32).unwrap();
            for t in &m.tiles {
                w.u64(t.n_symbols as u64).unwrap();
                w.u64(t.encoded_len as u64).unwrap();
                w.u32(t.crc32).unwrap();
            }
        }
        w.bytes(&model.payload).unwrap();
        w.inner
    }

    /// Serialize a model in the v3 wire format with injectable codec
    /// bytes and tANS section — the forgery rig for the adversarial
    /// codec tests ([`ElmModel::write_to`] can only emit consistent
    /// containers).
    fn write_v3_raw(
        model: &ElmModel,
        ans_section: &[u8; ANS_TABLE_BYTES],
        layer_codec: impl Fn(usize) -> u8,
        tile_codec: impl Fn(usize, usize) -> u8,
    ) -> Vec<u8> {
        let mut w = Writer { inner: Vec::new() };
        w.bytes(MAGIC).unwrap();
        w.u32(VERSION).unwrap();
        w.u8(model.bits.bits() as u8).unwrap();
        w.u32(model.layers.len() as u32).unwrap();
        w.bytes(model.code.lengths()).unwrap();
        w.bytes(ans_section).unwrap();
        for (i, m) in model.layers.iter().enumerate() {
            w.u16(m.name.len() as u16).unwrap();
            w.bytes(m.name.as_bytes()).unwrap();
            w.u8(m.shape.rank() as u8).unwrap();
            for &d in m.shape.dims() {
                w.u64(d as u64).unwrap();
            }
            w.u8(m.params.scheme.tag()).unwrap();
            w.f32(m.params.scale).unwrap();
            w.f32(m.params.zero_point).unwrap();
            w.u64(m.n_symbols as u64).unwrap();
            w.u64(m.encoded_len as u64).unwrap();
            w.u32(m.crc32).unwrap();
            w.u8(layer_codec(i)).unwrap();
            w.u32(m.tiles.len() as u32).unwrap();
            for (t, tile) in m.tiles.iter().enumerate() {
                w.u64(tile.n_symbols as u64).unwrap();
                w.u64(tile.encoded_len as u64).unwrap();
                w.u32(tile.crc32).unwrap();
                w.u8(tile_codec(i, t)).unwrap();
            }
        }
        w.bytes(&model.payload).unwrap();
        w.inner
    }

    #[test]
    fn compress_codec_choice_marks_layers_and_tables() {
        let layers = make_layers(30);
        let (h, hr) = compress_with_options(&layers, BitWidth::U8, None, CodecChoice::Huffman).unwrap();
        assert!(h.ans.is_none(), "all-Huffman model must not carry a tANS table");
        assert!(hr.codecs.iter().all(|(_, c)| *c == Codec::Huffman));

        let (a, ar) = compress_with_options(&layers, BitWidth::U8, None, CodecChoice::Ans).unwrap();
        assert!(a.ans.is_some(), "tANS model must carry its table");
        assert!(ar.codecs.iter().all(|(_, c)| *c == Codec::Ans));
        assert!(a.layers.iter().all(|m| m.codec == Codec::Ans));

        // Both decode to the same symbols as a fresh quantization.
        for i in 0..layers.len() {
            let want = quantize_mixed(&layers[i].1, BitWidth::U8);
            assert_eq!(decode_layer(&h, i).unwrap().symbols.data(), want.symbols.data());
            assert_eq!(decode_layer(&a, i).unwrap().symbols.data(), want.symbols.data());
        }
    }

    #[test]
    fn auto_codec_never_larger_than_either_pure_choice() {
        let layers = make_layers(32);
        for bits in [BitWidth::U4, BitWidth::U8] {
            let (h, _) = compress_with_options(&layers, bits, None, CodecChoice::Huffman).unwrap();
            let (a, _) = compress_with_options(&layers, bits, None, CodecChoice::Ans).unwrap();
            let (auto, report) = compress_with_options(&layers, bits, None, CodecChoice::Auto).unwrap();
            // Auto picks per layer, so its total can only match or beat
            // both fixed choices.
            assert!(auto.payload.len() <= h.payload.len().min(a.payload.len()));
            assert_eq!(report.codecs.len(), layers.len());
            for (m, (name, codec)) in auto.layers.iter().zip(&report.codecs) {
                assert_eq!(&m.name, name);
                assert_eq!(m.codec, *codec);
            }
            let mut buf = Vec::new();
            auto.write_to(&mut buf).unwrap();
            let loaded = ElmModel::read_from(buf.as_slice()).unwrap();
            for i in 0..layers.len() {
                assert_eq!(
                    decode_layer(&loaded, i).unwrap().symbols.data(),
                    quantize_mixed(&layers[i].1, bits).symbols.data()
                );
            }
        }
    }

    #[test]
    fn mixed_codec_layers_roundtrip_and_decode() {
        // One container, codecs alternating per layer — what an Auto
        // run produces when the win flips between layers. Hand-built so
        // the mix is deterministic.
        let layers = make_layers(33);
        let quant: Vec<QuantizedTensor> = layers
            .iter()
            .map(|(_, w)| quantize_mixed(w, BitWidth::U8))
            .collect();
        let mut freq = FreqTable::new();
        for q in &quant {
            freq.add_symbols(q.symbols.data());
        }
        let code = CodeSpec::build(&freq).unwrap();
        let table = AnsTable::build(&freq).unwrap();
        let henc = Encoder::new(&code);
        let aenc = crate::ans::Encoder::new(&table);

        let mut payload = Vec::new();
        let mut metas = Vec::new();
        for (i, ((name, _), q)) in layers.iter().zip(&quant).enumerate() {
            let syms = q.symbols.data();
            let codec = if i % 2 == 0 { Codec::Huffman } else { Codec::Ans };
            let seg = match codec {
                Codec::Huffman => henc.encode_to_vec(syms).unwrap(),
                Codec::Ans => aenc.encode_to_vec(syms).unwrap(),
            };
            let off = payload.len();
            let crc = crate::crc32::hash(&seg);
            payload.extend_from_slice(&seg);
            metas.push(LayerMeta {
                name: name.clone(),
                shape: q.symbols.shape().clone(),
                params: q.params,
                n_symbols: syms.len(),
                offset: off,
                encoded_len: seg.len(),
                crc32: crc,
                codec,
                tiles: vec![TileMeta {
                    sym_offset: 0,
                    n_symbols: syms.len(),
                    offset: off,
                    encoded_len: seg.len(),
                    crc32: crc,
                }],
            });
        }
        let model = ElmModel {
            bits: BitWidth::U8,
            code,
            ans: Some(table),
            layers: metas,
            payload,
        };

        let mut buf = Vec::new();
        model.write_to(&mut buf).unwrap();
        let loaded = ElmModel::read_from(buf.as_slice()).unwrap();
        assert_eq!(loaded.layers[0].codec, Codec::Huffman);
        assert_eq!(loaded.layers[1].codec, Codec::Ans);
        for i in 0..layers.len() {
            assert_eq!(
                decode_layer(&loaded, i).unwrap().symbols.data(),
                quant[i].symbols.data(),
                "mixed-codec layer {i}"
            );
        }
    }

    #[test]
    fn golden_container_cross_version_codec_matrix() {
        // The same tiny, seeded weight set written as every container
        // generation (v1, v2, v3×huffman, v3×tans) must open on both
        // readers and decode to identical EQW symbols.
        let layers = make_layers(31);
        let want: Vec<Vec<u8>> = layers
            .iter()
            .map(|(_, w)| quantize_mixed(w, BitWidth::U8).symbols.data().to_vec())
            .collect();

        let (flat, _) = compress_with_tile_size(&layers, BitWidth::U8, Some(usize::MAX)).unwrap();
        let (tiled_h, _) =
            compress_with_options(&layers, BitWidth::U8, Some(256), CodecChoice::Huffman).unwrap();
        let (tiled_a, _) =
            compress_with_options(&layers, BitWidth::U8, Some(256), CodecChoice::Ans).unwrap();

        let mut variants: Vec<(String, Vec<u8>)> = vec![
            ("v1_huffman".into(), write_v1(&flat)),
            ("v2_huffman_flat".into(), write_v2(&flat)),
            ("v2_huffman_tiled".into(), write_v2(&tiled_h)),
        ];
        for (label, m) in [("v3_huffman", &tiled_h), ("v3_tans", &tiled_a)] {
            let mut buf = Vec::new();
            m.write_to(&mut buf).unwrap();
            variants.push((label.into(), buf));
        }

        let dir = std::env::temp_dir().join(format!("elm_matrix_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for (label, bytes) in &variants {
            // Eager reader.
            let loaded = ElmModel::read_from(bytes.as_slice()).unwrap();
            for i in 0..want.len() {
                assert_eq!(
                    decode_layer(&loaded, i).unwrap().symbols.data(),
                    &want[i][..],
                    "{label}: eager decode, layer {i}"
                );
            }
            // Lazy reader, tile-by-tile through the codec seam.
            let path = dir.join(format!("{label}.elm"));
            std::fs::write(&path, bytes).unwrap();
            let lazy = SegmentSource::open(&path).unwrap();
            let codecs = CodecSet::new(lazy.code(), lazy.ans_table()).unwrap();
            for (i, meta) in lazy.layers().iter().enumerate() {
                let dec = codecs.get(meta.codec).unwrap();
                let mut out = vec![0u8; meta.n_symbols];
                for (t, tile) in meta.tiles.iter().enumerate() {
                    let tb = lazy.verified_tile(i, t).unwrap();
                    dec.decode_tile(&tb, &mut out[tile.sym_offset..tile.sym_offset + tile.n_symbols])
                        .unwrap();
                }
                assert_eq!(out, want[i], "{label}: lazy decode, layer {i}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adversarial_v3_codec_fields_rejected() {
        let layers = make_layers(34);
        let (h, _) = compress_with_options(&layers, BitWidth::U8, None, CodecChoice::Huffman).unwrap();
        let (a, _) = compress_with_options(&layers, BitWidth::U8, None, CodecChoice::Ans).unwrap();
        let zeros = [0u8; ANS_TABLE_BYTES];
        let table_bytes = a.ans.as_ref().unwrap().to_bytes();

        // Unknown layer codec id: rejected at parse, before any
        // payload allocation or decode.
        let buf = write_v3_raw(&h, &zeros, |_| 7, |_, _| 0);
        let err = ElmModel::read_from(buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("unknown codec id"), "{err}");

        // A tile disagreeing with its layer's codec is a forgery.
        let buf = write_v3_raw(&a, &table_bytes, |_| 1, |i, t| u8::from(!(i == 0 && t == 0)));
        let err = ElmModel::read_from(buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("claims codec"), "{err}");

        // A tANS layer in a container with no tANS table cannot decode
        // — rejected up front.
        let buf = write_v3_raw(&a, &zeros, |_| 1, |_, _| 1);
        let err = ElmModel::read_from(buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("no tANS table"), "{err}");

        // A garbage (non-zero, wrong-sum) table section is itself
        // rejected, whatever the layers claim.
        let mut bad = zeros;
        bad[0] = 1;
        let buf = write_v3_raw(&h, &bad, |_| 0, |_, _| 0);
        assert!(ElmModel::read_from(buf.as_slice()).is_err());

        // Same rejections through the lazy reader.
        let dir = std::env::temp_dir().join(format!("elm_advc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("forged.elm");
        std::fs::write(&path, write_v3_raw(&h, &zeros, |_| 7, |_, _| 0)).unwrap();
        assert!(SegmentSource::open(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn differential_fuzz_cross_codec_containers_bitexact() {
        // Differential sweep: the same random weight set compressed
        // through both codec arms (and reloaded from serialized bytes)
        // must decode to bit-identical EQW symbol streams.
        let cases: usize = std::env::var("ENTROLLM_FUZZ_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(40);
        let mut rng = Rng::new(0xA45_C0DE);
        for case in 0..cases {
            let n_layers = 1 + rng.below(3);
            let layers: Vec<(String, TensorF32)> = (0..n_layers)
                .map(|i| {
                    let n = 1 + rng.below(800);
                    (
                        format!("f{case}.{i}"),
                        TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.05)).unwrap(),
                    )
                })
                .collect();
            let bits = if rng.below(2) == 0 { BitWidth::U4 } else { BitWidth::U8 };
            let tile = match rng.below(3) {
                0 => Some(1 + rng.below(300)),
                1 => Some(usize::MAX),
                _ => None,
            };
            let (hm, _) = compress_with_options(&layers, bits, tile, CodecChoice::Huffman).unwrap();
            let (am, _) = compress_with_options(&layers, bits, tile, CodecChoice::Ans).unwrap();
            let mut hbuf = Vec::new();
            hm.write_to(&mut hbuf).unwrap();
            let mut abuf = Vec::new();
            am.write_to(&mut abuf).unwrap();
            let hl = ElmModel::read_from(hbuf.as_slice()).unwrap();
            let al = ElmModel::read_from(abuf.as_slice()).unwrap();
            for i in 0..n_layers {
                let h = decode_layer(&hl, i).unwrap();
                let a = decode_layer(&al, i).unwrap();
                assert_eq!(
                    h.symbols.data(),
                    a.symbols.data(),
                    "case {case} layer {i}: codec arms disagree"
                );
                assert_eq!(h.params, a.params);
            }
        }
    }

    #[test]
    fn property_save_load_many_shapes() {
        let mut rng = Rng::new(0x57E);
        for case in 0..20 {
            let n_layers = 1 + rng.below(6);
            let layers: Vec<(String, TensorF32)> = (0..n_layers)
                .map(|i| {
                    let n = 1 + rng.below(500);
                    (
                        format!("l{case}.{i}"),
                        TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.1)).unwrap(),
                    )
                })
                .collect();
            let bits = if rng.below(2) == 0 { BitWidth::U4 } else { BitWidth::U8 };
            let (model, _) = compress(&layers, bits).unwrap();
            let mut buf = Vec::new();
            model.write_to(&mut buf).unwrap();
            let loaded = ElmModel::read_from(buf.as_slice()).unwrap();
            for i in 0..n_layers {
                assert_eq!(
                    decode_layer(&loaded, i).unwrap().symbols.data(),
                    quantize_mixed(&layers[i].1, bits).symbols.data()
                );
            }
        }
    }
}
