//! Mixed quantization scheme (paper §III-A, Algorithm 1 lines 4–10).
//!
//! Per layer, EntroLLM picks between two uniform-grid quantizers based on
//! the layer's weight distribution:
//!
//! * **Symmetric unsigned** (eq. 1), when the weights are single-signed
//!   (`max(W) · min(W) ≥ 0`): `W_int = round(W / s)` with the scale
//!   chosen so the occupied range maps onto `[0, L-1]`.
//! * **Asymmetric** (eq. 2) otherwise: `W_int = round((W - z) / s)` with
//!   zero-point `z = min(W)`.
//!
//! The point of the mix is *compressibility*: both branches land every
//! layer's integer histogram on a common `[0, L-1]` grid whose shape
//! remains the (near-Gaussian) shape of the float weights, so pooling
//! all layers yields one low-entropy histogram for the model-global
//! Huffman code (§III-B).
//!
//! ## Example: quantize → dequantize stays within half a step
//!
//! ```
//! use entrollm::quant::{dequantize, max_error_bound, quantize_mixed, BitWidth};
//! use entrollm::tensor::TensorF32;
//!
//! let w = TensorF32::new(vec![4], vec![-0.20, -0.05, 0.05, 0.20])?;
//! let q = quantize_mixed(&w, BitWidth::U8);
//! let bound = max_error_bound(&q.params);
//! for (a, b) in w.data().iter().zip(dequantize(&q).data()) {
//!     assert!((a - b).abs() <= bound);
//! }
//! # Ok::<(), entrollm::Error>(())
//! ```

use crate::tensor::{TensorF32, TensorU8};
use crate::{Error, Result};

/// Quantization bit-width. The paper evaluates uint8 and uint4.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BitWidth {
    /// 16 levels.
    U4,
    /// 256 levels.
    U8,
}

impl BitWidth {
    /// Number of representable levels.
    pub fn levels(self) -> usize {
        match self {
            BitWidth::U4 => 16,
            BitWidth::U8 => 256,
        }
    }

    /// Nominal bits per weight before entropy coding.
    pub fn bits(self) -> u32 {
        match self {
            BitWidth::U4 => 4,
            BitWidth::U8 => 8,
        }
    }

    /// Parse `"u4"`/`"uint4"`/`"u8"`/`"uint8"`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "u4" | "uint4" | "4" => Ok(BitWidth::U4),
            "u8" | "uint8" | "8" => Ok(BitWidth::U8),
            other => Err(Error::InvalidArg(format!("unknown bit width {other:?}"))),
        }
    }
}

impl std::fmt::Display for BitWidth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BitWidth::U4 => write!(f, "uint4"),
            BitWidth::U8 => write!(f, "uint8"),
        }
    }
}

/// Which uniform grid a layer was quantized on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Eq. 1 — single-signed layers. The scale may be negative (for
    /// all-negative layers) so symbols are always non-negative.
    SymmetricUnsigned,
    /// Eq. 2 — layers whose weights straddle zero.
    Asymmetric,
}

impl Scheme {
    /// Stable on-disk tag for the ELM container.
    pub fn tag(self) -> u8 {
        match self {
            Scheme::SymmetricUnsigned => 0,
            Scheme::Asymmetric => 1,
        }
    }

    /// Inverse of [`Scheme::tag`].
    pub fn from_tag(t: u8) -> Result<Self> {
        match t {
            0 => Ok(Scheme::SymmetricUnsigned),
            1 => Ok(Scheme::Asymmetric),
            other => Err(Error::Format(format!("unknown scheme tag {other}"))),
        }
    }
}

/// Per-layer quantization parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Grid selection for this layer.
    pub scheme: Scheme,
    /// Bit width of the integer grid.
    pub bits: BitWidth,
    /// Scale factor `s` (float units per level). Negative for
    /// all-negative symmetric-unsigned layers.
    pub scale: f32,
    /// Zero-point `z` in *float* units (paper eq. 2); 0 for symmetric.
    pub zero_point: f32,
}

impl QuantParams {
    /// Dequantize a single symbol.
    #[inline]
    pub fn dequant_one(&self, symbol: u8) -> f32 {
        match self.scheme {
            Scheme::SymmetricUnsigned => symbol as f32 * self.scale,
            Scheme::Asymmetric => symbol as f32 * self.scale + self.zero_point,
        }
    }
}

/// A quantized layer: integer symbols plus the grid parameters.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    /// Integer symbols in `[0, levels)` (one per byte, pre-packing).
    pub symbols: TensorU8,
    /// Grid parameters.
    pub params: QuantParams,
}

/// The paper's per-layer scheme selection rule (Algorithm 1, line 5):
/// single-signed layers take the symmetric-unsigned grid.
pub fn choose_scheme(weights: &[f32]) -> Scheme {
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    for &w in weights {
        mn = mn.min(w);
        mx = mx.max(w);
    }
    if weights.is_empty() || mx * mn >= 0.0 {
        Scheme::SymmetricUnsigned
    } else {
        Scheme::Asymmetric
    }
}

fn quantize_with(weights: &[f32], bits: BitWidth, scheme: Scheme) -> (Vec<u8>, QuantParams) {
    let levels = bits.levels() as f32;
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    for &w in weights {
        mn = mn.min(w);
        mx = mx.max(w);
    }
    if weights.is_empty() {
        mn = 0.0;
        mx = 0.0;
    }
    match scheme {
        Scheme::SymmetricUnsigned => {
            // Map the occupied single-signed range onto [0, L-1]. For an
            // all-negative layer the extreme is `mn`, giving a negative
            // scale — W/s is then non-negative, exactly eq. 1.
            let extreme = if mx.abs() >= mn.abs() { mx } else { mn };
            let scale = if extreme == 0.0 {
                1.0
            } else {
                extreme / (levels - 1.0)
            };
            let params = QuantParams {
                scheme,
                bits,
                scale,
                zero_point: 0.0,
            };
            let syms = weights
                .iter()
                .map(|&w| {
                    let q = (w / scale).round();
                    q.clamp(0.0, levels - 1.0) as u8
                })
                .collect();
            (syms, params)
        }
        Scheme::Asymmetric => {
            let z = mn;
            let range = mx - mn;
            let scale = if range == 0.0 { 1.0 } else { range / (levels - 1.0) };
            let params = QuantParams {
                scheme,
                bits,
                scale,
                zero_point: z,
            };
            let syms = weights
                .iter()
                .map(|&w| {
                    let q = ((w - z) / scale).round();
                    q.clamp(0.0, levels - 1.0) as u8
                })
                .collect();
            (syms, params)
        }
    }
}

/// Quantize one layer with the mixed scheme (Algorithm 1 lines 4–10).
pub fn quantize_mixed(weights: &TensorF32, bits: BitWidth) -> QuantizedTensor {
    let scheme = choose_scheme(weights.data());
    let (syms, params) = quantize_with(weights.data(), bits, scheme);
    QuantizedTensor {
        symbols: TensorU8::new(weights.shape().clone(), syms)
            .expect("symbol count equals weight count"),
        params,
    }
}

/// Quantize forcing a specific scheme (used by the ablation bench that
/// compares mixed vs. all-symmetric vs. all-asymmetric).
pub fn quantize_forced(weights: &TensorF32, bits: BitWidth, scheme: Scheme) -> QuantizedTensor {
    let (syms, params) = quantize_with(weights.data(), bits, scheme);
    QuantizedTensor {
        symbols: TensorU8::new(weights.shape().clone(), syms)
            .expect("symbol count equals weight count"),
        params,
    }
}

/// Dequantize a full layer back to f32 (the lossless-after-quantization
/// inference path: Huffman decode → symbols → this).
pub fn dequantize(q: &QuantizedTensor) -> TensorF32 {
    let data = q
        .symbols
        .data()
        .iter()
        .map(|&s| q.params.dequant_one(s))
        .collect();
    TensorF32::new(q.symbols.shape().clone(), data).expect("shape preserved")
}

/// Max absolute reconstruction error permitted for a correct uniform
/// quantizer: half a quantization step (plus float slack).
pub fn max_error_bound(params: &QuantParams) -> f32 {
    params.scale.abs() * 0.5 + 1e-6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tensor(data: Vec<f32>) -> TensorF32 {
        let n = data.len();
        TensorF32::new(vec![n], data).unwrap()
    }

    #[test]
    fn scheme_selection_follows_paper_rule() {
        assert_eq!(choose_scheme(&[0.1, 0.5, 0.9]), Scheme::SymmetricUnsigned);
        assert_eq!(choose_scheme(&[-0.1, -0.5]), Scheme::SymmetricUnsigned);
        assert_eq!(choose_scheme(&[-0.1, 0.5]), Scheme::Asymmetric);
        assert_eq!(choose_scheme(&[0.0, 0.5]), Scheme::SymmetricUnsigned);
        assert_eq!(choose_scheme(&[]), Scheme::SymmetricUnsigned);
    }

    #[test]
    fn symbols_stay_on_grid() {
        let mut rng = Rng::new(21);
        for bits in [BitWidth::U4, BitWidth::U8] {
            let w = tensor(rng.gaussian_vec(10_000, 0.0, 0.05));
            let q = quantize_mixed(&w, bits);
            assert!(q.symbols.data().iter().all(|&s| (s as usize) < bits.levels()));
        }
    }

    #[test]
    fn reconstruction_error_bounded_by_half_step() {
        let mut rng = Rng::new(22);
        for bits in [BitWidth::U4, BitWidth::U8] {
            for (mean, std) in [(0.0, 0.02), (0.1, 0.01), (-0.3, 0.05)] {
                let w = tensor(rng.gaussian_vec(5_000, mean, std));
                let q = quantize_mixed(&w, bits);
                let dq = dequantize(&q);
                let bound = max_error_bound(&q.params);
                for (a, b) in w.data().iter().zip(dq.data()) {
                    assert!(
                        (a - b).abs() <= bound,
                        "|{a} - {b}| > {bound} ({bits}, scheme {:?})",
                        q.params.scheme
                    );
                }
            }
        }
    }

    #[test]
    fn all_negative_layer_uses_negative_scale() {
        let w = tensor(vec![-0.5, -0.25, -0.1, -0.9]);
        let q = quantize_mixed(&w, BitWidth::U8);
        assert_eq!(q.params.scheme, Scheme::SymmetricUnsigned);
        assert!(q.params.scale < 0.0);
        let dq = dequantize(&q);
        for (a, b) in w.data().iter().zip(dq.data()) {
            assert!((a - b).abs() <= max_error_bound(&q.params));
        }
    }

    #[test]
    fn zero_tensor_quantizes_to_zero() {
        let w = tensor(vec![0.0; 64]);
        let q = quantize_mixed(&w, BitWidth::U4);
        assert!(q.symbols.data().iter().all(|&s| s == 0));
        assert_eq!(dequantize(&q).data(), w.data());
    }

    #[test]
    fn constant_tensor_roundtrips_exactly() {
        let w = tensor(vec![0.37; 100]);
        let q = quantize_mixed(&w, BitWidth::U8);
        let dq = dequantize(&q);
        for (a, b) in w.data().iter().zip(dq.data()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn asymmetric_grid_covers_extremes_exactly() {
        let w = tensor(vec![-1.0, 0.0, 2.0]);
        let q = quantize_mixed(&w, BitWidth::U8);
        assert_eq!(q.params.scheme, Scheme::Asymmetric);
        let dq = dequantize(&q);
        // min and max land exactly on grid endpoints.
        assert!((dq.data()[0] - -1.0).abs() < 1e-6);
        assert!((dq.data()[2] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn forced_scheme_is_respected() {
        let w = tensor(vec![0.1, 0.2, 0.3]);
        let q = quantize_forced(&w, BitWidth::U8, Scheme::Asymmetric);
        assert_eq!(q.params.scheme, Scheme::Asymmetric);
    }

    #[test]
    fn scheme_tags_roundtrip() {
        for s in [Scheme::SymmetricUnsigned, Scheme::Asymmetric] {
            assert_eq!(Scheme::from_tag(s.tag()).unwrap(), s);
        }
        assert!(Scheme::from_tag(9).is_err());
    }

    #[test]
    fn property_random_layers_error_bound() {
        // Property test: arbitrary layer contents, both widths, the
        // half-step bound always holds and symbols stay on-grid.
        let mut rng = Rng::new(0x5172);
        for _ in 0..100 {
            let n = 1 + rng.below(2000);
            let mode = rng.below(4);
            let data: Vec<f32> = (0..n)
                .map(|_| match mode {
                    0 => rng.gaussian_f32(0.0, 0.1),
                    1 => rng.range_f32(0.0, 1.0),
                    2 => rng.range_f32(-2.0, -1.0),
                    _ => rng.gaussian_f32(0.5, 2.0),
                })
                .collect();
            let w = tensor(data);
            let bits = if rng.below(2) == 0 { BitWidth::U4 } else { BitWidth::U8 };
            let q = quantize_mixed(&w, bits);
            let dq = dequantize(&q);
            let bound = max_error_bound(&q.params);
            for (a, b) in w.data().iter().zip(dq.data()) {
                assert!((a - b).abs() <= bound);
            }
        }
    }
}
