//! Line-protocol TCP server + client for the serving example.
//!
//! Offline build: no tokio, so the server is a plain `std::net` design —
//! one acceptor thread, per-connection reader threads feeding an mpsc
//! channel, and the engine thread draining it. This mirrors the paper's
//! single-device edge deployment (one model, one engine loop, multiple
//! lightweight clients).
//!
//! Protocol: one JSON object per line (at most [`MAX_LINE_BYTES`]
//! bytes — longer lines earn an error reply and a dropped connection,
//! never unbounded buffering).
//!
//! ```text
//! → {"id": 1, "prompt": "the model", "max_tokens": 32, "temperature": 0.8}
//! ← {"id": 1, "text": "...", "tokens": 32, "finish": "length",
//!    "first_token_ms": 12.3, "decode_ms": 45.6}
//! ```
//!
//! A multi-model server ([`serve_multi`], over
//! [`crate::coordinator::MultiModelServer`]) additionally routes by an
//! optional `"model"` field: the first hosted model serves requests
//! that omit it, unknown names earn an error line, and the
//! `{"stats":true}` reply grows a `models` array (per-model serving +
//! `cache_*`/`prefetch_*` counters) plus `ledger_*` fields for the
//! shared byte budget. Single-model servers reject the field so a
//! misrouted client fails loudly instead of silently getting the
//! wrong model.

use crate::coordinator::{Backend, Engine, MultiModelServer, Request, Response};
use crate::corpus::ByteTokenizer;
use crate::json::{self, Value};
use crate::{Error, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Hard cap on one protocol line. A line that exceeds it is answered
/// with an error and the connection is dropped — the reader never
/// buffers an unbounded line, so one hostile client cannot balloon
/// server memory.
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// Parse one request line. Public for tests and the client.
pub fn parse_request(line: &str, next_id: u64) -> Result<Request> {
    let v = Value::parse(line)?;
    parse_request_value(&v, next_id)
}

/// Build a [`Request`] from an already-parsed line (the connection
/// reader parses each line exactly once and branches on the result).
pub fn parse_request_value(v: &Value, next_id: u64) -> Result<Request> {
    let prompt_text = v.get("prompt")?.as_str()?.to_string();
    let prompt = ByteTokenizer.encode(&prompt_text);
    if prompt.is_empty() {
        return Err(Error::InvalidArg("empty prompt".into()));
    }
    // Strict id parse: `as_f64()? as u64` would silently truncate a
    // fractional id, wrap a negative one, and round ids at/beyond 2^53
    // — three ways for distinct clients to collide on one id and steal
    // each other's replies. Reject instead.
    let id = match v.get_opt("id") {
        None => next_id,
        Some(x) => x.as_u64().map_err(|_| {
            Error::InvalidArg("\"id\" must be a non-negative integer below 2^53".into())
        })?,
    };
    Ok(Request {
        id,
        prompt,
        max_new_tokens: v
            .get_opt("max_tokens")
            .map(|x| x.as_usize())
            .transpose()?
            .unwrap_or(32),
        temperature: v
            .get_opt("temperature")
            .map(|x| x.as_f64())
            .transpose()?
            .unwrap_or(0.0) as f32,
        top_k: v
            .get_opt("top_k")
            .map(|x| x.as_usize())
            .transpose()?
            .unwrap_or(0),
        stop_token: Some(u32::from(b'.')),
        enqueued_at: None,
    })
}

/// Serialize a response line.
pub fn format_response(r: &Response) -> String {
    let text = ByteTokenizer.decode(&r.tokens);
    json::obj(vec![
        ("id", json::num(r.id as f64)),
        ("text", json::s(&text)),
        ("tokens", json::num(r.tokens.len() as f64)),
        (
            "finish",
            json::s(match r.finish_reason {
                crate::coordinator::request::FinishReason::Length => "length",
                crate::coordinator::request::FinishReason::Stop => "stop",
                crate::coordinator::request::FinishReason::Capacity => "capacity",
            }),
        ),
        (
            "first_token_ms",
            json::num(r.timing.first_token.as_secs_f64() * 1e3),
        ),
        ("decode_ms", json::num(r.timing.decode.as_secs_f64() * 1e3)),
    ])
    .to_json()
}

enum Incoming {
    /// A generation request plus its optional `"model"` routing name.
    Req(Request, Option<String>, mpsc::Sender<String>),
    Stats(mpsc::Sender<String>),
    Bad(String, mpsc::Sender<String>),
}

/// Build one error reply line through the real JSON serializer:
/// quotes, backslashes, and control characters (including newlines) are
/// escaped losslessly, so hostile content echoed inside an error — a
/// weird model name, a parser message quoting the input — can never
/// corrupt the line protocol or smuggle a fake reply.
fn error_line(msg: &str) -> String {
    json::obj(vec![("error", json::s(msg))]).to_json()
}

/// Extract the optional `"model"` routing field (must be a string when
/// present).
fn parse_model(v: &Value) -> Result<Option<String>> {
    match v.get_opt("model") {
        None => Ok(None),
        Some(Value::Str(name)) => Ok(Some(name.clone())),
        Some(other) => Err(Error::InvalidArg(format!(
            "\"model\" must be a string, got {other:?}"
        ))),
    }
}

/// Serialize an engine-stats snapshot (the `{"stats": true}` admin
/// line's reply): serving counters plus live occupancy, so an operator
/// can watch a streaming-loaded server warm up without a side channel.
/// When the backend serves weights through a residency cache
/// ([`crate::residency`]), the cache's hit/miss/evict counters and
/// byte occupancy ride along under `cache_*` keys; when it prefetches
/// decode-ahead ([`crate::residency::prefetch`]), the prefetcher's
/// scheduled/completed/hit/wait counters ride along under `prefetch_*`
/// keys.
pub fn format_stats<B: Backend>(engine: &Engine<B>) -> String {
    json::obj(engine_stats_fields(engine)).to_json()
}

/// The per-engine stats fields of the admin line — shared by the
/// single-model reply ([`format_stats`]) and each entry of the
/// multi-model `models` array ([`format_multi_stats`]).
fn engine_stats_fields<B: Backend>(engine: &Engine<B>) -> Vec<(&'static str, Value)> {
    let s = engine.stats();
    let q = engine.queue_stats();
    let mut fields = vec![
        ("completed", json::num(s.completed as f64)),
        ("tokens", json::num(s.tokens as f64)),
        ("decode_steps", json::num(s.decode_steps as f64)),
        ("mean_occupancy", json::num(s.mean_occupancy())),
        ("active_slots", json::num(engine.active() as f64)),
        ("queue_depth", json::num(q.depth as f64)),
        ("admitted", json::num(q.admitted as f64)),
        ("rejected", json::num(q.rejected as f64)),
    ];
    if let Some(c) = engine.residency() {
        fields.push(("cache_hits", json::num(c.hits as f64)));
        fields.push(("cache_misses", json::num(c.misses as f64)));
        fields.push(("cache_evictions", json::num(c.evictions as f64)));
        fields.push(("cache_resident_bytes", json::num(c.resident_bytes as f64)));
        fields.push((
            "cache_peak_resident_bytes",
            json::num(c.peak_resident_bytes as f64),
        ));
        fields.push(("cache_budget_bytes", json::num(c.budget_bytes as f64)));
        fields.push(("cache_pinned_layers", json::num(c.pinned_layers as f64)));
    }
    if let Some(p) = engine.prefetch() {
        fields.push(("prefetch_scheduled", json::num(p.scheduled as f64)));
        fields.push(("prefetch_completed", json::num(p.completed as f64)));
        fields.push(("prefetch_hits", json::num(p.hits as f64)));
        fields.push(("prefetch_waits", json::num(p.waits as f64)));
        fields.push(("prefetch_sync_faults", json::num(p.sync_faults as f64)));
    }
    fields
}

/// The multi-model admin-line reply: the existing global fields
/// (summed across engines), the shared ledger's `ledger_*` fields, and
/// a `models` array carrying each model's full per-engine snapshot —
/// serving counters plus its `cache_*`/`prefetch_*` families.
pub fn format_multi_stats(multi: &MultiModelServer) -> String {
    let mut completed = 0u64;
    let mut tokens = 0u64;
    let mut decode_steps = 0u64;
    let mut occupancy_sum = 0u64;
    let mut active = 0usize;
    let mut depth = 0usize;
    let mut admitted = 0u64;
    let mut rejected = 0u64;
    let mut models = Vec::with_capacity(multi.n_models());
    for i in 0..multi.n_models() {
        let engine = multi.engine(i);
        let s = engine.stats();
        let q = engine.queue_stats();
        completed += s.completed;
        tokens += s.tokens;
        decode_steps += s.decode_steps;
        occupancy_sum += s.occupancy_sum;
        active += engine.active();
        depth += q.depth;
        admitted += q.admitted;
        rejected += q.rejected;
        let mut fields = vec![("model", json::s(multi.name(i)))];
        fields.extend(engine_stats_fields(engine));
        // Per-model QoS under the shared ledger: the configured
        // reservation/weight plus the shed traffic in both directions,
        // so an operator can see who is leaning on whom.
        let q = multi.model_counters(i);
        fields.push(("reserved_bytes", json::num(q.reserved_bytes as f64)));
        fields.push(("qos_weight", json::num(q.weight)));
        fields.push(("shed_from_peers", json::num(q.shed_from_peers as f64)));
        fields.push(("shed_by_peers", json::num(q.shed_by_peers as f64)));
        models.push(json::obj(fields));
    }
    let mean_occupancy = if decode_steps == 0 {
        0.0
    } else {
        occupancy_sum as f64 / decode_steps as f64
    };
    let ledger = multi.ledger().counters();
    json::obj(vec![
        ("completed", json::num(completed as f64)),
        ("tokens", json::num(tokens as f64)),
        ("decode_steps", json::num(decode_steps as f64)),
        ("mean_occupancy", json::num(mean_occupancy)),
        ("active_slots", json::num(active as f64)),
        ("queue_depth", json::num(depth as f64)),
        ("admitted", json::num(admitted as f64)),
        ("rejected", json::num(rejected as f64)),
        ("ledger_budget_bytes", json::num(ledger.budget_bytes as f64)),
        ("ledger_used_bytes", json::num(ledger.used_bytes as f64)),
        (
            "ledger_peak_used_bytes",
            json::num(ledger.peak_used_bytes as f64),
        ),
        (
            "ledger_reserved_bytes",
            json::num(ledger.reserved_bytes as f64),
        ),
        ("models", json::arr(models)),
    ])
    .to_json()
}

/// Spawn the acceptor thread shared by [`serve`] and [`serve_multi`]:
/// it owns the listener, spawns one reader thread per connection, and
/// joins them all on shutdown.
fn spawn_acceptor(
    listener: TcpListener,
    tx: mpsc::Sender<Incoming>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut conns = Vec::new();
        while !stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let tx = tx.clone();
                    let stop = stop.clone();
                    conns.push(std::thread::spawn(move || read_conn(stream, tx, stop)));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
        for c in conns {
            let _ = c.join();
        }
    })
}

/// Serve an engine over TCP until `stop` flips. Returns total requests
/// served. Spawns one thread per connection (edge workloads: few
/// clients) plus the engine loop on the calling thread.
pub fn serve<B: Backend>(
    engine: &mut Engine<B>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
) -> Result<u64> {
    listener.set_nonblocking(true)?;
    let (tx, rx) = mpsc::channel::<Incoming>();
    let acceptor = spawn_acceptor(listener, tx, stop.clone());

    // Engine loop: drain incoming, step, route responses.
    let mut next_id: u64 = 1;
    let mut waiters: Vec<(u64, mpsc::Sender<String>)> = Vec::new();
    let mut served = 0u64;
    while !stop.load(Ordering::Relaxed) {
        let mut idle = true;
        while let Ok(msg) = rx.try_recv() {
            idle = false;
            match msg {
                Incoming::Req(req, model, reply) => {
                    if let Some(name) = model {
                        // One unnamed model here: failing loudly beats
                        // silently serving the wrong model to a client
                        // that believes it reached a multi-model host.
                        let _ = reply.send(error_line(&format!(
                            "this server hosts a single unnamed model; drop the \
                             'model' field (got {name:?})"
                        )));
                        continue;
                    }
                    let id = req.id.max(next_id);
                    next_id = id + 1;
                    let mut req = req;
                    req.id = id;
                    match engine.submit(req) {
                        Ok(()) => waiters.push((id, reply)),
                        Err(e) => {
                            let _ = reply.send(error_line(&e.to_string()));
                        }
                    }
                }
                Incoming::Stats(reply) => {
                    let _ = reply.send(format_stats(engine));
                }
                Incoming::Bad(err, reply) => {
                    let _ = reply.send(error_line(&err));
                }
            }
        }
        if engine.has_work() {
            idle = false;
            for resp in engine.step()? {
                served += 1;
                if let Some(i) = waiters.iter().position(|(id, _)| *id == resp.id) {
                    let (_, reply) = waiters.swap_remove(i);
                    let _ = reply.send(format_response(&resp));
                }
            }
        }
        if idle {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    drop(rx);
    let _ = acceptor.join();
    Ok(served)
}

/// Serve a [`MultiModelServer`] over TCP until `stop` flips — the
/// multi-model counterpart of [`serve`]. Connection handling is
/// identical; requests route by their optional `"model"` field (first
/// hosted model when omitted, error line for unknown names), every
/// model's engine steps in the same loop so a busy model never
/// starves an idle one's admissions, and `{"stats":true}` answers
/// with the aggregated + per-model snapshot ([`format_multi_stats`]).
/// Returns total requests served across all models.
pub fn serve_multi(
    multi: &mut MultiModelServer,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
) -> Result<u64> {
    listener.set_nonblocking(true)?;
    let (tx, rx) = mpsc::channel::<Incoming>();
    let acceptor = spawn_acceptor(listener, tx, stop.clone());

    // Engine loop: route incoming by model, step every engine, match
    // responses back to their waiters by (model, id).
    let mut next_id: u64 = 1;
    let mut waiters: Vec<(usize, u64, mpsc::Sender<String>)> = Vec::new();
    let mut served = 0u64;
    while !stop.load(Ordering::Relaxed) {
        let mut idle = true;
        while let Ok(msg) = rx.try_recv() {
            idle = false;
            match msg {
                Incoming::Req(req, model, reply) => {
                    let target = match multi.resolve(model.as_deref()) {
                        Ok(i) => i,
                        Err(e) => {
                            let _ = reply.send(error_line(&e.to_string()));
                            continue;
                        }
                    };
                    // Ids may be remapped upward so they stay unique
                    // across all connections (two clients reusing id 1
                    // would otherwise steal each other's replies); the
                    // reply's id field is authoritative — documented in
                    // docs/SERVING.md.
                    let id = req.id.max(next_id);
                    next_id = id + 1;
                    let mut req = req;
                    req.id = id;
                    match multi.engine_mut(target).submit(req) {
                        Ok(()) => waiters.push((target, id, reply)),
                        Err(e) => {
                            let _ = reply.send(error_line(&e.to_string()));
                        }
                    }
                }
                Incoming::Stats(reply) => {
                    let _ = reply.send(format_multi_stats(multi));
                }
                Incoming::Bad(err, reply) => {
                    let _ = reply.send(error_line(&err));
                }
            }
        }
        for mi in 0..multi.n_models() {
            if !multi.engine(mi).has_work() {
                continue;
            }
            idle = false;
            for resp in multi.engine_mut(mi).step()? {
                served += 1;
                if let Some(i) = waiters
                    .iter()
                    .position(|(m, id, _)| *m == mi && *id == resp.id)
                {
                    let (_, _, reply) = waiters.swap_remove(i);
                    let _ = reply.send(format_response(&resp));
                }
            }
        }
        if idle {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    drop(rx);
    let _ = acceptor.join();
    Ok(served)
}

/// Outcome of one bounded line read.
enum LineRead {
    /// Clean end of stream (any unterminated partial line is dropped —
    /// a mid-write disconnect never becomes a request).
    Eof,
    /// One complete line is in the buffer (newline stripped).
    Line,
    /// The line exceeded the cap; its consumed prefix was discarded.
    Oversized,
}

/// Read one newline-terminated line into `line`, never letting the
/// buffer grow past `max` bytes — the memory-safety half of the line
/// protocol (`BufRead::read_line` would buffer an arbitrarily long
/// hostile line). I/O errors (including `WouldBlock` timeout ticks)
/// propagate with the partial line preserved, so the caller can
/// re-check its stop flag and resume mid-line.
fn read_bounded_line(
    reader: &mut BufReader<TcpStream>,
    line: &mut Vec<u8>,
    max: usize,
) -> std::io::Result<LineRead> {
    enum Step {
        Done,
        Oversized,
        More,
    }
    loop {
        let (step, used) = {
            let buf = reader.fill_buf()?;
            if buf.is_empty() {
                return Ok(LineRead::Eof);
            }
            match buf.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if line.len() + pos > max {
                        (Step::Oversized, pos + 1)
                    } else {
                        line.extend_from_slice(&buf[..pos]);
                        (Step::Done, pos + 1)
                    }
                }
                None => {
                    let n = buf.len();
                    if line.len() + n > max {
                        (Step::Oversized, n)
                    } else {
                        line.extend_from_slice(buf);
                        (Step::More, n)
                    }
                }
            }
        };
        reader.consume(used);
        match step {
            Step::Done => return Ok(LineRead::Line),
            Step::Oversized => return Ok(LineRead::Oversized),
            Step::More => {}
        }
    }
}

/// Classify one complete protocol line: the `{"stats": true}` admin
/// line, a generation request (with its optional `"model"` routing
/// name), or a malformed line that earns an error reply. `None` for
/// blank lines.
fn classify_line(line: &[u8], reply_tx: &mpsc::Sender<String>) -> Option<Incoming> {
    let Ok(text) = std::str::from_utf8(line) else {
        return Some(Incoming::Bad(
            "request line is not valid utf-8".into(),
            reply_tx.clone(),
        ));
    };
    let trimmed = text.trim();
    if trimmed.is_empty() {
        return None;
    }
    // Parse once; `{"stats": true}` is the admin line, anything else is
    // a generation request.
    match Value::parse(trimmed) {
        Ok(ref v) if matches!(v.get_opt("stats"), Some(Value::Bool(true))) => {
            Some(Incoming::Stats(reply_tx.clone()))
        }
        Ok(ref v) => match parse_model(v)
            .and_then(|model| parse_request_value(v, 0).map(|req| (req, model)))
        {
            Ok((req, model)) => Some(Incoming::Req(req, model, reply_tx.clone())),
            Err(e) => Some(Incoming::Bad(e.to_string(), reply_tx.clone())),
        },
        Err(e) => Some(Incoming::Bad(e.to_string(), reply_tx.clone())),
    }
}

/// Drain reply lines from `rx` onto `w`, one `\n`-terminated line per
/// message, until the channel closes or the sink fails. A failed
/// *flush* ends the loop exactly like a failed write: both mean the
/// peer is unreachable, and swallowing the flush error (`let _ =
/// w.flush()`) left the thread happily pushing every later reply into
/// a sink that had already told us it was dead. Generic over the sink
/// so the teardown contract is unit-testable without a socket
/// (`TcpStream::flush` itself is a no-op, but buffered or wrapped
/// sinks surface real errors there).
fn writer_loop<W: Write>(rx: mpsc::Receiver<String>, mut w: W) {
    while let Ok(line) = rx.recv() {
        if w.write_all(line.as_bytes()).is_err()
            || w.write_all(b"\n").is_err()
            || w.flush().is_err()
        {
            break;
        }
    }
}

fn read_conn(stream: TcpStream, tx: mpsc::Sender<Incoming>, stop: Arc<AtomicBool>) {
    let peer_write = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    // Read with a timeout so a long-lived idle client can't pin this
    // thread past server shutdown.
    stream
        .set_read_timeout(Some(Duration::from_millis(200)))
        .ok();
    let (reply_tx, reply_rx) = mpsc::channel::<String>();
    // Writer thread serializes replies back to this connection; it
    // tears down on the first write OR flush error.
    let writer = std::thread::spawn(move || writer_loop(reply_rx, peer_write));
    let mut reader = BufReader::new(stream);
    let mut line: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match read_bounded_line(&mut reader, &mut line, MAX_LINE_BYTES) {
            Ok(LineRead::Eof) => break, // client closed
            Ok(LineRead::Oversized) => {
                // Answer, then drop the connection: a client this far
                // out of protocol cannot be resynchronized reliably.
                let _ = reply_tx.send(error_line(&format!(
                    "request line exceeds {MAX_LINE_BYTES} bytes; closing connection"
                )));
                break;
            }
            Ok(LineRead::Line) => {
                let msg = classify_line(&line, &reply_tx);
                line.clear();
                if let Some(msg) = msg {
                    if tx.send(msg).is_err() {
                        break;
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Timeout tick: keep any partial line and re-check stop.
                continue;
            }
            Err(_) => break,
        }
    }
    drop(reply_tx);
    let _ = writer.join();
}

/// Blocking client for the line protocol (used by examples/benches).
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to a server.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one request line and wait for the reply line.
    pub fn request(&mut self, prompt: &str, max_tokens: usize, temperature: f32) -> Result<Value> {
        let line = json::obj(vec![
            ("prompt", json::s(prompt)),
            ("max_tokens", json::num(max_tokens as f64)),
            ("temperature", json::num(temperature as f64)),
        ])
        .to_json();
        self.roundtrip(&line)
    }

    /// [`Client::request`] with an explicit `"model"` routing name (for
    /// multi-model servers).
    pub fn request_model(
        &mut self,
        model: &str,
        prompt: &str,
        max_tokens: usize,
        temperature: f32,
    ) -> Result<Value> {
        let line = json::obj(vec![
            ("model", json::s(model)),
            ("prompt", json::s(prompt)),
            ("max_tokens", json::num(max_tokens as f64)),
            ("temperature", json::num(temperature as f64)),
        ])
        .to_json();
        self.roundtrip(&line)
    }

    /// Request the server's engine-stats snapshot.
    pub fn stats(&mut self) -> Result<Value> {
        self.roundtrip(r#"{"stats":true}"#)
    }

    fn roundtrip(&mut self, line: &str) -> Result<Value> {
        self.stream.write_all(line.as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        if reply.is_empty() {
            return Err(Error::Engine("server closed connection".into()));
        }
        Value::parse(reply.trim())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{EngineConfig, MockBackend};

    #[test]
    fn parse_request_accepts_minimal_and_full() {
        let r = parse_request(r#"{"prompt":"hi"}"#, 42).unwrap();
        assert_eq!(r.id, 42);
        assert_eq!(r.prompt, vec![104, 105]);
        assert_eq!(r.max_new_tokens, 32);
        let r = parse_request(
            r#"{"id":7,"prompt":"x","max_tokens":5,"temperature":0.5,"top_k":3}"#,
            0,
        )
        .unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.max_new_tokens, 5);
        assert!((r.temperature - 0.5).abs() < 1e-6);
        assert_eq!(r.top_k, 3);
    }

    #[test]
    fn parse_request_rejects_garbage() {
        assert!(parse_request("not json", 1).is_err());
        assert!(parse_request(r#"{"prompt":""}"#, 1).is_err());
        assert!(parse_request(r#"{"no_prompt":1}"#, 1).is_err());
    }

    /// Regression for the id-truncation bug: `as_f64()? as u64` turned
    /// negative ids into huge ones, fractional ids into their floor,
    /// and ≥2^53 ids into rounded collisions — all silently. Every such
    /// id must now be rejected.
    #[test]
    fn parse_request_rejects_non_integer_ids() {
        for line in [
            r#"{"id":-1,"prompt":"x"}"#,
            r#"{"id":1.25,"prompt":"x"}"#,
            r#"{"id":1e20,"prompt":"x"}"#,
            r#"{"id":9007199254740993,"prompt":"x"}"#,
            r#"{"id":"7","prompt":"x"}"#,
        ] {
            let err = parse_request(line, 1).unwrap_err();
            assert!(err.to_string().contains("id"), "{line}: {err}");
        }
        // The largest exactly-representable id is accepted unchanged.
        let r = parse_request(r#"{"id":9007199254740991,"prompt":"x"}"#, 1).unwrap();
        assert_eq!(r.id, 9_007_199_254_740_991);
    }

    #[test]
    fn format_response_roundtrips_as_json() {
        let r = Response {
            id: 3,
            tokens: vec![104, 105],
            finish_reason: crate::coordinator::request::FinishReason::Length,
            timing: Default::default(),
        };
        let v = Value::parse(&format_response(&r)).unwrap();
        assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("text").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "length");
    }

    #[test]
    fn end_to_end_over_loopback_with_mock_backend() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut c = Client::connect(&addr).unwrap();
        let reply = c.request("ab", 4, 0.0).unwrap();
        assert_eq!(reply.get("tokens").unwrap().as_usize().unwrap(), 4);
        let reply2 = c.request("cd", 2, 0.0).unwrap();
        assert_eq!(reply2.get("tokens").unwrap().as_usize().unwrap(), 2);

        // Admin stats line reports the two completed requests.
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("completed").unwrap().as_usize().unwrap(), 2);
        assert_eq!(stats.get("tokens").unwrap().as_usize().unwrap(), 6);
        assert_eq!(stats.get("active_slots").unwrap().as_usize().unwrap(), 0);
        assert_eq!(stats.get("rejected").unwrap().as_usize().unwrap(), 0);

        // `"stats": false` is NOT the admin line: it falls through to
        // request parsing and earns an error (no prompt), not a snapshot.
        let not_stats = c.roundtrip(r#"{"stats":false}"#).unwrap();
        assert!(not_stats.get_opt("error").is_some(), "{not_stats:?}");

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert_eq!(served, 2);
    }

    #[test]
    fn format_stats_is_valid_json_with_counters() {
        let engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
        let v = Value::parse(&format_stats(&engine)).unwrap();
        assert_eq!(v.get("completed").unwrap().as_usize().unwrap(), 0);
        assert_eq!(v.get("queue_depth").unwrap().as_usize().unwrap(), 0);
        assert!(v.get("mean_occupancy").unwrap().as_f64().unwrap() >= 0.0);
        // Fully-resident backends have no residency cache to report.
        assert!(v.get_opt("cache_hits").is_none());
    }

    /// The acceptance loop for the weight-residency subsystem: a model
    /// whose decoded weights exceed the byte budget serves over TCP,
    /// and the `{"stats":true}` admin line carries the cache counters.
    #[test]
    fn stats_line_surfaces_residency_counters_over_loopback() {
        use crate::pipeline::synthetic_layers;
        use crate::quant::BitWidth;
        use crate::residency::{ResidentDigestBackend, ResidentWeightSet};
        use crate::store::{compress, SegmentSource};

        let layers = synthetic_layers(8, 0xFEED);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let bytes: Vec<usize> = model.layers.iter().map(|m| m.n_symbols).collect();
        let largest = *bytes.iter().max().unwrap();
        let total: usize = bytes.iter().sum();
        let budget = largest.max(total / 2);
        assert!(budget < total, "model must exceed the budget");
        let src = Arc::new(SegmentSource::from_model(Arc::new(model)));
        let ws = ResidentWeightSet::new(src, budget, Vec::new()).unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(
                ResidentDigestBackend::new(ws, 2, 32, 256),
                EngineConfig::default(),
            );
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut c = Client::connect(&addr).unwrap();
        let reply = c.request("residency", 4, 0.0).unwrap();
        // Token values are digest-driven, so generation may stop early
        // on the protocol's '.' stop token; at least one token arrives.
        assert!(reply.get("tokens").unwrap().as_usize().unwrap() >= 1);

        let stats = c.stats().unwrap();
        assert!(stats.get("cache_misses").unwrap().as_usize().unwrap() > 0);
        assert!(
            stats.get("cache_evictions").unwrap().as_usize().unwrap() > 0,
            "under-budget serving must evict"
        );
        let peak = stats
            .get("cache_peak_resident_bytes")
            .unwrap()
            .as_usize()
            .unwrap();
        assert!(peak <= budget, "peak {peak} must respect budget {budget}");
        assert_eq!(
            stats.get("cache_budget_bytes").unwrap().as_usize().unwrap(),
            budget
        );

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert_eq!(served, 1);
    }

    /// A healthy sink drains the whole channel, one line per message.
    #[test]
    fn writer_loop_drains_channel_when_sink_is_healthy() {
        let (tx, rx) = mpsc::channel::<String>();
        tx.send("a".into()).unwrap();
        tx.send("b".into()).unwrap();
        drop(tx);
        let mut out: Vec<u8> = Vec::new();
        writer_loop(rx, &mut out);
        assert_eq!(out, b"a\nb\n");
    }

    /// Regression: the writer thread used to swallow flush errors
    /// (`let _ = w.flush();`), so a sink that reported the peer dead at
    /// flush time kept receiving every later reply. The first failed
    /// flush must end the loop like a failed write does.
    #[test]
    fn writer_loop_tears_down_on_first_flush_failure() {
        struct FailingFlush {
            buf: Vec<u8>,
            flushes: usize,
        }
        impl Write for FailingFlush {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.buf.extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                self.flushes += 1;
                Err(std::io::Error::new(
                    std::io::ErrorKind::BrokenPipe,
                    "peer disconnected",
                ))
            }
        }
        let (tx, rx) = mpsc::channel::<String>();
        for i in 0..3 {
            tx.send(format!("line {i}")).unwrap();
        }
        drop(tx);
        let mut w = FailingFlush {
            buf: Vec::new(),
            flushes: 0,
        };
        writer_loop(rx, &mut w);
        assert_eq!(w.flushes, 1, "first failed flush must end the loop");
        assert_eq!(
            w.buf, b"line 0\n",
            "replies after the failed flush must not be written into a dead sink"
        );
    }

    /// The same contract at the socket level: a client that reads its
    /// first response line, queues more requests, and disconnects
    /// *between* response lines must only cost its own connection —
    /// the server keeps serving a healthy neighbor.
    #[test]
    fn client_disconnecting_between_response_lines_leaves_server_healthy() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut healthy = Client::connect(&addr).unwrap();
        assert_eq!(
            healthy.request("ab", 2, 0.0).unwrap().get("tokens").unwrap().as_usize().unwrap(),
            2
        );

        // The flaky client: one full round trip, then two queued
        // requests whose replies it will never read.
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).ok();
            s.write_all(b"{\"prompt\":\"ab\",\"max_tokens\":2}\n").unwrap();
            let mut reader = BufReader::new(s.try_clone().unwrap());
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            assert!(reply.contains("tokens"), "{reply:?}");
            s.write_all(
                b"{\"prompt\":\"cd\",\"max_tokens\":2}\n{\"prompt\":\"ef\",\"max_tokens\":2}\n",
            )
            .unwrap();
            // Dropped here: the connection dies between response lines,
            // with replies still owed.
        }

        // The neighbor never notices: same connection, fresh
        // connection, and the admin line all still answer.
        for prompt in ["cd", "ef", "gh"] {
            let ok = healthy.request(prompt, 2, 0.0).unwrap();
            assert_eq!(ok.get("tokens").unwrap().as_usize().unwrap(), 2);
        }
        let mut fresh = Client::connect(&addr).unwrap();
        let stats = fresh.stats().unwrap();
        assert!(stats.get("completed").unwrap().as_usize().unwrap() >= 5);

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert!(served >= 5, "server must keep serving after the disconnect, served {served}");
    }

    /// Adversarial line-protocol suite, part 1: every malformed line on
    /// a live connection earns an error line, and the connection stays
    /// usable afterwards.
    #[test]
    fn adversarial_lines_earn_error_replies_without_killing_the_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut c = Client::connect(&addr).unwrap();
        for line in [
            "{not json",
            r#"[1,2,3]"#,
            r#"{"id":-1,"prompt":"x"}"#,
            r#"{"id":1.5,"prompt":"x"}"#,
            r#"{"id":1e20,"prompt":"x"}"#,
            r#"{"model":"m","prompt":"x"}"#, // single-model server: no routing
            r#"{"model":3,"prompt":"x"}"#,   // model must be a string
            r#"{"prompt":""}"#,
        ] {
            let reply = c.roundtrip(line).unwrap();
            assert!(
                reply.get_opt("error").is_some(),
                "{line} must earn an error line, got {reply:?}"
            );
        }
        // The "model" rejection tells the client what went wrong.
        let reply = c.roundtrip(r#"{"model":"m","prompt":"x"}"#).unwrap();
        assert!(
            reply.get("error").unwrap().as_str().unwrap().contains("single"),
            "{reply:?}"
        );

        // After all that abuse, the same connection still serves.
        let ok = c.request("ab", 2, 0.0).unwrap();
        assert_eq!(ok.get("tokens").unwrap().as_usize().unwrap(), 2);

        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    /// Adversarial suite, part 2: an oversized line is answered and the
    /// connection dropped with bounded buffering; a mid-write
    /// disconnect evaporates; neither disturbs another client.
    #[test]
    fn oversized_lines_and_midwrite_disconnects_leave_other_clients_unaffected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
            serve(&mut engine, listener, stop2).unwrap()
        });

        // A well-behaved client connects first and must stay healthy
        // throughout.
        let mut healthy = Client::connect(&addr).unwrap();
        assert_eq!(
            healthy.request("ab", 2, 0.0).unwrap().get("tokens").unwrap().as_usize().unwrap(),
            2
        );

        // Hostile client 1: one line far beyond the cap, never
        // newline-terminated. The server must reply with an error (or
        // just close) without ever buffering the whole thing.
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).ok();
            let chunk = vec![b'a'; 64 * 1024];
            let mut sent = 0usize;
            while sent <= MAX_LINE_BYTES {
                if s.write_all(&chunk).is_err() {
                    break; // server already hung up — equally fine
                }
                sent += chunk.len();
            }
            let mut reader = BufReader::new(s);
            let mut reply = String::new();
            let _ = reader.read_line(&mut reply);
            assert!(
                reply.is_empty() || reply.contains("exceeds"),
                "oversized line must be refused, got {reply:?}"
            );
            // Connection is closed: the next read sees EOF.
            let mut rest = String::new();
            let closed = matches!(reader.read_line(&mut rest), Ok(0));
            assert!(closed || rest.is_empty(), "server must drop the connection");
        }

        // Hostile client 2: writes half a JSON object, then vanishes.
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(br#"{"prompt":"interru"#).unwrap();
            // dropped here, mid-line, no newline
        }

        // The healthy client never noticed either neighbor.
        let ok = healthy.request("cd", 3, 0.0).unwrap();
        assert_eq!(ok.get("tokens").unwrap().as_usize().unwrap(), 3);
        let stats = healthy.stats().unwrap();
        assert_eq!(stats.get("completed").unwrap().as_usize().unwrap(), 2);

        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    /// Adversarial suite, part 3 (the lock-poisoning satellite at the
    /// server level): a thread that panics while holding the serving
    /// backend's shared state lock must not cascade — the server keeps
    /// answering on live and new connections.
    #[test]
    fn panicking_handler_thread_does_not_take_the_server_down() {
        use crate::pipeline::synthetic_layers;
        use crate::quant::BitWidth;
        use crate::residency::{PrefetchConfig, PrefetchingDigestBackend, PrefetchingWeightSet};
        use crate::store::{compress, SegmentSource};

        let layers = synthetic_layers(6, 0xFACE);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let total: usize = model.layers.iter().map(|m| m.n_symbols).sum();
        let largest = model.layers.iter().map(|m| m.n_symbols).max().unwrap();
        let budget = total.max(3 * largest);
        let src = Arc::new(SegmentSource::from_model(Arc::new(model)));
        let ws = PrefetchingWeightSet::new(src, budget, Vec::new(), PrefetchConfig::default())
            .unwrap();
        let shared = Arc::clone(ws.shared());

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(
                PrefetchingDigestBackend::new(ws, 2, 32, 256),
                EngineConfig::default(),
            );
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut c = Client::connect(&addr).unwrap();
        let first = c.request("first", 3, 0.0).unwrap();
        assert!(first.get("tokens").unwrap().as_usize().unwrap() >= 1);

        // A handler thread panics while holding the backend's shared
        // state lock (the cascading-poison scenario).
        let poisoner = Arc::clone(&shared);
        std::thread::spawn(move || {
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _ = poisoner.with_layer(0, |_| -> () { panic!("handler bug") });
            }));
        })
        .join()
        .unwrap();

        // Existing connection still serves…
        let reply = c.request("still alive", 3, 0.0).unwrap();
        assert!(reply.get("tokens").unwrap().as_usize().unwrap() >= 1);
        // …and so does a fresh one, stats included.
        let mut c2 = Client::connect(&addr).unwrap();
        let stats = c2.stats().unwrap();
        assert!(stats.get("completed").unwrap().as_usize().unwrap() >= 2);

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert_eq!(served, 2);
    }

    /// The tentpole acceptance over loopback: two models on one port
    /// produce token streams bit-identical to two isolated
    /// single-model engines at the same per-model budget, with routing
    /// by `"model"`, a default model, error lines for unknown names,
    /// and per-model + ledger fields in `{"stats":true}`.
    #[test]
    fn two_models_one_port_bit_identical_with_per_model_stats() {
        use crate::coordinator::{ModelSpec, MultiModelConfig};
        use crate::pipeline::synthetic_layers;
        use crate::quant::BitWidth;
        use crate::residency::{
            Policy, PrefetchConfig, PrefetchingDigestBackend, PrefetchingWeightSet,
        };
        use crate::store::{compress, SegmentSource};

        let build = |n: usize, seed: u64| {
            let (m, _) = compress(&synthetic_layers(n, seed), BitWidth::U8).unwrap();
            Arc::new(SegmentSource::from_model(Arc::new(m)))
        };
        let src_a = build(6, 0xA0);
        let src_b = build(8, 0xB0);
        let per_budget = |s: &SegmentSource| {
            let largest = s.layers().iter().map(|m| m.n_symbols).max().unwrap();
            (s.n_params() / 2).max(3 * largest)
        };
        let (budget_a, budget_b) = (per_budget(&src_a), per_budget(&src_b));
        let prompts_a = ["alpha one", "alpha two"];
        let prompts_b = ["beta one", "beta two"];

        // Isolated per-model references at the same per-model budget,
        // fed through `parse_request` so request shape (stop token,
        // defaults) is exactly what the server builds. Requests run one
        // at a time: a TCP client blocks on each reply, so the serving
        // engine sees them sequentially too (slot occupancy — which the
        // digest backend folds into its tokens — must match).
        let isolated = |src: &Arc<SegmentSource>, budget: usize, prompts: &[&str]| {
            let ws = PrefetchingWeightSet::new(
                Arc::clone(src),
                budget,
                Vec::new(),
                PrefetchConfig {
                    decode_ahead: 2,
                    workers: 2,
                    policy: Policy::SegmentedLru,
                },
            )
            .unwrap();
            let mut engine = Engine::new(
                PrefetchingDigestBackend::new(ws, 2, 64, 256),
                EngineConfig::default(),
            );
            let mut texts = Vec::new();
            for (i, p) in prompts.iter().enumerate() {
                let line = json::obj(vec![
                    ("prompt", json::s(p)),
                    ("max_tokens", json::num(6.0)),
                ])
                .to_json();
                engine.submit(parse_request(&line, 1 + i as u64).unwrap()).unwrap();
                let rs = engine.run_to_completion(10_000).unwrap();
                assert_eq!(rs.len(), 1);
                texts.push(ByteTokenizer.decode(&rs[0].tokens));
            }
            texts
        };
        let want_a = isolated(&src_a, budget_a, &prompts_a);
        let want_b = isolated(&src_b, budget_b, &prompts_b);

        // One multi-model server, one port, same total budget. Alpha
        // carries a QoS reservation + weight — which must change
        // residency pressure only, never tokens (the bit-identical
        // assertions below hold regardless).
        let mut multi = MultiModelServer::new(
            vec![
                ModelSpec::new("alpha", src_a).with_qos(budget_a, 2.0),
                ModelSpec::new("beta", src_b),
            ],
            MultiModelConfig {
                budget_bytes: budget_a + budget_b,
                ..MultiModelConfig::default()
            },
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let served = serve_multi(&mut multi, listener, stop2).unwrap();
            (served, multi)
        });

        let mut ca = Client::connect(&addr).unwrap();
        let mut cb = Client::connect(&addr).unwrap();
        // Interleaved load across the two models on two connections.
        let mut got_a = Vec::new();
        let mut got_b = Vec::new();
        for i in 0..2 {
            let ra = ca.request_model("alpha", prompts_a[i], 6, 0.0).unwrap();
            let rb = cb.request_model("beta", prompts_b[i], 6, 0.0).unwrap();
            got_a.push(ra.get("text").unwrap().as_str().unwrap().to_string());
            got_b.push(rb.get("text").unwrap().as_str().unwrap().to_string());
        }
        assert_eq!(got_a, want_a, "alpha's stream must match its isolated engine");
        assert_eq!(got_b, want_b, "beta's stream must match its isolated engine");

        // Omitting "model" routes to the first (default) model.
        let r = ca.request(prompts_a[0], 6, 0.0).unwrap();
        assert_eq!(r.get("text").unwrap().as_str().unwrap(), want_a[0]);

        // Unknown model: error line naming the hosted set; the
        // connection stays usable.
        let bad = ca.roundtrip(r#"{"model":"gamma","prompt":"x"}"#).unwrap();
        let msg = bad.get("error").unwrap().as_str().unwrap().to_string();
        assert!(msg.contains("unknown model"), "{msg}");
        assert!(msg.contains("alpha") && msg.contains("beta"), "{msg}");
        let ok = ca.request_model("beta", prompts_b[0], 6, 0.0).unwrap();
        assert_eq!(ok.get("text").unwrap().as_str().unwrap(), want_b[0]);

        // Admin line: global aggregates + per-model counter families +
        // shared-ledger fields.
        let stats = ca.stats().unwrap();
        assert_eq!(stats.get("completed").unwrap().as_usize().unwrap(), 6);
        let models = stats.get("models").unwrap().as_array().unwrap().to_vec();
        assert_eq!(models.len(), 2);
        assert_eq!(models[0].get("model").unwrap().as_str().unwrap(), "alpha");
        assert_eq!(models[1].get("model").unwrap().as_str().unwrap(), "beta");
        for m in &models {
            assert_eq!(m.get("completed").unwrap().as_usize().unwrap(), 3);
            assert!(m.get("cache_misses").unwrap().as_usize().unwrap() > 0);
            assert!(m.get("prefetch_scheduled").unwrap().as_usize().unwrap() > 0);
            // The QoS family rides along on every model entry.
            for key in ["reserved_bytes", "qos_weight", "shed_from_peers", "shed_by_peers"] {
                assert!(m.get(key).is_ok(), "missing {key}: {m:?}");
            }
        }
        assert_eq!(
            models[0].get("reserved_bytes").unwrap().as_usize().unwrap(),
            budget_a,
            "alpha's reservation must surface in its stats entry"
        );
        assert_eq!(
            models[1].get("reserved_bytes").unwrap().as_usize().unwrap(),
            0
        );
        let budget = stats.get("ledger_budget_bytes").unwrap().as_usize().unwrap();
        assert_eq!(budget, budget_a + budget_b);
        assert_eq!(
            stats.get("ledger_reserved_bytes").unwrap().as_usize().unwrap(),
            budget_a
        );
        assert!(stats.get("ledger_used_bytes").unwrap().as_usize().unwrap() <= budget);
        assert!(
            stats.get("ledger_peak_used_bytes").unwrap().as_usize().unwrap() <= budget,
            "shared budget must hold under interleaved load"
        );

        stop.store(true, Ordering::Relaxed);
        let (served, multi) = server.join().unwrap();
        assert_eq!(served, 6);
        drop(multi);
    }

    /// The decode-ahead acceptance loop: a prefetching backend serves
    /// over TCP and the `{"stats":true}` admin line carries both the
    /// `cache_*` and the `prefetch_*` counter families.
    #[test]
    fn stats_line_surfaces_prefetch_counters_over_loopback() {
        use crate::pipeline::synthetic_layers;
        use crate::quant::BitWidth;
        use crate::residency::{PrefetchConfig, PrefetchingDigestBackend, PrefetchingWeightSet};
        use crate::store::{compress, SegmentSource};

        let layers = synthetic_layers(8, 0xFEED);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let total: usize = model.layers.iter().map(|m| m.n_symbols).sum();
        let largest = model.layers.iter().map(|m| m.n_symbols).max().unwrap();
        // Whole model plus the decode-ahead floor (window 2 + active).
        let budget = total.max(3 * largest);
        let src = Arc::new(SegmentSource::from_model(Arc::new(model)));
        let ws = PrefetchingWeightSet::new(src, budget, Vec::new(), PrefetchConfig::default())
            .unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(
                PrefetchingDigestBackend::new(ws, 2, 32, 256),
                EngineConfig::default(),
            );
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut c = Client::connect(&addr).unwrap();
        let reply = c.request("decode ahead", 4, 0.0).unwrap();
        assert!(reply.get("tokens").unwrap().as_usize().unwrap() >= 1);

        let stats = c.stats().unwrap();
        // Residency family still present…
        assert!(stats.get("cache_misses").unwrap().as_usize().unwrap() > 0);
        // …and the prefetch family rides along. The walk schedules
        // ahead on every consumed layer; how many jobs the pool won
        // against the consumer is timing-dependent, so only
        // `scheduled` has a guaranteed floor.
        assert!(stats.get("prefetch_scheduled").unwrap().as_usize().unwrap() > 0);
        for key in [
            "prefetch_completed",
            "prefetch_hits",
            "prefetch_waits",
            "prefetch_sync_faults",
        ] {
            assert!(stats.get(key).is_ok(), "missing {key}: {stats:?}");
        }

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert_eq!(served, 1);
    }
}
